"""The Pallas flash-attention kernel wired into the full model: whole-model
forward with the kernel path == the jnp path (interpret mode on CPU)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import attention as attn_mod
from repro.models import factory

KEY = jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _reset_kernel_flag():
    yield
    attn_mod.set_kernel_attention(False)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "gemma3-27b"])
def test_model_forward_with_pallas_attention(arch):
    cfg = get_arch(arch).reduced()
    model = factory.build(cfg)
    params = model.init(KEY)
    # S must be a multiple of 128 for the kernel path
    batch = factory.synth_batch(KEY, cfg, 1, 256)

    attn_mod.set_kernel_attention(False)
    loss_ref, _ = model.loss(params, batch)
    attn_mod.set_kernel_attention(True)
    loss_kernel, _ = model.loss(params, batch)
    assert float(loss_kernel) == pytest.approx(float(loss_ref), rel=2e-4)


def test_kernel_respects_sliding_window():
    """gemma3 reduced has sliding-window layers; kernel masking must match."""
    cfg = get_arch("gemma3-27b").reduced()
    model = factory.build(cfg)
    params = model.init(KEY)
    toks = jax.random.randint(KEY, (1, 256), 0, cfg.vocab_size)
    from repro.models import transformer

    attn_mod.set_kernel_attention(False)
    x_ref, _, _ = transformer.forward(params, cfg, toks, mode="train", remat=False)
    attn_mod.set_kernel_attention(True)
    x_k, _, _ = transformer.forward(params, cfg, toks, mode="train", remat=False)
    np.testing.assert_allclose(
        np.asarray(x_ref, np.float32), np.asarray(x_k, np.float32),
        atol=2e-3, rtol=2e-3,
    )
