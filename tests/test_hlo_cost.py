"""The while-aware HLO cost model vs known-flop programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import hlo_cost, roofline_terms
from repro.roofline.analysis import model_flops


def _compiled(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_single_matmul_flops():
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    c = _compiled(lambda a, b: a @ b, x, w)
    out = hlo_cost.analyze(c.as_text())
    assert out["flops"] == pytest.approx(2 * 128 * 256 * 64, rel=0.01)


def test_scan_flops_multiplied_by_trip_count():
    R = 11
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(a):
        def body(c, _):
            return jnp.tanh(c @ c), None

        y, _ = jax.lax.scan(body, a, None, length=R)
        return y

    c = _compiled(f, x)
    out = hlo_cost.analyze(c.as_text())
    assert out["flops"] == pytest.approx(R * 2 * 64**3, rel=0.05)
    # the naive cost_analysis undercounts (documents why hlo_cost exists)
    ca = c.cost_analysis()
    raw = (ca[0] if isinstance(ca, list) else ca)["flops"]
    assert raw < out["flops"] / (R / 2)


def test_nested_scan_multipliers_compose():
    R1, R2 = 3, 5
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def f(a):
        def inner(c, _):
            return c @ c, None

        def outer(c, _):
            y, _ = jax.lax.scan(inner, c, None, length=R2)
            return y, None

        y, _ = jax.lax.scan(outer, a, None, length=R1)
        return y

    c = _compiled(f, x)
    out = hlo_cost.analyze(c.as_text())
    assert out["flops"] == pytest.approx(R1 * R2 * 2 * 32**3, rel=0.05)


def test_batched_dot_flops():
    x = jax.ShapeDtypeStruct((4, 64, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((4, 32, 16), jnp.float32)
    c = _compiled(lambda a, b: jnp.einsum("bij,bjk->bik", a, b), x, w)
    out = hlo_cost.analyze(c.as_text())
    assert out["flops"] == pytest.approx(2 * 4 * 64 * 32 * 16, rel=0.01)


def test_roofline_terms_dominance():
    t = roofline_terms(197e12, 100e9, {"all-reduce": 0})
    assert t["dominant"] == "compute_s"
    assert t["compute_s"] == pytest.approx(1.0)
    t = roofline_terms(1e9, 819e9, {"all-reduce": 0})
    assert t["dominant"] == "memory_s"
    t = roofline_terms(1e9, 1e6, {"all-reduce": 50e9 * 3})
    assert t["dominant"] == "collective_s"


def test_model_flops_formula():
    assert model_flops(1e9, 1000, "train") == 6e12
    assert model_flops(1e9, 1, "serve") == 2e9
