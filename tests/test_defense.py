"""Adaptive defense tier: reputation scoring, quarantine/probation, and
moving-target aggregation (``repro.defense``).

The contract under test mirrors ``tests/test_faults.py``:

  * defense off is *structurally* bit-for-bit (no state keys, no key
    folds, no ops);
  * armed-but-never-triggered (``threshold=inf``) is bitwise the calm
    run too — every armed effect goes through per-slot ``where`` /
    ``& ~mask`` seams;
  * armed-and-firing agrees bitwise between per-step and chunked
    execution, between the single-device and fleet-sharded async
    engines, and across a checkpoint crash-restart;
  * quarantine actually catches injected attackers and bars them from
    selection, and the mtd ladder escalates under sustained pressure.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cnn import MNIST_CNN
from repro.data.synthetic import make_image_dataset
from repro.engine import (
    AsyncEngine,
    RunConfig,
    ShardedAsyncEngine,
    make_engine,
    run_engine,
)
from repro.engine.registry import make_aggregator

SMALL_CNN = dataclasses.replace(
    MNIST_CNN, name="paper-cnn-mnist-defense", image_size=8,
    conv_channels=(4, 8), fc_width=32,
)

N = 16

# one mixed attacker cohort shared by the armed-and-firing tests:
# a quarter of the fleet submits -3x (sign-flipped, boosted) deltas
ATTACK = dict(
    faults=("scale_attack",), fault_rate=1.0,
    fault_kwargs={"scale_attack": {"factor": -3.0, "client_frac": 0.25}},
)


@pytest.fixture(scope="module")
def small_task():
    from repro.fl import make_cnn_task

    train, test = make_image_dataset(
        "mnist-defense", 10, 8, 1, 120, 60, seed=0, difficulty=0.8
    )
    return make_cnn_task(SMALL_CNN, train, test, n_clients=N)


def _cfg(**kw):
    base = dict(
        n_clients=N, k=4, m=4, policy="markov", rounds=4, local_epochs=1,
        batch_size=5, eval_every=2, mode="async", buffer_size=3,
        profile="mobile",
    )
    base.update(kw)
    return RunConfig(**base)


def _raw(leaf):
    if hasattr(leaf, "dtype") and jax.dtypes.issubdtype(
        leaf.dtype, jax.dtypes.prng_key
    ):
        return np.asarray(jax.random.key_data(leaf))
    return np.asarray(leaf)


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(_raw(la), _raw(lb))


# ---------------------------------------------------------------------------
# (1) config validation
# ---------------------------------------------------------------------------


def test_defense_config_validates_knobs():
    from repro.defense import DefenseConfig

    DefenseConfig()  # defaults are valid
    with pytest.raises(ValueError, match="threshold"):
        DefenseConfig(threshold=0.0)
    with pytest.raises(ValueError, match="ewma"):
        DefenseConfig(ewma=0.0)
    with pytest.raises(ValueError, match="q_decay"):
        DefenseConfig(q_decay=1.5)
    with pytest.raises(ValueError, match="p_probation"):
        DefenseConfig(p_probation=-0.1)
    with pytest.raises(ValueError, match="mtd_trims"):
        DefenseConfig(mtd_trims=(0.0, 0.6))
    with pytest.raises(ValueError, match="mtd_window"):
        DefenseConfig(mtd_window=0)
    with pytest.raises(ValueError, match="mtd_down"):
        DefenseConfig(mtd_up=0.05, mtd_down=0.1)


def test_defense_config_validates_collusion_knobs():
    from repro.defense import DefenseConfig

    DefenseConfig(detector="learned", collusion=True,
                  mtd=True, mtd_families=("base", "coordinate_median"),
                  mtd_trims=(0.0, 0.2))  # the full new surface is valid
    with pytest.raises(ValueError, match="detector"):
        DefenseConfig(detector="oracle")
    with pytest.raises(ValueError, match="learned_lr"):
        DefenseConfig(learned_lr=0.0)
    with pytest.raises(ValueError, match="d_sketch"):
        DefenseConfig(d_sketch=4)
    with pytest.raises(ValueError, match="sketch_ewma"):
        DefenseConfig(sketch_ewma=1.5)
    with pytest.raises(ValueError, match="clique_thresh"):
        DefenseConfig(clique_thresh=1.0)
    with pytest.raises(ValueError, match="clique_min_obs"):
        DefenseConfig(clique_min_obs=0)
    # the family ladder must ride an armed mtd, match the trim ladder
    # in length, keep the calm rung first, and name known families
    with pytest.raises(ValueError, match="mtd_families"):
        DefenseConfig(mtd_families=("base", "trimmed_mean"))
    with pytest.raises(ValueError, match="mtd_families"):
        DefenseConfig(mtd=True, mtd_families=("base",))
    with pytest.raises(ValueError, match="mtd_families"):
        DefenseConfig(mtd=True, mtd_trims=(0.0, 0.2),
                      mtd_families=("trimmed_mean", "base"))
    with pytest.raises(ValueError, match="mtd_families"):
        DefenseConfig(mtd=True, mtd_trims=(0.0, 0.2),
                      mtd_families=("base", "krum"))


def test_run_config_gates_defense_flags():
    with pytest.raises(ValueError, match="defense_kwargs"):
        _cfg(defense_kwargs={"threshold": 0.5})
    with pytest.raises(ValueError, match="threshold"):
        _cfg(defense=True, defense_kwargs={"threshold": -1.0})
    # moving-target trim swaps are order statistics: not additive, so
    # they cannot ride a tiered reduction or the cohort-sharded psum
    with pytest.raises(ValueError, match="tiered topology"):
        _cfg(defense=True, defense_kwargs={"mtd": True},
             topology="hierarchical", topology_kwargs={"tiers": (4,)})
    with pytest.raises(ValueError, match="shard_cohort"):
        _cfg(mode="sync", buffer_size=None, profile="lognormal",
             defense=True, defense_kwargs={"mtd": True},
             mesh_shards=0, shard_cohort=True)
    with pytest.raises(ValueError, match="fault_exposure"):
        _cfg(fault_exposure=True)
    assert _cfg(defense=True).resolved_defense().threshold == 0.55
    assert _cfg().resolved_defense() is None


def test_run_config_rejects_stray_defense_kwargs():
    """A typo'd knob must fail loudly and name every accepted key."""
    with pytest.raises(ValueError, match="colusion.*accepted.*collusion"):
        _cfg(defense=True, defense_kwargs={"colusion": True})


def test_shard_cohort_rejects_collusion_and_learned():
    """Collusion scoring and the learned head keep whole-cohort state a
    cohort-sharded psum cannot merge; the error must point at the
    working layout (fleet sharding: --mesh-shards without
    --shard-cohort). Plain zscore stays allowed under shard_cohort."""
    sync = dict(mode="sync", buffer_size=None, profile="lognormal",
                mesh_shards=0, shard_cohort=True)
    with pytest.raises(ValueError,
                       match=r"--mesh-shards \*without\* --shard-cohort"):
        _cfg(defense=True, defense_kwargs={"collusion": True}, **sync)
    with pytest.raises(ValueError,
                       match=r"--mesh-shards \*without\* --shard-cohort"):
        _cfg(defense=True, defense_kwargs={"detector": "learned"}, **sync)
    # the default detector keeps working cohort-sharded
    assert _cfg(defense=True, **sync).resolved_defense().detector == "zscore"


# ---------------------------------------------------------------------------
# (2) structural gating + armed-never-triggered bitwise golden
# ---------------------------------------------------------------------------


def test_defense_off_adds_no_state(small_task):
    state = AsyncEngine(small_task, _cfg()).init()
    assert "defense" not in state
    armed = AsyncEngine(small_task, _cfg(defense=True)).init()
    assert "defense" in armed
    assert set(armed["defense"]) == {
        "rep", "status", "quarantined", "readmitted",
        "pressure", "win_obs", "win", "level",
    }


def test_collusion_and_learned_state_is_conditional(small_task):
    """The sketch/head leaves exist exactly when their mechanism is
    armed — the default detector must not grow the carry (and with it
    the checkpoint schema) of every existing run."""
    base_keys = set(
        AsyncEngine(small_task, _cfg(defense=True)).init()["defense"])
    col = AsyncEngine(small_task, _cfg(
        defense=True, defense_kwargs={"collusion": True})).init()["defense"]
    assert set(col) == base_keys | {"sketch", "sk_obs", "clique_hits"}
    assert col["sketch"].shape == (N, 64)
    lrn = AsyncEngine(small_task, _cfg(
        defense=True,
        defense_kwargs={"detector": "learned"})).init()["defense"]
    assert set(lrn) == base_keys | {"lw", "auc"}


def test_explicit_zscore_detector_is_bitwise_default(small_task):
    """detector='zscore' spelled out must route through the exact
    default scoring path (the PR 9 pipeline), not a rebuilt one."""
    eng_d = make_engine(small_task, _cfg(rounds=4, **ARMED))
    kw = dict(ARMED)
    kw["defense_kwargs"] = {**ARMED["defense_kwargs"], "detector": "zscore"}
    eng_z = make_engine(small_task, _cfg(rounds=4, **kw))
    s1, _ = eng_d.run_chunk(eng_d.init(), 0, 4, False)
    s2, _ = eng_z.run_chunk(eng_z.init(), 0, 4, False)
    _assert_trees_equal(s1["defense"], s2["defense"])
    _assert_trees_equal(eng_d.eval_params(s1), eng_z.eval_params(s2))


@pytest.mark.parametrize("mode", ["async", "sync", "sharded"])
def test_threshold_inf_defense_is_bitwise_identity(small_task, mode):
    """Arming the full scoring pipeline with an unreachable quarantine
    threshold must not move a single bit: scores are computed but every
    exclusion is ``x & ~False`` and the mtd ladder stays at level 0
    (bitwise the base aggregator). Per-step and chunked."""
    if mode == "sync":
        kw = dict(mode="sync", buffer_size=None, profile="lognormal")
    else:
        kw = dict(mesh_shards=0) if mode == "sharded" else {}
    base = make_engine(small_task, _cfg(**kw))
    armed = make_engine(small_task, _cfg(
        defense=True,
        defense_kwargs={"threshold": float("inf"), "mtd": True,
                        "mtd_window": 2},
        **kw,
    ))
    sb = base.init()
    sa = armed.init()
    for r in range(4):
        sb, auxb = base.step(sb, r)
        sa, auxa = armed.step(sa, r)
        np.testing.assert_array_equal(np.asarray(auxb["send"]),
                                      np.asarray(auxa["send"]))
        np.testing.assert_array_equal(np.asarray(auxb["loss"]),
                                      np.asarray(auxa["loss"]))
    _assert_trees_equal(base.eval_params(sb), armed.eval_params(sa))
    sc = armed.init()
    sc, _ = armed.run_chunk(sc, 0, 4, False)
    _assert_trees_equal(armed.eval_params(sa), armed.eval_params(sc))


# ---------------------------------------------------------------------------
# (3) armed-and-firing parity: chunked, sharded, crash-restart
# ---------------------------------------------------------------------------

ARMED = dict(
    defense=True,
    defense_kwargs={"threshold": 0.3, "mtd": True, "mtd_window": 2,
                    "mtd_up": 0.05, "mtd_down": 0.01},
    **ATTACK,
)


def test_armed_chunked_matches_per_step(small_task):
    eng = make_engine(small_task, _cfg(rounds=8, **ARMED))
    sa = eng.init()
    for r in range(8):
        sa, _ = eng.step(sa, r)
    sc, _ = eng.run_chunk(eng.init(), 0, 8, False)
    _assert_trees_equal(eng.eval_params(sa), eng.eval_params(sc))
    _assert_trees_equal(sa["defense"], sc["defense"])


def test_armed_sharded_matches_single(small_task):
    cfg = lambda **kw: _cfg(rounds=8, **ARMED, **kw)  # noqa: E731
    single = AsyncEngine(small_task, cfg())
    sharded = ShardedAsyncEngine(small_task, cfg(mesh_shards=0))
    s1, _ = single.run_chunk(single.init(), 0, 8, False)
    s2, _ = sharded.run_chunk(sharded.init(), 0, 8, False)
    _assert_trees_equal(single.eval_params(s1), sharded.eval_params(s2))
    _assert_trees_equal(s1["defense"], s2["defense"])
    assert int(np.asarray(s1["defense"]["quarantined"])) > 0


def test_crash_restart_resumes_bitwise_with_defense(small_task, tmp_path):
    """Kill an armed run mid-flight and restart from the checkpointed
    carry: the continuation (reputation EWMAs, quarantine statuses, mtd
    window counters included) must be bit-for-bit the uninterrupted
    run."""
    from repro.checkpoint import load_checkpoint, save_checkpoint

    engine = AsyncEngine(small_task, _cfg(rounds=6, rng_impl="rbg", **ARMED))
    full, _ = engine.run_chunk(engine.init(), 0, 6, False)

    half, _ = engine.run_chunk(engine.init(), 0, 3, False)
    save_checkpoint(str(tmp_path / "crash"), half, step=3)
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), half
    )
    restored, step = load_checkpoint(str(tmp_path / "crash"), like)
    assert step == 3
    resumed, _ = engine.run_chunk(restored, 3, 3, False)
    _assert_trees_equal(full, resumed)


# ---------------------------------------------------------------------------
# (4) detection + quarantine semantics
# ---------------------------------------------------------------------------


def test_quarantine_catches_attackers_and_bars_selection(small_task):
    """The closed loop: injected attackers accumulate reputation, get
    quarantined, and stop being selected — honest clients stay clean."""
    res = run_engine(make_engine(small_task, _cfg(
        rounds=12, fault_exposure=True, defense=True,
        defense_kwargs={"threshold": 0.55, "ewma": 0.5}, **ATTACK,
    )))
    exposed = res.fault_exposure["scale_attack"]
    suspect = res.defense["status"] != 0
    assert exposed.sum() > 0
    # most attacked clients are flagged, and no honest client is
    assert (suspect & (exposed > 0)).sum() >= 2
    assert not (suspect & (exposed == 0)).any()
    assert res.load_stats["def_quarantine_inflow"] > 0
    # reputations separate: flagged clients score above the clean ones
    rep = res.defense["reputation"]
    assert rep[suspect].min() > rep[~suspect].max()


def test_mtd_escalates_under_pressure(small_task):
    calm = run_engine(make_engine(small_task, _cfg(
        rounds=12, defense=True,
        defense_kwargs={"threshold": 0.55, "ewma": 0.5, "mtd": True,
                        "mtd_window": 2, "mtd_up": 0.45, "mtd_down": 0.01},
    )))
    hot = run_engine(make_engine(small_task, _cfg(
        rounds=12, defense=True,
        defense_kwargs={"threshold": 0.55, "ewma": 0.5, "mtd": True,
                        "mtd_window": 2, "mtd_up": 0.45, "mtd_down": 0.01},
        **ATTACK,
    )))
    assert calm.load_stats["def_mtd_level"] == 0
    assert hot.load_stats["def_mtd_level"] > 0


RAGGED_NS = [8, 12, 16]


def _check_quarantine_parity(n):
    """Property: fleet-sharded and single-device engines agree bitwise
    on the final reputation vector and quarantine mask, whatever the
    fleet size (padding slots must never generate evidence)."""
    from repro.fl import make_cnn_task

    train, test = make_image_dataset(
        f"mnist-defense-q{n}", 10, 8, 1, 120, 60, seed=0, difficulty=0.8
    )
    task = make_cnn_task(SMALL_CNN, train, test, n_clients=n)
    cfg = lambda **kw: _cfg(  # noqa: E731
        n_clients=n, rounds=6, defense=True,
        defense_kwargs={"threshold": 0.3}, **ATTACK, **kw,
    )
    single = AsyncEngine(task, cfg())
    sharded = ShardedAsyncEngine(task, cfg(mesh_shards=0))
    s1, _ = single.run_chunk(single.init(), 0, 6, False)
    s2, _ = sharded.run_chunk(sharded.init(), 0, 6, False)
    _assert_trees_equal(s1["defense"], s2["defense"])
    _assert_trees_equal(single.eval_params(s1), sharded.eval_params(s2))


def test_quarantine_mask_sharded_matches_single():
    """Property-based when hypothesis is available; otherwise sweep the
    same ragged fleet sizes directly (the container may not ship
    hypothesis and installing it is off the table)."""
    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:
        for n in RAGGED_NS[:2]:
            _check_quarantine_parity(n)
        return

    @settings(max_examples=3, deadline=None)
    @given(n=st.sampled_from(RAGGED_NS))
    def check(n):
        _check_quarantine_parity(n)

    check()


# ---------------------------------------------------------------------------
# (5) scoring + adaptive-aggregate units
# ---------------------------------------------------------------------------


def test_ewma_scatter_update_handles_duplicates_and_padding():
    from repro.core.load_metric import ewma_scatter_update

    vec = jnp.zeros((4,), jnp.float32)
    idx = jnp.asarray([1, 1, 3, 99])  # duplicate + out-of-range pad
    vals = jnp.asarray([1.0, 1.0, 0.5, 7.0])
    mask = jnp.asarray([True, True, True, False])
    out = np.asarray(ewma_scatter_update(vec, idx, vals, mask, 0.5))
    # duplicate slots both contribute their (identical) EWMA step
    np.testing.assert_allclose(out, [0.0, 1.0, 0.0, 0.25])
    # masked and out-of-range entries write nothing
    again = np.asarray(ewma_scatter_update(
        vec, idx, vals, jnp.zeros((4,), jnp.bool_), 0.5
    ))
    np.testing.assert_array_equal(again, np.zeros((4,)))


def test_slot_scores_flag_flipped_and_scaled_outliers():
    from repro.defense import DefenseConfig
    from repro.defense.reputation import _slot_scores

    key = jax.random.PRNGKey(0)
    b = 8
    base = {"w": jax.random.normal(key, (5, 3))}
    honest = jax.random.normal(jax.random.fold_in(key, 1), (b, 5, 3)) * 0.1
    deltas = honest.at[0].multiply(-3.0)  # the attacker slot
    updated = {"w": base["w"][None] + deltas}
    bases = {"w": jnp.broadcast_to(base["w"], (b, 5, 3))}
    valid = jnp.ones((b,), bool)
    scores = np.asarray(_slot_scores(
        updated, bases, valid, jnp.zeros((b,), jnp.int32), DefenseConfig()
    ))
    assert scores[0] > scores[1:].max()
    assert scores[0] > 0.5


def test_adaptive_aggregate_level0_is_bitwise_base():
    from repro.defense import adaptive_aggregate
    from repro.engine.registry import make_aggregator

    key = jax.random.PRNGKey(3)
    g = {"w": jax.random.normal(key, (3, 4))}
    updates = {"w": g["w"][None] + jax.random.normal(
        jax.random.fold_in(key, 1), (8, 3, 4))}
    w = jnp.ones((8,), jnp.float32)
    idx = jnp.arange(8)
    agg = make_aggregator("fedavg")

    def base_apply(gp, u, b, wv, ix):
        acc = agg.accumulate(agg.init(gp), u, b, wv)
        from repro.engine.aggregators import acc_stats

        return agg.finalize(gp, acc), acc_stats(acc)

    wrapped = adaptive_aggregate(base_apply, (0.0, 0.2))
    p0, _ = wrapped(g, updates, g, w, idx, jnp.int32(0))
    pb, _ = base_apply(g, updates, g, w, idx)
    _assert_trees_equal(p0, pb)
    # level 1 applies the 0.2-trimmed mean of the deltas instead
    p1, _ = wrapped(g, updates, g, w, idx, jnp.int32(1))
    ref = make_aggregator("trimmed_mean", trim=0.2)
    wr = ref.weigh(w > 0, jnp.zeros((8,), jnp.int32))
    pr = ref.finalize(g, ref.accumulate(ref.init(g), updates, g, wr))
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(pr["w"]),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# (6) satellites: exposure surface + order-stat aggregator contract
# ---------------------------------------------------------------------------


def test_fault_exposure_surface_matches_counters(small_task):
    off = run_engine(make_engine(small_task, _cfg(
        faults=("sign_flip",), fault_rate=0.5,
    )))
    assert off.fault_exposure is None
    on = run_engine(make_engine(small_task, _cfg(
        faults=("sign_flip",), fault_rate=0.5, fault_exposure=True,
        collect_history=False, mesh_shards=0,
    )))
    exp = on.fault_exposure["sign_flip"]
    assert exp.shape == (N,)
    assert exp.sum() == on.load_stats["fault_sign_flip_injected"]


def test_order_stat_aggregators_reject_staleness_kwargs():
    with pytest.raises(ValueError, match="staleness"):
        make_aggregator("trimmed_mean", trim=0.2, staleness_mode="poly")
    with pytest.raises(ValueError, match="staleness"):
        make_aggregator("coordinate_median", staleness_exp=0.5)


def test_agg_unweighted_counter_in_engine_run(small_task):
    res = run_engine(make_engine(small_task, _cfg(
        aggregator="trimmed_mean", aggregator_kwargs={"trim": 0.25},
    )))
    # every aggregated slot was an unweighted order-stat vote
    assert res.load_stats["agg_unweighted"] > 0


# ---------------------------------------------------------------------------
# (7) serve tier: restarts + crash reputation
# ---------------------------------------------------------------------------


def test_penalized_load_preserves_dead_markers():
    from repro.serve import penalized_load

    load = jnp.asarray([1.0, np.inf, 0.0])
    out = np.asarray(penalized_load(load, jnp.asarray([2.0, 2.0, 0.5])))
    np.testing.assert_array_equal(out, [3.0, np.inf, 0.5])


def test_serve_restart_revives_replicas():
    from repro.configs import get_arch
    from repro.faults import make_fault
    from repro.models import factory
    from repro.serve import Request, VersionStore, run_serve_loop

    arch = get_arch("tinyllama-1.1b").reduced()
    model = factory.build(arch)
    params = model.init(jax.random.PRNGKey(0))
    store = VersionStore(
        jax.tree.map(lambda p: jnp.stack([p] * 2), params),
        jnp.asarray(1, jnp.int32), 2,
    )
    key = jax.random.PRNGKey(5)
    reqs = [
        Request(rid=i, tick=i % 4,
                prompt=np.asarray(jax.random.randint(
                    jax.random.fold_in(key, i), (4,), 0, arch.vocab_size)),
                gen_len=3)
        for i in range(10)
    ]
    kw = dict(router="least_loaded", n_replicas=3, slots=2, stagger=0,
              seed=0, faults=[make_fault("replica_crash", 3, 0.3)])
    rep = run_serve_loop(model, store, reqs, restart_ticks=2,
                         reputation_penalty=0.5, **kw)
    assert rep.serve_stats["crashes"] > 0
    assert rep.serve_stats["revived"] > 0
    assert len(rep.results) == len(reqs)
    with pytest.raises(ValueError, match="restart_ticks"):
        run_serve_loop(model, store, [], restart_ticks=-1, **kw)
