"""The device-resident selection accumulators must reproduce
``empirical_load_stats`` computed from the materialized history.

Three layers:
  * a hypothesis property test over arbitrary (T, n) selection matrices —
    the accumulator recurrence itself against the numpy reference;
  * ``simulate_stats`` (one fused scan, no history) against
    ``empirical_load_stats(simulate(...))`` for every registered policy;
  * both engines: one ``run_chunk`` per policy returns the final state
    *and* the stacked selection history, so the accumulator statistics
    and the history-derived statistics come from the same realized run.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cnn import MNIST_CNN
from repro.core import load_metric as lm
from repro.core.selection import make_policy, simulate, simulate_stats
from repro.data.synthetic import make_image_dataset
from repro.engine import AsyncEngine, RunConfig, SyncEngine, policy_names
from repro.engine.chunk import dealias_pytree

ALL_POLICIES = ("random", "markov", "markov_probs", "markov_hetero",
                "oldest_age", "round_robin", "gumbel_age")

SMALL_CNN = dataclasses.replace(
    MNIST_CNN, name="paper-cnn-mnist-accum", image_size=8,
    conv_channels=(4, 8), fc_width=16,
)


def _accum_stats_from_history(history: np.ndarray) -> dict:
    acc = lm.init_selection_accum(history.shape[1])
    step = jax.jit(lm.update_selection_accum)
    for row in np.asarray(history, dtype=bool):
        acc = step(acc, jnp.asarray(row))
    return lm.selection_stats_from_accum(acc)


def _assert_stats_match(accum: dict, ref: dict):
    assert set(accum) == set(ref)
    assert accum["num_samples"] == ref["num_samples"]
    assert accum["min_cohort"] == ref["min_cohort"]
    assert accum["max_cohort"] == ref["max_cohort"]
    for key in ("mean_X", "var_X", "mean_cohort", "std_cohort"):
        np.testing.assert_allclose(accum[key], ref[key], rtol=1e-5,
                                   atol=1e-6, err_msg=key)


def test_registered_policy_set_is_exactly_the_seven():
    assert set(ALL_POLICIES) <= set(policy_names())


# ---------------------------------------------------------------------------
# Property test: the recurrence vs the numpy reference
# ---------------------------------------------------------------------------

try:  # property test only where hypothesis is installed (CI always is);
    # the policy/engine comparisons below run regardless
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(
        st.lists(
            st.lists(st.booleans(), min_size=5, max_size=5),
            min_size=1, max_size=40,
        )
    )
    def test_accum_matches_empirical_load_stats_on_arbitrary_histories(rows):
        history = np.asarray(rows, dtype=bool)  # (T, 5)
        _assert_stats_match(
            _accum_stats_from_history(history), lm.empirical_load_stats(history)
        )

except ImportError:  # pragma: no cover

    def test_accum_matches_empirical_load_stats_on_arbitrary_histories():
        pytest.skip("hypothesis not installed")


def test_accum_no_sample_before_second_selection():
    # a client's first selection opens its window and yields no X sample
    history = np.zeros((4, 3), dtype=bool)
    history[1, 0] = True
    stats = _accum_stats_from_history(history)
    assert stats["num_samples"] == 0 and np.isnan(stats["mean_X"])
    history[3, 0] = True
    stats = _accum_stats_from_history(history)
    assert stats["num_samples"] == 1 and stats["mean_X"] == 2.0


# ---------------------------------------------------------------------------
# Every registered policy: fused-scan stats == history stats
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ALL_POLICIES)
def test_simulate_stats_matches_history_for_policy(name):
    n, k, m, rounds = 24, 5, 6, 60
    key = jax.random.PRNGKey(7)
    ref = lm.empirical_load_stats(simulate(make_policy(name, n, k, m), key, n, rounds))
    stats = simulate_stats(make_policy(name, n, k, m), key, n, rounds, k)
    _assert_stats_match(stats, ref)


# ---------------------------------------------------------------------------
# Both engines: accumulator state vs the same run's stacked history
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_task():
    from repro.fl import make_cnn_task

    train, test = make_image_dataset(
        "mnist-accum", 10, 8, 1, 120, 60, seed=0, difficulty=0.8
    )
    return make_cnn_task(SMALL_CNN, train, test, n_clients=12)


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_per_step_driving_also_feeds_accumulators(tiny_task, mode):
    # Engine.step must fold the accumulators exactly like run_chunk does —
    # finalize reads them whenever the history is off
    kw = dict(profile="mobile", buffer_size=3) if mode == "async" else {}
    cfg = RunConfig(
        n_clients=12, k=3, m=4, policy="markov", rounds=6,
        local_epochs=1, batch_size=5, eval_every=6, mode=mode, **kw,
    )
    make = SyncEngine if mode == "sync" else AsyncEngine
    engine = make(tiny_task, cfg)
    state = engine.init()
    history = np.zeros((cfg.rounds, cfg.n_clients), dtype=bool)
    for r in range(cfg.rounds):
        state, aux = engine.step(state, r)
        history[r] = np.asarray(aux["send"])
    _assert_stats_match(
        lm.selection_stats_from_accum(state["load_acc"]),
        lm.empirical_load_stats(history),
    )


@pytest.mark.parametrize("mode", ["sync", "async"])
@pytest.mark.parametrize("name", ALL_POLICIES)
def test_engine_accumulators_match_history(tiny_task, mode, name):
    kw = dict(profile="mobile", buffer_size=3) if mode == "async" else {}
    cfg = RunConfig(
        n_clients=12, k=3, m=4, policy=name, rounds=6,
        local_epochs=1, batch_size=5, eval_every=6, mode=mode, **kw,
    )
    make = SyncEngine if mode == "sync" else AsyncEngine
    engine = make(tiny_task, cfg)
    state = dealias_pytree(engine.init())
    state, aux = engine.run_chunk(state, 0, cfg.rounds, with_history=True)
    history = np.asarray(aux["send"])
    assert history.shape == (cfg.rounds, cfg.n_clients)
    _assert_stats_match(
        lm.selection_stats_from_accum(state["load_acc"]),
        lm.empirical_load_stats(history),
    )
