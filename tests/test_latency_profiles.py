"""Closed-form latency moments vs the empirical samplers.

``LatencyProfile.mean_latency`` is used to size runs and hop budgets, so
it must track ``sample_latency`` exactly — including the lognormal mean
correction ``exp(mu + (sigma^2 + hetero^2)/2)`` that a naive
``exp(mu)`` estimate misses. It deliberately ignores ``avail_gap`` and
``dropout``; ``mean_update_interval`` is the closed form that folds those
in. Both are pinned here against large-sample Monte Carlo means for
every shipped profile.
"""
import dataclasses
import math

import jax
import numpy as np
import pytest

from repro.sim.latency import (
    PROFILES,
    LatencyProfile,
    client_speed,
    sample_avail_gap,
    sample_dropout,
    sample_latency,
)

SAMPLES = 200_000


def _empirical_mean_latency(profile, seed=0):
    key = jax.random.PRNGKey(seed)
    k_speed, k_lat = jax.random.split(key)
    speed = client_speed(k_speed, SAMPLES, profile)
    return float(np.mean(sample_latency(k_lat, profile, speed)))


@pytest.mark.parametrize("name", sorted(PROFILES))
def test_mean_latency_matches_sampler(name):
    profile = PROFILES[name]
    analytic = profile.mean_latency()
    empirical = _empirical_mean_latency(profile)
    # heavy-tailed profiles (mobile: sigma=1, hetero=0.8) converge slowly;
    # 4% at 200k samples distinguishes the correct lognormal mean from
    # e.g. the median exp(mu)=1, which is off by exp(0.82)≈2.27x
    assert empirical == pytest.approx(analytic, rel=0.04), name


@pytest.mark.parametrize("name", sorted(PROFILES))
def test_mean_update_interval_matches_samplers(name):
    profile = PROFILES[name]
    key = jax.random.PRNGKey(1)
    k_speed, k_lat, k_gap, k_drop = jax.random.split(key, 4)
    speed = client_speed(k_speed, SAMPLES, profile)
    lat = np.asarray(sample_latency(k_lat, profile, speed))
    gap = np.asarray(sample_avail_gap(k_gap, profile, SAMPLES))
    kept = ~np.asarray(sample_dropout(k_drop, profile, SAMPLES))
    # total wall time across all attempts / number of surviving updates
    empirical = float(np.sum(lat + gap) / np.sum(kept))
    assert empirical == pytest.approx(profile.mean_update_interval(), rel=0.04), name


def test_mean_latency_excludes_availability_and_dropout():
    base = LatencyProfile("base", compute_mu=0.3, comm_shift=0.1)
    flaky = dataclasses.replace(base, avail_gap=5.0, dropout=0.5)
    assert flaky.mean_latency() == base.mean_latency()
    assert flaky.mean_update_interval() == pytest.approx(
        (base.mean_latency() + 5.0) / 0.5
    )


def test_mobile_interval_inflation():
    # the docstring's claim: sizing mobile runs by mean_latency alone
    # underestimates the per-update wall time by ~1.8x
    mobile = PROFILES["mobile"]
    inflation = mobile.mean_update_interval() / mobile.mean_latency()
    assert 1.5 < inflation < 2.1


def test_mean_update_interval_rejects_certain_dropout():
    doomed = LatencyProfile("doomed", dropout=1.0)
    with pytest.raises(ValueError, match="dropout"):
        doomed.mean_update_interval()


def test_degenerate_profile_is_exact():
    uniform = PROFILES["uniform"]
    assert uniform.mean_latency() == pytest.approx(math.exp(0.0))
    assert uniform.mean_update_interval() == uniform.mean_latency()
    lat = sample_latency(jax.random.PRNGKey(0), uniform,
                         client_speed(jax.random.PRNGKey(1), 64, uniform))
    np.testing.assert_allclose(np.asarray(lat), 1.0, rtol=1e-6)
