"""Engine API surface: registries, pluggable aggregators, RunConfig
validation, and the shared JSON-safe serializer.

The headline property (acceptance criterion of the redesign): a new
policy and a new aggregator can each be added via the registry and driven
through either engine without editing any round loop.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cnn import MNIST_CNN
from repro.core.selection import Policy, make_policy
from repro.data.synthetic import make_image_dataset
from repro.engine import (
    AsyncEngine,
    RunConfig,
    SyncEngine,
    aggregator_names,
    make_aggregator,
    make_engine,
    policy_names,
    register_aggregator,
    register_policy,
    run_config_from_legacy,
    run_engine,
    to_jsonable,
)
from repro.engine.aggregators import Aggregator
from repro.fl import FLConfig, make_cnn_task

SMALL_CNN = dataclasses.replace(
    MNIST_CNN, name="paper-cnn-mnist-small", image_size=16,
    conv_channels=(8, 16), fc_width=64,
)


@pytest.fixture(scope="module")
def small_task():
    train, test = make_image_dataset(
        "mnist-small", 10, 16, 1, 600, 500, seed=0, difficulty=0.8
    )
    return make_cnn_task(SMALL_CNN, train, test, n_clients=20)


def _cfg(**kw):
    base = dict(
        n_clients=20, k=4, m=6, policy="markov", rounds=3,
        local_epochs=1, batch_size=10, eval_every=3,
    )
    base.update(kw)
    return RunConfig(**base)


# ---------------------------------------------------------------------------
# Registries
# ---------------------------------------------------------------------------


def test_all_paper_policies_registered_and_constructible():
    expected = {"random", "markov", "markov_probs", "markov_hetero",
                "oldest_age", "round_robin", "gumbel_age"}
    assert expected <= set(policy_names())
    for name in expected:
        pol = make_policy(name, 20, 4, 6)
        state = pol.init(jax.random.PRNGKey(0), 20)
        sel, state2 = jax.jit(pol.step)(state, jax.random.PRNGKey(1))
        assert sel.shape == (20,) and sel.dtype == jnp.bool_

    with pytest.raises(ValueError, match="unknown policy"):
        make_policy("nope", 20, 4, 6)


def test_markov_probs_accepts_custom_probs():
    probs = np.array([0.0, 0.1, 0.2, 0.3, 0.5, 0.7, 1.0], dtype=np.float32)
    pol = make_policy("markov_probs", 30, 5, 6, probs=probs, steady_start=False)
    assert pol.name == "markov" and not pol.exact_k


def test_markov_hetero_rate_spread():
    pol = make_policy("markov_hetero", 30, 6, 8, rate_spread=1.0)
    state = pol.init(jax.random.PRNGKey(0), 30)
    sel, _ = pol.step(state, jax.random.PRNGKey(1))
    assert sel.shape == (30,)


def test_aggregator_registry():
    assert {"fedavg", "fedbuff", "fedprox"} <= set(aggregator_names())
    with pytest.raises(ValueError, match="unknown aggregator"):
        make_aggregator("geometric_median")
    with pytest.raises(ValueError):
        make_aggregator("fedprox", prox_mu=-1.0)


def test_duplicate_registration_rejected():
    @register_policy("dup_policy_test")
    def _f(n, k, m=10):
        return make_policy("random", n, k, m)

    with pytest.raises(ValueError, match="already registered"):
        register_policy("dup_policy_test")(_f)


# ---------------------------------------------------------------------------
# New policy + new aggregator via the registry, no round-loop edits
# ---------------------------------------------------------------------------


@register_policy("first_k_test")
def _make_first_k(n, k, m=10):
    """Degenerate deterministic policy: always clients 0..k-1."""

    def init(key, n_=n):
        return {"ages": jnp.zeros((n_,), jnp.int32),
                "round": jnp.zeros((), jnp.int32)}

    def step(state, key):
        sel = jnp.arange(n) < k
        return sel, {**state, "round": state["round"] + 1}

    return Policy("first_k_test", init, step, exact_k=True)


def test_registered_policy_drives_sync_engine(small_task):
    res = run_engine(SyncEngine(small_task, _cfg(policy="first_k_test")))
    # every round selected exactly clients 0..k-1
    assert res.selection.shape == (3, 20)
    assert (res.selection[:, :4]).all() and not (res.selection[:, 4:]).any()
    assert np.isfinite([r.train_loss for r in res.records]).all()


def test_registered_policy_drives_async_engine(small_task):
    cfg = _cfg(policy="first_k_test", mode="async", buffer_size=4,
               profile="uniform")
    res = run_engine(AsyncEngine(small_task, cfg))
    assert res.wall_stats["aggregations"] > 0


@register_aggregator("signmean_test")
def _make_signmean():
    """Toy robust aggregator: sign of the weighted mean delta, tiny lr."""
    fedbuff = make_aggregator("fedbuff", staleness_mode="const")

    def finalize(g, acc):
        has = acc["wsum"] > 0
        denom = jnp.maximum(acc["wsum"], 1e-9)

        def fin(gl, s):
            return jnp.where(has, gl + 1e-3 * jnp.sign(s / denom).astype(gl.dtype), gl)

        return jax.tree.map(fin, g, acc["dsum"])

    return Aggregator("signmean_test", fedbuff.weigh, fedbuff.init,
                      fedbuff.accumulate, finalize)


def test_registered_aggregator_drives_both_engines(small_task):
    for mode in ("sync", "async"):
        cfg = _cfg(mode=mode, aggregator="signmean_test",
                   profile="uniform", buffer_size=4)
        res = run_engine(make_engine(small_task, cfg))
        assert len(res.records) == 1
        assert np.isfinite(res.records[-1].eval_loss)


def test_fedprox_zero_mu_equals_fedbuff(small_task):
    kw = dict(mode="async", rounds=4, profile="lognormal", buffer_size=4)
    buff = run_engine(AsyncEngine(small_task, _cfg(aggregator="fedbuff", **kw)))
    prox = run_engine(AsyncEngine(
        small_task, _cfg(aggregator="fedprox",
                         aggregator_kwargs={"prox_mu": 0.0}, **kw)
    ))
    for a, b in zip(jax.tree.leaves(buff.params), jax.tree.leaves(prox.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fedprox_damps_updates(small_task):
    kw = dict(mode="async", rounds=3, profile="uniform", buffer_size=4,
              eval_every=1)
    buff = run_engine(AsyncEngine(small_task, _cfg(aggregator="fedbuff", **kw)))
    prox = run_engine(AsyncEngine(
        small_task, _cfg(aggregator="fedprox",
                         aggregator_kwargs={"prox_mu": 4.0}, **kw)
    ))
    init = SyncEngine(small_task, _cfg()).init()["params"]

    def dist(p):
        return sum(
            float(jnp.sum((a - b).astype(jnp.float32) ** 2))
            for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(init))
        )

    # heavy proximal damping keeps the global model closer to its start
    assert dist(prox.params) < dist(buff.params)


# ---------------------------------------------------------------------------
# RunConfig validation + legacy conversion
# ---------------------------------------------------------------------------


def test_run_config_validates_mode_and_k():
    with pytest.raises(ValueError, match="mode"):
        RunConfig(mode="semi_sync")
    with pytest.raises(ValueError, match="k="):
        RunConfig(n_clients=10, k=11)


def test_max_cohort_below_k_rejected():
    with pytest.raises(ValueError, match="max_cohort"):
        RunConfig(n_clients=100, k=15, max_cohort=10)
    with pytest.raises(ValueError, match="max_cohort"):
        FLConfig(n_clients=100, k=15, max_cohort=10)


def test_cohort_width_default_padding():
    cfg = RunConfig(n_clients=100, k=15)
    fl = FLConfig(n_clients=100, k=15)
    assert cfg.cohort_width() == fl.cohort_width()
    assert 15 < cfg.cohort_width() <= 100
    assert RunConfig(n_clients=100, k=15, max_cohort=40).cohort_width() == 40


def test_run_config_from_legacy_roundtrip():
    from repro.sim import AsyncConfig

    fl = FLConfig(n_clients=30, k=5, m=8, policy="oldest_age", rounds=7,
                  seed=3, eval_every=2)
    cfg = run_config_from_legacy(fl)
    assert cfg.mode == "sync" and cfg.resolved_aggregator() == "fedavg"
    assert (cfg.n_clients, cfg.k, cfg.m, cfg.rounds) == (30, 5, 8, 7)

    acfg = AsyncConfig(buffer_size=3, staleness_mode="poly",
                       staleness_exp=0.9, max_versions=4, profile="mobile")
    cfg = run_config_from_legacy(fl, acfg)
    assert cfg.mode == "async" and cfg.resolved_aggregator() == "fedbuff"
    assert cfg.aggregator_kwargs["staleness_exp"] == 0.9
    assert cfg.resolved_buffer_size() == 3 and cfg.max_versions == 4


# ---------------------------------------------------------------------------
# Serializer
# ---------------------------------------------------------------------------


def test_to_jsonable_nan_and_numpy():
    payload = {
        "nan": float("nan"), "inf": float("inf"),
        "np_f": np.float32(1.5), "np_i": np.int64(3), "np_b": np.bool_(True),
        "arr": np.array([1.0, np.nan]),
        "jax": jnp.ones((2,)),
        "nested": [{"x": (1, 2)}],
    }
    out = to_jsonable(payload)
    assert out["nan"] is None and out["inf"] is None
    assert out["np_f"] == 1.5 and out["np_i"] == 3 and out["np_b"] is True
    assert out["arr"] == [1.0, None]
    assert out["jax"] == [1.0, 1.0]
    # strict JSON round-trips (this is what allow_nan=False consumers need)
    json.dumps(out, allow_nan=False)


def test_run_result_jsonable(small_task):
    res = run_engine(SyncEngine(small_task, _cfg()))
    payload = res.to_jsonable()
    s = json.dumps(payload, allow_nan=False)
    back = json.loads(s)
    assert back["config"]["policy"] == "markov"
    assert back["history"]["round"] == [3]
    assert back["wall_stats"] is None
