"""The serving tier: router registry + Markov admission equivalence,
replica-axis Var[X] accumulators vs a NumPy reference, version-ring read
clipping (staleness >= H), and bit-for-bit stream isolation of the
continuous-batching pool under join/evict churn.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.paper_cnn import MNIST_CNN
from repro.core import load_metric, selection
from repro.data.synthetic import make_image_dataset
from repro.engine import AsyncEngine, RunConfig
from repro.models import factory
from repro.serve import (
    Request,
    VersionStore,
    make_router,
    router_names,
    run_serve_loop,
)
from repro.serve.batching import prefill_tokens
from repro.serve.router import register_router

ARCH = get_arch("tinyllama-1.1b").reduced()


@pytest.fixture(scope="module")
def lm():
    model = factory.build(ARCH)
    return model, model.init(jax.random.PRNGKey(0))


def _store(params, h=4, latest=3):
    """Synthetic ring: slot v % h carries version v's params (scaled so
    every retained version is distinguishable)."""
    lo = max(latest - (h - 1), 0)
    slot_ver = [0] * h
    for v in range(lo, latest + 1):
        slot_ver[v % h] = v
    hist = jax.tree.map(
        lambda p: jnp.stack([p * (1.0 + 0.01 * v) for v in slot_ver]), params
    )
    return VersionStore(hist, jnp.asarray(latest, jnp.int32), h)


# ---------------------------------------------------------------------------
# (1) router registry + Markov admission == core.selection
# ---------------------------------------------------------------------------


def test_router_registry_roundtrip():
    names = router_names()
    assert {"round_robin", "least_loaded", "markov"} <= set(names)
    key = jax.random.PRNGKey(0)
    load = jnp.zeros((3,), jnp.float32)
    for name in names:
        router = make_router(name, 3)
        assert router.name == name
        state = router.init(key, 3)
        idx, state = router.step(state, load, jax.random.fold_in(key, 1))
        assert idx.dtype == jnp.int32
        assert -1 <= int(idx) < 3
    with pytest.raises(ValueError, match="unknown router"):
        make_router("nope", 3)
    register_router("_test_dummy")(lambda r: make_router("round_robin", r))
    with pytest.raises(ValueError, match="already registered"):
        register_router("_test_dummy")(lambda r: None)


def test_markov_router_bitmatches_selection_policy():
    """Degenerate 1-replica pool: the router's admit/reject sequence is
    bit-for-bit the Markov selection policy's draw under the same keys —
    the serving tier reuses the paper's admission rule, not a lookalike."""
    probs = np.array([0.3, 0.6, 1.0], np.float32)
    router = make_router("markov", 1, m=2, probs=probs)
    policy = selection.make_markov(1, 1, 2, probs=probs)
    key = jax.random.PRNGKey(42)
    rstate = router.init(key, 1)
    pstate = policy.init(key, 1)
    load = jnp.zeros((1,), jnp.float32)
    admitted, selected = [], []
    for t in range(300):
        k = jax.random.fold_in(key, t)
        idx, rstate = router.step(rstate, load, k)
        sel, pstate = policy.step(pstate, k)
        admitted.append(int(idx) == 0)
        selected.append(bool(sel[0]))
    assert admitted == selected
    rate = np.mean(admitted)
    assert rate == pytest.approx(
        load_metric.selection_rate(probs), abs=0.1
    )


def test_markov_router_routes_to_least_loaded_willing():
    router = make_router("markov", 4, m=2, probs=np.array([1.0, 1.0, 1.0]))
    key = jax.random.PRNGKey(0)
    state = router.init(key, 4)
    # all replicas willing (p == 1 everywhere): the loaded ones lose
    load = jnp.asarray([3.0, 1.0, 0.0, 2.0])
    idx, _ = router.step(state, load, jax.random.fold_in(key, 1))
    assert int(idx) == 2


# ---------------------------------------------------------------------------
# (2) replica-axis accumulators vs NumPy reference
# ---------------------------------------------------------------------------


def test_replica_accum_matches_numpy_reference():
    rng = np.random.default_rng(0)
    T, R = 400, 5
    # routing decisions: mostly one-hot assignments, some rejections
    hist = np.zeros((T, R), bool)
    for t in range(T):
        if rng.random() < 0.85:
            hist[t, rng.integers(R)] = True

    acc = load_metric.init_replica_accum(R)

    def body(acc, row):
        return load_metric.update_replica_accum(acc, row), None

    acc, _ = jax.lax.scan(body, acc, jnp.asarray(hist))
    stats = load_metric.replica_stats_from_accum(acc)

    gaps = load_metric.peak_ages_from_history(hist)
    assert stats["num_samples"] == gaps.size
    assert stats["decisions"] == T
    np.testing.assert_allclose(stats["mean_X"], gaps.mean(), rtol=1e-6)
    np.testing.assert_allclose(stats["var_X"], gaps.var(), rtol=1e-5)
    for r in range(R):
        g = np.diff(np.flatnonzero(hist[:, r]))
        assert stats["replica_num_samples"][r] == g.size
        if g.size:
            np.testing.assert_allclose(
                stats["replica_mean_X"][r], g.mean(), rtol=1e-6
            )
            np.testing.assert_allclose(
                stats["replica_var_X"][r], g.var(), rtol=1e-5, atol=1e-5
            )


# ---------------------------------------------------------------------------
# (satellite) version-ring read clipping
# ---------------------------------------------------------------------------


def test_version_store_read_clipping(lm):
    _, params = lm
    store = _store(params, h=4, latest=10)  # retained: 7..10
    assert store.oldest_retained == 7
    assert store.retained_versions() == [7, 8, 9, 10]
    # in-window reads serve the exact version
    for v in (7, 8, 9, 10):
        read = store.read(v)
        assert int(read.read_ver) == v
        assert int(read.staleness) == 10 - v
    # versions that fell off the ring (staleness >= H) clip to the oldest
    # retained model; staleness reports the served version's true age
    for v in (6, 3, 0, -2):
        read = store.read(v)
        assert int(read.read_ver) == 7
        assert int(read.staleness) == 3
    # futures clip to the head
    assert int(store.read(99).read_ver) == 10
    # served params are the pinned slot's, bitwise
    want = jax.tree.map(lambda p: p * 1.07, params)  # slot of version 7
    got = store.read(0).params
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_version_store_before_first_wrap(lm):
    _, params = lm
    store = _store(params, h=4, latest=1)  # ring not yet wrapped
    assert store.oldest_retained == 0
    assert int(store.read(-3).read_ver) == 0
    assert int(store.read(5).read_ver) == 1


SMALL_CNN = dataclasses.replace(
    MNIST_CNN, name="paper-cnn-mnist-small", image_size=16,
    conv_channels=(8, 16), fc_width=64,
)


def test_ring_snapshot_matches_engine_state():
    """The store's head read is the engine's live params, bitwise, and
    dispatch versions older than the ring resolve to the oldest retained
    slot — the exact clipping the training step applies."""
    from repro.fl import make_cnn_task

    train, test = make_image_dataset(
        "mnist-small", 10, 16, 1, 600, 500, seed=0, difficulty=0.8
    )
    task = make_cnn_task(SMALL_CNN, train, test, n_clients=12)
    cfg = RunConfig(
        mode="async", n_clients=12, k=3, m=4, policy="markov", rounds=6,
        local_epochs=1, batch_size=10, eval_every=6, max_versions=4,
        collect_history=False,
    )
    engine = AsyncEngine(task, cfg)
    state = engine.init()
    state, _ = engine.run_chunk(state, 0, 6, False)
    store = VersionStore.from_engine(engine, state)
    assert store.max_versions == 4
    latest = int(state["version"])
    assert store.latest == latest
    head = store.read(latest)
    assert int(head.staleness) == 0
    for a, b in zip(
        jax.tree.leaves(head.params), jax.tree.leaves(state["params"])
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    old = store.read(latest - 10)
    assert int(old.read_ver) == max(latest - 3, 0)


# ---------------------------------------------------------------------------
# (3) continuous batching: join/evict churn preserves streams bit-for-bit
# ---------------------------------------------------------------------------


def _solo_decode(model, params, prompt, gen_len, ctx):
    """Reference: the request decoded alone on a plain (unvmapped)
    batch-1 decode path."""
    caches = model.init_decode_caches(1, ctx)
    logits, caches = prefill_tokens(
        model.decode_step, params, caches, jnp.asarray(prompt)[None, :]
    )
    tok = int(jnp.argmax(logits[0, -1]))
    out = [tok]
    step = jax.jit(model.decode_step)
    for _ in range(gen_len - 1):
        logits, caches = step(params, caches, jnp.full((1, 1), tok, jnp.int32))
        tok = int(jnp.argmax(logits[0, -1]))
        out.append(tok)
    return out


def test_join_evict_streams_bitwise_vs_solo(lm):
    model, params = lm
    store = _store(params, h=4, latest=3)
    key = jax.random.PRNGKey(7)
    reqs = [
        Request(
            rid=i, tick=i,
            prompt=np.asarray(
                jax.random.randint(
                    jax.random.fold_in(key, i), (5,), 0, ARCH.vocab_size
                )
            ),
            gen_len=3 + (i % 3),
        )
        for i in range(6)
    ]
    ctx = max(len(r.prompt) + r.gen_len for r in reqs)
    report = run_serve_loop(
        model, store, reqs, router="round_robin", n_replicas=2, slots=2,
        ctx=ctx, seed=0,
    )
    assert len(report.results) == len(reqs)
    assert report.queue_left == 0
    # staggered pins: replica 0 serves the head, replica 1 one behind
    assert {r.staleness for r in report.results} == {0, 1}
    # streams joined and evicted at different ticks around each other;
    # every stream's tokens must equal its solo decode, bit for bit
    for res in report.results:
        req = reqs[res.rid]
        solo = _solo_decode(
            model, store.read(res.version).params, req.prompt, req.gen_len,
            ctx,
        )
        assert res.tokens == solo, f"stream {res.rid} diverged"
    # round_robin routing is the Var[X] = 0 reference over replicas
    assert report.serve_stats["var_X"] == 0.0
    assert report.serve_stats["mean_X"] == 2.0


def test_prefill_scan_matches_per_token_loop(lm):
    """Pins the launch/serve.py satellite: scanned prefill is bit-for-bit
    the Python per-token decode loop."""
    model, params = lm
    prompts = jax.random.randint(
        jax.random.PRNGKey(3), (2, 6), 0, ARCH.vocab_size
    )
    ctx = 16
    lg_scan, c_scan = prefill_tokens(
        model.decode_step, params, model.init_decode_caches(2, ctx), prompts
    )
    c_loop = model.init_decode_caches(2, ctx)
    step = jax.jit(model.decode_step)
    for t in range(prompts.shape[1]):
        lg_loop, c_loop = step(params, c_loop, prompts[:, t : t + 1])
    np.testing.assert_array_equal(np.asarray(lg_scan), np.asarray(lg_loop))
    for a, b in zip(jax.tree.leaves(c_scan), jax.tree.leaves(c_loop)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_replica_crash_failover_drops_no_streams(lm):
    """Satellite + tentpole acceptance: replica crashes mid-decode drop
    zero in-flight streams — orphans re-enter the queue head and resume
    on survivors through the join path, and at stagger=0 (all replicas
    pin the same version) every stream's tokens stay bit-for-bit the
    crash-free run's."""
    from repro.faults import make_fault

    model, params = lm
    store = _store(params, h=4, latest=3)
    key = jax.random.PRNGKey(11)
    reqs = [
        Request(
            rid=i, tick=i % 3,
            prompt=np.asarray(
                jax.random.randint(
                    jax.random.fold_in(key, i), (5,), 0, ARCH.vocab_size
                )
            ),
            gen_len=3 + (i % 3),
        )
        for i in range(8)
    ]
    ctx = max(len(r.prompt) + r.gen_len for r in reqs)
    kw = dict(router="round_robin", n_replicas=3, slots=2, ctx=ctx,
              stagger=0, seed=0)
    calm = run_serve_loop(model, store, reqs, **kw)
    chaos = run_serve_loop(
        model, store, reqs,
        faults=[make_fault("replica_crash", 3, 0.15)], **kw,
    )
    assert chaos.serve_stats["crashes"] > 0
    assert chaos.serve_stats["failed_over"] > 0
    # zero dropped streams: every request completes despite the crashes
    assert len(chaos.results) == len(reqs)
    assert chaos.queue_left == 0
    assert sum(r.migrations for r in chaos.results) >= \
        chaos.serve_stats["failed_over"]
    calm_tokens = {r.rid: r.tokens for r in calm.results}
    for res in chaos.results:
        assert res.tokens == calm_tokens[res.rid], \
            f"stream {res.rid} diverged across failover"


def test_serve_loop_rejects_engine_scope_faults(lm):
    from repro.faults import make_fault

    model, params = lm
    store = _store(params, h=4, latest=3)
    with pytest.raises(ValueError, match="engine-scope"):
        run_serve_loop(model, store, [],
                       faults=[make_fault("dropout", 4, 0.1)])


def test_ring_miss_counted_at_staleness_ge_h(lm):
    """Satellite regression: a replica pinned ``stagger >= H`` behind the
    head asks for a version that fell off the ring — the read clips to
    the oldest retained slot AND flags ``ring_miss``, surfaced in
    ``serve_stats`` instead of silently serving the wrong version."""
    model, params = lm
    h = 4
    store = _store(params, h=h, latest=10)  # retained: 7..10
    # direct flag: v >= lo clean, v < lo is a miss
    assert not bool(store.read(7).ring_miss)
    assert bool(store.read(6).ring_miss)
    key = jax.random.PRNGKey(13)
    reqs = [
        Request(
            rid=i, tick=i,
            prompt=np.asarray(
                jax.random.randint(
                    jax.random.fold_in(key, i), (4,), 0, ARCH.vocab_size
                )
            ),
            gen_len=2,
        )
        for i in range(2)
    ]
    report = run_serve_loop(
        model, store, reqs, router="round_robin", n_replicas=2, slots=2,
        ctx=8, stagger=h, seed=0,
    )
    # replica 1 pins latest - h < lo: its refresh read is a ring miss
    assert report.serve_stats["ring_miss"] >= 1
    calm = run_serve_loop(
        model, store, reqs, router="round_robin", n_replicas=2, slots=2,
        ctx=8, stagger=1, seed=0,
    )
    assert calm.serve_stats["ring_miss"] == 0
