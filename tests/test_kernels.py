"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _mk_qkv(B, Hk, G, S, D, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hk, G, S, D), dtype)
    k = jax.random.normal(ks[1], (B, Hk, S, D), dtype)
    v = jax.random.normal(ks[2], (B, Hk, S, D), dtype)
    return q, k, v


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hk,G,S,D,kind,window",
    [
        (1, 2, 2, 256, 64, "full", 0),
        (2, 1, 4, 512, 32, "full", 0),
        (1, 2, 1, 512, 128, "sliding", 128),
        (1, 1, 2, 512, 64, "chunked", 128),
        (1, 4, 8, 256, 64, "full", 0),  # llama-like GQA block
    ],
)
def test_flash_attention_allclose(B, Hk, G, S, D, kind, window, dtype):
    q, k, v = _mk_qkv(B, Hk, G, S, D, dtype)
    scale = D**-0.5
    out = ops.flash_attention(
        q, k, v, scale=scale, kind=kind, window=window, block_q=128, block_k=128
    )
    exp = ref.flash_attention_ref(q, k, v, scale=scale, kind=kind, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32), atol=tol, rtol=tol
    )


def test_flash_attention_uneven_blocks():
    q, k, v = _mk_qkv(1, 2, 2, 384, 64, jnp.float32)
    out = ops.flash_attention(q, k, v, scale=0.125, block_q=128, block_k=384)
    exp = ref.flash_attention_ref(q, k, v, scale=0.125)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,nh,hd,ds,chunk",
    [
        (2, 128, 3, 32, 16, 32),
        (1, 256, 2, 64, 128, 64),
        (1, 64, 4, 16, 8, 64),  # single chunk
    ],
)
def test_ssd_scan_allclose(B, S, nh, hd, ds, chunk, dtype):
    ks = jax.random.split(KEY, 5)
    x = (jax.random.normal(ks[0], (B, S, nh, hd)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    B_ = (jax.random.normal(ks[3], (B, S, ds)) * 0.5).astype(dtype)
    C_ = (jax.random.normal(ks[4], (B, S, ds)) * 0.5).astype(dtype)
    out = ops.ssd_scan(x, dt, A, B_, C_, chunk=chunk)
    exp = ref.ssd_scan_ref(x, dt, A, B_, C_)
    tol = 5e-2 if dtype == jnp.bfloat16 else 5e-4
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32), atol=tol, rtol=tol
    )


def test_ssd_kernel_matches_model_chunked_form():
    """Kernel == the model's own chunked implementation too."""
    from repro.models.ssm import ssd_chunked

    ks = jax.random.split(KEY, 5)
    B, S, nh, hd, ds = 2, 128, 2, 32, 16
    x = jax.random.normal(ks[0], (B, S, nh, hd)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    B_ = jax.random.normal(ks[3], (B, S, ds)) * 0.5
    C_ = jax.random.normal(ks[4], (B, S, ds)) * 0.5
    out = ops.ssd_scan(x, dt, A, B_, C_, chunk=32)
    exp, _ = ssd_chunked(x, dt, A, B_, C_, 32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("C,N,block", [(4, 1000, 256), (33, 4096, 4096), (1, 17, 8)])
def test_fedavg_reduce_allclose(C, N, block):
    ks = jax.random.split(KEY, 2)
    params = jax.random.normal(ks[0], (C, N))
    w = jax.nn.softmax(jax.random.normal(ks[1], (C,)))
    out = ops.fedavg_reduce(params, w, block_n=block)
    exp = ref.fedavg_reduce_ref(params, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5, rtol=1e-5)


def test_fedavg_reduce_masked_weights():
    """Zero weights (cohort padding) contribute nothing."""
    params = jnp.stack([jnp.ones(100), 5 * jnp.ones(100), 9 * jnp.ones(100)])
    w = jnp.array([0.5, 0.5, 0.0])
    out = ops.fedavg_reduce(params, w, block_n=64)
    np.testing.assert_allclose(np.asarray(out), 3.0 * np.ones(100), atol=1e-6)


@pytest.mark.parametrize("n,k,block", [(10_000, 16, 1024), (1000, 7, 128), (65_536, 64, 8192)])
def test_aoi_topk_matches_ref(n, k, block):
    ages = jax.random.randint(KEY, (n,), 0, 10_000).astype(jnp.float32)
    tv, ti = ops.oldest_age_topk(ages, k, block_n=block)
    rv, _ = ref.topk_ref(ages, k)
    np.testing.assert_allclose(np.asarray(tv), np.asarray(rv))
    # indices actually point at those values
    np.testing.assert_allclose(np.asarray(ages)[np.asarray(ti)], np.asarray(tv))


def test_aoi_topk_fleet_scale():
    """1M clients, k=128 — the decentralization comparison scenario."""
    ages = jax.random.randint(KEY, (1_000_000,), 0, 50).astype(jnp.float32)
    tv, ti = ops.oldest_age_topk(ages, 128)
    rv, _ = ref.topk_ref(ages, 128)
    np.testing.assert_allclose(np.asarray(tv), np.asarray(rv))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hk,G,L,D,vlen,block",
    [
        (2, 2, 4, 512, 64, 512, 128),
        (1, 4, 1, 1024, 128, 700, 256),  # partial cache (masked tail)
        (1, 1, 8, 384, 64, 384, 256),  # L not a multiple of block (padding)
    ],
)
def test_flash_decode_allclose(B, Hk, G, L, D, vlen, block, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hk, G, D), dtype)
    k = jax.random.normal(ks[1], (B, Hk, L, D), dtype)
    v = jax.random.normal(ks[2], (B, Hk, L, D), dtype)
    out = ops.flash_decode(q, k, v, vlen, scale=D**-0.5, block_l=block)
    exp = ref.flash_decode_ref(q, k, v, vlen, scale=D**-0.5)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32), atol=tol, rtol=tol
    )


def test_flash_decode_per_batch_valid_len():
    ks = jax.random.split(KEY, 3)
    B, Hk, G, L, D = 3, 2, 2, 256, 64
    q = jax.random.normal(ks[0], (B, Hk, G, D))
    k = jax.random.normal(ks[1], (B, Hk, L, D))
    v = jax.random.normal(ks[2], (B, Hk, L, D))
    vlen = jnp.array([64, 128, 256], jnp.int32)
    out = ops.flash_decode(q, k, v, vlen, scale=0.125, block_l=64)
    exp = ref.flash_decode_ref(q, k, v, vlen, scale=0.125)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5, rtol=2e-5)
