"""Golden equivalence for the chunked hot loop: driving an engine in
jitted, donated ``lax.scan`` chunks must be *bit-for-bit* identical to
per-step execution — same selection history, same per-round losses, same
final params — for both engines. The per-step key schedule
``fold_in(k_run, r)`` makes the scan body a pure function of the global
step index, so any numeric drift (op reordering, dtype, key handling) is
a bug, and these tests fail on exact comparison.

Also pins the empty-cohort loss convention: a round/step that aggregates
nothing reports ``train_loss = NaN`` (not a fake near-zero datapoint) in
both engines.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cnn import MNIST_CNN
from repro.core.selection import Policy
from repro.data.synthetic import make_image_dataset
from repro.engine import AsyncEngine, RunConfig, SyncEngine, run_engine
from repro.engine.config import chunk_plan

SMALL_CNN = dataclasses.replace(
    MNIST_CNN, name="paper-cnn-mnist-small", image_size=16,
    conv_channels=(8, 16), fc_width=64,
)


@pytest.fixture(scope="module")
def small_task():
    from repro.fl import make_cnn_task

    train, test = make_image_dataset(
        "mnist-small", 10, 16, 1, 600, 500, seed=0, difficulty=0.8
    )
    return make_cnn_task(SMALL_CNN, train, test, n_clients=20)


def _cfg(**kw):
    base = dict(
        n_clients=20, k=4, m=6, policy="markov", rounds=7,
        local_epochs=1, batch_size=10, eval_every=3,
    )
    base.update(kw)
    return RunConfig(**base)


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _per_step_reference(engine, rounds, n):
    """The pre-chunking hot loop: one dispatch + one (n,) host pull per
    step, eval cadence inline."""
    state = engine.init()
    sel = np.zeros((rounds, n), dtype=bool)
    losses = []
    for r in range(rounds):
        state, aux = engine.step(state, r)
        sel[r] = np.asarray(aux["send"])
        losses.append(float(aux["loss"]))
    return state, sel, losses


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_chunked_matches_per_step_bit_for_bit(small_task, mode):
    kw = dict(profile="lognormal", buffer_size=3) if mode == "async" else {}
    cfg = _cfg(mode=mode, **kw)
    make = SyncEngine if mode == "sync" else AsyncEngine

    ref_state, ref_sel, ref_losses = _per_step_reference(
        make(small_task, cfg), cfg.rounds, cfg.n_clients
    )

    # steps_per_chunk=2 against eval_every=3 exercises both chunk lengths
    # (full chunks and eval-boundary remainders) plus the compiled-chunk
    # cache; steps_per_chunk=64 collapses each eval segment to one chunk
    for spc in (1, 2, 64):
        res = run_engine(make(small_task, dataclasses.replace(
            cfg, steps_per_chunk=spc
        )))
        np.testing.assert_array_equal(res.selection, ref_sel, err_msg=f"spc={spc}")
        eval_rounds = [r0 + ln for r0, ln, ev in
                       chunk_plan(cfg.rounds, cfg.eval_every, spc) if ev]
        assert [rec.round for rec in res.records] == eval_rounds
        np.testing.assert_array_equal(
            [rec.train_loss for rec in res.records],
            [ref_losses[r - 1] for r in eval_rounds],
            err_msg=f"spc={spc}",
        )
        _assert_trees_equal(res.params, ref_state["params"])


def test_eval_cadence_identical_to_per_step_rule():
    # the chunk plan's eval chunks must land exactly on the legacy rule:
    # (r + 1) % eval_every == 0 or r == rounds - 1
    for rounds, every, spc in [(7, 3, 2), (10, 4, 64), (5, 1, 2), (6, 10, 4)]:
        legacy = [r for r in range(rounds)
                  if (r + 1) % every == 0 or r == rounds - 1]
        plan = chunk_plan(rounds, every, spc)
        assert sum(ln for _, ln, _ in plan) == rounds
        assert [r0 + ln - 1 for r0, ln, ev in plan if ev] == legacy
        assert all(ln <= spc for _, ln, _ in plan)


def test_collect_history_off_matches_history_run(small_task):
    cfg = _cfg(rounds=6, eval_every=2)
    with_hist = run_engine(SyncEngine(small_task, cfg))
    no_hist = run_engine(SyncEngine(
        small_task, dataclasses.replace(cfg, collect_history=False)
    ))
    assert with_hist.selection is not None and no_hist.selection is None
    np.testing.assert_array_equal(
        [r.train_loss for r in with_hist.records],
        [r.train_loss for r in no_hist.records],
    )
    _assert_trees_equal(with_hist.params, no_hist.params)
    # device accumulators reproduce the history-derived load statistics
    for key, val in with_hist.load_stats.items():
        np.testing.assert_allclose(
            no_hist.load_stats[key], val, rtol=1e-5, err_msg=key
        )


def _never_send_policy(n):
    def init(key, n_=n):
        return {"ages": jnp.zeros((n_,), jnp.int32),
                "round": jnp.zeros((), jnp.int32)}

    def step(state, key):
        return jnp.zeros((n,), jnp.bool_), {**state, "round": state["round"] + 1}

    return Policy("never_send", init, step, exact_k=False)


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_empty_cohort_reports_nan_loss(small_task, mode):
    kw = dict(profile="lognormal", buffer_size=3) if mode == "async" else {}
    cfg = _cfg(mode=mode, rounds=2, eval_every=1, **kw)
    make = SyncEngine if mode == "sync" else AsyncEngine
    res = run_engine(make(small_task, cfg, policy=_never_send_policy(20)))
    assert all(np.isnan(rec.train_loss) for rec in res.records)
    assert not res.selection.any()
