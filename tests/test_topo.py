"""The ``repro.topo`` aggregation-topology subsystem.

Three contracts pinned here:

  * **Star identity** — a ``topology="star"`` run (and ``topology=None``)
    is *bit-for-bit* identical to the pre-topology engines, per-step and
    chunked, async and sync: the degenerate topology adds no state keys,
    no key folds, no ops.
  * **Reduction structure, not math** — the tiered reduction over any
    additive aggregator equals the flat single-server reduction
    (segment-summing accumulators up the tree preserves the total), and
    is invariant to how clients permute across tier-0 nodes (hypothesis
    property test).
  * **Heartbeat churn** — clients dark for longer than the timeout never
    contribute to their tier's reduction (weight 0, counted in
    ``hb_expired``), and an unreachable timeout is bitwise inert.

Multi-device equivalences (sharded fleet + topology, cohort-sharded
tiered reduction) run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
multi-device job does).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cnn import MNIST_CNN
from repro.core import distributed as dist
from repro.data.synthetic import make_image_dataset
from repro.engine import (
    AsyncEngine,
    RunConfig,
    ShardedAsyncEngine,
    SyncEngine,
    make_engine,
    run_engine,
)
from repro.engine.aggregators import make_fedavg, make_fedbuff
from repro.topo import Topology, make_topology, tiered_apply, topology_names
from repro.topo.reduce import make_hop_latency

SMALL_CNN = dataclasses.replace(
    MNIST_CNN, name="paper-cnn-mnist-topo", image_size=8,
    conv_channels=(4, 8), fc_width=32,
)

N = 16
DEVICES = jax.local_device_count()
SHARDS = dist.resolve_fleet_shards(N, 0, DEVICES)
needs_mesh = pytest.mark.skipif(
    DEVICES < 2, reason="needs a multi-device mesh"
)

# cohort-sharded tolerance (cross-device reduction order), matching
# tests/test_cohort_engine.py
RTOL, ATOL = 5e-4, 1e-5

HIER = {"topology": "hierarchical", "topology_kwargs": {"tiers": (4, 2)}}


@pytest.fixture(scope="module")
def small_task():
    from repro.fl import make_cnn_task

    train, test = make_image_dataset(
        "mnist-topo", 10, 8, 1, 120, 60, seed=0, difficulty=0.8
    )
    return make_cnn_task(SMALL_CNN, train, test, n_clients=N)


def _cfg(**kw):
    base = dict(
        n_clients=N, k=4, m=4, policy="markov", rounds=5, local_epochs=1,
        batch_size=5, eval_every=2, mode="async", buffer_size=3,
        profile="mobile",
    )
    base.update(kw)
    return RunConfig(**base)


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _assert_trees_close(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(
            np.asarray(la), np.asarray(lb), rtol=RTOL, atol=ATOL
        )


def _per_step(engine, rounds, n):
    state = engine.init()
    sel = np.zeros((rounds, n), dtype=bool)
    losses = []
    for r in range(rounds):
        state, aux = engine.step(state, r)
        sel[r] = np.asarray(aux["send"])
        losses.append(float(aux["loss"]))
    return state, sel, losses


# ---------------------------------------------------------------------------
# Graph structure + registry
# ---------------------------------------------------------------------------


def test_registry_builtins():
    for name in ("star", "hierarchical", "gossip"):
        assert name in topology_names()
    topo = make_topology("hierarchical", tiers=(8, 2))
    assert topo.tier_sizes == (8, 2)
    assert not topo.is_star
    assert make_topology("star").is_star
    with pytest.raises(ValueError, match="unknown topology"):
        make_topology("ring-of-fire")


def test_topology_validation():
    with pytest.raises(ValueError, match="no aggregation tiers"):
        Topology("bad", kind="star", tier_sizes=(4,))
    with pytest.raises(ValueError, match=">= 1 tier"):
        Topology("bad", kind="hier")
    with pytest.raises(ValueError, match="non-increasing"):
        Topology("bad", kind="hier", tier_sizes=(2, 8))
    with pytest.raises(ValueError, match="tier_profiles"):
        Topology("bad", kind="hier", tier_sizes=(4,),
                 tier_profiles=("datacenter",))  # needs 2 hops
    with pytest.raises(ValueError, match="exactly one tier"):
        Topology("bad", kind="gossip", tier_sizes=(8, 2))
    with pytest.raises(ValueError, match="gossip_degree"):
        Topology("bad", kind="gossip", tier_sizes=(4,), gossip_degree=3)
    with pytest.raises(ValueError, match="heartbeat_timeout"):
        Topology("bad", heartbeat_timeout=-1.0)
    # fleet-shape validation
    with pytest.raises(ValueError, match="tier-0"):
        make_topology("hierarchical", tiers=(64,)).validate(16)
    with pytest.raises(ValueError, match="topology_kwargs"):
        _cfg(topology_kwargs={"tiers": (4,)})


def test_assign_and_parents_are_balanced():
    topo = make_topology("hierarchical", tiers=(4, 2))
    assign = topo.assign(N)
    assert assign.shape == (N,) and assign.dtype == np.int32
    np.testing.assert_array_equal(np.bincount(assign), [4, 4, 4, 4])
    (p0,) = topo.parents()
    np.testing.assert_array_equal(p0, [0, 0, 1, 1])


def test_gossip_mixing_doubly_stochastic():
    topo = make_topology("gossip", nodes=8, degree=4)
    mix = topo.gossip_mixing()
    np.testing.assert_allclose(mix.sum(axis=0), 1.0, rtol=1e-6)
    np.testing.assert_allclose(mix.sum(axis=1), 1.0, rtol=1e-6)
    np.testing.assert_array_equal(mix, mix.T)


# ---------------------------------------------------------------------------
# Golden: the degenerate star is bit-for-bit today's engines
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("agg", ["fedbuff", "fedavg"])
def test_star_async_bit_for_bit(small_task, agg):
    cfg = _cfg(aggregator=agg)
    ref_state, ref_sel, ref_losses = _per_step(
        AsyncEngine(small_task, cfg), cfg.rounds, N
    )
    st_state, st_sel, st_losses = _per_step(
        AsyncEngine(small_task, dataclasses.replace(cfg, topology="star")),
        cfg.rounds, N,
    )
    np.testing.assert_array_equal(st_sel, ref_sel)
    np.testing.assert_array_equal(st_losses, ref_losses)
    _assert_trees_equal(st_state["params"], ref_state["params"])
    assert set(st_state["stats"]) == set(ref_state["stats"])
    for key, val in ref_state["stats"].items():
        np.testing.assert_array_equal(
            np.asarray(st_state["stats"][key]), np.asarray(val), err_msg=key
        )
    # chunked driving too
    ref = run_engine(AsyncEngine(small_task, dataclasses.replace(
        cfg, steps_per_chunk=5
    )))
    star = run_engine(AsyncEngine(small_task, dataclasses.replace(
        cfg, steps_per_chunk=5, topology="star"
    )))
    np.testing.assert_array_equal(star.selection, ref.selection)
    _assert_trees_equal(star.params, ref.params)
    assert star.wall_stats == ref.wall_stats


def test_star_sync_bit_for_bit(small_task):
    cfg = _cfg(mode="sync", buffer_size=None, profile="lognormal")
    ref_state, ref_sel, ref_losses = _per_step(
        SyncEngine(small_task, cfg), cfg.rounds, N
    )
    st_state, st_sel, st_losses = _per_step(
        SyncEngine(small_task, dataclasses.replace(cfg, topology="star")),
        cfg.rounds, N,
    )
    np.testing.assert_array_equal(st_sel, ref_sel)
    np.testing.assert_array_equal(st_losses, ref_losses)
    _assert_trees_equal(st_state["params"], ref_state["params"])
    ref = run_engine(SyncEngine(small_task, dataclasses.replace(
        cfg, steps_per_chunk=5
    )))
    star = run_engine(SyncEngine(small_task, dataclasses.replace(
        cfg, steps_per_chunk=5, topology="star"
    )))
    np.testing.assert_array_equal(star.selection, ref.selection)
    _assert_trees_equal(star.params, ref.params)
    assert star.load_stats == ref.load_stats


# ---------------------------------------------------------------------------
# Tier reductions: structure only, no new aggregator math
# ---------------------------------------------------------------------------


def _toy_cohort(seed, b=8, n=N):
    key = jax.random.PRNGKey(seed)
    g = {"w": jax.random.normal(key, (3, 4)), "b": jnp.zeros((4,))}
    updates = jax.tree.map(
        lambda p: jax.random.normal(jax.random.fold_in(key, 1),
                                    (b,) + p.shape), g
    )
    bases = jax.tree.map(
        lambda p: jax.random.normal(jax.random.fold_in(key, 2),
                                    (b,) + p.shape), g
    )
    w = jax.random.uniform(jax.random.fold_in(key, 3), (b,))
    idx = jax.random.randint(jax.random.fold_in(key, 4), (b,), 0, n)
    return g, updates, bases, w, idx


@pytest.mark.parametrize("make_agg", [make_fedavg, make_fedbuff])
@pytest.mark.parametrize("tiers", [(4,), (4, 2), (8, 4, 2)])
def test_tiered_apply_matches_flat_reduction(make_agg, tiers):
    agg = make_agg()
    topo = make_topology("hierarchical", tiers=tiers)
    g, updates, bases, w, idx = _toy_cohort(0)
    flat = agg.finalize(g, agg.accumulate(agg.init(g), updates, bases, w))
    tiered, _ = tiered_apply(agg, topo, N)(g, updates, bases, w, idx)
    _assert_trees_close(tiered, flat)


def test_tiered_apply_unstacked_bases_matches_flat():
    agg = make_fedbuff()
    topo = make_topology("hierarchical", tiers=(4,))
    g, updates, _, w, idx = _toy_cohort(1)
    flat = agg.finalize(g, agg.accumulate(agg.init(g), updates, g, w))
    tiered, _ = tiered_apply(agg, topo, N, stacked_bases=False)(
        g, updates, g, w, idx
    )
    _assert_trees_close(tiered, flat)


def test_gossip_converges_to_flat_reduction():
    # enough mixing rounds -> every node's view is the network mean and
    # the node-0 readout equals the hierarchical (= flat) reduction
    agg = make_fedavg()
    topo = make_topology("gossip", nodes=4, degree=2, rounds=64)
    g, updates, bases, w, idx = _toy_cohort(2)
    flat = agg.finalize(g, agg.accumulate(agg.init(g), updates, bases, w))
    gossiped, _ = tiered_apply(agg, topo, N)(g, updates, bases, w, idx)
    _assert_trees_close(gossiped, flat)


def test_tiered_apply_rejections():
    topo = make_topology("hierarchical", tiers=(4,))
    non_additive = dataclasses.replace(make_fedavg(), additive=False)
    with pytest.raises(ValueError, match="not additive"):
        tiered_apply(non_additive, topo, N)
    with pytest.raises(ValueError, match="star"):
        tiered_apply(make_fedavg(), make_topology("star"), N)


def test_tier_permutation_invariance_hypothesis():
    """Property: for additive aggregators the tiered reduction does not
    depend on which tier-0 node a client hangs off — permuting the
    client -> tier assignment leaves the aggregated params unchanged."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    agg = make_fedavg()
    topo = make_topology("hierarchical", tiers=(4, 2))
    apply = jax.jit(tiered_apply(agg, topo, N))
    g, updates, bases, _, _ = _toy_cohort(3)

    @settings(max_examples=20, deadline=None)
    @given(
        perm=st.permutations(list(range(N))),
        data=st.data(),
    )
    def check(perm, data):
        b = jax.tree.leaves(updates)[0].shape[0]
        w = jnp.asarray(
            data.draw(st.lists(
                st.floats(0.0, 4.0, allow_nan=False, width=32),
                min_size=b, max_size=b,
            )),
            jnp.float32,
        )
        idx = jnp.asarray(
            data.draw(st.lists(st.integers(0, N - 1), min_size=b,
                               max_size=b)),
            jnp.int32,
        )
        base, _ = apply(g, updates, bases, w, idx)
        permuted, _ = apply(g, updates, bases, w, jnp.asarray(perm)[idx])
        _assert_trees_close(permuted, base)

    check()


# ---------------------------------------------------------------------------
# End-to-end topology runs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["async", "sync"])
def test_hierarchical_run_reports_per_tier_stats(small_task, mode):
    kw = dict(HIER)
    if mode == "sync":
        kw.update(mode="sync", buffer_size=None, profile="lognormal")
    res = run_engine(make_engine(small_task, _cfg(rounds=8, **kw)))
    for key in ("tier_num_samples", "tier_mean_X", "tier_var_X"):
        assert key in res.load_stats
        assert len(res.load_stats[key]) == 4  # tier-0 nodes
    # tier samples partition the fleet-wide samples
    assert sum(res.load_stats["tier_num_samples"]) == \
        res.load_stats["num_samples"]
    assert all(np.isfinite(res.records[-1].train_loss)
               for _ in [0])  # run completed


def test_hop_latency_slows_the_simulated_clock(small_task):
    # every dispatch pays >= comm_shift per hop on top of its own
    # latency, so the hierarchical clock must run ahead of the star's
    cfg = _cfg(rounds=5)
    star = run_engine(AsyncEngine(small_task, cfg))
    hier = run_engine(AsyncEngine(small_task, dataclasses.replace(
        cfg, **HIER
    )))
    assert hier.wall_stats["sim_time"] > star.wall_stats["sim_time"]
    hop = make_hop_latency(_cfg(**HIER).resolved_topology(), N)
    extra = np.asarray(hop(jax.random.PRNGKey(0)))
    assert extra.shape == (N,) and (extra > 0).all()
    assert make_hop_latency(make_topology("star"), N) is None


# ---------------------------------------------------------------------------
# Heartbeat churn
# ---------------------------------------------------------------------------


def test_heartbeat_excludes_churned_clients(small_task):
    # a timeout below any possible latency declares every completion
    # dark: nothing may ever reach the reduction, params stay at init
    cfg = _cfg(rounds=5, topology="hierarchical",
               topology_kwargs={"tiers": (4,), "heartbeat_timeout": 1e-6})
    eng = AsyncEngine(small_task, cfg)
    state, _, _ = _per_step(eng, cfg.rounds, N)
    assert float(state["stats"]["updates"]) == 0
    assert float(state["stats"]["hb_expired"]) > 0
    _assert_trees_equal(state["params"], eng.init()["params"])
    # version never advances: no aggregation ever happened
    assert int(state["version"]) == 0


def test_heartbeat_unreachable_timeout_is_inert(small_task):
    # a timeout no simulated gap can exceed changes nothing but the
    # bookkeeping keys: params/selection/losses stay bitwise identical
    cfg = _cfg(rounds=5, topology="hierarchical",
               topology_kwargs={"tiers": (4,)})
    ref_state, ref_sel, ref_losses = _per_step(
        AsyncEngine(small_task, cfg), cfg.rounds, N
    )
    hcfg = _cfg(rounds=5, topology="hierarchical",
                topology_kwargs={"tiers": (4,), "heartbeat_timeout": 1e9})
    hb_state, hb_sel, hb_losses = _per_step(
        AsyncEngine(small_task, hcfg), cfg.rounds, N
    )
    np.testing.assert_array_equal(hb_sel, ref_sel)
    np.testing.assert_array_equal(hb_losses, ref_losses)
    _assert_trees_equal(hb_state["params"], ref_state["params"])
    assert float(hb_state["stats"]["hb_expired"]) == 0
    assert float(hb_state["stats"]["updates"]) == float(
        ref_state["stats"]["updates"]
    )


def test_heartbeat_on_a_star(small_task):
    # heartbeat is orthogonal to tiers: a star with an unreachable
    # timeout still matches the plain engine bitwise on params/selection
    cfg = _cfg(rounds=4)
    ref = run_engine(AsyncEngine(small_task, cfg))
    hb = run_engine(AsyncEngine(small_task, dataclasses.replace(
        cfg, topology="star", topology_kwargs={"heartbeat_timeout": 1e9}
    )))
    np.testing.assert_array_equal(hb.selection, ref.selection)
    _assert_trees_equal(hb.params, ref.params)
    assert hb.wall_stats["hb_expired"] == 0


def test_sync_rejects_heartbeat(small_task):
    with pytest.raises(ValueError, match="async"):
        SyncEngine(small_task, _cfg(
            mode="sync", buffer_size=None, profile="lognormal",
            topology="star", topology_kwargs={"heartbeat_timeout": 1.0},
        ))


# ---------------------------------------------------------------------------
# Sharded execution under a topology
# ---------------------------------------------------------------------------


def test_sharded_hierarchical_bit_for_bit(small_task):
    # fleet sharding must stay bit-exact under a topology, exactly like
    # it is for the star (tests/test_sharded_engine.py)
    cfg = _cfg(rounds=5, topology="hierarchical",
               topology_kwargs={"tiers": (4, 2), "heartbeat_timeout": 50.0})
    ref_state, ref_sel, ref_losses = _per_step(
        AsyncEngine(small_task, cfg), cfg.rounds, N
    )
    sh_state, sh_sel, sh_losses = _per_step(
        ShardedAsyncEngine(
            small_task, dataclasses.replace(cfg, mesh_shards=SHARDS)
        ),
        cfg.rounds, N,
    )
    np.testing.assert_array_equal(sh_sel, ref_sel)
    np.testing.assert_array_equal(sh_losses, ref_losses)
    _assert_trees_equal(sh_state["params"], ref_state["params"])
    for key, val in ref_state["stats"].items():
        np.testing.assert_array_equal(
            np.asarray(sh_state["stats"][key]), np.asarray(val), err_msg=key
        )
    _assert_trees_equal(sh_state["tier_acc"], ref_state["tier_acc"])


@needs_mesh
def test_cohort_sharded_hierarchical_matches_replicated(small_task):
    # the tiered reduction in cohort-parallel form: same one-psum merge
    # pattern, allclose to the replicated layout
    cfg = _cfg(rounds=5, **HIER)
    ref = run_engine(AsyncEngine(small_task, cfg))
    coh = run_engine(make_engine(small_task, dataclasses.replace(
        cfg, mesh_shards=SHARDS, shard_cohort=True
    )))
    np.testing.assert_array_equal(coh.selection, ref.selection)
    _assert_trees_close(coh.params, ref.params)
    for key, val in ref.load_stats.items():
        np.testing.assert_allclose(coh.load_stats[key], val,
                                   rtol=RTOL, atol=ATOL, err_msg=key)


@needs_mesh
def test_cohort_sharded_sync_hierarchical(small_task):
    cfg = _cfg(mode="sync", buffer_size=None, profile="lognormal",
               rounds=5, **HIER)
    ref = run_engine(SyncEngine(small_task, cfg))
    coh = run_engine(make_engine(small_task, dataclasses.replace(
        cfg, mesh_shards=0, shard_cohort=True
    )))
    np.testing.assert_array_equal(coh.selection, ref.selection)
    _assert_trees_close(coh.params, ref.params)
    for key, val in ref.load_stats.items():
        np.testing.assert_allclose(coh.load_stats[key], val,
                                   rtol=RTOL, atol=ATOL, err_msg=key)
