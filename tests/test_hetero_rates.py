"""Heterogeneous per-client selection rates (beyond-paper extension)."""
import jax
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import load_metric as lm
from repro.core.selection import make_markov_hetero, simulate


@given(mean_gap=st.floats(1.0, 50.0), m=st.integers(1, 30))
@settings(max_examples=100, deadline=None)
def test_rate_generalization_consistent(mean_gap, m):
    p = lm.optimal_probs_for_mean(mean_gap, m)
    ex, _, var = lm.markov_moments(p)
    assert ex == pytest.approx(mean_gap, rel=1e-6)
    assert var == pytest.approx(lm.optimal_var_for_mean(mean_gap, m), abs=1e-6)


def test_hetero_policy_rates_and_variance():
    # three speed tiers: fast clients every ~4 rounds, slow every ~20
    rates = np.concatenate([
        np.full(20, 0.25), np.full(40, 0.10), np.full(40, 0.05),
    ])
    m = 25
    pol = make_markov_hetero(rates, m)
    hist = simulate(pol, jax.random.PRNGKey(0), len(rates), 6000)
    realized = hist.mean(axis=0)
    # per-tier realized rates match targets
    assert realized[:20].mean() == pytest.approx(0.25, rel=0.03)
    assert realized[20:60].mean() == pytest.approx(0.10, rel=0.05)
    assert realized[60:].mean() == pytest.approx(0.05, rel=0.07)
    # per-tier Var[X] at each tier's own optimum
    for sl, rate in [(slice(0, 20), 0.25), (slice(60, 100), 0.05)]:
        gaps = []
        for c in range(*sl.indices(100)):
            rounds = np.flatnonzero(hist[:, c])
            if len(rounds) > 1:
                gaps.append(np.diff(rounds))
        gaps = np.concatenate(gaps)
        expect = lm.optimal_var_for_mean(1 / rate, m)
        assert gaps.var() == pytest.approx(expect, abs=max(0.3, 0.15 * expect))


def test_total_load_matches_budget():
    rates = np.full(50, 0.2)
    pol = make_markov_hetero(rates, 10)
    hist = simulate(pol, jax.random.PRNGKey(1), 50, 3000)
    assert hist.sum(axis=1).mean() == pytest.approx(10.0, rel=0.05)
