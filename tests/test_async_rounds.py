"""Buffered asynchronous training loop: staleness weighting and the
degenerate reduction onto the synchronous FedAvg round."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cnn import MNIST_CNN
from repro.data.synthetic import make_image_dataset
from repro.fl import FLConfig, make_cnn_task, run_training
from repro.sim import AsyncConfig, get_profile, run_async_training, staleness_weight

SMALL_CNN = dataclasses.replace(
    MNIST_CNN, name="paper-cnn-mnist-small", image_size=16,
    conv_channels=(8, 16), fc_width=64,
)


@pytest.fixture(scope="module")
def small_task():
    train, test = make_image_dataset(
        "mnist-small", 10, 16, 1, 600, 500, seed=0, difficulty=0.8
    )
    return make_cnn_task(SMALL_CNN, train, test, n_clients=20)


def _fl(policy, rounds=6, **kw):
    base = dict(
        n_clients=20, k=4, m=6, policy=policy, rounds=rounds,
        local_epochs=2, batch_size=10, eval_every=2,
    )
    base.update(kw)
    return FLConfig(**base)


# ---------------------------------------------------------------------------
# staleness weights
# ---------------------------------------------------------------------------


def test_staleness_weight_polynomial():
    s = jnp.array([0, 1, 3, 8])
    w = staleness_weight(s, "poly", 0.5)
    np.testing.assert_allclose(
        np.asarray(w), (1.0 + np.array([0, 1, 3, 8])) ** -0.5, rtol=1e-6
    )
    # fresh updates always carry full weight; staler never weighs more
    assert float(w[0]) == 1.0
    assert (np.diff(np.asarray(w)) <= 0).all()


def test_staleness_weight_const_and_errors():
    s = jnp.array([0, 5, 2])
    np.testing.assert_allclose(np.asarray(staleness_weight(s, "const")), 1.0)
    with pytest.raises(ValueError):
        staleness_weight(s, "geometric")


def test_staleness_weights_sum_in_aggregation(small_task):
    """Under a heterogeneous profile the realized weights are normalized:
    each aggregation advances exactly one version and the loop reports one
    successful update per buffered completion (no double counting)."""
    fl = _fl("markov", rounds=10)
    out = run_async_training(
        small_task, fl, AsyncConfig(buffer_size=4, profile="lognormal")
    )
    ws = out["wall_stats"]
    assert 0 < ws["aggregations"] <= fl.rounds
    assert ws["updates_applied"] <= fl.rounds * 4
    assert out["history"]["version"][-1] == ws["aggregations"]
    assert ws["mean_staleness"] >= 0.0
    assert ws["max_staleness"] >= ws["mean_staleness"]
    # params actually moved
    assert out["history"]["train_loss"][-1] > 0


def test_dropouts_reduce_applied_updates(small_task):
    fl = _fl("markov", rounds=10)
    drop = run_async_training(
        small_task, fl,
        AsyncConfig(buffer_size=4,
                    profile=dataclasses.replace(get_profile("lognormal"), dropout=0.6)),
    )
    clean = run_async_training(
        small_task, fl, AsyncConfig(buffer_size=4, profile="lognormal")
    )
    assert drop["wall_stats"]["updates_applied"] < clean["wall_stats"]["updates_applied"]


def test_all_idle_fleet_does_not_freeze_clock(small_task):
    """With long availability gaps and a buffer that drains the whole
    fleet, one step leaves everyone idle inside their off-window; the
    clock must jump to the next window opening instead of deadlocking."""
    fl = _fl("random", rounds=8, k=20)
    prof = dataclasses.replace(get_profile("uniform"), avail_gap=50.0)
    out = run_async_training(
        small_task, fl,
        AsyncConfig(buffer_size=fl.n_clients, staleness_mode="const", profile=prof),
    )
    ws = out["wall_stats"]
    # without the clock jump the run freezes after the first aggregation
    # at sim_time == 1.0 (the one unit-latency cohort)
    assert ws["aggregations"] >= 2
    assert ws["sim_time"] > 1.5


# ---------------------------------------------------------------------------
# degenerate reduction: zero latency spread + buffer k == sync FedAvg
# ---------------------------------------------------------------------------


def test_degenerate_profile_matches_sync_fedavg(small_task):
    fl = _fl("random", rounds=6)
    sync = run_training(small_task, fl)
    asy = run_async_training(
        small_task, fl,
        AsyncConfig(buffer_size=fl.k, staleness_mode="const", profile="uniform"),
    )
    # identical realized cohorts round for round
    np.testing.assert_array_equal(sync["selection"], np.asarray(asy["selection"]))
    # per-update losses and eval trajectory match within float tolerance
    np.testing.assert_allclose(
        sync["history"]["train_loss"], asy["history"]["train_loss"], rtol=1e-4
    )
    np.testing.assert_allclose(
        sync["history"]["eval_loss"], asy["history"]["eval_loss"], rtol=1e-4
    )
    np.testing.assert_allclose(
        sync["history"]["accuracy"], asy["history"]["accuracy"], atol=1e-3
    )
    ws = asy["wall_stats"]
    assert ws["mean_staleness"] == 0.0 and ws["max_staleness"] == 0
    assert ws["aggregations"] == fl.rounds
    # one unit-latency cohort per step: simulated clock counts the steps
    assert ws["sim_time"] == pytest.approx(fl.rounds)


def test_degenerate_markov_policy_also_reduces(small_task):
    """Same reduction with the paper's Markov policy (variable cohorts):
    buffer >= max cohort drains every completion each step, so version
    lags never appear."""
    fl = _fl("markov", rounds=8)
    asy = run_async_training(
        small_task, fl,
        AsyncConfig(buffer_size=fl.n_clients, staleness_mode="const",
                    profile="uniform"),
    )
    ws = asy["wall_stats"]
    assert ws["max_staleness"] == 0
    # empirical epoch-indexed X sees the same chain the sync loop would
    assert ws["mean_X_epoch"] == pytest.approx(fl.n_clients / fl.k, rel=0.5)
