"""pshard constraint fallbacks + input_specs sanity for every (arch, shape)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import INPUT_SHAPES, all_archs, shape_applicable
from repro.models import factory, pshard


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 8))
    assert pshard.constrain(x, "data", "model") is x or (
        pshard.constrain(x, "data", "model") == x
    ).all()


def test_axis_size_and_dp_without_mesh():
    assert pshard.axis_size("model") == 1
    assert pshard.dp() == ()


def test_mesh_context_restores():
    class FakeMesh:
        shape = {"data": 4, "model": 2}

    m = FakeMesh()
    assert pshard.current_mesh() is None
    with pshard.mesh_context(m):
        assert pshard.current_mesh() is m
        assert pshard.axis_size("model") == 2
        assert pshard.dp() == ("data",)
    assert pshard.current_mesh() is None


@pytest.mark.parametrize("arch", sorted(all_archs()))
@pytest.mark.parametrize("shape", sorted(INPUT_SHAPES))
def test_input_specs_cover_all_pairs(arch, shape):
    """input_specs builds ShapeDtypeStructs for every required pair without
    allocating; shapes are internally consistent."""
    cfg = all_archs()[arch]
    sc = INPUT_SHAPES[shape]
    ok, why = shape_applicable(cfg, sc)
    if not ok:
        assert why
        return
    specs = factory.input_specs(cfg, sc)
    leaves = jax.tree_util.tree_leaves(specs)
    assert leaves, (arch, shape)
    for leaf in leaves:
        assert hasattr(leaf, "shape") and hasattr(leaf, "dtype")
    if sc.mode == "train":
        assert specs["labels"].shape == (sc.global_batch, sc.seq_len)
    if sc.mode == "decode":
        assert specs["token"].shape == (sc.global_batch, 1)
        # caches must fit per device once sharded: apply the cache rules on
        # the production mesh shape and bound per-device bytes
        if shape == "long_500k":
            from jax.sharding import PartitionSpec as P

            from repro import sharding as sr

            class FakeMesh:
                shape = {"data": 16, "model": 16}

            pspecs = sr.cache_pspecs(specs["caches"], FakeMesh())
            total = 0.0
            for leaf, spec in zip(
                jax.tree_util.tree_leaves(specs["caches"]),
                jax.tree_util.tree_leaves(pspecs, is_leaf=lambda x: isinstance(x, P)),
            ):
                shard = 1
                for ax in tuple(spec):
                    if ax is not None:
                        shard *= 16 if not isinstance(ax, tuple) else 16 ** len(ax)
                total += leaf.size * leaf.dtype.itemsize / shard
            assert total < 14e9, (arch, f"{total / 1e9:.1f} GB/device")


def test_decode_cache_len_respects_window():
    cfg = all_archs()["gemma3-27b"]
    sc = INPUT_SHAPES["long_500k"]
    specs = factory.input_specs(cfg, sc)
    lens = set()
    for leaf in jax.tree_util.tree_leaves(specs["caches"]):
        if leaf.ndim >= 3 and leaf.shape[-1] in (128,):
            lens.add(leaf.shape[-3])
    # both the 1024-window local caches and full-length global caches exist
    assert 1024 in lens
    assert sc.seq_len in lens
