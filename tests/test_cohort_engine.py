"""Cohort-parallel execution (``RunConfig.shard_cohort``) equivalence.

Flag-on partitions the cohort axis over the device mesh (shard-local
aggregator accumulation merged by one psum) instead of replicating it, so
results are **allclose**, not bitwise, to the replicated layout: the only
permitted difference is floating-point reduction order across cohort
shards. The tolerance pinned here (``RTOL``/``ATOL``) is the documented
contract of the mode — selections are still *exact* (every (n,) fleet
draw keeps the unpadded shapes and key schedule), and ``shard_cohort=False``
stays bit-for-bit pinned by the untouched ``tests/test_sharded_engine.py``.

Equivalence runs need a real mesh: execute under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
multi-device job does); on one device only the validation tests run.

Also pins the zero-dropout fast path: profiles with ``dropout == 0`` skip
the per-step dropout fold/draw entirely, bitwise-identically to drawing a
never-true dropout mask.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cnn import MNIST_CNN
from repro.core import distributed as dist
from repro.data.synthetic import make_image_dataset
from repro.engine import (
    AsyncEngine,
    RunConfig,
    ShardedAsyncEngine,
    SyncEngine,
    make_engine,
    run_engine,
)
from repro.engine.aggregators import cohort_sharded_apply, make_fedavg
from repro.sim import latency as lat_mod

SMALL_CNN = dataclasses.replace(
    MNIST_CNN, name="paper-cnn-mnist-cohort", image_size=8,
    conv_channels=(4, 8), fc_width=32,
)

N = 16
DEVICES = jax.local_device_count()
SHARDS = dist.resolve_fleet_shards(N, 0, DEVICES)
needs_mesh = pytest.mark.skipif(
    DEVICES < 2, reason="cohort sharding needs a multi-device mesh"
)

# the documented tolerance contract of shard_cohort=True: reduction order
# across cohort shards differs, nothing else does
RTOL, ATOL = 5e-4, 1e-5


@pytest.fixture(scope="module")
def small_task():
    from repro.fl import make_cnn_task

    train, test = make_image_dataset(
        "mnist-cohort", 10, 8, 1, 120, 64, seed=0, difficulty=0.8
    )
    return make_cnn_task(SMALL_CNN, train, test, n_clients=N)


def _cfg(**kw):
    base = dict(
        n_clients=N, k=4, m=4, policy="markov", rounds=5, local_epochs=1,
        batch_size=5, eval_every=2, mode="async", buffer_size=3,
        profile="mobile",
    )
    base.update(kw)
    return RunConfig(**base)


def _assert_trees_close(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(
            np.asarray(la), np.asarray(lb), rtol=RTOL, atol=ATOL
        )


def _per_step(engine, rounds, n):
    state = engine.init()
    sel = np.zeros((rounds, n), dtype=bool)
    losses = []
    for r in range(rounds):
        state, aux = engine.step(state, r)
        sel[r] = np.asarray(aux["send"])
        losses.append(float(aux["loss"]))
    return state, sel, losses


@needs_mesh
@pytest.mark.parametrize("agg", ["fedbuff", "fedavg"])
@pytest.mark.parametrize("policy", ["markov", "oldest_age", "round_robin"])
def test_cohort_matches_replicated_async(small_task, policy, agg):
    # buffer_size=3 does not divide an 8-way mesh: the padding path is
    # exercised on the CI mesh (padded slots must never leak)
    cfg = _cfg(policy=policy, aggregator=agg)
    ref_state, ref_sel, ref_losses = _per_step(
        AsyncEngine(small_task, cfg), cfg.rounds, N
    )

    ccfg = dataclasses.replace(cfg, mesh_shards=SHARDS, shard_cohort=True)
    coh_state, coh_sel, coh_losses = _per_step(
        ShardedAsyncEngine(small_task, ccfg), cfg.rounds, N
    )
    # selections are exact: every (n,) draw keeps the unpadded schedule
    np.testing.assert_array_equal(coh_sel, ref_sel)
    np.testing.assert_allclose(coh_losses, ref_losses, rtol=RTOL, atol=ATOL)
    _assert_trees_close(coh_state["params"], ref_state["params"])
    for key, val in ref_state["stats"].items():
        np.testing.assert_allclose(
            np.asarray(coh_state["stats"][key]), np.asarray(val),
            rtol=RTOL, atol=ATOL, err_msg=key,
        )

    # chunked driving through run_engine (donated scan chunks + eval)
    ref = run_engine(AsyncEngine(small_task, dataclasses.replace(
        cfg, steps_per_chunk=5
    )))
    coh = run_engine(make_engine(small_task, dataclasses.replace(
        ccfg, steps_per_chunk=5
    )))
    np.testing.assert_array_equal(coh.selection, ref.selection)
    _assert_trees_close(coh.params, ref.params)
    for rr, cr in zip(ref.records, coh.records):
        np.testing.assert_allclose(cr.train_loss, rr.train_loss,
                                   rtol=RTOL, atol=ATOL)
        np.testing.assert_allclose(cr.eval_loss, rr.eval_loss,
                                   rtol=RTOL, atol=ATOL)
        np.testing.assert_allclose(cr.accuracy, rr.accuracy,
                                   rtol=RTOL, atol=ATOL)
    for key, val in ref.load_stats.items():
        np.testing.assert_allclose(coh.load_stats[key], val,
                                   rtol=RTOL, atol=ATOL, err_msg=key)
    for key, val in ref.wall_stats.items():
        np.testing.assert_allclose(coh.wall_stats[key], val,
                                   rtol=RTOL, atol=ATOL, err_msg=key)


@needs_mesh
@pytest.mark.parametrize("agg", ["fedavg", "fedbuff"])
def test_cohort_matches_plain_sync(small_task, agg):
    cfg = _cfg(mode="sync", buffer_size=None, profile="lognormal",
               aggregator=agg)
    ref = run_engine(SyncEngine(small_task, cfg))
    coh = run_engine(make_engine(small_task, dataclasses.replace(
        cfg, mesh_shards=0, shard_cohort=True
    )))
    np.testing.assert_array_equal(coh.selection, ref.selection)
    _assert_trees_close(coh.params, ref.params)
    for rr, cr in zip(ref.records, coh.records):
        np.testing.assert_allclose(cr.train_loss, rr.train_loss,
                                   rtol=RTOL, atol=ATOL)
        np.testing.assert_allclose(cr.eval_loss, rr.eval_loss,
                                   rtol=RTOL, atol=ATOL)
    for key, val in ref.load_stats.items():
        np.testing.assert_allclose(coh.load_stats[key], val,
                                   rtol=RTOL, atol=ATOL, err_msg=key)


@needs_mesh
def test_cohort_eval_is_sharded(small_task):
    eng = make_engine(
        small_task, _cfg(mesh_shards=SHARDS, shard_cohort=True)
    )
    # the 64-example eval prefix divides the mesh: the sharded eval path
    # must actually engage (no silent fallback to replicated eval)
    assert eng._sharded_eval is not None
    state = eng.init()
    got = {k: float(v) for k, v in eng.evaluate(state).items()}
    want = {k: float(v) for k, v in
            small_task.eval_fn(state["params"]).items()}
    assert set(got) == set(want)
    for key, val in want.items():
        np.testing.assert_allclose(got[key], val, rtol=RTOL, atol=ATOL,
                                   err_msg=key)


@needs_mesh
def test_sharded_eval_fallbacks(small_task):
    from repro.engine.sharded import make_sharded_eval

    mesh = dist.fleet_mesh(SHARDS)
    assert make_sharded_eval(small_task, mesh, dist.FLEET_AXIS) is not None
    # no batched-eval interface -> replicated fallback
    bare = dataclasses.replace(small_task, eval_batch_fn=None)
    assert make_sharded_eval(bare, mesh, dist.FLEET_AXIS) is None
    # eval prefix not divisible by the mesh -> replicated fallback
    ragged = dataclasses.replace(
        small_task,
        eval_data=jax.tree.map(lambda a: a[: SHARDS + 1],
                               small_task.eval_data),
    )
    assert make_sharded_eval(ragged, mesh, dist.FLEET_AXIS) is None


@needs_mesh
def test_cohort_sharded_apply_matches_inline():
    agg = make_fedavg()
    mesh = dist.fleet_mesh(SHARDS)
    key = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(key, (3, 4)), "b": jnp.zeros((4,))}
    B = 2 * SHARDS
    updates = jax.tree.map(
        lambda p: jax.random.normal(jax.random.fold_in(key, 1),
                                    (B,) + p.shape), g
    )
    bases = jax.tree.map(
        lambda p: jax.random.normal(jax.random.fold_in(key, 2),
                                    (B,) + p.shape), g
    )
    w = jnp.asarray([1.0, 0.0] * SHARDS)
    inline = agg.finalize(g, agg.accumulate(agg.init(g), updates, bases, w))
    sharded, _ = cohort_sharded_apply(agg, mesh, dist.FLEET_AXIS)(
        g, updates, bases, w
    )
    _assert_trees_close(sharded, inline)


def test_cohort_sharded_apply_rejects_non_additive():
    agg = dataclasses.replace(make_fedavg(), additive=False)
    mesh = dist.fleet_mesh(1)
    with pytest.raises(ValueError, match="not additive"):
        cohort_sharded_apply(agg, mesh, dist.FLEET_AXIS)


def test_shard_cohort_validation(small_task):
    # config level: no mesh at all would be a silent no-op
    with pytest.raises(ValueError, match="shard_cohort.*mesh"):
        _cfg(shard_cohort=True)
    # sync + mesh_shards is only meaningful with shard_cohort
    with pytest.raises(ValueError, match="shard_cohort"):
        RunConfig(mode="sync", mesh_shards=2)
    # engine level: a 1-device mesh is not a cohort mesh, regardless of
    # how many devices the host has
    with pytest.raises(ValueError, match=">= 2 devices"):
        make_engine(small_task, _cfg(mesh_shards=1, shard_cohort=True))
    with pytest.raises(ValueError, match=">= 2 devices"):
        make_engine(small_task, _cfg(
            mode="sync", buffer_size=None, profile="lognormal",
            mesh_shards=1, shard_cohort=True,
        ))


def test_cohort_padding():
    assert dist.cohort_padding(3, 8) == 5
    assert dist.cohort_padding(8, 8) == 0
    assert dist.cohort_padding(9, 8) == 7
    assert dist.cohort_padding(5, 1) == 0
    with pytest.raises(ValueError, match=">= 1"):
        dist.cohort_padding(3, 0)


@pytest.mark.parametrize("profile_name", ["lognormal", "uniform"])
def test_zero_dropout_skips_draw_unchanged(small_task, profile_name):
    """Zero-dropout profiles skip the per-step dropout fold/draw; results
    must be bitwise identical to a profile whose dropout draw runs but
    never fires (the 102 fold feeds nothing else)."""
    base = lat_mod.get_profile(profile_name)
    assert base.dropout == 0.0
    never = dataclasses.replace(base, dropout=1e-30)
    res0 = run_engine(AsyncEngine(small_task, _cfg(profile=base, rounds=4)))
    res1 = run_engine(AsyncEngine(small_task, _cfg(profile=never, rounds=4)))
    np.testing.assert_array_equal(res0.selection, res1.selection)
    for la, lb in zip(jax.tree.leaves(res0.params),
                      jax.tree.leaves(res1.params)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    for rr, cr in zip(res0.records, res1.records):
        np.testing.assert_array_equal(cr.train_loss, rr.train_loss)
