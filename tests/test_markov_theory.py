"""Property tests for the paper's theory (Eqs. 5-22, Theorems 1-2)."""
import math

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import load_metric as lm

nk_pairs = st.tuples(st.integers(2, 200), st.integers(1, 199)).filter(
    lambda t: t[1] < t[0]
)


@given(nk=nk_pairs)
@settings(max_examples=200, deadline=None)
def test_selection_rate_is_k_over_n(nk):
    """Constraint (3)/(8): steady-state selection probability == k/n."""
    n, k = nk
    m = max(min(2 * math.floor(n / k), 40), 1)
    p = lm.optimal_probs(n, k, m)
    assert lm.selection_rate(p) == pytest.approx(k / n, rel=1e-9)


@given(nk=nk_pairs)
@settings(max_examples=200, deadline=None)
def test_mean_is_n_over_k(nk):
    """Eq. (17): E[X] = n/k for any feasible chain; optimal included."""
    n, k = nk
    m = max(min(math.floor(n / k) + 3, 50), 1)
    p = lm.optimal_probs(n, k, m)
    ex, _, _ = lm.markov_moments(p)
    assert ex == pytest.approx(n / k, rel=1e-9)


@given(nk=nk_pairs, m=st.integers(1, 40))
@settings(max_examples=300, deadline=None)
def test_theorem2_variance_closed_form(nk, m):
    """Var[X] of the optimal chain equals Theorem 2's closed form."""
    n, k = nk
    p = lm.optimal_probs(n, k, m)
    assert lm.markov_var(p) == pytest.approx(lm.optimal_var(n, k, m), abs=1e-7)


@given(nk=nk_pairs, m=st.integers(1, 40))
@settings(max_examples=300, deadline=None)
def test_optimal_beats_random(nk, m):
    """Remark 2: optimal Markov Var < random selection Var (for k < n)."""
    n, k = nk
    v_opt = lm.optimal_var(n, k, m)
    v_rand = lm.random_selection_var(n, k)
    assert v_opt <= v_rand + 1e-9
    if k < n:  # strict when chain can help
        assert v_opt < v_rand + 1e-9


@given(nk=nk_pairs)
@settings(max_examples=200, deadline=None)
def test_variance_monotone_in_m(nk):
    """Remark 2: optimal Var[X] is non-increasing in m and saturates at
    m = floor(n/k)."""
    n, k = nk
    r = math.floor(n / k)
    vs = [lm.optimal_var(n, k, m) for m in range(1, r + 3)]
    for a, b in zip(vs, vs[1:]):
        assert b <= a + 1e-9
    assert lm.optimal_var(n, k, r) == pytest.approx(
        lm.optimal_var(n, k, r + 5), abs=1e-9
    )


@given(nk=nk_pairs, m=st.integers(1, 25), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_random_feasible_probs_never_beat_optimal(nk, m, seed):
    """Optimality: no feasible chain (constraint 17 satisfied) has lower
    Var than Theorem 2's construction."""
    n, k = nk
    rng = np.random.default_rng(seed)
    # random chain, then rescale p_m to satisfy E[X]=n/k if feasible
    p = rng.uniform(0.01, 0.99, size=m + 1)
    # solve for p_m from (17): E0 = 1 + sum prods + prod/p_m
    prods = np.cumprod(1 - p[:-1])
    base = 1 + prods[:-1].sum() if m >= 1 else 1.0
    rem = n / k - base
    tail = prods[-1] if m >= 1 else 1.0
    if rem <= 0 or tail / rem > 1 or tail / rem <= 0:
        return  # infeasible draw
    p[-1] = tail / rem
    ex, _, var = lm.markov_moments(p)
    if not math.isclose(ex, n / k, rel_tol=1e-6):
        return
    assert var >= lm.optimal_var(n, k, m) - 1e-6


def test_theorem1_both_regimes():
    """Theorem 1 closed forms for m=1, k <= n/2 and k >= n/2."""
    for n, k in [(100, 15), (100, 30), (100, 50), (100, 70), (10, 9)]:
        p, v = lm.theorem1_optimal(n, k)
        assert lm.selection_rate(p) == pytest.approx(k / n, rel=1e-9)
        assert lm.markov_var(p) == pytest.approx(v, abs=1e-9)
        # matches Theorem 2 at m=1
        assert v == pytest.approx(lm.optimal_var(n, k, 1), abs=1e-9)
        # Theorem 1 variance formula itself
        assert lm.theorem1_var(n, k, p[0], p[1]) == pytest.approx(v, abs=1e-9)


def test_paper_headline_numbers():
    """The paper's simulation setting: n=100, k=15, m=10."""
    n, k, m = 100, 15, 10
    p = lm.optimal_probs(n, k, m)
    # m >= floor(n/k)=6: p* = [0,0,0,0,0, 1/3, 1,1,1,1,1]
    assert p[:5] == pytest.approx(np.zeros(5))
    assert p[5] == pytest.approx(1 / 3, abs=1e-9)
    assert p[6:] == pytest.approx(np.ones(5))
    c = 100 / 15 - 6
    assert lm.optimal_var(n, k, m) == pytest.approx(c * (1 - c), abs=1e-12)
    assert lm.random_selection_var(n, k) == pytest.approx(100 * 85 / 225)


def test_integer_ratio_gives_zero_variance():
    """When k | n and m >= n/k the optimal policy is deterministic."""
    assert lm.optimal_var(100, 20, 10) == pytest.approx(0.0, abs=1e-12)
    p = lm.optimal_probs(100, 20, 10)
    assert lm.markov_var(p) == pytest.approx(0.0, abs=1e-9)
