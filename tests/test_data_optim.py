"""Data pipeline (synthetic datasets, partitioners) and optimizers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.data import (
    label_histograms,
    load_dataset,
    make_token_stream,
    partition_dirichlet,
    partition_iid,
)
from repro.optim import adamw, sgd
from repro.optim.schedules import exponential_decay, warmup_cosine


def test_image_dataset_shapes():
    train, test = load_dataset("mnist", scale=0.1)
    assert train.images.shape == (1200, 28, 28, 1)
    assert test.labels.shape == (200,)
    assert train.images.dtype == np.float32
    assert set(np.unique(train.labels)) <= set(range(10))


def test_dataset_is_learnable_but_not_trivial():
    """A linear probe gets above chance but below ~90% (CNN has headroom)."""
    train, test = load_dataset("mnist", scale=0.2)
    x = train.images.reshape(len(train.labels), -1)
    y = train.labels
    # one ridge-regression step as a linear probe
    xtx = x.T @ x + 10.0 * np.eye(x.shape[1])
    onehot = np.eye(10)[y]
    w = np.linalg.solve(xtx, x.T @ onehot)
    xt = test.images.reshape(len(test.labels), -1)
    acc = (np.argmax(xt @ w, 1) == test.labels).mean()
    assert 0.2 < acc < 0.95


@given(n=st.integers(2, 50), total=st.integers(100, 2000))
@settings(max_examples=30, deadline=None)
def test_partition_iid_equal_disjoint(n, total):
    parts = partition_iid(total, n)
    assert parts.shape[0] == n
    flat = parts.reshape(-1)
    assert len(np.unique(flat)) == len(flat)  # disjoint


def test_partition_dirichlet_skewed_but_equal_size():
    labels = np.random.default_rng(0).integers(0, 10, 5000)
    parts = partition_dirichlet(labels, 20, alpha=0.6, seed=0)
    assert parts.shape == (20, 250)
    flat = parts.reshape(-1)
    assert len(np.unique(flat)) == len(flat)
    hist = label_histograms(labels, parts, 10)
    # non-IID: per-client label distributions differ strongly from uniform
    frac = hist / hist.sum(1, keepdims=True)
    tv = np.abs(frac - 0.1).sum(1).mean() / 2
    assert tv > 0.25
    # IID baseline is much flatter
    parts_iid = partition_iid(5000, 20)
    frac_iid = label_histograms(labels, parts_iid, 10)
    frac_iid = frac_iid / frac_iid.sum(1, keepdims=True)
    tv_iid = np.abs(frac_iid - 0.1).sum(1).mean() / 2
    assert tv_iid < 0.1


def test_token_stream_zipf_and_structure():
    toks = make_token_stream(1000, 20_000, seed=0)
    assert toks.min() >= 0 and toks.max() < 1000
    counts = np.bincount(toks, minlength=1000)
    assert counts[np.argsort(-counts)[:10]].sum() > 0.2 * len(toks)  # heavy head


def _quad_loss(params):
    return jnp.sum((params["w"] - 3.0) ** 2)


@pytest.mark.parametrize("opt", [sgd(), sgd(momentum=0.9), adamw()])
def test_optimizers_converge_quadratic(opt):
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    lr = 0.1
    for _ in range(200):
        g = jax.grad(_quad_loss)(params)
        params, state = opt.update(params, g, state, lr)
    assert float(_quad_loss(params)) < 1e-3


def test_exponential_decay_matches_paper():
    f = exponential_decay(0.1, 0.998)
    assert float(f(jnp.asarray(0))) == pytest.approx(0.1)
    assert float(f(jnp.asarray(100))) == pytest.approx(0.1 * 0.998**100, rel=1e-5)


def test_warmup_cosine_monotone_warmup():
    f = warmup_cosine(1.0, 10, 100)
    vals = [float(f(jnp.asarray(i))) for i in range(12)]
    assert vals[0] < vals[5] < vals[9]
    assert float(f(jnp.asarray(99))) < 0.01
