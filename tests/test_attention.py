"""Attention unit tests: masks, GQA grouping, MLA absorption identity,
ring caches, flash-vs-direct equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AttentionSpec
from repro.models import attention as A
from repro.models.common import rope_frequencies

KEY = jax.random.PRNGKey(0)


def _spec(**kw):
    base = dict(num_heads=4, num_kv_heads=2, head_dim=32)
    base.update(kw)
    return AttentionSpec(**base)


def test_pair_mask_causal_window_chunk():
    qp = jnp.arange(8)
    m = A._pair_mask(_spec(), qp, qp)
    assert bool(m[3, 3]) and bool(m[5, 2]) and not bool(m[2, 5])
    ms = A._pair_mask(_spec(kind="sliding", window=3), qp, qp)
    assert bool(ms[5, 3]) and not bool(ms[5, 2])
    mc = A._pair_mask(_spec(kind="chunked", window=4), qp, qp)
    assert bool(mc[5, 4]) and not bool(mc[5, 3])  # chunk boundary at 4


@pytest.mark.parametrize("kind,window", [("full", 0), ("sliding", 5), ("chunked", 4)])
def test_flash_jnp_equals_direct(kind, window):
    spec = _spec(kind=kind, window=window)
    B, Hk, G, S, D = 1, 2, 2, 256, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hk, G, S, D))
    k = jax.random.normal(ks[1], (B, Hk, S, D))
    v = jax.random.normal(ks[2], (B, Hk, S, D))
    pos = jnp.arange(S)
    direct = A._attend_direct(
        q, k, v, A._pair_mask(spec, pos, pos)[None, None, None], 0.2
    )
    # force the blocked path with small blocks
    old_q, old_k = A.BLOCK_Q, A.BLOCK_K
    A.BLOCK_Q, A.BLOCK_K = 64, 64
    try:
        flash = A._attend_flash_jnp(q, k, v, spec, pos, pos, 0.2)
    finally:
        A.BLOCK_Q, A.BLOCK_K = old_q, old_k
    np.testing.assert_allclose(np.asarray(direct), np.asarray(flash), atol=2e-5, rtol=2e-5)


def test_gqa_equals_repeated_mha():
    """GQA grouped computation == kv repeated to full MHA."""
    spec = _spec()
    d_model = 64
    p = A.init_attention(KEY, d_model, spec, jnp.float32)
    x = jax.random.normal(KEY, (2, 16, d_model))
    pos = jnp.arange(16)
    inv, rot = rope_frequencies(spec.head_dim, 10_000.0)
    table = A.RopeTable(inv, rot)
    out = A.attention_fwd(p, x, spec, table, pos)
    # same weights, MHA with repeated kv
    spec_mha = dataclasses.replace(spec, num_kv_heads=spec.num_heads)
    p2 = dict(p)
    p2["w_k"] = jnp.repeat(p["w_k"], 2, axis=1)
    p2["w_v"] = jnp.repeat(p["w_v"], 2, axis=1)
    out2 = A.attention_fwd(p2, x, spec_mha, table, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=2e-5, rtol=2e-5)


def test_ring_cache_slot_positions():
    spec = _spec(kind="sliding", window=4)
    L = 4
    pos = A._slot_positions(spec, L, jnp.asarray(6))
    # slots hold the newest position == slot (mod 4), <= 6 (being written)
    assert pos.tolist() == [4, 5, 6, 3]
    valid = A._slot_valid(spec, pos, jnp.asarray(6))
    assert valid.tolist() == [True, True, True, True]  # all within window 4
    spec_c = _spec(kind="chunked", window=4)
    valid_c = A._slot_valid(spec_c, pos, jnp.asarray(6))
    # chunk of 6 is [4..7]: position 3 invalid
    assert valid_c.tolist() == [True, True, True, False]


def test_decode_matches_fwd_full():
    """Cached decode over a sequence == full forward last-token logits."""
    spec = _spec()
    d_model = 64
    p = A.init_attention(KEY, d_model, spec, jnp.float32)
    S = 12
    x = jax.random.normal(KEY, (1, S, d_model))
    pos = jnp.arange(S)
    inv, rot = rope_frequencies(spec.head_dim, 10_000.0)
    table = A.RopeTable(inv, rot)
    full = A.attention_fwd(p, x, spec, table, pos)
    cache = A.init_cache(spec, 1, S, jnp.float32)
    outs = []
    for t in range(S):
        y, cache = A.attention_decode(p, x[:, t : t + 1], spec, table, cache)
        outs.append(y)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs, 1)), np.asarray(full), atol=1e-4, rtol=1e-4
    )


def test_mla_absorption_identity():
    """Absorbed MLA decode == naive decompression decode (bit-for-bit math)."""
    spec = AttentionSpec(
        num_heads=4, num_kv_heads=4, head_dim=32, kv_lora=16, q_lora=24, rope_dim=8
    )
    d_model = 64
    p = A.init_attention(KEY, d_model, spec, jnp.float32)
    inv, rot = rope_frequencies(spec.rope_dim, 10_000.0)
    table = A.RopeTable(inv, rot)
    cache1 = A.init_cache(spec, 1, 8, jnp.float32)
    cache2 = A.init_cache(spec, 1, 8, jnp.float32)
    for t in range(8):
        x = jax.random.normal(jax.random.fold_in(KEY, t), (1, 1, d_model))
        y1, cache1 = A._mla_decode(p, x, spec, table, cache1, absorb=True)
        y2, cache2 = A._mla_decode(p, x, spec, table, cache2, absorb=False)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-4)


def test_mla_decode_matches_fwd():
    spec = AttentionSpec(
        num_heads=4, num_kv_heads=4, head_dim=32, kv_lora=16, rope_dim=8
    )
    d_model = 64
    p = A.init_attention(KEY, d_model, spec, jnp.float32)
    inv, rot = rope_frequencies(spec.rope_dim, 10_000.0)
    table = A.RopeTable(inv, rot)
    S = 8
    x = jax.random.normal(KEY, (1, S, d_model))
    full = A._mla_fwd(p, x, spec, table, jnp.arange(S))
    cache = A.init_cache(spec, 1, S, jnp.float32)
    outs = []
    for t in range(S):
        y, cache = A._mla_decode(p, x[:, t : t + 1], spec, table, cache, absorb=True)
        outs.append(y)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs, 1)), np.asarray(full), atol=1e-4, rtol=1e-4
    )
