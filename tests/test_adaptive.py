"""Dropout-robust adaptive policy (Remark 1 / Conclusion extension)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import load_metric as lm
from repro.core.adaptive import (
    dropout_update_probability,
    floored_probs,
    tradeoff_curve,
)


@given(
    nk=st.tuples(st.integers(5, 150), st.integers(1, 149)).filter(lambda t: t[1] < t[0]),
    eps=st.floats(0.0, 1.0),
)
@settings(max_examples=80, deadline=None)
def test_rate_constraint_preserved(nk, eps):
    """The blend keeps the paper's fairness constraint (8): rate == k/n."""
    n, k = nk
    m = max(2 * (n // k), 2)
    p = floored_probs(n, k, m, eps)
    # feasible unless the floor family can't reach the rate (extreme eps
    # with k/n tiny); tolerate small deviation at the clip boundary
    assert lm.selection_rate(p) == pytest.approx(k / n, rel=0.02)


def test_eps_zero_is_optimal():
    p = floored_probs(100, 15, 10, 0.0)
    np.testing.assert_allclose(p[:-1], lm.optimal_probs(100, 15, 10)[:-1], atol=1e-9)
    assert lm.markov_var(p) == pytest.approx(lm.optimal_var(100, 15, 10), abs=1e-6)


def test_variance_monotone_in_eps():
    """More floor -> less age-determinism -> higher Var[X]."""
    eps, var, _ = tradeoff_curve(100, 15, 10, d=0.01, eps_grid=np.linspace(0, 1, 6))
    assert all(b >= a - 1e-6 for a, b in zip(var, var[1:]))
    # endpoints: optimal ... close to geometric
    assert var[0] == pytest.approx(lm.optimal_var(100, 15, 10), abs=1e-6)
    assert var[-1] > 10  # near random-selection variance (37.8)


def test_dropout_update_probability_monotone():
    """The floor increases the chance of an update before dropout — the
    quantitative version of Remark 1's argument."""
    n, k, m, d = 100, 15, 10, 0.05
    p_opt = floored_probs(n, k, m, 0.0)
    p_flr = floored_probs(n, k, m, 0.5)
    assert dropout_update_probability(p_flr, d) > dropout_update_probability(p_opt, d)


def test_dropout_probability_closed_form_vs_simulation():
    rng = np.random.default_rng(0)
    n, k, m, d = 100, 15, 10, 0.08
    p = floored_probs(n, k, m, 0.3)
    # simulate fresh clients until dropout
    wins = 0
    trials = 4000
    for _ in range(trials):
        state = 0
        while True:
            if rng.random() < d:
                break
            if rng.random() < p[state]:
                wins += 1
                break
            state = min(state + 1, m)
    est = wins / trials
    assert dropout_update_probability(p, d) == pytest.approx(est, abs=0.025)


def test_no_dropout_always_updates():
    p = floored_probs(50, 10, 8, 0.2)
    assert dropout_update_probability(p, 0.0) == pytest.approx(1.0, abs=1e-6)
