"""FL integration: end-to-end FedAvg rounds with each policy, aggregation
semantics, empirical load stats, checkpoint round-trip of server state."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cnn import MNIST_CNN
from repro.core import load_metric, make_policy
from repro.data.synthetic import make_image_dataset
from repro.fl import FLConfig, make_cnn_task, make_lm_task, run_training
from repro.fl.server import broadcast_to_cohort, cohort_indices, fedavg_aggregate


import dataclasses

SMALL_CNN = dataclasses.replace(
    MNIST_CNN, name="paper-cnn-mnist-small", image_size=16, conv_channels=(8, 16),
    fc_width=64,
)


@pytest.fixture(scope="module")
def small_task():
    train, test = make_image_dataset(
        "mnist-small", 10, 16, 1, 600, 500, seed=0, difficulty=0.8
    )
    return make_cnn_task(SMALL_CNN, train, test, n_clients=20)


def _fl(policy, rounds=8, **kw):
    base = dict(
        n_clients=20, k=4, m=6, policy=policy, rounds=rounds,
        local_epochs=2, batch_size=10, eval_every=rounds,
    )
    base.update(kw)
    return FLConfig(**base)


def test_cohort_indices_padding():
    sel = jnp.array([False, True, False, True, True, False])
    idx, w = cohort_indices(sel, 5)
    assert idx.shape == (5,)
    assert w.sum() == 3
    assert set(np.asarray(idx)[np.asarray(w) > 0].tolist()) == {1, 3, 4}


def test_fedavg_aggregate_weighted_mean():
    g = {"w": jnp.zeros((3,))}
    cohort = {"w": jnp.stack([jnp.ones(3), 3 * jnp.ones(3), 100 * jnp.ones(3)])}
    out = fedavg_aggregate(g, cohort, jnp.array([1.0, 1.0, 0.0]))
    np.testing.assert_allclose(np.asarray(out["w"]), 2.0 * np.ones(3))


def test_fedavg_aggregate_empty_cohort_keeps_params():
    g = {"w": 7 * jnp.ones((3,))}
    cohort = {"w": jnp.zeros((2, 3))}
    out = fedavg_aggregate(g, cohort, jnp.zeros(2))
    np.testing.assert_allclose(np.asarray(out["w"]), 7.0)


def test_fedavg_aggregate_kernel_path_matches():
    key = jax.random.PRNGKey(0)
    g = {"w": jnp.zeros((4, 5))}
    cohort = {"w": jax.random.normal(key, (3, 4, 5))}
    w = jnp.array([1.0, 1.0, 1.0])
    a = fedavg_aggregate(g, cohort, w, use_kernel=False)
    b = fedavg_aggregate(g, cohort, w, use_kernel=True)
    np.testing.assert_allclose(np.asarray(a["w"]), np.asarray(b["w"]), atol=1e-6)


@pytest.mark.parametrize("policy", ["random", "markov"])
def test_training_improves_accuracy(small_task, policy):
    out = run_training(small_task, _fl(policy))
    accs = out["history"]["accuracy"]
    assert accs[-1] > 0.2  # 10-class synthetic after 8 rounds
    assert np.isfinite(out["history"]["train_loss"]).all()


@pytest.mark.parametrize("policy", ["oldest_age", "round_robin"])
def test_other_policies_run(small_task, policy):
    out = run_training(small_task, _fl(policy, rounds=3))
    assert np.isfinite(out["history"]["train_loss"]).all()


def test_markov_load_stats_in_training(small_task):
    out = run_training(small_task, _fl("markov", rounds=60, local_epochs=1))
    stats = out["load_stats"]
    # n/k = 5 exactly and m=6 >= 5: the optimal policy is deterministic —
    # every client selected every 5th round, Var*[X] = 0 (Theorem 2)
    v_opt = load_metric.optimal_var(20, 4, 6)
    assert v_opt == pytest.approx(0.0, abs=1e-12)
    assert stats["mean_X"] == pytest.approx(5.0, rel=0.05)
    assert stats["var_X"] == pytest.approx(0.0, abs=0.3)


def test_lm_task_federated():
    """A reduced assigned architecture as the federated workload."""
    from repro.configs import get_arch

    cfg = get_arch("tinyllama-1.1b").reduced()
    task = make_lm_task(cfg, n_clients=8, seq_len=32, docs_per_client=4)
    fl = FLConfig(n_clients=8, k=2, m=4, policy="markov", rounds=3,
                  local_epochs=1, batch_size=2, lr0=0.05, eval_every=3)
    out = run_training(task, fl)
    assert np.isfinite(out["history"]["eval_loss"]).all()


def test_server_state_checkpoint_roundtrip(tmp_path, small_task):
    from repro.checkpoint import load_checkpoint, save_checkpoint

    pol = make_policy("markov", 20, 4, 6)
    key = jax.random.PRNGKey(0)
    params = small_task.init(key)
    sched = pol.init(key, 20)
    state = {"params": params, "sched": sched}
    save_checkpoint(str(tmp_path / "ckpt"), state, step=17)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored, step = load_checkpoint(str(tmp_path / "ckpt"), like)
    assert step == 17
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
