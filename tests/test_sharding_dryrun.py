"""Distribution tests: sharding rules produce valid specs, and a reduced
arch lowers+compiles on a multi-device (forced host device) mesh with the
production rules — run in a subprocess because device count is fixed at
first jax init and the rest of the suite must see 1 device."""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import all_archs
from repro.models import factory
from repro import sharding as sr

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_param_pspecs_cover_all_leaves():
    from jax.sharding import PartitionSpec as P

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    for name in ("tinyllama-1.1b", "deepseek-v2-236b", "jamba-v0.1-52b", "whisper-tiny"):
        cfg = all_archs()[name]  # FULL config: specs only, no allocation
        model = factory.build(cfg)
        p_sds = jax.eval_shape(lambda m=model: m.init(jax.random.PRNGKey(0)))
        specs = sr.params_pspecs(p_sds, FakeMesh())
        leaves_p = jax.tree_util.tree_leaves(p_sds)
        leaves_s = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(leaves_p) == len(leaves_s)
        # every sharded axis divides
        for leaf, spec in zip(leaves_p, leaves_s):
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is None:
                    continue
                size = 16 if not isinstance(ax, tuple) else 16 ** len(ax)
                assert dim % size == 0, (name, leaf.shape, spec)


def test_big_params_actually_sharded():
    """Anything > 8M params must shard on at least one axis (fits HBM)."""
    from jax.sharding import PartitionSpec as P

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    cfg = all_archs()["llama4-maverick-400b-a17b"]
    model = factory.build(cfg)
    p_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    specs = sr.params_pspecs(p_sds, FakeMesh())
    for leaf, spec in zip(
        jax.tree_util.tree_leaves(p_sds),
        jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P)),
    ):
        if leaf.size > 8_000_000:
            assert any(ax is not None for ax in tuple(spec)), (leaf.shape, spec)


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax, jax.numpy as jnp, json
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_arch, INPUT_SHAPES
from repro.models import factory, pshard
from repro import sharding as sr
import dataclasses

mesh = jax.make_mesh((4, 4), ("data", "model"))
cfg = get_arch("jamba-v0.1-52b").reduced()
cfg = dataclasses.replace(cfg, d_model=256, vocab_size=512)
model = factory.build(cfg)
shape = dataclasses.replace(INPUT_SHAPES["train_4k"], seq_len=128, global_batch=8)
specs = factory.input_specs(cfg, shape)
p_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t, is_leaf=lambda x: isinstance(x, P))
j = jax.jit(model.sgd_train_step,
            in_shardings=(named(sr.params_pspecs(p_sds, mesh)), named(sr.batch_pspecs(specs, mesh)), None),
            out_shardings=(named(sr.params_pspecs(p_sds, mesh)), None))
with mesh, pshard.mesh_context(mesh):
    compiled = j.lower(p_sds, specs, jax.ShapeDtypeStruct((), jnp.float32)).compile()
text = compiled.as_text()
has_coll = any(k in text for k in ("all-reduce", "all-gather", "reduce-scatter"))
# ALSO actually execute on the 16 fake devices with real values
params = jax.device_put(model.init(jax.random.PRNGKey(0)), named(sr.params_pspecs(p_sds, mesh)))
batch = factory.synth_batch(jax.random.PRNGKey(1), cfg, 8, 128)
with mesh, pshard.mesh_context(mesh):
    new_params, metrics = j(params, batch, jnp.asarray(0.01, jnp.float32))
loss = float(metrics["total_loss"])
print(json.dumps({"ok": True, "has_collectives": has_coll, "loss": loss}))
"""


def test_sharded_train_step_16_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC], capture_output=True, text=True, env=env,
        timeout=540,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok"] and res["has_collectives"]
    assert np.isfinite(res["loss"])
