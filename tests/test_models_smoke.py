"""Per-arch smoke tests: a REDUCED variant of each assigned architecture
(2 layers, d_model<=512, <=4 experts) runs one forward/train step and one
cached decode step on CPU; output shapes and finiteness asserted."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs
from repro.models import factory

ARCHS = sorted(all_archs())
KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def built():
    out = {}
    for name in ARCHS:
        cfg = all_archs()[name].reduced()
        model = factory.build(cfg)
        out[name] = (cfg, model, model.init(KEY))
    return out


@pytest.mark.parametrize("name", ARCHS)
def test_reduced_constraints(name):
    cfg = all_archs()[name].reduced()
    assert cfg.num_layers <= 4
    assert cfg.d_model <= 512
    for spec in cfg.all_layers():
        if spec.mlp.kind == "moe":
            assert spec.mlp.moe.num_experts <= 4


@pytest.mark.parametrize("name", ARCHS)
def test_train_step(name, built):
    cfg, model, params = built[name]
    batch = factory.synth_batch(KEY, cfg, 2, 64)
    new_params, metrics = jax.jit(model.sgd_train_step)(params, batch, 0.05)
    loss = float(metrics["total_loss"])
    assert np.isfinite(loss) and 0.0 < loss < 20.0
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()), params, new_params),
    )
    assert delta > 0


@pytest.mark.parametrize("name", ARCHS)
def test_loss_decreases_over_steps(name, built):
    cfg, model, params = built[name]
    batch = factory.synth_batch(KEY, cfg, 2, 64)
    step = jax.jit(model.sgd_train_step)
    losses = []
    for _ in range(5):
        params, metrics = step(params, batch, 0.1)
        losses.append(float(metrics["total_loss"]))
    assert losses[-1] < losses[0]  # can fit a repeated batch


@pytest.mark.parametrize("name", ARCHS)
def test_decode_step(name, built):
    cfg, model, params = built[name]
    caches = model.init_decode_caches(2, 32)
    if cfg.encoder is not None:
        from repro.models import encdec

        frames = jax.random.normal(KEY, (2, cfg.encoder.source_len, cfg.d_model))
        mem = encdec.encode(params, cfg, frames)
        ck, cv = encdec.precompute_cross(params, cfg, mem)
        caches = {**caches, "cross_k": ck, "cross_v": cv}
    tok = jnp.zeros((2, 1), jnp.int32)
    step = jax.jit(model.decode_step)
    logits, caches = step(params, caches, tok)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # second step advances the cache index
    logits2, caches2 = step(params, caches, tok)
    idx = jax.tree_util.tree_leaves(
        jax.tree.map(lambda x: x, caches2), is_leaf=lambda x: hasattr(x, "shape")
    )
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


def test_prefill_decode_consistency_dense():
    """Dense arch: prefill logits == step-by-step decode logits."""
    cfg = all_archs()["tinyllama-1.1b"].reduced()
    model = factory.build(cfg)
    params = model.init(KEY)
    S = 16
    toks = jax.random.randint(KEY, (1, S), 0, cfg.vocab_size)
    logits_p, _ = jax.jit(model.prefill)(params, {"tokens": toks})
    caches = model.init_decode_caches(1, S)
    step = jax.jit(model.decode_step)
    for t in range(S):
        lg, caches = step(params, caches, toks[:, t : t + 1])
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(lg), atol=3e-4, rtol=3e-4
    )


def test_sliding_window_cache_is_bounded():
    cfg = all_archs()["gemma3-27b"].reduced()
    model = factory.build(cfg)
    caches = model.init_decode_caches(1, 4096)
    sizes = [x.shape for x in jax.tree.leaves(caches["blocks"][0]) if hasattr(x, "shape")]
    # sliding layer cache length must be bounded by the (reduced) window
    lens = [s[2] for s in sizes if len(s) >= 3]
    assert min(lens) <= 32  # reduced window


def test_param_counts_match_analytic():
    """init() parameter count ~= ArchConfig.param_count() (5%)."""
    for name in ("tinyllama-1.1b", "mamba2-370m", "deepseek-v2-236b"):
        cfg = all_archs()[name].reduced()
        model = factory.build(cfg)
        params = model.init(KEY)
        actual = sum(x.size for x in jax.tree.leaves(params))
        expect = cfg.param_count()
        assert abs(actual - expect) / expect < 0.08, (name, actual, expect)
