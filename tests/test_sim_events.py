"""Event engine: determinism, Pallas event_topk vs jnp reference
(interpret mode on CPU), latency-model properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.sim import events as ev_mod
from repro.sim import latency as lat_mod

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# latency models
# ---------------------------------------------------------------------------


def test_uniform_profile_is_degenerate():
    p = lat_mod.get_profile("uniform")
    speed = lat_mod.client_speed(KEY, 64, p)
    lat = lat_mod.sample_latency(jax.random.fold_in(KEY, 1), p, speed)
    np.testing.assert_allclose(np.asarray(lat), 1.0)
    assert not bool(lat_mod.sample_dropout(KEY, p, 64).any())
    np.testing.assert_allclose(np.asarray(lat_mod.sample_avail_gap(KEY, p, 64)), 0.0)


def test_latency_samples_positive_and_shaped():
    for name in ("datacenter", "lognormal", "mobile"):
        p = lat_mod.get_profile(name)
        speed = lat_mod.client_speed(KEY, 128, p)
        lat = lat_mod.sample_latency(jax.random.fold_in(KEY, 2), p, speed)
        assert lat.shape == (128,)
        assert bool((lat > 0).all())
        # spread profiles actually spread
        assert float(lat.std()) > 0.0


def test_dropout_rate_matches_hazard():
    p = lat_mod.get_profile("mobile")
    drops = lat_mod.sample_dropout(KEY, p, 20000)
    assert abs(float(drops.mean()) - p.dropout) < 0.02


def test_unknown_profile_raises():
    with pytest.raises(ValueError):
        lat_mod.get_profile("nope")


def test_mean_latency_closed_form():
    p = lat_mod.get_profile("lognormal")
    speed = lat_mod.client_speed(KEY, 200_000, p)
    lat = lat_mod.sample_latency(jax.random.fold_in(KEY, 3), p, speed)
    assert abs(float(lat.mean()) - p.mean_latency()) / p.mean_latency() < 0.05


# ---------------------------------------------------------------------------
# next-k extraction: jnp reference vs Pallas kernel (interpret on CPU)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,k,block_n,pending_frac", [
    (64, 4, 16, 1.0),
    (1000, 16, 128, 0.3),
    (1000, 16, 256, 0.01),  # fewer pending events than k in most tiles
    (513, 8, 128, 0.5),  # ragged final tile
])
def test_event_topk_kernel_matches_reference(n, k, block_n, pending_frac):
    kx, km = jax.random.split(jax.random.fold_in(KEY, n * k))
    t = jax.random.uniform(kx, (n,)) * 100
    pending = jax.random.uniform(km, (n,)) < pending_frac
    times = jnp.where(pending, t, jnp.inf).astype(jnp.float32)
    ref_v, ref_i = ev_mod.next_k_events(times, k, use_kernel=False)
    ker_v, ker_i = ops.event_next_k(times, k, block_n=block_n)
    np.testing.assert_allclose(np.asarray(ker_v), np.asarray(ref_v), rtol=1e-6)
    valid = np.isfinite(np.asarray(ref_v))
    # indices must agree wherever a real event exists
    np.testing.assert_array_equal(np.asarray(ker_i)[valid], np.asarray(ref_i)[valid])


def test_next_k_ties_break_low_index():
    times = jnp.full((10,), 5.0, jnp.float32)
    for use_kernel in (False, True):
        _, idx = ev_mod.next_k_events(times, 3, use_kernel=use_kernel)
        np.testing.assert_array_equal(np.asarray(idx), [0, 1, 2])


def test_next_k_all_idle_returns_inf():
    times = jnp.full((32,), jnp.inf, jnp.float32)
    v, _ = ev_mod.next_k_events(times, 4, use_kernel=False)
    assert not np.isfinite(np.asarray(v)).any()


# ---------------------------------------------------------------------------
# engine semantics
# ---------------------------------------------------------------------------


def test_pop_removes_events_and_is_deterministic():
    n, k = 50, 8

    def run():
        ev = ev_mod.init_event_state(n)
        lat = lat_mod.sample_latency(
            KEY, lat_mod.get_profile("lognormal"),
            lat_mod.client_speed(jax.random.fold_in(KEY, 9), n,
                                 lat_mod.get_profile("lognormal")),
        )
        send = jnp.arange(n) % 2 == 0
        ev = ev_mod.schedule_completions(
            ev, send, jnp.float32(0.0), lat, jnp.int32(0),
            jnp.zeros((n,), jnp.bool_),
        )
        pops = []
        for _ in range(3):
            t, idx, valid, ev = ev_mod.pop_events(ev, k)
            pops.append((np.asarray(t), np.asarray(idx), np.asarray(valid)))
        return pops, np.asarray(ev["t_done"])

    pops_a, tdone_a = run()
    pops_b, tdone_b = run()
    for (ta, ia, va), (tb, ib, vb) in zip(pops_a, pops_b):
        np.testing.assert_array_equal(ta, tb)
        np.testing.assert_array_equal(ia, ib)
        np.testing.assert_array_equal(va, vb)
    np.testing.assert_array_equal(tdone_a, tdone_b)
    # 25 dispatched, popped 8+8+8=24 valid, never the same client twice
    all_idx = np.concatenate([i[v] for _, i, v in pops_a])
    assert len(all_idx) == len(set(all_idx.tolist())) == 24
    # popped clients are idle again
    assert np.isinf(tdone_a[all_idx]).all()
    # pops arrive in nondecreasing time order across batches
    all_t = np.concatenate([t[v] for t, _, v in pops_a])
    assert (np.diff(all_t) >= -1e-6).all()


def test_pop_kernel_path_fewer_events_than_k():
    """Exhausted kernel tiles emit duplicate real indices for their +inf
    filler slots; the scatter back must drop them — the popped event must
    stay cleared, not be resurrected by a stale duplicate write."""
    n = 8
    ev = ev_mod.init_event_state(n)
    ev = ev_mod.schedule_completions(
        ev, jnp.arange(n) == 0, jnp.float32(0.0),
        jnp.full((n,), 2.0, jnp.float32), jnp.int32(0),
        jnp.zeros((n,), jnp.bool_),
    )
    t, idx, valid, ev2 = ev_mod.pop_events(ev, 4, use_kernel=True)
    assert int(valid.sum()) == 1
    assert float(t[0]) == pytest.approx(2.0) and int(idx[0]) == 0
    assert np.isinf(np.asarray(ev2["t_done"])).all()
    _, _, valid2, _ = ev_mod.pop_events(ev2, 4, use_kernel=True)
    assert not bool(valid2.any())


def test_pop_invalid_slots_are_noops():
    ev = ev_mod.init_event_state(16)
    ev = ev_mod.schedule_completions(
        ev, jnp.arange(16) == 3, jnp.float32(1.0),
        jnp.full((16,), 2.0, jnp.float32), jnp.int32(0),
        jnp.zeros((16,), jnp.bool_),
    )
    t, idx, valid, ev2 = ev_mod.pop_events(ev, 4)
    assert int(valid.sum()) == 1
    assert float(t[0]) == pytest.approx(3.0)
    assert np.isinf(np.asarray(ev2["t_done"])).all()
