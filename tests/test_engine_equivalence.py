"""Golden seed-equivalence: the unified engines must reproduce the
pre-refactor ``run_training`` / ``run_async_training`` loops bit-for-bit.

The reference implementations below are frozen copies of the round loops
as they stood before the ``repro.engine`` refactor (PR 1), with the
aggregation math inlined exactly as it was hardwired then. If the engines
or the fedavg/fedbuff aggregators drift numerically — different op order,
dtype, or key schedule — these tests fail on exact comparison.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cnn import MNIST_CNN
from repro.core.selection import make_policy
from repro.data.synthetic import make_image_dataset
from repro.engine import AsyncEngine, RunConfig, SyncEngine, run_engine
from repro.fl import FLConfig, make_cnn_task, run_training
from repro.fl.client import make_local_update
from repro.fl.server import broadcast_to_cohort, cohort_indices, fedavg_aggregate
from repro.optim.schedules import exponential_decay
from repro.sim import AsyncConfig, run_async_training
from repro.sim import events as ev_mod
from repro.sim import latency as lat_mod
from repro.sim.async_rounds import staleness_weight

SMALL_CNN = dataclasses.replace(
    MNIST_CNN, name="paper-cnn-mnist-small", image_size=16,
    conv_channels=(8, 16), fc_width=64,
)


@pytest.fixture(scope="module")
def small_task():
    train, test = make_image_dataset(
        "mnist-small", 10, 16, 1, 600, 500, seed=0, difficulty=0.8
    )
    return make_cnn_task(SMALL_CNN, train, test, n_clients=20)


def _fl(policy, rounds=5, **kw):
    base = dict(
        n_clients=20, k=4, m=6, policy=policy, rounds=rounds,
        local_epochs=2, batch_size=10, eval_every=1,
    )
    base.update(kw)
    return FLConfig(**base)


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# Reference sync loop (pre-refactor fl/rounds.py, verbatim math)
# ---------------------------------------------------------------------------


def _reference_sync_run(task, fl):
    policy = make_policy(fl.policy, fl.n_clients, fl.k, fl.m)
    width = fl.cohort_width() if not policy.exact_k else fl.k
    local_update = make_local_update(
        task.loss_fn, fl.local_epochs, fl.batch_size, task.examples_per_client
    )
    lr_fn = exponential_decay(fl.lr0, fl.lr_decay)

    @jax.jit
    def round_fn(params, sched_state, key):
        k_sel, k_local = jax.random.split(key)
        selected, sched_state = policy.step(sched_state, k_sel)
        idx, weights = cohort_indices(selected, width)
        shards = jax.tree.map(lambda a: a[idx], task.client_data)
        lr = lr_fn(sched_state["round"] - 1)
        cohort_params = broadcast_to_cohort(params, width)
        keys = jax.random.split(k_local, width)
        updated, losses = jax.vmap(local_update, in_axes=(0, 0, 0, None))(
            cohort_params, shards, keys, lr
        )
        params = fedavg_aggregate(params, updated, weights)
        mean_loss = jnp.sum(losses * weights) / jnp.maximum(weights.sum(), 1.0)
        return params, sched_state, selected, mean_loss

    key = jax.random.PRNGKey(fl.seed)
    k_init, k_policy, k_run = jax.random.split(key, 3)
    params = task.init(k_init)
    sched_state = policy.init(k_policy, fl.n_clients)
    sel_hist = np.zeros((fl.rounds, fl.n_clients), dtype=bool)
    losses = []
    for r in range(fl.rounds):
        params, sched_state, selected, loss = round_fn(
            params, sched_state, jax.random.fold_in(k_run, r)
        )
        sel_hist[r] = np.asarray(selected)
        losses.append(float(loss))
    return params, sel_hist, losses


@pytest.mark.parametrize("policy", ["markov", "random"])
def test_sync_engine_matches_prerefactor_loop(small_task, policy):
    fl = _fl(policy)
    ref_params, ref_sel, ref_losses = _reference_sync_run(small_task, fl)
    out = run_training(small_task, fl)
    np.testing.assert_array_equal(out["selection"], ref_sel)
    np.testing.assert_array_equal(out["history"]["train_loss"], ref_losses)
    _assert_trees_equal(out["params"], ref_params)


def test_sync_engine_direct_api_matches_prerefactor_loop(small_task):
    fl = _fl("markov")
    ref_params, ref_sel, ref_losses = _reference_sync_run(small_task, fl)
    cfg = RunConfig(
        n_clients=fl.n_clients, k=fl.k, m=fl.m, policy=fl.policy,
        rounds=fl.rounds, local_epochs=fl.local_epochs,
        batch_size=fl.batch_size, eval_every=1,
    )
    res = run_engine(SyncEngine(small_task, cfg))
    np.testing.assert_array_equal(res.selection, ref_sel)
    np.testing.assert_array_equal(
        [r.train_loss for r in res.records], ref_losses
    )
    _assert_trees_equal(res.params, ref_params)


# ---------------------------------------------------------------------------
# Reference async loop (pre-refactor sim/async_rounds.py, verbatim math)
# ---------------------------------------------------------------------------


def _reference_async_run(task, fl, acfg):
    policy = make_policy(fl.policy, fl.n_clients, fl.k, fl.m)
    n = fl.n_clients
    B = acfg.buffer_size or fl.k
    H = acfg.max_versions
    profile = acfg.resolved_profile()
    local_update = make_local_update(
        task.loss_fn, fl.local_epochs, fl.batch_size, task.examples_per_client
    )
    lr_fn = exponential_decay(fl.lr0, fl.lr_decay)

    def init_state(params, sched_state, key):
        return {
            "params": params,
            "hist": jax.tree.map(
                lambda p: jnp.broadcast_to(p[None], (H,) + p.shape), params
            ),
            "sched": sched_state,
            "ev": ev_mod.init_event_state(n),
            "speed": lat_mod.client_speed(key, n, profile),
            "clock": jnp.zeros((), jnp.float32),
            "version": jnp.zeros((), jnp.int32),
        }

    @jax.jit
    def step(state, key):
        ev, sched = state["ev"], state["sched"]
        clock, version = state["clock"], state["version"]
        k_sel, k_local = jax.random.split(key)
        k_lat = jax.random.fold_in(k_sel, 101)
        k_drop = jax.random.fold_in(k_sel, 102)
        k_gap = jax.random.fold_in(k_sel, 103)

        from repro.core.aoi import age_update

        prev_ages = sched["ages"]
        idle = jnp.isinf(ev["t_done"])
        available = ev["next_avail"] <= clock
        want, sched = policy.step(sched, k_sel)
        send = want & idle & available
        sched = {**sched, "ages": age_update(prev_ages, send)}

        latency = lat_mod.sample_latency(k_lat, profile, state["speed"])
        dropped = lat_mod.sample_dropout(k_drop, profile, n)
        ev = ev_mod.schedule_completions(ev, send, clock, latency, version, dropped)

        t_ev, idx, valid, ev = ev_mod.pop_events(ev, B, use_kernel=acfg.use_kernel)
        new_clock = jnp.maximum(clock, jnp.max(jnp.where(valid, t_ev, -jnp.inf)))
        new_clock = jnp.where(
            valid.any(), new_clock,
            jnp.maximum(new_clock, jnp.min(ev["next_avail"])),
        )

        disp_ver = ev["disp_ver"][idx]
        read_ver = jnp.clip(disp_ver, jnp.maximum(version - (H - 1), 0), version)
        disp_params = jax.tree.map(lambda h: h[read_ver % H], state["hist"])
        shards = jax.tree.map(lambda a: a[idx], task.client_data)
        keys = jax.random.split(k_local, B)
        lr = lr_fn(jnp.maximum(disp_ver, 0))
        updated, losses = jax.vmap(local_update, in_axes=(0, 0, 0, 0))(
            disp_params, shards, keys, lr
        )

        succ = valid & ~ev["dropped"][idx]
        staleness = jnp.maximum(version - disp_ver, 0)
        w = succ.astype(jnp.float32) * staleness_weight(
            staleness, acfg.staleness_mode, acfg.staleness_exp
        )
        wsum = w.sum()
        has = wsum > 0
        denom = jnp.maximum(wsum, 1e-9)

        def agg(g, u, d):
            wshape = (-1,) + (1,) * (g.ndim)
            delta = (u - d).astype(jnp.float32)
            upd = g + (jnp.sum(delta * w.reshape(wshape), axis=0) / denom).astype(g.dtype)
            return jnp.where(has, upd, g)

        params = jax.tree.map(agg, state["params"], updated, disp_params)
        version = version + has.astype(jnp.int32)
        hist = jax.tree.map(
            lambda h, p: h.at[version % H].set(p), state["hist"], params
        )
        mean_loss = jnp.where(has, jnp.sum(losses * w) / denom, jnp.nan)

        gaps = lat_mod.sample_avail_gap(k_gap, profile, B)
        ev = {
            **ev,
            "next_avail": ev["next_avail"]
            .at[ev_mod.scatter_idx(idx, valid)]
            .set(new_clock + gaps, mode="drop"),
        }
        ev = {
            **ev,
            "last_done": ev["last_done"]
            .at[ev_mod.scatter_idx(idx, succ)]
            .set(t_ev, mode="drop"),
        }
        state = {
            **state,
            "params": params, "hist": hist, "sched": sched, "ev": ev,
            "clock": new_clock, "version": version,
        }
        return state, {"send": send, "loss": mean_loss}

    key = jax.random.PRNGKey(fl.seed)
    k_init, k_policy, k_run = jax.random.split(key, 3)
    params = task.init(k_init)
    sched = policy.init(k_policy, fl.n_clients)
    state = init_state(params, sched, jax.random.fold_in(k_run, 2**31))
    sel_hist = np.zeros((fl.rounds, fl.n_clients), dtype=bool)
    losses = []
    for s in range(fl.rounds):
        state, aux = step(state, jax.random.fold_in(k_run, s))
        sel_hist[s] = np.asarray(aux["send"])
        losses.append(float(aux["loss"]))
    return state["params"], sel_hist, losses


def test_async_engine_matches_prerefactor_loop(small_task):
    fl = _fl("markov")
    acfg = AsyncConfig(buffer_size=4, profile="lognormal")
    ref_params, ref_sel, ref_losses = _reference_async_run(small_task, fl, acfg)
    out = run_async_training(small_task, fl, acfg)
    np.testing.assert_array_equal(np.asarray(out["selection"]), ref_sel)
    np.testing.assert_array_equal(out["history"]["train_loss"], ref_losses)
    _assert_trees_equal(out["params"], ref_params)


def test_async_engine_with_dropout_matches_prerefactor_loop(small_task):
    fl = _fl("random", rounds=6)
    prof = dataclasses.replace(lat_mod.get_profile("mobile"), dropout=0.3)
    acfg = AsyncConfig(buffer_size=3, staleness_mode="poly",
                       staleness_exp=0.7, max_versions=4, profile=prof)
    ref_params, ref_sel, ref_losses = _reference_async_run(small_task, fl, acfg)
    cfg = RunConfig(
        n_clients=fl.n_clients, k=fl.k, m=fl.m, policy=fl.policy,
        rounds=fl.rounds, local_epochs=fl.local_epochs,
        batch_size=fl.batch_size, eval_every=1, mode="async",
        aggregator="fedbuff",
        aggregator_kwargs={"staleness_mode": "poly", "staleness_exp": 0.7},
        buffer_size=3, max_versions=4, profile=prof,
    )
    res = run_engine(AsyncEngine(small_task, cfg))
    np.testing.assert_array_equal(np.asarray(res.selection), ref_sel)
    np.testing.assert_array_equal(
        np.asarray([r.train_loss for r in res.records]),
        np.asarray(ref_losses),
    )
    _assert_trees_equal(res.params, ref_params)


# ---------------------------------------------------------------------------
# Zero-spread async == sync FedAvg through the new API
# ---------------------------------------------------------------------------


def test_degenerate_async_equals_sync_through_engine_api(small_task):
    base = RunConfig(
        n_clients=20, k=4, m=6, policy="random", rounds=5,
        local_epochs=2, batch_size=10, eval_every=1,
    )
    sync = run_engine(SyncEngine(small_task, base))
    acfg = dataclasses.replace(
        base, mode="async", buffer_size=base.k,
        aggregator_kwargs={"staleness_mode": "const"}, profile="uniform",
    )
    asy = run_engine(AsyncEngine(small_task, acfg))
    np.testing.assert_array_equal(sync.selection, asy.selection)
    np.testing.assert_allclose(
        [r.train_loss for r in sync.records],
        [r.train_loss for r in asy.records], rtol=1e-4,
    )
    np.testing.assert_allclose(
        [r.eval_loss for r in sync.records],
        [r.eval_loss for r in asy.records], rtol=1e-4,
    )
    assert asy.wall_stats["max_staleness"] == 0
    assert asy.wall_stats["aggregations"] == base.rounds
