"""Fault injection (``repro.faults``), robust aggregation, and graceful
degradation.

Pins the subsystem's core contracts:

  * registry errors (unknown / duplicate fault names) and RunConfig
    validation of fault flags;
  * faults-off is *structurally* identical (no extra state keys) and a
    rate-0 fault set is *bitwise* identity — per-step and chunked,
    async + sync + fleet-sharded;
  * injection counters surface in ``load_stats``; stragglers stretch the
    simulated clock; sync rejects wall-clock faults loudly;
  * robust aggregators match NumPy references (trimmed mean, coordinate
    median), ``norm_clip`` bounds a scaled attacker, and the
    non-additive ones are rejected by the psum/tier merge seams;
  * deadline re-dispatch is gated (no ``rd`` carry unless armed) and
    counts re-sends;
  * checkpoints round-trip typed PRNG keys, detect shard corruption and
    truncation, and a mid-run crash-restart of the full async carry
    (heartbeat + tier accumulators + fault state + AoI scheduler)
    resumes bit-for-bit;
  * ``hb_expired`` matches bitwise between the sharded and single-device
    engines across ragged fleet sizes (hypothesis).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cnn import MNIST_CNN
from repro.data.synthetic import make_image_dataset
from repro.engine import (
    AsyncEngine,
    RunConfig,
    ShardedAsyncEngine,
    SyncEngine,
    make_engine,
    run_engine,
)
from repro.engine.registry import make_aggregator
from repro.faults import (
    FaultSet,
    corrupt_updates,
    fault_names,
    identity_effects,
    known_fault_names,
    make_fault,
    merge_effects,
    register_fault,
)

SMALL_CNN = dataclasses.replace(
    MNIST_CNN, name="paper-cnn-mnist-faults", image_size=8,
    conv_channels=(4, 8), fc_width=32,
)

N = 16


@pytest.fixture(scope="module")
def small_task():
    from repro.fl import make_cnn_task

    train, test = make_image_dataset(
        "mnist-faults", 10, 8, 1, 120, 60, seed=0, difficulty=0.8
    )
    return make_cnn_task(SMALL_CNN, train, test, n_clients=N)


def _cfg(**kw):
    base = dict(
        n_clients=N, k=4, m=4, policy="markov", rounds=4, local_epochs=1,
        batch_size=5, eval_every=2, mode="async", buffer_size=3,
        profile="mobile",
    )
    base.update(kw)
    return RunConfig(**base)


def _raw(leaf):
    # typed PRNG key leaves (rng_impl="rbg" carries) have no np view;
    # compare their raw key data instead
    if hasattr(leaf, "dtype") and jax.dtypes.issubdtype(
        leaf.dtype, jax.dtypes.prng_key
    ):
        return np.asarray(jax.random.key_data(leaf))
    return np.asarray(leaf)


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(_raw(la), _raw(lb))


# ---------------------------------------------------------------------------
# (1) registry + config validation
# ---------------------------------------------------------------------------


def test_registry_rejects_unknown_and_duplicate():
    with pytest.raises(ValueError, match="unknown fault 'nope'.*registered"):
        make_fault("nope", N, 0.1)
    assert set(fault_names()) == set(known_fault_names())
    assert {"dropout", "straggler", "stale_replay", "corrupt", "sign_flip",
            "scale_attack", "replica_crash"} <= set(known_fault_names())
    register_fault("_test_dup")(lambda n, rate: None)
    with pytest.raises(ValueError, match="already registered"):
        register_fault("_test_dup")(lambda n, rate: None)


def test_config_validates_fault_flags():
    with pytest.raises(ValueError, match="unknown fault"):
        _cfg(faults=("nope",))
    with pytest.raises(ValueError, match="fault_rate"):
        _cfg(faults=("dropout",), fault_rate=1.5)
    with pytest.raises(ValueError, match="fault_kwargs"):
        _cfg(faults=("dropout",), fault_kwargs={"corrupt": {"sigma": 2.0}})
    with pytest.raises(ValueError, match="fault_kwargs"):
        _cfg(fault_kwargs={"dropout": {}})
    with pytest.raises(ValueError, match="redispatch"):
        _cfg(mode="sync", buffer_size=None, profile="lognormal",
             redispatch_timeout=5.0)
    with pytest.raises(ValueError, match="redispatch"):
        _cfg(redispatch_timeout=-1.0)
    # comma string and sequence forms agree
    assert _cfg(faults="dropout, corrupt").fault_names() == \
        _cfg(faults=("dropout", "corrupt")).fault_names()


def test_fault_set_rejects_serve_scope_and_duplicates():
    with pytest.raises(ValueError, match="serve"):
        FaultSet([make_fault("replica_crash", N, 0.1)])
    with pytest.raises(ValueError, match="duplicate"):
        FaultSet([make_fault("dropout", N, 0.1),
                  make_fault("dropout", N, 0.2)])


def test_effects_merge_and_identity():
    eff = identity_effects((3,))
    assert not bool(eff.kill.any())
    kill = eff._replace(kill=jnp.array([True, False, False]))
    scale = eff._replace(delta_scale=jnp.array([1.0, -1.0, 1.0]))
    m = merge_effects(kill, scale)
    assert bool(m.kill[0]) and float(m.delta_scale[1]) == -1.0


def test_corrupt_updates_identity_is_bitwise():
    key = jax.random.PRNGKey(0)
    u = {"w": jax.random.normal(key, (4, 3, 2))}
    b = {"w": jax.random.normal(jax.random.fold_in(key, 1), (4, 3, 2))}
    out = corrupt_updates(u, b, identity_effects((4,)),
                          jax.random.fold_in(key, 2), True, True)
    _assert_trees_equal(out, u)
    # a hit slot moves, the misses stay bitwise put
    eff = identity_effects((4,))._replace(
        delta_scale=jnp.array([1.0, -1.0, 1.0, 1.0])
    )
    out = corrupt_updates(u, b, eff, jax.random.fold_in(key, 2), True, False)
    assert not np.array_equal(np.asarray(out["w"][1]), np.asarray(u["w"][1]))
    np.testing.assert_array_equal(np.asarray(out["w"][0]),
                                  np.asarray(u["w"][0]))


# ---------------------------------------------------------------------------
# (2) faults-off golden: structure + rate-0 bitwise, per-step and chunked
# ---------------------------------------------------------------------------


def test_faults_off_adds_no_state(small_task):
    state = AsyncEngine(small_task, _cfg()).init()
    assert "faults" not in state and "rd" not in state
    armed = AsyncEngine(
        small_task, _cfg(faults=("dropout",), redispatch_timeout=5.0)
    ).init()
    assert "faults" in armed and "rd" in armed


ALL_ENGINE_FAULTS = ("dropout", "straggler", "stale_replay", "corrupt",
                     "sign_flip", "scale_attack")


@pytest.mark.parametrize("mode", ["async", "sync", "sharded"])
def test_rate_zero_fault_set_is_bitwise_identity(small_task, mode):
    """Arming every engine fault at rate 0 must not move a single bit:
    effect application is per-slot ``where`` and fault keys live on
    dedicated folds, so the training stream is untouched."""
    if mode == "sync":
        kw = dict(mode="sync", buffer_size=None, profile="lognormal")
        faults = ("dropout", "corrupt", "sign_flip", "scale_attack")
    else:
        kw = dict(mesh_shards=0) if mode == "sharded" else {}
        faults = ALL_ENGINE_FAULTS
    base = make_engine(small_task, _cfg(**kw))
    armed = make_engine(
        small_task, _cfg(faults=faults, fault_rate=0.0, **kw)
    )
    sb = base.init()
    sa = armed.init()
    for r in range(4):
        sb, auxb = base.step(sb, r)
        sa, auxa = armed.step(sa, r)
        np.testing.assert_array_equal(np.asarray(auxb["send"]),
                                      np.asarray(auxa["send"]))
        np.testing.assert_array_equal(np.asarray(auxb["loss"]),
                                      np.asarray(auxa["loss"]))
    _assert_trees_equal(base.eval_params(sb), armed.eval_params(sa))
    # chunked == per-step under armed-but-cold faults too
    sc = armed.init()
    sc, _ = armed.run_chunk(sc, 0, 4, False)
    _assert_trees_equal(armed.eval_params(sa), armed.eval_params(sc))


# ---------------------------------------------------------------------------
# (3) injection semantics
# ---------------------------------------------------------------------------


def test_injection_counters_surface_in_load_stats(small_task):
    res = run_engine(make_engine(small_task, _cfg(
        faults=("dropout", "corrupt"), fault_rate=1.0,
    )))
    assert res.load_stats["fault_dropout_injected"] > 0
    assert res.load_stats["fault_corrupt_injected"] > 0


def test_straggler_stretches_the_simulated_clock(small_task):
    base = run_engine(make_engine(small_task, _cfg(rounds=6)))
    stalled = run_engine(make_engine(small_task, _cfg(
        rounds=6, faults=("straggler",), fault_rate=1.0,
        fault_kwargs={"straggler": {"stall": 100.0}},
    )))
    assert stalled.load_stats["fault_straggler_injected"] > 0
    assert stalled.wall_stats["sim_time"] > base.wall_stats["sim_time"]


def test_sync_rejects_async_only_faults(small_task):
    cfg = _cfg(mode="sync", buffer_size=None, profile="lognormal",
               faults=("straggler", "stale_replay"))
    with pytest.raises(ValueError, match="straggler, stale_replay"):
        SyncEngine(small_task, cfg)


def test_dropout_reduces_applied_updates(small_task):
    base = run_engine(make_engine(small_task, _cfg(rounds=6)))
    dropped = run_engine(make_engine(small_task, _cfg(
        rounds=6, faults=("dropout",), fault_rate=1.0,
    )))
    assert dropped.wall_stats["updates_applied"] < \
        base.wall_stats["updates_applied"]


# ---------------------------------------------------------------------------
# (4) robust aggregators
# ---------------------------------------------------------------------------


def _toy(b=8, seed=0):
    key = jax.random.PRNGKey(seed)
    g = {"w": jax.random.normal(key, (3, 4)), "b": jnp.zeros((4,))}
    updates = jax.tree.map(
        lambda p: p + jax.random.normal(jax.random.fold_in(key, 1),
                                        (b,) + p.shape), g
    )
    w = jnp.asarray([1.0, 1.0, 0.0, 1.0, 1.0, 1.0, 0.0, 1.0])[:b]
    return g, updates, w


def test_trimmed_mean_matches_numpy_reference():
    g, updates, w = _toy()
    agg = make_aggregator("trimmed_mean", trim=0.2)
    wv = agg.weigh(w > 0, jnp.zeros((8,), jnp.int32))
    out = agg.finalize(g, agg.accumulate(agg.init(g), updates, g, wv))
    valid = np.asarray(w) > 0
    c = valid.sum()
    t = int(np.floor(c * 0.2))
    for name in g:
        d = np.asarray(updates[name]) - np.asarray(g[name])
        d = np.sort(np.where(valid.reshape((-1,) + (1,) * (d.ndim - 1)),
                             d, np.inf), axis=0)
        ref = d[t:c - t].mean(axis=0)
        np.testing.assert_allclose(
            np.asarray(out[name]), np.asarray(g[name]) + ref, rtol=1e-5
        )


def test_coordinate_median_matches_numpy_reference():
    g, updates, w = _toy(seed=1)
    agg = make_aggregator("coordinate_median")
    wv = agg.weigh(w > 0, jnp.zeros((8,), jnp.int32))
    out = agg.finalize(g, agg.accumulate(agg.init(g), updates, g, wv))
    valid = np.asarray(w) > 0
    for name in g:
        d = np.asarray(updates[name]) - np.asarray(g[name])
        ref = np.median(d[valid], axis=0)
        np.testing.assert_allclose(
            np.asarray(out[name]), np.asarray(g[name]) + ref, rtol=1e-5
        )


def test_norm_clip_bounds_a_scaled_attacker():
    g, updates, w = _toy(seed=2)
    # one slot goes rogue with a 1000x delta
    updates = jax.tree.map(
        lambda u, p: u.at[0].set(p + 1000.0 * (u[0] - p)), updates, g
    )
    agg = make_aggregator("norm_clip", clip=1.0, staleness_mode="const")
    wv = agg.weigh(w > 0, jnp.zeros((8,), jnp.int32))
    acc = agg.accumulate(agg.init(g), updates, g, wv)
    out = agg.finalize(g, acc)
    delta_norm = np.sqrt(sum(
        ((np.asarray(out[n]) - np.asarray(g[n])) ** 2).sum() for n in g
    ))
    # the mean of <= 6 unit-clipped deltas can't exceed the ball
    assert delta_norm <= 1.0 + 1e-5
    assert float(acc["stats"]["clipped"]) >= 1


def test_order_statistic_aggregators_handle_empty_cohort():
    g, updates, _ = _toy(seed=3)
    for name in ("trimmed_mean", "coordinate_median"):
        agg = make_aggregator(name)
        wv = jnp.zeros((8,), jnp.float32)
        out = agg.finalize(g, agg.accumulate(agg.init(g), updates, g, wv))
        for leaf_out, leaf_g in zip(jax.tree.leaves(out),
                                    jax.tree.leaves(g)):
            assert np.isfinite(np.asarray(leaf_out)).all()
            np.testing.assert_array_equal(np.asarray(leaf_out),
                                          np.asarray(leaf_g))


def test_non_additive_rejected_by_merge_seams():
    from repro.core import distributed as dist
    from repro.engine.aggregators import cohort_sharded_apply
    from repro.topo import make_topology, tiered_apply

    agg = make_aggregator("trimmed_mean")
    with pytest.raises(ValueError, match="not additive"):
        tiered_apply(agg, make_topology("hierarchical", tiers=(4,)), N)
    with pytest.raises(ValueError, match="not additive"):
        cohort_sharded_apply(agg, dist.fleet_mesh(1), dist.FLEET_AXIS)


def test_agg_clipped_counter_in_engine_run(small_task):
    res = run_engine(make_engine(small_task, _cfg(
        aggregator="norm_clip", aggregator_kwargs={"clip": 1e-4},
    )))
    assert res.load_stats["agg_clipped"] > 0


# ---------------------------------------------------------------------------
# (5) deadline re-dispatch
# ---------------------------------------------------------------------------


def test_redispatch_counts_and_gating(small_task):
    off = run_engine(make_engine(small_task, _cfg(rounds=6)))
    assert "redispatched" not in off.load_stats
    on = run_engine(make_engine(small_task, _cfg(
        rounds=6, faults=("straggler",), fault_rate=1.0,
        fault_kwargs={"straggler": {"stall": 1000.0}},
        redispatch_timeout=1.0, redispatch_retries=2,
    )))
    # every dispatch straggles 1000x, so the deadline must fire
    assert on.load_stats["rd_expired"] > 0
    assert on.load_stats["redispatched"] > 0


# ---------------------------------------------------------------------------
# (6) checkpoint: typed keys, corruption detection, crash-restart
# ---------------------------------------------------------------------------


def test_checkpoint_typed_prng_key_roundtrip(tmp_path):
    from repro.checkpoint import load_checkpoint, save_checkpoint

    tree = {
        "k": jax.random.key(7, impl="rbg"),
        "w": jnp.arange(6.0).reshape(2, 3),
        "h": jnp.ones((3,), jnp.bfloat16),
    }
    save_checkpoint(str(tmp_path / "c"), tree, step=3)
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )
    restored, step = load_checkpoint(str(tmp_path / "c"), like)
    assert step == 3
    np.testing.assert_array_equal(
        np.asarray(jax.random.key_data(restored["k"])),
        np.asarray(jax.random.key_data(tree["k"])),
    )
    # the restored key draws the same stream
    np.testing.assert_array_equal(
        np.asarray(jax.random.normal(restored["k"], (4,))),
        np.asarray(jax.random.normal(tree["k"], (4,))),
    )
    _assert_trees_equal(restored["w"], tree["w"])
    _assert_trees_equal(restored["h"], tree["h"])


def test_checkpoint_detects_corruption_and_truncation(tmp_path):
    import json

    from repro.checkpoint import load_checkpoint, save_checkpoint

    tree = {"w": jnp.arange(100.0)}
    d = str(tmp_path / "c")
    save_checkpoint(d, tree, step=1)
    with open(tmp_path / "c" / "manifest.json") as f:
        fname = json.load(f)["shards"][0]["file"]
    shard = tmp_path / "c" / fname
    blob = bytearray(shard.read_bytes())
    blob[len(blob) // 2] ^= 0xFF
    shard.write_bytes(bytes(blob))
    with pytest.raises(ValueError, match="corrupted"):
        load_checkpoint(d, tree)
    save_checkpoint(d, tree, step=1)
    shard.write_bytes(shard.read_bytes()[: len(blob) // 3])
    with pytest.raises(ValueError, match="corrupt"):
        load_checkpoint(d, tree)


def test_crash_restart_resumes_bitwise(small_task, tmp_path):
    """Kill a run mid-flight and restart from the checkpointed carry:
    the continuation must be bit-for-bit the uninterrupted run — with
    the whole degradation stack armed (hierarchical reduction, heartbeat
    liveness, fault state, re-dispatch timers, AoI scheduler ages, load
    accumulators, typed rbg run key)."""
    from repro.checkpoint import load_checkpoint, save_checkpoint

    kw = dict(
        rounds=6, rng_impl="rbg",
        topology="hierarchical",
        topology_kwargs={"tiers": (4,), "heartbeat_timeout": 50.0},
        faults=("dropout", "corrupt"), fault_rate=0.5,
        redispatch_timeout=20.0,
    )
    engine = AsyncEngine(small_task, _cfg(**kw))
    full, _ = engine.run_chunk(engine.init(), 0, 6, False)

    half, _ = engine.run_chunk(engine.init(), 0, 3, False)
    save_checkpoint(str(tmp_path / "crash"), half, step=3)
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), half
    )
    restored, step = load_checkpoint(str(tmp_path / "crash"), like)
    assert step == 3
    resumed, _ = engine.run_chunk(restored, 3, 3, False)
    _assert_trees_equal(full, resumed)


# ---------------------------------------------------------------------------
# (7) hb_expired: sharded == single-device over ragged fleets (hypothesis)
# ---------------------------------------------------------------------------


RAGGED_NS = [8, 12, 16]


def _check_hb_parity(n):
    """The property: under heartbeat churn + injected dropout, the
    sharded and single-device engines agree bitwise on params AND on the
    ``hb_expired`` churn counter, whatever the fleet size."""
    from repro.fl import make_cnn_task

    train, test = make_image_dataset(
        f"mnist-faults-hb{n}", 10, 8, 1, 120, 60, seed=0, difficulty=0.8
    )
    task = make_cnn_task(SMALL_CNN, train, test, n_clients=n)
    cfg = lambda **kw: _cfg(  # noqa: E731
        n_clients=n, rounds=4, topology="hierarchical",
        topology_kwargs={"tiers": (4,), "heartbeat_timeout": 1e-6},
        faults=("dropout",), fault_rate=0.5, **kw,
    )
    single = AsyncEngine(task, cfg())
    sharded = ShardedAsyncEngine(task, cfg(mesh_shards=0))
    s1, _ = single.run_chunk(single.init(), 0, 4, False)
    s2, _ = sharded.run_chunk(sharded.init(), 0, 4, False)
    assert float(s1["stats"]["hb_expired"]) == \
        float(s2["stats"]["hb_expired"])
    assert float(s1["stats"]["hb_expired"]) > 0
    _assert_trees_equal(single.eval_params(s1), sharded.eval_params(s2))


def test_hb_expired_sharded_matches_single():
    """Property-based when hypothesis is available; otherwise sweep the
    same ragged fleet sizes directly (the container may not ship
    hypothesis and installing it is off the table)."""
    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:
        for n in RAGGED_NS[:2]:
            _check_hb_parity(n)
        return

    @settings(max_examples=3, deadline=None)
    @given(n=st.sampled_from(RAGGED_NS))
    def check(n):
        _check_hb_parity(n)

    check()
