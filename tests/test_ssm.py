"""Mamba2 SSD: chunked == naive recurrence, decode == prefill, conv cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import SSMSpec
from repro.models import ssm as S

KEY = jax.random.PRNGKey(0)


def _inputs(B, L, nh, hd, ds, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, L, nh, hd)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    B_ = jax.random.normal(ks[3], (B, L, ds)) * 0.5
    C_ = jax.random.normal(ks[4], (B, L, ds)) * 0.5
    return x, dt, A, B_, C_


@given(
    chunk=st.sampled_from([8, 16, 32, 64]),
    L=st.sampled_from([64, 128]),
    seed=st.integers(0, 100),
)
@settings(max_examples=12, deadline=None)
def test_chunked_equals_reference(chunk, L, seed):
    x, dt, A, B_, C_ = _inputs(1, L, 2, 16, 8, seed)
    y1, h1 = S.ssd_chunked(x, dt, A, B_, C_, chunk)
    y2, h2 = S.ssd_reference(x, dt, A, B_, C_)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4, rtol=1e-4)


def test_state_carry_across_calls():
    """Running two halves with carried state == one full pass."""
    x, dt, A, B_, C_ = _inputs(2, 64, 2, 16, 8)
    y_full, h_full = S.ssd_chunked(x, dt, A, B_, C_, 16)
    y1, h1 = S.ssd_chunked(x[:, :32], dt[:, :32], A, B_[:, :32], C_[:, :32], 16)
    y2, h2 = S.ssd_chunked(x[:, 32:], dt[:, 32:], A, B_[:, 32:], C_[:, 32:], 16, h0=h1)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full), atol=1e-4, rtol=1e-4
    )
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), atol=1e-4, rtol=1e-4)


def test_block_decode_matches_fwd():
    """Full mamba2 block: step-by-step decode == full-sequence forward."""
    spec = SSMSpec(d_inner=32, d_state=8, head_dim=16, conv_width=4, chunk=8)
    p = S.init_ssm(KEY, 24, spec, jnp.float32)
    x = jax.random.normal(KEY, (1, 24, 24)) * 0.5
    full = S.ssm_fwd(p, x, spec)
    cache = S.init_ssm_cache(spec, 1, jnp.float32)
    outs = []
    for t in range(24):
        y, cache = S.ssm_decode(p, x[:, t : t + 1], spec, cache)
        outs.append(y)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs, 1)), np.asarray(full), atol=1e-4, rtol=1e-4
    )


def test_decode_state_is_constant_size():
    spec = SSMSpec(d_inner=32, d_state=8, head_dim=16)
    c = S.init_ssm_cache(spec, 3, jnp.float32)
    assert c["h"].shape == (3, 2, 16, 8)
    assert c["conv"].shape == (3, 3, 32 + 16)


def test_decay_bounds():
    """exp(dt*A) in (0,1): state is a contraction (no blowup over time)."""
    x, dt, A, B_, C_ = _inputs(1, 512, 2, 8, 4)
    y, h = S.ssd_chunked(x, dt, A, B_, C_, 64)
    assert np.isfinite(np.asarray(y)).all()
    assert np.abs(np.asarray(h)).max() < 1e3
