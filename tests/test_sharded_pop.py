"""Property test: the sharded buffer pop equals the global top-k.

``sharded_next_k_events`` (per-shard local top-B -> all_gather of the
``devices x B`` candidates -> one stable merge) must reproduce a global
``lax.top_k`` over the full fleet *exactly* — times, indices, and tie
order — including ragged fleets where ``n % devices != 0`` (padded
internally with ``+inf`` sentinels) and times vectors dense with ties and
idle ``+inf`` slots. Hypothesis drives sizes and contents; the reference
is the unsharded ``next_k_events`` path the single-device engine uses.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import distributed as dist
from repro.sim import events as ev_mod

DEVICES = jax.local_device_count()
MESH = dist.fleet_mesh(DEVICES)

# a small value pool forces heavy ties; +inf models idle clients
_times = st.lists(
    st.one_of(
        st.sampled_from([1.0, 2.0, 3.0, jnp.inf]),
        st.floats(0.01, 100.0, allow_nan=False, allow_infinity=False,
                  width=32),
    ),
    min_size=1, max_size=4 * DEVICES + 5,
)


def _check(times_list, k):
    times = jnp.asarray(times_list, jnp.float32)
    n = times.shape[0]
    ref_t, ref_i = ev_mod.next_k_events(times, k, use_kernel=False)
    merge = jax.jit(dist.sharded_next_k_events(MESH, n, k))
    sh_t, sh_i = merge(times)
    # identical times everywhere, identical indices (tie order included)
    # wherever a real event exists
    np.testing.assert_array_equal(np.asarray(sh_t), np.asarray(ref_t))
    valid = np.isfinite(np.asarray(ref_t))
    np.testing.assert_array_equal(
        np.asarray(sh_i)[valid], np.asarray(ref_i)[valid]
    )


@settings(deadline=None, max_examples=25)
@given(data=st.data())
def test_sharded_pop_matches_global_topk(data):
    times = data.draw(_times)
    k = data.draw(st.integers(1, len(times)))
    _check(times, k)


def test_sharded_pop_ragged_all_tied():
    # ragged n for every device count > 1, all times tied: indices must
    # come back 0..k-1 in order (lower-global-index tie contract)
    n = 4 * DEVICES + 3
    times = jnp.full((n,), 7.5, jnp.float32)
    merge = jax.jit(dist.sharded_next_k_events(MESH, n, 5))
    t, idx = merge(times)
    np.testing.assert_array_equal(np.asarray(t), np.full(5, 7.5))
    np.testing.assert_array_equal(np.asarray(idx), np.arange(5))


def test_sharded_pop_all_idle():
    n = 2 * DEVICES + 1
    merge = jax.jit(dist.sharded_next_k_events(MESH, n, 3))
    t, _ = merge(jnp.full((n,), jnp.inf, jnp.float32))
    assert not np.isfinite(np.asarray(t)).any()


def test_sharded_pop_feeds_apply_pop():
    # end to end through the event-engine bookkeeping: popped clients go
    # idle, invalid slots never write back
    n = 3 * DEVICES + 1
    ev = ev_mod.init_event_state(n)
    send = jnp.arange(n) % 3 == 0
    ev = ev_mod.schedule_completions(
        ev, send, jnp.float32(0.0), jnp.full((n,), 2.0, jnp.float32),
        jnp.int32(0), jnp.zeros((n,), jnp.bool_),
    )
    merge = jax.jit(dist.sharded_next_k_events(MESH, n, n))
    t, idx = merge(ev["t_done"])
    t, idx_safe, valid, ev2 = ev_mod.apply_pop(ev, t, idx)
    assert int(valid.sum()) == int(send.sum())
    np.testing.assert_array_equal(
        np.sort(np.asarray(idx_safe)[np.asarray(valid)]),
        np.flatnonzero(np.asarray(send)),
    )
    assert np.isinf(np.asarray(ev2["t_done"])).all()
