"""Golden equivalence for the mesh-sharded async engine.

``ShardedAsyncEngine`` on a ``fleet`` mesh of D devices must be
*bit-for-bit* identical to ``AsyncEngine`` on one device for the same
``RunConfig`` seed — same selection history, same per-step losses, same
final params, same simulator telemetry — both per-step and chunked,
across policies x aggregators. Every random draw keeps the exact (n,)
shape and key schedule of the single-device engine and cohort-sized
intermediates are pinned to a replicated layout, so any drift (a
resharded reduction, a diverged key fold, a tie broken differently in the
distributed pop) fails these tests on exact comparison.

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
multi-device job does) to exercise a real 8-way mesh; on a single device
the engine still routes through the shard_map pop on a 1-shard mesh.

Also pins the deterministic lower-global-index tie-break of
``oldest_age_step_sharded`` (the contract documented in ``sim/events.py``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cnn import MNIST_CNN
from repro.core import distributed as dist
from repro.data.synthetic import make_image_dataset
from repro.engine import (
    AsyncEngine,
    RunConfig,
    ShardedAsyncEngine,
    make_engine,
    run_engine,
)

SMALL_CNN = dataclasses.replace(
    MNIST_CNN, name="paper-cnn-mnist-sharded", image_size=8,
    conv_channels=(4, 8), fc_width=32,
)

N = 16
DEVICES = jax.local_device_count()
SHARDS = dist.resolve_fleet_shards(N, 0, DEVICES)


@pytest.fixture(scope="module")
def small_task():
    from repro.fl import make_cnn_task

    train, test = make_image_dataset(
        "mnist-sharded", 10, 8, 1, 120, 60, seed=0, difficulty=0.8
    )
    return make_cnn_task(SMALL_CNN, train, test, n_clients=N)


def _cfg(**kw):
    base = dict(
        n_clients=N, k=4, m=4, policy="markov", rounds=5, local_epochs=1,
        batch_size=5, eval_every=2, mode="async", buffer_size=3,
        profile="mobile",
    )
    base.update(kw)
    return RunConfig(**base)


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _per_step(engine, rounds, n):
    state = engine.init()
    sel = np.zeros((rounds, n), dtype=bool)
    losses = []
    for r in range(rounds):
        state, aux = engine.step(state, r)
        sel[r] = np.asarray(aux["send"])
        losses.append(float(aux["loss"]))
    return state, sel, losses


@pytest.mark.parametrize("agg", ["fedbuff", "fedavg"])
@pytest.mark.parametrize("policy", ["markov", "oldest_age", "round_robin"])
def test_sharded_matches_async_bit_for_bit(small_task, policy, agg):
    cfg = _cfg(policy=policy, aggregator=agg)

    ref_state, ref_sel, ref_losses = _per_step(
        AsyncEngine(small_task, cfg), cfg.rounds, N
    )

    # per-step driving of the sharded engine
    scfg = dataclasses.replace(cfg, mesh_shards=SHARDS)
    sh_state, sh_sel, sh_losses = _per_step(
        ShardedAsyncEngine(small_task, scfg), cfg.rounds, N
    )
    np.testing.assert_array_equal(sh_sel, ref_sel)
    np.testing.assert_array_equal(sh_losses, ref_losses)
    _assert_trees_equal(sh_state["params"], ref_state["params"])
    for key, val in ref_state["stats"].items():
        np.testing.assert_array_equal(
            np.asarray(sh_state["stats"][key]), np.asarray(val), err_msg=key
        )

    # chunked driving (whole run in donated scan chunks)
    res = run_engine(make_engine(small_task, dataclasses.replace(
        scfg, steps_per_chunk=5
    )))
    np.testing.assert_array_equal(res.selection, ref_sel)
    _assert_trees_equal(res.params, ref_state["params"])
    np.testing.assert_array_equal(
        [rec.train_loss for rec in res.records],
        [ref_losses[r] for r in (1, 3, 4)],  # eval_every=2 cadence + final
    )


def test_sharded_wall_stats_match_async(small_task):
    cfg = _cfg(rounds=6, eval_every=3)
    ref = run_engine(AsyncEngine(small_task, cfg))
    sh = run_engine(make_engine(small_task, dataclasses.replace(
        cfg, mesh_shards=SHARDS
    )))
    assert set(ref.wall_stats) == set(sh.wall_stats)
    for key, val in ref.wall_stats.items():
        np.testing.assert_array_equal(sh.wall_stats[key], val, err_msg=key)
    for key, val in ref.load_stats.items():
        np.testing.assert_allclose(
            sh.load_stats[key], val, rtol=1e-6, err_msg=key
        )


def test_make_engine_routes_mesh_shards(small_task):
    eng = make_engine(small_task, _cfg(mesh_shards=SHARDS))
    assert isinstance(eng, ShardedAsyncEngine)
    assert eng.mesh_shards == SHARDS
    auto = make_engine(small_task, _cfg(mesh_shards=0))
    assert isinstance(auto, ShardedAsyncEngine)
    assert auto.mesh_shards == SHARDS
    plain = make_engine(small_task, _cfg())
    assert not isinstance(plain, ShardedAsyncEngine)


@pytest.mark.skipif(DEVICES < 2, reason="needs a multi-device mesh")
def test_fleet_state_is_actually_sharded(small_task):
    engine = ShardedAsyncEngine(small_task, _cfg(mesh_shards=SHARDS))
    state = engine.init()
    t_done = state["ev"]["t_done"]
    shard_shapes = [s.data.shape for s in t_done.addressable_shards]
    assert len(shard_shapes) == SHARDS
    assert all(shape == (N // SHARDS,) for shape in shard_shapes)
    # params are replicated: every device holds the full leaf
    leaf = jax.tree.leaves(state["params"])[0]
    assert all(s.data.shape == leaf.shape for s in leaf.addressable_shards)
    # the engine's own memory accounting sees at most 1/SHARDS of the
    # (n,)-wide event state on any one device
    per_dev = engine.per_device_state_bytes(state)
    assert per_dev > 0


def test_mesh_shards_config_validation():
    with pytest.raises(ValueError, match="mode='async'"):
        RunConfig(mode="sync", mesh_shards=2)
    with pytest.raises(ValueError, match="divide"):
        _cfg(mesh_shards=3)  # 16 % 3 != 0
    with pytest.raises(ValueError, match=">= 0"):
        _cfg(mesh_shards=-1)


def test_resolve_fleet_shards():
    assert dist.resolve_fleet_shards(16, 0, 8) == 8
    assert dist.resolve_fleet_shards(16, 0, 3) == 2  # largest divisor <= 3
    assert dist.resolve_fleet_shards(10, 0, 8) == 5
    assert dist.resolve_fleet_shards(7, 0, 4) == 1  # prime fleet, no fit
    assert dist.resolve_fleet_shards(16, 4, 8) == 4  # explicit wins
    with pytest.raises(ValueError, match="divisible"):
        dist.resolve_fleet_shards(16, 3, 8)


def test_oldest_age_sharded_tie_break_low_index():
    n, k = N, 4
    mesh = dist.fleet_mesh(SHARDS)
    step = dist.oldest_age_step_sharded(mesh, dist.FLEET_AXIS, k)
    # all ages tied: the k winners must be exactly the k lowest global
    # indices, regardless of which shard they live on
    sel, new_ages, chosen = step(jnp.full((n,), 5, jnp.int32))
    np.testing.assert_array_equal(np.sort(np.asarray(chosen)), np.arange(k))
    np.testing.assert_array_equal(
        np.asarray(sel), np.arange(n) < k
    )
    # a strictly older client beats the tied block; remaining slots fill
    # with the lowest tied indices
    ages = jnp.full((n,), 5, jnp.int32).at[n - 1].set(9)
    sel, _, chosen = step(ages)
    assert bool(sel[n - 1])
    np.testing.assert_array_equal(
        np.sort(np.asarray(chosen)), [0, 1, 2, n - 1]
    )
    # determinism: same input, same selection (no RNG in the tie-break)
    sel2, _, _ = step(ages)
    np.testing.assert_array_equal(np.asarray(sel), np.asarray(sel2))
