"""Collusion-aware defense (``repro.defense.collusion`` /
``.learned`` / the family mtd ladder) and the ``collude`` fault.

The contract under test extends ``tests/test_defense.py``:

  * the ``collude`` fault is norm-invisible per slot (each poisoned
    update carries the slot's own honest norm) and bitwise identity on
    missed slots;
  * clique scoring is a pure, slot-permutation-equivariant function of
    the gathered histories, flags a coalition without flagging honest
    clients, and never self-pairs duplicate slots of one client;
  * the learned head cold-starts safe (sigmoid(0) < threshold), learns
    to separate labelled cohorts, and reports an exact AUC;
  * armed collusion + learned detection stay bitwise across chunked
    execution, fleet sharding (ragged fleet sizes), and crash-restart;
  * the family mtd ladder is bitwise the base rule at level 0 and each
    rung mirrors its ``engine.robust`` registry twin.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cnn import MNIST_CNN
from repro.data.synthetic import make_image_dataset
from repro.defense import DefenseConfig
from repro.engine import (
    AsyncEngine,
    ShardedAsyncEngine,
    SyncEngine,
    make_engine,
    run_engine,
)

SMALL_CNN = dataclasses.replace(
    MNIST_CNN, name="paper-cnn-mnist-collusion", image_size=8,
    conv_channels=(4, 8), fc_width=32,
)

N = 16

# a quarter of the fleet colludes on every pop: norm-invisible by
# construction, so the PR 9 norm/cosine channels alone stay quiet
COLLUDE = dict(
    faults=("collude",), fault_rate=1.0,
    fault_kwargs={"collude": {"client_frac": 0.25, "jitter": 0.1}},
)

ARMED = dict(
    defense=True,
    defense_kwargs={"threshold": 0.3, "collusion": True,
                    "detector": "learned", "clique_min_obs": 2},
    fault_exposure=True,
    **COLLUDE,
)


@pytest.fixture(scope="module")
def small_task():
    from repro.fl import make_cnn_task

    train, test = make_image_dataset(
        "mnist-collusion", 10, 8, 1, 120, 60, seed=0, difficulty=0.8
    )
    return make_cnn_task(SMALL_CNN, train, test, n_clients=N)


def _cfg(**kw):
    from repro.engine import RunConfig

    base = dict(
        n_clients=N, k=4, m=4, policy="markov", rounds=4, local_epochs=1,
        batch_size=5, eval_every=2, mode="async", buffer_size=3,
        profile="mobile",
    )
    base.update(kw)
    return RunConfig(**base)


def _raw(leaf):
    if hasattr(leaf, "dtype") and jax.dtypes.issubdtype(
        leaf.dtype, jax.dtypes.prng_key
    ):
        return np.asarray(jax.random.key_data(leaf))
    return np.asarray(leaf)


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(_raw(la), _raw(lb))


# ---------------------------------------------------------------------------
# (1) the collude fault: norm-invisible, bitwise on missed slots
# ---------------------------------------------------------------------------


def _toy_cohort(b=6, seed=0):
    key = jax.random.PRNGKey(seed)
    bases = {"w": jax.random.normal(key, (b, 5, 3)),
             "b": jax.random.normal(jax.random.fold_in(key, 9), (b, 4))}
    deltas = {
        "w": jax.random.normal(jax.random.fold_in(key, 1), (b, 5, 3)) * 0.1,
        "b": jax.random.normal(jax.random.fold_in(key, 2), (b, 4)) * 0.1,
    }
    updated = jax.tree.map(lambda p, d: p + d, bases, deltas)
    return updated, bases


def _norms(updated, bases):
    sq = sum(
        np.sum((np.asarray(u, np.float64) - np.asarray(b, np.float64)) ** 2,
               axis=tuple(range(1, np.asarray(u).ndim)))
        for u, b in zip(jax.tree.leaves(updated), jax.tree.leaves(bases)))
    return np.sqrt(sq)


def test_collude_updates_norm_invisible_and_identity_on_miss():
    from repro.faults.inject import collude_updates, identity_effects

    updated, bases = _toy_cohort()
    mult = jnp.asarray([0.0, 1.0, 0.0, 1.3, 0.0, 0.8], jnp.float32)
    eff = identity_effects((6,))._replace(collude=mult)
    out = collude_updates(updated, bases, eff)

    honest = _norms(updated, bases)
    poisoned = _norms(out, bases)
    hit = np.asarray(mult) > 0
    # per-slot norm statistics see nothing: ||poison|| = mult * ||honest||
    np.testing.assert_allclose(
        poisoned[hit], (np.asarray(mult) * honest)[hit], rtol=1e-5)
    # missed slots keep their exact buffers
    for u, o in zip(jax.tree.leaves(updated), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(u)[~hit],
                                      np.asarray(o)[~hit])
    # every hit slot points the same (shared, trace-constant) way
    flat = np.concatenate(
        [(np.asarray(o, np.float64) - np.asarray(b, np.float64))
         .reshape(6, -1)
         for o, b in zip(jax.tree.leaves(out), jax.tree.leaves(bases))],
        axis=1)[hit]
    unit = flat / np.linalg.norm(flat, axis=1, keepdims=True)
    cos = unit @ unit.T
    assert cos.min() > 1.0 - 1e-6


def test_effects_hit_covers_every_channel():
    from repro.faults.inject import effects_hit, identity_effects

    eff = identity_effects((4,))
    np.testing.assert_array_equal(np.asarray(effects_hit(eff)),
                                  [False] * 4)
    eff = eff._replace(collude=jnp.asarray([0.0, 0.9, 0.0, 0.0]))
    np.testing.assert_array_equal(np.asarray(effects_hit(eff)),
                                  [False, True, False, False])


def test_collude_fault_validates_kwargs():
    from repro.faults import make_fault

    with pytest.raises(ValueError, match="jitter"):
        make_fault("collude", 16, 0.5, jitter=-0.1)


# ---------------------------------------------------------------------------
# (2) clique scoring: permutation equivariance + separation
# ---------------------------------------------------------------------------


def _clique_inputs(seed, b=12, d=32, n_colluders=3):
    """The engine regime in miniature: honest histories share a loose
    consensus direction (EWMA'd SGD updates on one objective), the
    coalition shares a tight poison direction. First ``n_colluders``
    rows collude."""
    rng = np.random.default_rng(seed)
    consensus = rng.standard_normal(d).astype(np.float32)
    poison = rng.standard_normal(d).astype(np.float32)
    hists = np.stack(
        [poison + 0.05 * rng.standard_normal(d).astype(np.float32)
         for _ in range(n_colluders)]
        + [consensus + 0.6 * rng.standard_normal(d).astype(np.float32)
           for _ in range(b - n_colluders)])
    obs = np.full((b,), 5.0, np.float32)
    valid = np.ones((b,), bool)
    idx = np.arange(b, dtype=np.int32)
    return hists, obs, valid, idx


def _check_permutation_equivariance(seed):
    from repro.defense.collusion import clique_scores

    cfg = DefenseConfig(collusion=True, clique_min_obs=2)
    hists, obs, valid, idx = _clique_inputs(seed)
    perm = np.random.default_rng(seed + 1).permutation(len(idx))
    a_c, a_f = clique_scores(jnp.asarray(hists), jnp.asarray(obs),
                             jnp.asarray(valid), jnp.asarray(idx), cfg)
    p_c, p_f = clique_scores(jnp.asarray(hists[perm]), jnp.asarray(obs[perm]),
                             jnp.asarray(valid[perm]), jnp.asarray(idx[perm]),
                             cfg)
    # every reduction over the slot axis is a sort or a max, so the
    # scores permute with the slots — up to ~1 ulp of GEMM-tiling
    # reassociation in the two matmuls (edge vs main micro-kernels)
    np.testing.assert_allclose(np.asarray(a_c)[perm], np.asarray(p_c),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(a_f)[perm], np.asarray(p_f),
                               rtol=1e-6, atol=1e-6)


def test_clique_scores_permutation_equivariant():
    """Property-based when hypothesis is available; otherwise a direct
    seed sweep (the container may not ship hypothesis and installing it
    is off the table)."""
    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:
        for seed in range(5):
            _check_permutation_equivariance(seed)
        return

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10_000))
    def check(seed):
        _check_permutation_equivariance(seed)

    check()


def test_clique_scores_flag_coalition_not_honest():
    from repro.defense.collusion import clique_scores

    cfg = DefenseConfig(collusion=True, clique_min_obs=2)
    hists, obs, valid, idx = _clique_inputs(7, n_colluders=3)
    s_clique, _ = clique_scores(jnp.asarray(hists), jnp.asarray(obs),
                                jnp.asarray(valid), jnp.asarray(idx), cfg)
    s = np.asarray(s_clique)
    assert s[:3].min() > 0.5  # the coalition lights up
    assert s[3:].max() < 0.2  # consensus-following honesty stays dark


def test_clique_scores_never_self_pair_duplicate_slots():
    """Async re-dispatch can pop two buffer slots of one client in a
    cohort; agreeing with yourself is not collusion."""
    from repro.defense.collusion import clique_scores

    cfg = DefenseConfig(collusion=True, clique_min_obs=2)
    hists, obs, valid, idx = _clique_inputs(3, n_colluders=2)
    idx[1] = idx[0]  # the "coalition" is one client popped twice
    s_clique, _ = clique_scores(jnp.asarray(hists), jnp.asarray(obs),
                                jnp.asarray(valid), jnp.asarray(idx), cfg)
    assert np.asarray(s_clique).max() < 0.2


def test_flip_channel_flags_anti_aligned_history():
    from repro.defense.collusion import clique_scores

    cfg = DefenseConfig(collusion=True, clique_min_obs=2)
    rng = np.random.default_rng(11)
    d = 32
    consensus = rng.standard_normal(d).astype(np.float32)
    hists = np.stack(
        [consensus + 0.3 * rng.standard_normal(d).astype(np.float32)
         for _ in range(7)]
        + [-consensus])
    obs = np.full((8,), 5.0, np.float32)
    s_clique, s_flip = clique_scores(
        jnp.asarray(hists), jnp.asarray(obs), jnp.ones((8,), bool),
        jnp.arange(8, dtype=jnp.int32), cfg)
    f = np.asarray(s_flip)
    assert f[-1] > 0.8  # the lone sign-flipper anti-aligns with center
    assert f[:7].max() < f[-1]


def test_project_deltas_unit_rows_and_zero_deltas():
    from repro.defense.collusion import project_deltas

    updated, bases = _toy_cohort(b=4, seed=3)
    # slot 2 reports exactly its dispatch snapshot: no direction evidence
    updated = jax.tree.map(
        lambda u, b: u.at[2].set(b[2]), updated, bases)
    rows = np.asarray(project_deltas(updated, bases, 16))
    assert rows.shape == (4, 16)
    nrm = np.linalg.norm(rows, axis=1)
    np.testing.assert_allclose(nrm[[0, 1, 3]], 1.0, rtol=1e-5)
    np.testing.assert_array_equal(rows[2], np.zeros((16,)))


# ---------------------------------------------------------------------------
# (3) the learned head: safe cold start, separation, exact AUC
# ---------------------------------------------------------------------------


def test_learned_head_cold_start_scores_half():
    from repro.defense.learned import N_FEATURES, learned_observe

    cfg = DefenseConfig(detector="learned")
    dstate = {"lw": jnp.zeros((1, N_FEATURES), jnp.float32),
              "auc": jnp.zeros((2, 16), jnp.float32)}
    feats = jnp.asarray(np.random.default_rng(0).random((5, N_FEATURES)),
                        jnp.float32)
    _, p = learned_observe(dstate, feats, jnp.ones((5,), bool),
                           jnp.zeros((5,), bool), cfg)
    # sigmoid(0) = 0.5 < the 0.55 default threshold: an untrained head
    # can never quarantine anyone
    np.testing.assert_allclose(np.asarray(p), 0.5)
    assert cfg.threshold > 0.5


def test_learned_head_separates_and_auc_tracks():
    from repro.defense.learned import (
        N_FEATURES, auc_from_hist, learned_observe)

    cfg = DefenseConfig(detector="learned", learned_lr=1.0)
    dstate = {"lw": jnp.zeros((1, N_FEATURES), jnp.float32),
              "auc": jnp.zeros((2, 16), jnp.float32)}
    rng = np.random.default_rng(4)
    valid = jnp.ones((8,), bool)
    for _ in range(60):
        feats = rng.random((8, N_FEATURES)).astype(np.float32) * 0.2
        labels = np.zeros((8,), bool)
        labels[:2] = True
        feats[:2, 2] = 0.9  # positives carry a hot clique score
        feats[:, 7] = 1.0  # the bias feature
        dstate, p = learned_observe(
            dstate, jnp.asarray(feats), valid, jnp.asarray(labels), cfg)
    p = np.asarray(p)
    assert p[:2].min() > p[2:].max()
    assert auc_from_hist(dstate["auc"]) > 0.85


def test_auc_from_hist_exact_and_nan_cases():
    from repro.defense.learned import auc_from_hist

    hist = np.zeros((2, 16))
    assert np.isnan(auc_from_hist(hist))  # no observations yet
    hist[0, 12] = 3.0  # every positive scored above
    hist[1, 2] = 5.0  # ... every negative: perfect ranking
    assert auc_from_hist(hist) == 1.0
    tied = np.zeros((2, 16))
    tied[0, 8] = 2.0
    tied[1, 8] = 2.0  # all ties: chance, by the half-tie convention
    assert auc_from_hist(tied) == 0.5


# ---------------------------------------------------------------------------
# (4) engine integration: detection, parity, restart
# ---------------------------------------------------------------------------


def test_sync_collusion_catches_coalition(small_task):
    """The closed loop at a cohort size where colluders co-occur: the
    coalition accumulates clique evidence and reputation separates it
    from honest clients (the attack is norm-invisible, so this is the
    sketch channel's catch, not the norm channel's)."""
    res = run_engine(make_engine(small_task, _cfg(
        mode="sync", buffer_size=None, profile="lognormal",
        k=12, m=12, rounds=10, fault_exposure=True,
        defense=True,
        defense_kwargs={"threshold": 0.5, "ewma": 0.5, "collusion": True,
                        "clique_min_obs": 2},
        **COLLUDE,
    )))
    exposed = res.fault_exposure["collude"] > 0
    assert exposed.sum() > 0
    assert res.load_stats["def_clique_hits"] > 0
    rep = res.defense["reputation"]
    # coalition reputations separate from the honest fleet's
    assert np.median(rep[exposed]) > rep[~exposed].max()


def test_learned_detector_runs_with_exposure_labels(small_task):
    """Evaluation mode: fault_exposure feeds the head per-slot ground
    truth and the AUC counter actually observes both classes."""
    res = run_engine(make_engine(small_task, _cfg(
        rounds=10, **ARMED,
    )))
    auc = res.load_stats["def_detector_auc"]
    assert not np.isnan(auc) and 0.0 <= auc <= 1.0
    assert res.load_stats["def_clique_hits"] >= 0


def test_armed_collusion_chunked_matches_per_step(small_task):
    eng = make_engine(small_task, _cfg(rounds=8, **ARMED))
    sa = eng.init()
    for r in range(8):
        sa, _ = eng.step(sa, r)
    sc, _ = eng.run_chunk(eng.init(), 0, 8, False)
    _assert_trees_equal(eng.eval_params(sa), eng.eval_params(sc))
    _assert_trees_equal(sa["defense"], sc["defense"])


RAGGED_NS = [8, 12, 16]


def _check_sharded_parity(n):
    """Fleet-sharded vs single-device with collusion + learned armed:
    the (n, d_sketch) sketches shard over the fleet axis, the (1, F)
    head and (2, 16) AUC histograms replicate — and every defense leaf
    plus the eval params must agree bit-for-bit."""
    from repro.fl import make_cnn_task

    train, test = make_image_dataset(
        f"mnist-collusion-s{n}", 10, 8, 1, 120, 60, seed=0, difficulty=0.8
    )
    task = make_cnn_task(SMALL_CNN, train, test, n_clients=n)
    cfg = lambda **kw: _cfg(n_clients=n, rounds=6, **ARMED, **kw)  # noqa: E731
    single = AsyncEngine(task, cfg())
    sharded = ShardedAsyncEngine(task, cfg(mesh_shards=0))
    s1, _ = single.run_chunk(single.init(), 0, 6, False)
    s2, _ = sharded.run_chunk(sharded.init(), 0, 6, False)
    _assert_trees_equal(s1["defense"], s2["defense"])
    _assert_trees_equal(single.eval_params(s1), sharded.eval_params(s2))


def test_collusion_sharded_matches_single_ragged():
    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:
        for n in RAGGED_NS[:2]:
            _check_sharded_parity(n)
        return

    @settings(max_examples=3, deadline=None)
    @given(n=st.sampled_from(RAGGED_NS))
    def check(n):
        _check_sharded_parity(n)

    check()


def test_crash_restart_resumes_bitwise_with_collusion(small_task, tmp_path):
    """Sketches, head weights, and AUC histograms all live on the carry:
    a restart from the checkpoint must continue bit-for-bit."""
    from repro.checkpoint import load_checkpoint, save_checkpoint

    engine = AsyncEngine(small_task, _cfg(rounds=6, rng_impl="rbg", **ARMED))
    full, _ = engine.run_chunk(engine.init(), 0, 6, False)

    half, _ = engine.run_chunk(engine.init(), 0, 3, False)
    save_checkpoint(str(tmp_path / "crash"), half, step=3)
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), half
    )
    restored, step = load_checkpoint(str(tmp_path / "crash"), like)
    assert step == 3
    resumed, _ = engine.run_chunk(restored, 3, 3, False)
    _assert_trees_equal(full, resumed)


def test_sync_engine_runs_learned_collusion(small_task):
    res = run_engine(SyncEngine(small_task, _cfg(
        mode="sync", buffer_size=None, profile="lognormal",
        k=8, m=8, rounds=6, **ARMED,
    )))
    assert "def_detector_auc" in res.load_stats
    assert "def_clique_hits" in res.load_stats


# ---------------------------------------------------------------------------
# (5) the aggregator-family mtd ladder
# ---------------------------------------------------------------------------


def _base_apply():
    from repro.engine.aggregators import acc_stats
    from repro.engine.registry import make_aggregator

    agg = make_aggregator("fedavg")

    def base_apply(gp, u, b, wv, ix):
        acc = agg.accumulate(agg.init(gp), u, b, wv)
        return agg.finalize(gp, acc), acc_stats(acc)

    return base_apply


def _family_fixture(seed=3, b=8):
    key = jax.random.PRNGKey(seed)
    g = {"w": jax.random.normal(key, (3, 4))}
    updates = {"w": g["w"][None] + jax.random.normal(
        jax.random.fold_in(key, 1), (b, 3, 4))}
    return g, updates, jnp.ones((b,), jnp.float32), jnp.arange(b)


def test_family_ladder_level0_is_bitwise_base():
    from repro.defense import adaptive_aggregate

    g, updates, w, idx = _family_fixture()
    base_apply = _base_apply()
    wrapped = adaptive_aggregate(
        base_apply, (0.0, 0.2, 0.0, 0.0),
        families=("base", "trimmed_mean", "coordinate_median", "norm_clip"))
    p0, _ = wrapped(g, updates, g, w, idx, jnp.int32(0))
    pb, _ = base_apply(g, updates, g, w, idx)
    _assert_trees_equal(p0, pb)


def test_family_rungs_match_robust_registry_twins():
    from repro.defense import adaptive_aggregate
    from repro.engine.registry import make_aggregator

    g, updates, w, idx = _family_fixture()
    base_apply = _base_apply()
    wrapped = adaptive_aggregate(
        base_apply, (0.0, 0.2, 0.0, 0.0),
        families=("base", "trimmed_mean", "coordinate_median", "norm_clip"))

    def ref(name, **kw):
        agg = make_aggregator(name, **kw)
        wr = agg.weigh(w > 0, jnp.zeros(w.shape, jnp.int32))
        return agg.finalize(g, agg.accumulate(agg.init(g), updates, g, wr))

    p1, _ = wrapped(g, updates, g, w, idx, jnp.int32(1))
    np.testing.assert_allclose(np.asarray(p1["w"]),
                               np.asarray(ref("trimmed_mean", trim=0.2)["w"]),
                               rtol=1e-5)
    p2, _ = wrapped(g, updates, g, w, idx, jnp.int32(2))
    np.testing.assert_allclose(np.asarray(p2["w"]),
                               np.asarray(ref("coordinate_median")["w"]),
                               rtol=1e-5)
    # the norm_clip rung clips at the cohort's *median* delta norm; its
    # registry twin takes a static radius — hand it that median
    deltas = np.asarray(updates["w"], np.float64) - np.asarray(g["w"])
    med = float(np.median(np.sqrt((deltas ** 2).sum(axis=(1, 2)))))
    p3, _ = wrapped(g, updates, g, w, idx, jnp.int32(3))
    np.testing.assert_allclose(
        np.asarray(p3["w"]),
        np.asarray(ref("norm_clip", clip=med, staleness_mode="const")["w"]),
        rtol=1e-5)
    # out-of-range levels clamp to the top rung instead of crashing
    p9, _ = wrapped(g, updates, g, w, idx, jnp.int32(9))
    _assert_trees_equal(p3, p9)


def test_family_ladder_escalates_in_engine(small_task):
    """Under a sustained collusion attack the family ladder leaves the
    base rung; calm fleets never do (level 0 stays bitwise-base, which
    test_threshold_inf_defense_is_bitwise_identity pins engine-wide)."""
    kw = dict(
        defense=True,
        defense_kwargs={
            "threshold": 0.5, "ewma": 0.5, "collusion": True,
            "clique_min_obs": 2, "mtd": True, "mtd_window": 2,
            "mtd_up": 0.35, "mtd_down": 0.01,
            "mtd_trims": (0.0, 0.1, 0.0, 0.0),
            "mtd_families": ("base", "trimmed_mean", "coordinate_median",
                             "norm_clip"),
        },
    )
    hot = run_engine(make_engine(small_task, _cfg(
        mode="sync", buffer_size=None, profile="lognormal",
        k=12, m=12, rounds=10, **kw, **COLLUDE,
    )))
    calm = run_engine(make_engine(small_task, _cfg(
        mode="sync", buffer_size=None, profile="lognormal",
        k=12, m=12, rounds=10, **kw,
    )))
    assert hot.load_stats["def_mtd_level"] > 0
    assert calm.load_stats["def_mtd_level"] == 0


# ---------------------------------------------------------------------------
# (6) CLI report surface
# ---------------------------------------------------------------------------


def test_print_defense_stats_reports_new_columns(capsys):
    from repro.launch._fl_cli import print_defense_stats

    print_defense_stats({
        "def_quarantined_now": 2, "def_probation_now": 1,
        "def_quarantine_inflow": 3, "def_readmitted": 0,
        "def_mtd_level": 1, "def_clique_hits": 7.0,
        "def_detector_auc": 0.912,
    })
    out = capsys.readouterr().out
    assert "clique_hits=7" in out
    assert "detector_auc=0.912" in out
    print_defense_stats({
        "def_quarantined_now": 0, "def_probation_now": 0,
        "def_quarantine_inflow": 0, "def_readmitted": 0,
        "def_detector_auc": float("nan"),
    })
    assert "detector_auc=n/a" in capsys.readouterr().out
