"""Policy behaviour: Monte-Carlo agreement with theory, AoI dynamics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    age_update,
    empirical_load_stats,
    load_metric,
    make_policy,
    simulate,
)

N, K, M = 100, 15, 10
ROUNDS = 3000


@pytest.fixture(scope="module")
def histories():
    key = jax.random.PRNGKey(0)
    return {
        name: simulate(make_policy(name, N, K, M), key, N, ROUNDS)
        for name in ("random", "markov", "oldest_age", "round_robin")
    }


def test_age_update_eq4():
    ages = jnp.array([0, 3, 7, 2])
    sel = jnp.array([True, False, True, False])
    out = age_update(ages, sel)
    assert out.tolist() == [0, 4, 0, 3]


def test_selection_rates(histories):
    """Every policy selects each client with rate ~= k/n (constraint 3)."""
    for name, hist in histories.items():
        per_client = hist.mean(axis=0)
        assert per_client.mean() == pytest.approx(K / N, rel=0.05), name
        # and no client starves or dominates
        assert per_client.min() > 0.5 * K / N, name
        assert per_client.max() < 2.0 * K / N, name


def test_markov_var_matches_theory(histories):
    stats = empirical_load_stats(histories["markov"])
    expect = load_metric.optimal_var(N, K, M)
    assert stats["mean_X"] == pytest.approx(N / K, rel=0.02)
    assert stats["var_X"] == pytest.approx(expect, abs=0.05)


def test_random_var_matches_theory(histories):
    stats = empirical_load_stats(histories["random"])
    expect = load_metric.random_selection_var(N, K)
    assert stats["var_X"] == pytest.approx(expect, rel=0.1)


def test_oldest_age_equals_optimal_markov(histories):
    """Remark 1: oldest-age == optimal Markov in Var[X]."""
    s = empirical_load_stats(histories["oldest_age"])
    assert s["var_X"] == pytest.approx(load_metric.optimal_var(N, K, M), abs=0.05)


def test_variance_ordering(histories):
    """round_robin <= markov ~ oldest < random."""
    v = {n: empirical_load_stats(h)["var_X"] for n, h in histories.items()}
    assert v["markov"] < v["random"] / 10
    assert v["oldest_age"] < v["random"] / 10
    assert v["round_robin"] <= v["markov"] + 0.05


def test_markov_cohort_is_variable_with_mean_k(histories):
    sizes = histories["markov"].sum(axis=1)
    assert sizes.mean() == pytest.approx(K, rel=0.05)
    assert sizes.std() > 1.0  # binomial-ish, not exact-k
    exact = histories["random"].sum(axis=1)
    assert (exact == K).all()


def test_markov_is_decentralized_jit_step():
    """The markov step must not gather global state: verify it is a pure
    per-client map + the age update (jit compiles, shapes preserved)."""
    pol = make_policy("markov", N, K, M)
    state = pol.init(jax.random.PRNGKey(1), N)
    step = jax.jit(pol.step)
    sel, state2 = step(state, jax.random.PRNGKey(2))
    assert sel.shape == (N,)
    assert state2["ages"].shape == (N,)
    # selected clients reset to 0; others incremented
    np.testing.assert_array_equal(
        np.asarray(state2["ages"]),
        np.asarray(age_update(state["ages"], sel)),
    )


def test_gumbel_age_interpolates():
    key = jax.random.PRNGKey(3)
    hist_oldest = simulate(make_policy("gumbel_age", N, K, beta=50.0), key, N, 2000)
    hist_rand = simulate(make_policy("gumbel_age", N, K, beta=0.0), key, N, 2000)
    v_old = empirical_load_stats(hist_oldest)["var_X"]
    v_rnd = empirical_load_stats(hist_rand)["var_X"]
    assert v_old < v_rnd / 3  # high beta ~ oldest-age, low beta ~ random
