"""MoE layer: routing semantics, capacity behaviour, aux loss, shared
experts, decode (single-token) path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoESpec
from repro.models import moe as M

KEY = jax.random.PRNGKey(0)


def _spec(**kw):
    base = dict(num_experts=4, top_k=2, d_ff_expert=32, capacity_factor=2.0)
    base.update(kw)
    return MoESpec(**base)


def test_moe_forward_shapes_and_finite():
    spec = _spec()
    p = M.init_moe(KEY, 16, spec, jnp.float32)
    x = jax.random.normal(KEY, (2, 8, 16))
    y, metrics = M.moe_fwd(p, x, spec, group_size=8)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(metrics["aux_loss"]) > 0


def test_high_capacity_no_drops():
    spec = _spec(capacity_factor=8.0)
    p = M.init_moe(KEY, 16, spec, jnp.float32)
    x = jax.random.normal(KEY, (2, 16, 16))
    _, metrics = M.moe_fwd(p, x, spec, group_size=16)
    assert float(metrics["drop_frac"]) == pytest.approx(0.0, abs=1e-6)


def test_moe_equals_dense_expert_mix_when_no_drop():
    """With no capacity drops, MoE == explicit per-token expert mixture."""
    spec = _spec(capacity_factor=8.0)
    d = 16
    p = M.init_moe(KEY, d, spec, jnp.float32)
    x = jax.random.normal(KEY, (1, 8, d))
    y, _ = M.moe_fwd(p, x, spec, group_size=8)

    xt = x.reshape(-1, d)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gk, ik = jax.lax.top_k(probs, spec.top_k)
    gk = gk / gk.sum(-1, keepdims=True)
    expect = jnp.zeros_like(xt)
    for t in range(xt.shape[0]):
        acc = jnp.zeros((d,))
        for j in range(spec.top_k):
            e = int(ik[t, j])
            h = xt[t] @ p["w_in"][e]
            g = xt[t] @ p["w_gate"][e]
            acc += gk[t, j] * ((jax.nn.silu(g) * h) @ p["w_out"][e])
        expect = expect.at[t].set(acc)
    np.testing.assert_allclose(
        np.asarray(y.reshape(-1, d)), np.asarray(expect), atol=1e-4, rtol=1e-4
    )


def test_capacity_drops_monotone():
    """Lower capacity factor => more dropped routes."""
    d = 16
    x = jax.random.normal(KEY, (2, 32, d))
    drops = []
    for cf in (8.0, 1.0, 0.5):
        spec = _spec(capacity_factor=cf)
        p = M.init_moe(KEY, d, spec, jnp.float32)
        _, metrics = M.moe_fwd(p, x, spec, group_size=32)
        drops.append(float(metrics["drop_frac"]))
    assert drops[0] <= drops[1] <= drops[2]
    assert drops[2] > 0


def test_shared_experts_contribute():
    spec = _spec(num_shared=1, d_ff_shared=32)
    p = M.init_moe(KEY, 16, spec, jnp.float32)
    x = jax.random.normal(KEY, (1, 8, 16))
    y1, _ = M.moe_fwd(p, x, spec, group_size=8)
    p2 = dict(p)
    p2["shared_out"] = jnp.zeros_like(p["shared_out"])
    y2, _ = M.moe_fwd(p2, x, spec, group_size=8)
    assert float(jnp.abs(y1 - y2).max()) > 1e-6


def test_single_token_decode_group():
    """T=1 (long-context decode) works: group collapses to 1 token."""
    spec = _spec()
    p = M.init_moe(KEY, 16, spec, jnp.float32)
    x = jax.random.normal(KEY, (1, 1, 16))
    y, _ = M.moe_fwd(p, x, spec, group_size=128)
    assert y.shape == (1, 1, 16)
    assert np.isfinite(np.asarray(y)).all()


def test_aux_loss_penalizes_imbalance():
    """A router collapsed onto one expert has higher aux loss than uniform."""
    spec = _spec(top_k=1)
    d = 16
    p = M.init_moe(KEY, d, spec, jnp.float32)
    x = jax.random.normal(KEY, (1, 64, d))
    p_collapsed = dict(p)
    bias = jnp.zeros((d, spec.num_experts)).at[:, 0].set(10.0)
    p_collapsed["router"] = p["router"] * 0.0 + bias
    _, m_uniform = M.moe_fwd(p, x, spec, group_size=64)
    _, m_collapsed = M.moe_fwd(p_collapsed, x, spec, group_size=64)
    assert float(m_collapsed["aux_loss"]) > float(m_uniform["aux_loss"])
