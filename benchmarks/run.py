"""Benchmark harness — one module per paper table/figure plus the
framework deliverables. Prints a ``name,us_per_call,derived`` CSV at the
end (and human-readable tables along the way).

  PYTHONPATH=src python -m benchmarks.run                # all, CPU-budget scale
  PYTHONPATH=src python -m benchmarks.run --only variance,roofline
  PYTHONPATH=src python -m benchmarks.run --paper-scale  # full Figs 2-4 protocol
  PYTHONPATH=src python -m benchmarks.run --out bench.json   # strict-JSON dump
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: variance,scheduler,kernels,convergence,roofline,async")
    ap.add_argument("--paper-scale", action="store_true")
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--out", default=None,
                    help="write the CSV rows as strict JSON (NaN-safe)")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else None

    def on(name):
        return want is None or name in want

    csv_rows = []
    t0 = time.time()
    if on("variance"):
        from benchmarks import bench_variance

        bench_variance.run(csv_rows)
    if on("scheduler"):
        from benchmarks import bench_scheduler_scale

        bench_scheduler_scale.run(csv_rows)
    if on("kernels"):
        from benchmarks import bench_kernels

        bench_kernels.run(csv_rows)
    if on("convergence"):
        from benchmarks import bench_convergence

        bench_convergence.run(csv_rows, rounds=args.rounds,
                              paper_scale=args.paper_scale)
    if on("async"):
        from benchmarks import bench_async_fleet

        bench_async_fleet.run(csv_rows, rounds=args.rounds)
    if on("roofline"):
        from benchmarks import bench_roofline

        bench_roofline.run(csv_rows)

    print(f"\n[{time.time() - t0:.1f}s total]")
    print("\nname,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.1f},{derived}")
    if args.out:
        from repro.engine import dump_json

        dump_json(args.out, {
            "rows": [
                {"name": name, "us_per_call": us, "derived": derived}
                for name, us, derived in csv_rows
            ],
            "total_s": time.time() - t0,
        })
        print("wrote", args.out)


if __name__ == "__main__":
    main()
