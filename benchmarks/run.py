"""Benchmark harness — one module per paper table/figure plus the
framework deliverables. Prints a ``name,us_per_call,derived`` CSV at the
end (and human-readable tables along the way).

  PYTHONPATH=src python -m benchmarks.run                # all, CPU-budget scale
  PYTHONPATH=src python -m benchmarks.run --only variance,roofline
  PYTHONPATH=src python -m benchmarks.run --paper-scale  # full Figs 2-4 protocol
  PYTHONPATH=src python -m benchmarks.run --out bench.json   # strict-JSON dump
  PYTHONPATH=src python -m benchmarks.run --only async \
      --check benchmarks/baselines/cpu.json              # regression gate

``--check`` compares every timed row against a committed baseline (same
strict-JSON schema as ``--out``) by name and exits nonzero when a row is
slower than ``baseline * (1 + rtol)``. Refresh a stale baseline by
re-running with ``--out`` pointed at the baseline file.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


# rows every committed baseline must carry, whatever --only subset is
# being checked: renaming or dropping one of these must fail the gate
# loudly instead of silently shrinking coverage. The hierarchical rows
# come from bench_async_fleet.run_topo on 8 fake devices; the serve row
# from bench_serve.run_serve (single device).
REQUIRED_BASELINE_ROWS = (
    "async_engine_step_n262144_hier64x8",
    "async_engine_step_n262144_hier64x8_sharded8",
    "serve_tick_tinyllama-1.1b_r2s4",
    # chaos stack: armed-fault step cost + the convergence-vs-corruption
    # evidence row (robust aggregation recovering what fedavg loses)
    "faults_step_n100_chaos",
    "faults_robust_recovers_replacement",
    # defense tier: armed-reputation step cost on a calm fleet + the
    # adaptive-vs-static-vs-fedavg recovery evidence row
    "defense_step_n100_armed",
    "defense_adaptive_recovers",
    # collusion-aware detection (norm-invisible sign-flip + coalition
    # recall/FPR gate) and the aggregator-family mtd recovery row
    "defense_collusion_recall",
    "defense_mtd_family_recovers",
)


def check_against_baseline(csv_rows, baseline_path: str, rtol: float) -> int:
    """Compare timed rows to a committed baseline; returns the number of
    regressions (rows slower than baseline * (1 + rtol))."""
    with open(baseline_path) as f:
        payload = json.load(f)
    base = {r["name"]: float(r["us_per_call"]) for r in payload["rows"]}
    absent = [name for name in REQUIRED_BASELINE_ROWS if name not in base]
    if absent:
        print(f"FAIL: baseline {baseline_path} is missing required row(s): "
              f"{', '.join(absent)} (refresh it with --out after running "
              f"the topo section on 8 fake devices)")
        return len(absent)
    regressions, faster, missing = [], [], []
    compared = 0
    print(f"\n== regression check vs {baseline_path} (rtol={rtol}) ==")
    for name, us, _ in csv_rows:
        if us <= 0:  # derived-only rows carry no timing
            continue
        if name not in base or base[name] <= 0:
            missing.append(name)
            continue
        compared += 1
        ratio = us / base[name]
        flag = ""
        if ratio > 1.0 + rtol:
            regressions.append(name)
            flag = "  <-- REGRESSION"
        elif ratio < 1.0 / (1.0 + rtol):
            faster.append(name)
            flag = "  (faster; consider refreshing the baseline)"
        print(f"  {name:40s} {us:12.1f}us vs {base[name]:12.1f}us "
              f"({ratio:5.2f}x){flag}")
    if missing:
        print(f"  [not in baseline: {', '.join(missing)}]")
    if regressions:
        print(f"FAIL: {len(regressions)} row(s) regressed: "
              f"{', '.join(regressions)}")
    elif compared == 0:
        # a gate that compared nothing must not read as green — either
        # the wrong --only subset was checked or every row was renamed
        print("FAIL: no timed row matched the baseline; nothing was "
              "actually checked (wrong --only subset, or rows renamed "
              "without refreshing the baseline?)")
        return 1
    else:
        print(f"OK: no regressions across {compared} compared rows"
              + (f" ({len(faster)} faster than baseline)" if faster else "")
              + (f"; {len(missing)} not in baseline" if missing else ""))
    return len(regressions)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: variance,scheduler,kernels,convergence,"
                         "roofline,async,sharded,topo,serve,faults,defense")
    ap.add_argument("--paper-scale", action="store_true")
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--out", default=None,
                    help="write the CSV rows as strict JSON (NaN-safe)")
    ap.add_argument("--check", default=None, metavar="BASELINE_JSON",
                    help="compare rows against a committed baseline "
                         "(benchmarks/baselines/cpu.json) and exit nonzero "
                         "on regression")
    ap.add_argument("--check-rtol", type=float, default=1.0,
                    help="relative tolerance for --check: a row regresses "
                         "when slower than baseline * (1 + rtol). The "
                         "default is deliberately loose — shared CI boxes "
                         "jitter ~2x; tighten locally for real perf work")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else None

    def on(name):
        return want is None or name in want

    csv_rows = []
    t0 = time.time()
    if on("variance"):
        from benchmarks import bench_variance

        bench_variance.run(csv_rows)
    if on("scheduler"):
        from benchmarks import bench_scheduler_scale

        bench_scheduler_scale.run(csv_rows)
    if on("kernels"):
        from benchmarks import bench_kernels

        bench_kernels.run(csv_rows)
    if on("convergence"):
        from benchmarks import bench_convergence

        bench_convergence.run(csv_rows, rounds=args.rounds,
                              paper_scale=args.paper_scale)
    if on("async"):
        from benchmarks import bench_async_fleet

        bench_async_fleet.run(csv_rows, rounds=args.rounds)
    if on("sharded"):
        from benchmarks import bench_async_fleet

        bench_async_fleet.run_sharded(csv_rows)
        bench_async_fleet.run_cohort(csv_rows)
    if on("topo"):
        from benchmarks import bench_async_fleet

        bench_async_fleet.run_topo(csv_rows)
    if on("serve"):
        from benchmarks import bench_serve

        bench_serve.run_serve(csv_rows)
    if on("faults"):
        from benchmarks import bench_faults

        bench_faults.run(csv_rows, rounds=args.rounds)
    if on("defense"):
        from benchmarks import bench_defense

        bench_defense.run(csv_rows, rounds=args.rounds)
    if on("roofline"):
        from benchmarks import bench_roofline

        bench_roofline.run(csv_rows)

    print(f"\n[{time.time() - t0:.1f}s total]")
    print("\nname,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.1f},{derived}")
    if args.out:
        from repro.engine import dump_json

        dump_json(args.out, {
            "rows": [
                {"name": name, "us_per_call": us, "derived": derived}
                for name, us, derived in csv_rows
            ],
            "total_s": time.time() - t0,
        })
        print("wrote", args.out)
    if args.check:
        if check_against_baseline(csv_rows, args.check, args.check_rtol):
            sys.exit(1)


if __name__ == "__main__":
    main()
