"""Paper Figs. 2-4: FedAvg convergence under random vs Markov selection.

Synthetic stand-ins for MNIST/CIFAR (offline container) with the paper's
setting n=100, k=15, m=10, SGD(lr 0.1, decay 0.998), E=5, B=50. Default
runs are CPU-budget-scaled (fewer rounds, reduced data); --paper-scale
restores the full protocol.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from repro.configs.paper_cnn import CNN_CONFIGS
from repro.core import load_metric as lm
from repro.data.synthetic import load_dataset
from repro.engine import RunConfig, SyncEngine, run_engine
from repro.fl import make_cnn_task
from repro.fl.rounds import rounds_to_target

# (dataset, noniid, target_acc, paper figure, cpu-budget scale multiplier)
EXPERIMENTS = [
    ("cifar10", False, 0.50, "Fig.2", 0.6),
    ("cifar100", False, 0.15, "Fig.3", 0.6),
    ("mnist", False, 0.60, "Fig.4 top", 1.0),
    ("mnist", True, 0.55, "Fig.4 bottom", 1.0),
]


def run_one(dataset: str, noniid: bool, policy: str, rounds: int, scale: float,
            seed: int = 0, batch_size: int = 50, local_epochs: int = 5,
            cnn_width: float = 1.0):
    import dataclasses

    train, test = load_dataset(dataset, seed=seed, scale=scale)
    cnn = CNN_CONFIGS[f"paper-cnn-{dataset}"]
    if cnn_width != 1.0:
        c1, c2 = cnn.conv_channels
        cnn = dataclasses.replace(
            cnn, conv_channels=(int(c1 * cnn_width), int(c2 * cnn_width)),
            fc_width=int(cnn.fc_width * cnn_width),
        )
    task = make_cnn_task(
        cnn, train, test, 100,
        noniid_alpha=0.6 if noniid else None, seed=seed,
    )
    cfg = RunConfig(
        n_clients=100, k=15, m=10, policy=policy, rounds=rounds,
        local_epochs=local_epochs, batch_size=batch_size,
        eval_every=max(rounds // 20, 1), seed=seed,
    )
    return run_engine(SyncEngine(task, cfg))


def run_one_mini(dataset: str, noniid: bool, policy: str, rounds: int, seed: int = 0):
    """CPU-budget mini protocol: 16x16 images, (8,16)-channel CNN, fc 64,
    n=100, k=15, m=10, SGD lr 0.1 x 0.998^t, E=2, B=10 — the paper's
    *structure* at a scale one CPU core can run in minutes."""
    import dataclasses

    from repro.data.synthetic import make_image_dataset

    base = CNN_CONFIGS[f"paper-cnn-{dataset}"]
    cnn = dataclasses.replace(
        base, name=base.name + "-mini", image_size=16, conv_channels=(8, 16),
        fc_width=64,
    )
    train, test = make_image_dataset(
        dataset + "-mini", base.num_classes, 16, base.channels,
        2000, 1000, seed=seed, difficulty=0.9,
    )
    task = make_cnn_task(cnn, train, test, 100,
                         noniid_alpha=0.6 if noniid else None, seed=seed)
    cfg = RunConfig(n_clients=100, k=15, m=10, policy=policy, rounds=rounds,
                    local_epochs=2, batch_size=10,
                    eval_every=max(rounds // 20, 1), seed=seed)
    return run_engine(SyncEngine(task, cfg))


def run(csv_rows, rounds: int = 14, scale: float = 0.05, paper_scale: bool = False):
    if paper_scale:
        rounds, scale = 300, 1.0
    print(f"\n== convergence: random vs markov "
          f"({'paper protocol' if paper_scale else 'CPU-budget mini protocol; --paper-scale for the full one'}, "
          f"rounds={rounds}) ==")
    for dataset, noniid, target, fig, mult in EXPERIMENTS:
        row = {}
        for policy in ("random", "markov"):
            t0 = time.time()
            if paper_scale:
                out = run_one(dataset, noniid, policy, rounds, scale)
            else:
                out = run_one_mini(dataset, noniid, policy,
                                   max(int(rounds * mult), 6))
            dt = time.time() - t0
            h = out.history()
            r2t = rounds_to_target(h, target)
            row[policy] = (h["accuracy"][-1], r2t, out.load_stats["var_X"], dt)
        tag = f"{dataset}{'-noniid' if noniid else ''}"
        acc_r, r2t_r, var_r, dt_r = row["random"]
        acc_m, r2t_m, var_m, dt_m = row["markov"]
        speedup = ""
        if r2t_r and r2t_m:
            speedup = f" speedup {100 * (r2t_r - r2t_m) / r2t_r:+.1f}%"
        print(f"{fig:12s} {tag:16s} acc@end rand={acc_r:.3f} mkv={acc_m:.3f} | "
              f"rounds->{target:.0%}: rand={r2t_r} mkv={r2t_m}{speedup} | "
              f"VarX {var_r:.1f} vs {var_m:.2f}")
        csv_rows.append(
            (f"convergence_{tag}", (dt_r + dt_m) / 2 * 1e6 / rounds,
             f"acc_random={acc_r:.4f};acc_markov={acc_m:.4f};"
             f"r2t_random={r2t_r};r2t_markov={r2t_m};varX_random={var_r:.2f};"
             f"varX_markov={var_m:.3f}")
        )
