"""Scheduler scalability (the paper's decentralization claim, quantified):
per-round wall time of the Markov decision step — as shipped through the
engine's policy registry — vs centralized oldest-age top-k as the fleet
grows, plus the paper-relevant age histogram check.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import load_metric as lm, make_policy
from repro.core.distributed import scheduler_comm_bytes
from repro.kernels import ops

KEY = jax.random.PRNGKey(0)

# nominal fleet-mesh width for the reported scheduler communication
# volume (matches the fake-device recipe of the sharded benchmarks/CI)
COMM_DEVICES = 8


def _markov_step(probs, m):
    @jax.jit
    def step(ages, key):
        chain = jnp.minimum(ages, m)
        sel = jax.random.uniform(key, ages.shape) < probs[chain]
        return sel, (ages + 1) * (1 - sel.astype(ages.dtype))

    return step


def run(csv_rows):
    print("\n== scheduler scaling: decentralized markov vs centralized top-k ==")
    m = 10
    for n in (10_000, 100_000, 1_000_000):
        k = int(n * 0.15)
        # the registered policy, exactly as the engines construct it
        pol = make_policy("markov", n, k, m)
        step = jax.jit(pol.step)
        state = pol.init(KEY, n)
        sel, state = step(state, KEY)  # warm
        t0 = time.time()
        for i in range(5):
            sel, state = step(state, jax.random.fold_in(KEY, i))
        jax.block_until_ready(state["ages"])
        t_markov = (time.time() - t0) / 5 * 1e6

        agesf = jax.random.randint(KEY, (n,), 0, 40).astype(jnp.float32)
        kk = min(k, 1024)  # top-k cost grows with k; cap for the bench
        ops.oldest_age_topk(agesf, kk)  # warm
        t0 = time.time()
        for _ in range(3):
            jax.block_until_ready(ops.oldest_age_topk(agesf, kk))
        t_topk = (time.time() - t0) / 3 * 1e6
        # the decentralization argument next to the measured times: per-round
        # scheduler communication on a COMM_DEVICES-way fleet mesh — O(1)
        # for the local Markov decisions vs O(devices * k) for the
        # centralized top-k candidate gather
        comm_mk, comm_old = scheduler_comm_bytes(n, k, COMM_DEVICES)
        print(f"n={n:>9,}: markov step {t_markov:10.0f}us | "
              f"oldest-age top-{kk} {t_topk:10.0f}us | "
              f"comm {comm_mk}B vs {comm_old:,}B ({COMM_DEVICES} devices)")
        csv_rows.append((f"sched_scale_n{n}", t_markov,
                         f"topk_us={t_topk:.0f};devices={COMM_DEVICES};"
                         f"comm_markov_B={comm_mk};comm_oldest_B={comm_old}"))

    # steady-state age distribution matches pi (Eqs. 12-14)
    n, k = 100_000, 15_000
    probs = jnp.asarray(lm.optimal_probs(n, k, m), jnp.float32)
    pi = lm.steady_state(np.asarray(probs))
    step = _markov_step(probs, m)
    ages = jnp.zeros((n,), jnp.int32)
    for i in range(200):
        _, ages = step(ages, jax.random.fold_in(KEY, i))
    hist = np.bincount(np.asarray(jnp.minimum(ages, m)), minlength=m + 1) / n
    err = np.abs(hist - pi).max()
    print(f"steady-state age histogram vs pi: max abs err {err:.4f}")
    csv_rows.append(("steady_state_hist_err", 0.0, f"{err:.5f}"))
