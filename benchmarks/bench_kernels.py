"""Kernel micro-benchmarks: interpret-mode correctness-path timing (CPU;
TPU wall-time is not measurable here) + analytic flops per call, and the
jnp reference timing for context.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _time(f, *args, reps=3):
    f(*args)  # compile/warm
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.time() - t0) / reps * 1e6  # us


def run(csv_rows):
    print("\n== kernels (interpret mode on CPU; ref = pure-jnp oracle) ==")
    # flash attention
    B, Hk, G, S, D = 1, 2, 2, 512, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hk, G, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hk, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hk, S, D), jnp.float32)
    flops = 2 * 2 * B * Hk * G * S * S * D
    t_k = _time(lambda: ops.flash_attention(q, k, v, scale=0.125, block_q=128, block_k=128))
    t_r = _time(lambda: ref.flash_attention_ref(q, k, v, scale=0.125))
    print(f"flash_attention  S={S}: kernel {t_k:9.0f}us ref {t_r:9.0f}us ({flops / 1e6:.0f} MFLOP)")
    csv_rows.append(("flash_attention_512", t_k, f"ref_us={t_r:.0f};mflop={flops / 1e6:.0f}"))

    # ssd scan
    Bb, S2, nh, hd, ds = 1, 512, 4, 32, 32
    x = jax.random.normal(ks[0], (Bb, S2, nh, hd)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, S2, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    B_ = jax.random.normal(ks[0], (Bb, S2, ds)) * 0.5
    C_ = jax.random.normal(ks[1], (Bb, S2, ds)) * 0.5
    t_k = _time(lambda: ops.ssd_scan(x, dt, A, B_, C_, chunk=64))
    t_r = _time(lambda: ref.ssd_scan_ref(x, dt, A, B_, C_))
    print(f"ssd_scan        S={S2}: kernel {t_k:9.0f}us ref {t_r:9.0f}us")
    csv_rows.append(("ssd_scan_512", t_k, f"ref_us={t_r:.0f}"))

    # fedavg reduce (cohort 32 x 1M params)
    p = jax.random.normal(ks[0], (32, 1_000_000), jnp.float32)
    w = jnp.ones((32,)) / 32
    t_k = _time(lambda: ops.fedavg_reduce(p, w))
    t_r = _time(lambda: ref.fedavg_reduce_ref(p, w))
    print(f"fedavg_reduce 32x1M : kernel {t_k:9.0f}us ref {t_r:9.0f}us")
    csv_rows.append(("fedavg_reduce_32x1M", t_k, f"ref_us={t_r:.0f}"))

    # aoi topk at fleet scale
    ages = jax.random.randint(ks[0], (1_000_000,), 0, 100).astype(jnp.float32)
    t_k = _time(lambda: ops.oldest_age_topk(ages, 128))
    t_r = _time(lambda: ref.topk_ref(ages, 128))
    print(f"aoi_topk n=1M k=128 : kernel {t_k:9.0f}us ref {t_r:9.0f}us")
    csv_rows.append(("aoi_topk_1M", t_k, f"ref_us={t_r:.0f}"))
