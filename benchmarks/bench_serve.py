"""Serving-tier benchmarks: continuous-batching decode tick throughput,
TTFT (prefill + join), and staleness/Var[X] telemetry from a full serve
loop — the metrics the ROADMAP's serving item promised next to the
training rows.

The store is a synthetic 8-deep version ring over the reduced
``tinyllama-1.1b`` params (no training run: the tick/prefill costs are a
property of the decode path, not of how the ring was filled); the loop
row runs the Markov router over a Poisson trace so the derived
staleness/Var[X] figures come from real routing decisions.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

ARCH = "tinyllama-1.1b"
REPLICAS, SLOTS = 2, 4
PROMPT, GEN = 16, 16


def _bench(fn, warmup: int = 3, iters: int = 20) -> float:
    """Mean us/call after warmup; ``fn`` must block on device work."""
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


def run_serve(csv_rows) -> None:
    from repro.configs import get_arch
    from repro.models import factory
    from repro.serve import ReplicaPool, Request, VersionStore, run_serve_loop
    from repro.sim import arrivals as arr_mod, get_profile

    cfg = get_arch(ARCH).reduced()
    model = factory.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    h = 8
    hist = jax.tree.map(lambda p: jnp.stack([p] * h), params)
    store = VersionStore(hist, jnp.asarray(h - 1, jnp.int32), h)
    ctx = PROMPT + 2 * GEN

    print(f"\n== serving tier ({cfg.name}, {REPLICAS} replicas x {SLOTS} "
          f"slots, ctx {ctx}) ==")

    # --- steady-state decode tick: every slot busy, no evictions
    pool = ReplicaPool(model, REPLICAS, SLOTS, ctx)
    pool.refresh(store)
    key = jax.random.PRNGKey(1)
    rid = 0
    for r in range(REPLICAS):
        for _ in range(SLOTS):
            prompt = np.asarray(jax.random.randint(
                jax.random.fold_in(key, rid), (PROMPT,), 0, cfg.vocab_size
            ))
            pool.join(r, Request(rid=rid, tick=0, prompt=prompt,
                                 gen_len=1 << 20), tick=0)
            rid += 1

    tick_holder = [0]

    def one_tick():
        tick_holder[0] += 1
        pool.decode_tick(tick_holder[0])  # host pull of next tokens blocks

    tick_us = _bench(one_tick)
    streams = REPLICAS * SLOTS
    tok_s = streams / (tick_us / 1e6)
    name = f"serve_tick_{ARCH}_r{REPLICAS}s{SLOTS}"
    print(f"  decode tick ({streams} streams): {tick_us:.0f}us "
          f"-> {tok_s:.0f} tok/s")
    csv_rows.append((name, tick_us, f"tok_s={tok_s:.0f}"))

    # --- TTFT compute path: prefill + slot write for one joining request
    prompt = np.asarray(jax.random.randint(
        jax.random.fold_in(key, 999), (PROMPT,), 0, cfg.vocab_size
    ))
    req = Request(rid=rid, tick=0, prompt=prompt, gen_len=1 << 20)

    def one_join():
        pool.active[0][0] = None  # re-admit over the same slot
        pool.join(0, req, tick=0)

    join_us = _bench(one_join, warmup=2, iters=10)
    print(f"  prefill+join (p{PROMPT}): {join_us:.0f}us")
    csv_rows.append(
        (f"serve_ttft_prefill_{ARCH}_p{PROMPT}", join_us, f"ctx={ctx}")
    )

    # --- full loop under the Markov router: staleness / Var[X] telemetry
    proc = arr_mod.from_profile(get_profile("lognormal"), 1.5, PROMPT, GEN)
    reqs = arr_mod.sample_requests(jax.random.PRNGKey(2), proc, 16,
                                   cfg.vocab_size)
    rep = run_serve_loop(
        model, store, reqs, router="markov", n_replicas=REPLICAS,
        slots=SLOTS, ctx=ctx, seed=0,
    )
    print(f"  {rep.summary()}")
    csv_rows.append((
        f"serve_loop_markov_r{REPLICAS}s{SLOTS}", 0.0,
        f"ttft_ticks={rep.ttft_ticks_mean:.2f} "
        f"staleness_mean={rep.staleness_mean:.2f} "
        f"staleness_max={rep.staleness_max} "
        f"var_X={rep.serve_stats['var_X']:.3f} tok_s={rep.tok_s:.0f}",
    ))
