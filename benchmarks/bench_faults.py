"""Fault injection & graceful degradation benchmarks.

Two questions, one section:

  (a) what does arming the chaos stack cost per engine step — faults
      (dropout + corrupt) and deadline re-dispatch riding the donated
      scan carry vs the identical fault-free engine;
  (b) the convergence-vs-fault-rate row the tentpole promises: under a
      pinned model-replacement corruption of the cohort, plain fedavg
      loses the accuracy the robust aggregation registry entries
      (trimmed_mean / coordinate_median) recover. Final eval losses land
      in the derived column so the committed baseline carries the
      evidence.

The replacement attack (sign-flipped, boosted deltas) is the clean one
for this comparison: it *reverses* the direction of the mean aggregate —
damage clipping cannot repair and a minority of honest rounds cannot
outvote — while order statistics discard it outright; ``trim`` is set
above the corruption rate so the trimmed band covers the attackers.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_cnn import CNN_CONFIGS
from repro.data.synthetic import make_image_dataset
from repro.engine import RunConfig, SyncEngine, make_engine, run_engine
from repro.fl import make_cnn_task

# pinned attack for the convergence rows: a model-replacement attacker
# hits 25% of every cohort, submitting its delta sign-flipped AND boosted
# (scale_attack factor -3) — the honest 75% mean is cancelled, so plain
# fedavg stalls and drifts (at -4 it diverges to NaN outright). The
# trimmed mean discards the 35% band per coordinate, comfortably above
# the corruption rate.
ATTACK_RATE = 0.25
ATTACK_FACTOR = -3.0
TRIM = 0.35


def _mini_task(seed: int = 0):
    base = CNN_CONFIGS["paper-cnn-mnist"]
    cnn = dataclasses.replace(
        base, name=base.name + "-faults-mini", image_size=16,
        conv_channels=(8, 16), fc_width=64,
    )
    train, test = make_image_dataset(
        "mnist-faults-mini", base.num_classes, 16, base.channels,
        2000, 1000, seed=seed, difficulty=0.9,
    )
    return make_cnn_task(cnn, train, test, 100, seed=seed)


def _time_chunks(engines, chunk: int, trials: int):
    """Per-step medians, trials interleaved (shared boxes drift)."""
    snaps = []
    for eng in engines:
        state = eng.init()
        state, _ = eng.run_chunk(state, 0, chunk, False)  # compile + warm
        jax.block_until_ready(jax.tree.leaves(state["params"])[0])
        snaps.append(state)
    times = [[] for _ in engines]
    for _ in range(trials):
        for i, eng in enumerate(engines):
            st = jax.tree.map(jnp.copy, snaps[i])  # run_chunk donates
            t0 = time.time()
            _, aux = eng.run_chunk(st, chunk, chunk, False)
            _ = jax.device_get(aux)
            times[i].append((time.time() - t0) / chunk * 1e6)
    return [float(np.median(t)) for t in times]


def run(csv_rows, rounds: int = 12, trials: int = 3):
    task = _mini_task()

    # --- (a) chaos overhead per async step -------------------------------
    def acfg(**kw):
        return RunConfig(
            n_clients=100, k=15, m=10, policy="markov", rounds=64,
            local_epochs=1, batch_size=10, eval_every=64, mode="async",
            profile="mobile", collect_history=False, **kw,
        )

    calm = make_engine(task, acfg())
    chaos = make_engine(task, acfg(
        faults=("dropout", "corrupt"), fault_rate=0.1,
        redispatch_timeout=30.0,
    ))
    print("\n== faults: chaos-stack overhead per async step "
          "(n=100, dropout+corrupt @ 0.1 + re-dispatch) ==")
    t_calm, t_chaos = _time_chunks([calm, chaos], chunk=8, trials=trials)
    ratio = t_chaos / t_calm if t_calm else float("nan")
    print(f"  calm  : {t_calm:9.1f}us/step")
    print(f"  chaos : {t_chaos:9.1f}us/step ({ratio:.2f}x)")
    csv_rows.append(("faults_step_n100_calm", t_calm, ""))
    csv_rows.append(("faults_step_n100_chaos", t_chaos, f"{ratio:.3f}x"))

    # --- (b) convergence under pinned replacement corruption -------------
    print(f"\n== faults: convergence under a replacement attack "
          f"(scale_attack x{ATTACK_FACTOR}, rate={ATTACK_RATE}, "
          f"rounds={rounds}) — fedavg vs robust ==")

    def converge(aggregator, aggregator_kwargs):
        cfg = RunConfig(
            n_clients=100, k=15, m=10, policy="markov", rounds=rounds,
            local_epochs=2, batch_size=10,
            eval_every=max(rounds // 4, 1),
            aggregator=aggregator, aggregator_kwargs=aggregator_kwargs,
            faults=("scale_attack",), fault_rate=ATTACK_RATE,
            fault_kwargs={"scale_attack": {"factor": ATTACK_FACTOR}},
        )
        t0 = time.time()
        res = run_engine(SyncEngine(task, cfg))
        last = res.records[-1]
        injected = res.load_stats.get("fault_scale_attack_injected", 0.0)
        return last, time.time() - t0, injected

    losses = {}
    for name, agg, kw in (
        ("fedavg", None, {}),
        ("trimmed_mean", "trimmed_mean", {"trim": TRIM}),
        ("coordinate_median", "coordinate_median", {}),
    ):
        last, dt, injected = converge(agg, kw)
        losses[name] = last.eval_loss
        print(f"  {name:18s}: eval_loss={last.eval_loss:.4f} "
              f"acc={last.accuracy:.4f} "
              f"({int(injected)} replacements injected, {dt:.1f}s)")
        csv_rows.append((
            f"faults_convergence_replacement_{name}", 0.0,
            f"loss={last.eval_loss:.4f};acc={last.accuracy:.4f}",
        ))
    best = min(losses["trimmed_mean"], losses["coordinate_median"])
    # a fedavg that diverged to NaN/inf lost by the widest possible margin
    recovered = best < losses["fedavg"] or not np.isfinite(losses["fedavg"])
    print(f"  robust {'recovers' if recovered else 'DOES NOT recover'}: "
          f"best robust loss {best:.4f} vs fedavg {losses['fedavg']:.4f}")
    csv_rows.append((
        "faults_robust_recovers_replacement", 0.0,
        f"{'yes' if recovered else 'NO'};fedavg={losses['fedavg']:.4f};"
        f"robust={best:.4f}",
    ))
