"""Adaptive defense tier benchmarks.

Three questions, one section:

  (a) what does arming the defense tier cost per engine step on a calm
      fleet — reputation scoring, the quarantine chain, and the mtd
      pressure window riding the donated scan carry vs the identical
      defense-free engine (the committed row pins the ratio; the
      acceptance budget is <= 1.10x);
  (b) detection quality: under a pinned 25% attacker mix, what fraction
      of the *truly hit* clients ends up quarantined/probation (recall)
      and how many honest clients get dragged in (false-positive rate) —
      ground truth comes from the per-client fault-exposure tallies;
  (c) convergence: adaptive (reputation exclusion + moving-target trim)
      vs the best static robust aggregator vs plain fedavg under the
      same attack — the defense must land within 10% of the static
      trimmed mean's eval loss while fedavg loses the model.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_cnn import CNN_CONFIGS
from repro.data.synthetic import make_image_dataset
from repro.engine import RunConfig, SyncEngine, make_engine, run_engine
from repro.fl import make_cnn_task

# the same pinned replacement attack bench_faults uses: sign-flipped,
# boosted deltas from a fixed susceptible quarter of the fleet
ATTACK_FACTOR = -3.0
ATTACK_FRAC = 0.25
# two-fault mix: independent prone draws at this frac give a ~25% union
MIX_FRAC = 0.134

# defense knobs for every armed row: one strong observation puts a
# client at rep 0.5, a second pushes it over the threshold — repeated
# evidence, not one unlucky cohort
DEFENSE = {"threshold": 0.55, "ewma": 0.5}
MTD = {"mtd": True, "mtd_window": 4, "mtd_trims": (0.0, 0.15, 0.25, 0.35),
       "mtd_up": 0.1, "mtd_down": 0.02}
# collusion-aware scoring: historical-direction sketches + residual
# clique/flip channels on top of the same reputation chain. Sticky
# quarantine (q_decay=1.0) keeps convicted clients benched for the
# final census — with the default passive decay, colluders cycle
# through readmission and the end-of-run status snapshot undercounts
# them. The two detection rows pin two operating points of the same
# detector: the threshold is the recall/FPR lever, and the collude
# coalition drags honest FPR higher than a lone flip does (the shared
# direction steers the norm-clipped-mean center, so honest cosine
# scores misfire more often). A single shared config (threshold 0.60,
# q_decay 0.995) also passes both gates but lands exactly on the
# 0.80-recall / 0.05-FPR boundaries, so the rows keep margin instead.
COLLUSION = {**DEFENSE, "ewma": 0.5, "collusion": True,
             "clique_min_obs": 2, "q_decay": 1.0, "threshold": 0.60}
COLLUSION_COALITION = {**COLLUSION, "threshold": 0.65}
# the family ladder: same pressure window, rungs rotate aggregator
# families instead of trim fractions
MTD_FAMILIES = {**MTD, "mtd_families": ("base", "trimmed_mean",
                                        "coordinate_median", "norm_clip")}


def _mini_task(seed: int = 0):
    base = CNN_CONFIGS["paper-cnn-mnist"]
    cnn = dataclasses.replace(
        base, name=base.name + "-defense-mini", image_size=16,
        conv_channels=(8, 16), fc_width=64,
    )
    train, test = make_image_dataset(
        "mnist-defense-mini", base.num_classes, 16, base.channels,
        2000, 1000, seed=seed, difficulty=0.9,
    )
    return make_cnn_task(cnn, train, test, 100, seed=seed)


def _time_chunks(engines, chunk: int, trials: int):
    """Per-step medians, trials interleaved (shared boxes drift)."""
    snaps = []
    for eng in engines:
        state = eng.init()
        state, _ = eng.run_chunk(state, 0, chunk, False)  # compile + warm
        jax.block_until_ready(jax.tree.leaves(state["params"])[0])
        snaps.append(state)
    times = [[] for _ in engines]
    for _ in range(trials):
        for i, eng in enumerate(engines):
            st = jax.tree.map(jnp.copy, snaps[i])  # run_chunk donates
            t0 = time.time()
            _, aux = eng.run_chunk(st, chunk, chunk, False)
            _ = jax.device_get(aux)
            times[i].append((time.time() - t0) / chunk * 1e6)
    return [float(np.median(t)) for t in times]


def _detection_row(task, label, faults, fault_kwargs, rounds,
                   defense_kwargs=None):
    """One detection-quality row: run armed defense against the attack,
    score quarantine decisions against the exposure ground truth.
    ``defense_kwargs`` defaults to the PR 9 z-score detector; pass
    ``COLLUSION`` (or a ``detector="learned"`` config) to measure the
    collusion-aware paths against the same ground truth."""
    cfg = RunConfig(
        n_clients=100, k=15, m=10, policy="markov", rounds=rounds,
        local_epochs=1, batch_size=10, eval_every=rounds,
        faults=faults, fault_rate=1.0, fault_kwargs=fault_kwargs,
        fault_exposure=True, defense=True,
        defense_kwargs=dict(defense_kwargs or DEFENSE),
    )
    t0 = time.time()
    res = run_engine(SyncEngine(task, cfg))
    dt = time.time() - t0
    hit = np.zeros(100, bool)
    for exp in res.fault_exposure.values():
        hit |= exp > 0
    suspect = res.defense["status"] != 0
    tp = int((suspect & hit).sum())
    fp = int((suspect & ~hit).sum())
    recall = tp / max(int(hit.sum()), 1)
    precision = tp / max(tp + fp, 1)
    fpr = fp / max(int((~hit).sum()), 1)
    print(f"  {label:18s}: {int(hit.sum())} clients hit -> "
          f"recall={recall:.2f} precision={precision:.2f} fpr={fpr:.3f} "
          f"(inflow {int(res.load_stats['def_quarantine_inflow'])}, "
          f"{dt:.1f}s)")
    return (
        f"defense_detection_{label}", 0.0,
        f"recall={recall:.2f};precision={precision:.2f};fpr={fpr:.3f}",
    ), recall, fpr


def run(csv_rows, rounds: int = 12, trials: int = 3):
    task = _mini_task()

    # --- (a) armed-defense overhead per async step on a calm fleet -------
    def acfg(**kw):
        return RunConfig(
            n_clients=100, k=15, m=10, policy="markov", rounds=64,
            local_epochs=1, batch_size=10, eval_every=64, mode="async",
            profile="mobile", collect_history=False, **kw,
        )

    calm = make_engine(task, acfg())
    armed = make_engine(task, acfg(
        defense=True, defense_kwargs={**DEFENSE, **MTD},
    ))
    print("\n== defense: armed-tier overhead per async step "
          "(n=100, calm fleet, reputation + quarantine + mtd) ==")
    t_calm, t_armed = _time_chunks([calm, armed], chunk=8, trials=trials)
    ratio = t_armed / t_calm if t_calm else float("nan")
    print(f"  calm  : {t_calm:9.1f}us/step")
    print(f"  armed : {t_armed:9.1f}us/step ({ratio:.2f}x)")
    csv_rows.append(("defense_step_n100_calm", t_calm, ""))
    csv_rows.append(("defense_step_n100_armed", t_armed, f"{ratio:.3f}x"))

    # --- (b) detection precision/recall per attack fault -----------------
    det_rounds = max(2 * rounds, 24)
    print(f"\n== defense: detection quality vs exposure ground truth "
          f"(n=100, ~25% attackers, rounds={det_rounds}) ==")
    row, _, _ = _detection_row(
        task, "scale_attack", ("scale_attack",),
        {"scale_attack": {"factor": ATTACK_FACTOR,
                          "client_frac": ATTACK_FRAC}},
        det_rounds,
    )
    csv_rows.append(row)
    row, _, _ = _detection_row(
        task, "sign_flip", ("sign_flip",),
        {"sign_flip": {"client_frac": ATTACK_FRAC}},
        det_rounds,
    )
    csv_rows.append(row)
    row, _, _ = _detection_row(
        task, "scale_sign", ("scale_attack", "sign_flip"),
        {"scale_attack": {"factor": ATTACK_FACTOR, "client_frac": MIX_FRAC},
         "sign_flip": {"client_frac": MIX_FRAC}},
        det_rounds,
    )
    csv_rows.append(row)

    # --- (b') collusion-aware detection: the attacks the z-score cannot
    # see. Pure -1x sign-flip is norm-invisible (the committed zscore row
    # pins recall ~0.10); the flip channel reads anti-alignment of the
    # historical-direction sketch with the cohort center instead. The
    # collude fault submits a shared poisoned direction norm-matched per
    # slot — only the residual clique channel catches the coalition.
    # These rows need more rounds than the norm-visible ones: at k=15 of
    # n=100 a client is drawn ~7 times in 48 rounds, and the EWMA sketch
    # needs several observations before its direction stops being noise.
    col_rounds = max(4 * rounds, 48)
    print(f"  (collusion-aware rows run rounds={col_rounds})")
    row, r_flip, f_flip = _detection_row(
        task, "sign_flip_clique", ("sign_flip",),
        {"sign_flip": {"client_frac": ATTACK_FRAC}},
        col_rounds, defense_kwargs=COLLUSION,
    )
    csv_rows.append(row)
    row, r_col, f_col = _detection_row(
        task, "collude", ("collude",),
        {"collude": {"client_frac": ATTACK_FRAC}},
        col_rounds, defense_kwargs=COLLUSION_COALITION,
    )
    csv_rows.append(row)
    # the headline gate: both norm-invisible attacks at >= 0.8 recall,
    # <= 5% FPR (vs 0.10 recall for the z-score detector on sign_flip)
    col_ok = (r_flip >= 0.8 and r_col >= 0.8
              and f_flip <= 0.05 and f_col <= 0.05)
    print(f"  collusion-aware detection "
          f"{'passes' if col_ok else 'FAILS'}: sign_flip recall="
          f"{r_flip:.2f}/fpr={f_flip:.3f}, collude recall={r_col:.2f}"
          f"/fpr={f_col:.3f}")
    csv_rows.append((
        "defense_collusion_recall", 0.0,
        f"{'yes' if col_ok else 'NO'};flip_recall={r_flip:.2f};"
        f"flip_fpr={f_flip:.3f};collude_recall={r_col:.2f};"
        f"collude_fpr={f_col:.3f}",
    ))

    # --- (c) convergence: adaptive vs static robust vs fedavg ------------
    conv_rounds = max(2 * rounds, 24)
    print(f"\n== defense: convergence under the replacement attack "
          f"(scale_attack x{ATTACK_FACTOR}, frac={ATTACK_FRAC}, "
          f"rounds={conv_rounds}) — adaptive vs static ==")

    def converge(label, **kw):
        cfg = RunConfig(
            n_clients=100, k=15, m=10, policy="markov", rounds=conv_rounds,
            local_epochs=2, batch_size=10,
            eval_every=max(conv_rounds // 4, 1),
            faults=("scale_attack",), fault_rate=1.0,
            fault_kwargs={"scale_attack": {"factor": ATTACK_FACTOR,
                                           "client_frac": ATTACK_FRAC}},
            **kw,
        )
        t0 = time.time()
        res = run_engine(SyncEngine(task, cfg))
        last = res.records[-1]
        extra = ""
        if res.load_stats.get("def_quarantine_inflow") is not None:
            extra = (f", quarantined {int(res.load_stats['def_quarantine_inflow'])}"
                     f", mtd level {int(res.load_stats['def_mtd_level'])}")
        print(f"  {label:14s}: eval_loss={last.eval_loss:.4f} "
              f"acc={last.accuracy:.4f} ({time.time() - t0:.1f}s{extra})")
        return last

    losses = {}
    for label, kw in (
        ("fedavg", {}),
        ("trimmed_mean", {"aggregator": "trimmed_mean",
                          "aggregator_kwargs": {"trim": 0.35}}),
        ("adaptive", {"defense": True,
                      "defense_kwargs": {**DEFENSE, **MTD}}),
        ("adaptive_family", {"defense": True,
                             "defense_kwargs": {**DEFENSE,
                                                **MTD_FAMILIES}}),
    ):
        last = converge(label, **kw)
        losses[label] = last.eval_loss
        csv_rows.append((
            f"defense_convergence_attack_{label}", 0.0,
            f"loss={last.eval_loss:.4f};acc={last.accuracy:.4f}",
        ))
    static = losses["trimmed_mean"]
    adaptive = losses["adaptive"]
    family = losses["adaptive_family"]
    # the family ladder must recover like the trim ladder does: within
    # 10% of the static robust loss, strictly better than fedavg
    fam_ok = (np.isfinite(family) and family <= static * 1.10
              and (family < losses["fedavg"]
                   or not np.isfinite(losses["fedavg"])))
    print(f"  family ladder {'recovers' if fam_ok else 'DOES NOT recover'}: "
          f"loss {family:.4f} vs static {static:.4f} "
          f"vs fedavg {losses['fedavg']:.4f}")
    csv_rows.append((
        "defense_mtd_family_recovers", 0.0,
        f"{'yes' if fam_ok else 'NO'};family={family:.4f};"
        f"static={static:.4f};fedavg={losses['fedavg']:.4f}",
    ))
    # the defense must land within 10% of the static robust loss while
    # fedavg (mean cancelled by the attackers) does strictly worse
    within = np.isfinite(adaptive) and adaptive <= static * 1.10
    beats_fedavg = (adaptive < losses["fedavg"]
                    or not np.isfinite(losses["fedavg"]))
    ok = within and beats_fedavg
    print(f"  adaptive {'recovers' if ok else 'DOES NOT recover'}: "
          f"loss {adaptive:.4f} vs static {static:.4f} "
          f"vs fedavg {losses['fedavg']:.4f}")
    csv_rows.append((
        "defense_adaptive_recovers", 0.0,
        f"{'yes' if ok else 'NO'};adaptive={adaptive:.4f};"
        f"static={static:.4f};fedavg={losses['fedavg']:.4f}",
    ))
