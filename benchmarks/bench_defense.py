"""Adaptive defense tier benchmarks.

Three questions, one section:

  (a) what does arming the defense tier cost per engine step on a calm
      fleet — reputation scoring, the quarantine chain, and the mtd
      pressure window riding the donated scan carry vs the identical
      defense-free engine (the committed row pins the ratio; the
      acceptance budget is <= 1.10x);
  (b) detection quality: under a pinned 25% attacker mix, what fraction
      of the *truly hit* clients ends up quarantined/probation (recall)
      and how many honest clients get dragged in (false-positive rate) —
      ground truth comes from the per-client fault-exposure tallies;
  (c) convergence: adaptive (reputation exclusion + moving-target trim)
      vs the best static robust aggregator vs plain fedavg under the
      same attack — the defense must land within 10% of the static
      trimmed mean's eval loss while fedavg loses the model.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_cnn import CNN_CONFIGS
from repro.data.synthetic import make_image_dataset
from repro.engine import RunConfig, SyncEngine, make_engine, run_engine
from repro.fl import make_cnn_task

# the same pinned replacement attack bench_faults uses: sign-flipped,
# boosted deltas from a fixed susceptible quarter of the fleet
ATTACK_FACTOR = -3.0
ATTACK_FRAC = 0.25
# two-fault mix: independent prone draws at this frac give a ~25% union
MIX_FRAC = 0.134

# defense knobs for every armed row: one strong observation puts a
# client at rep 0.5, a second pushes it over the threshold — repeated
# evidence, not one unlucky cohort
DEFENSE = {"threshold": 0.55, "ewma": 0.5}
MTD = {"mtd": True, "mtd_window": 4, "mtd_trims": (0.0, 0.15, 0.25, 0.35),
       "mtd_up": 0.1, "mtd_down": 0.02}


def _mini_task(seed: int = 0):
    base = CNN_CONFIGS["paper-cnn-mnist"]
    cnn = dataclasses.replace(
        base, name=base.name + "-defense-mini", image_size=16,
        conv_channels=(8, 16), fc_width=64,
    )
    train, test = make_image_dataset(
        "mnist-defense-mini", base.num_classes, 16, base.channels,
        2000, 1000, seed=seed, difficulty=0.9,
    )
    return make_cnn_task(cnn, train, test, 100, seed=seed)


def _time_chunks(engines, chunk: int, trials: int):
    """Per-step medians, trials interleaved (shared boxes drift)."""
    snaps = []
    for eng in engines:
        state = eng.init()
        state, _ = eng.run_chunk(state, 0, chunk, False)  # compile + warm
        jax.block_until_ready(jax.tree.leaves(state["params"])[0])
        snaps.append(state)
    times = [[] for _ in engines]
    for _ in range(trials):
        for i, eng in enumerate(engines):
            st = jax.tree.map(jnp.copy, snaps[i])  # run_chunk donates
            t0 = time.time()
            _, aux = eng.run_chunk(st, chunk, chunk, False)
            _ = jax.device_get(aux)
            times[i].append((time.time() - t0) / chunk * 1e6)
    return [float(np.median(t)) for t in times]


def _detection_row(task, label, faults, fault_kwargs, rounds):
    """One detection-quality row: run armed defense against the attack,
    score quarantine decisions against the exposure ground truth."""
    cfg = RunConfig(
        n_clients=100, k=15, m=10, policy="markov", rounds=rounds,
        local_epochs=1, batch_size=10, eval_every=rounds,
        faults=faults, fault_rate=1.0, fault_kwargs=fault_kwargs,
        fault_exposure=True, defense=True, defense_kwargs=dict(DEFENSE),
    )
    t0 = time.time()
    res = run_engine(SyncEngine(task, cfg))
    dt = time.time() - t0
    hit = np.zeros(100, bool)
    for exp in res.fault_exposure.values():
        hit |= exp > 0
    suspect = res.defense["status"] != 0
    tp = int((suspect & hit).sum())
    fp = int((suspect & ~hit).sum())
    recall = tp / max(int(hit.sum()), 1)
    precision = tp / max(tp + fp, 1)
    fpr = fp / max(int((~hit).sum()), 1)
    print(f"  {label:12s}: {int(hit.sum())} clients hit -> "
          f"recall={recall:.2f} precision={precision:.2f} fpr={fpr:.3f} "
          f"(inflow {int(res.load_stats['def_quarantine_inflow'])}, "
          f"{dt:.1f}s)")
    return (
        f"defense_detection_{label}", 0.0,
        f"recall={recall:.2f};precision={precision:.2f};fpr={fpr:.3f}",
    )


def run(csv_rows, rounds: int = 12, trials: int = 3):
    task = _mini_task()

    # --- (a) armed-defense overhead per async step on a calm fleet -------
    def acfg(**kw):
        return RunConfig(
            n_clients=100, k=15, m=10, policy="markov", rounds=64,
            local_epochs=1, batch_size=10, eval_every=64, mode="async",
            profile="mobile", collect_history=False, **kw,
        )

    calm = make_engine(task, acfg())
    armed = make_engine(task, acfg(
        defense=True, defense_kwargs={**DEFENSE, **MTD},
    ))
    print("\n== defense: armed-tier overhead per async step "
          "(n=100, calm fleet, reputation + quarantine + mtd) ==")
    t_calm, t_armed = _time_chunks([calm, armed], chunk=8, trials=trials)
    ratio = t_armed / t_calm if t_calm else float("nan")
    print(f"  calm  : {t_calm:9.1f}us/step")
    print(f"  armed : {t_armed:9.1f}us/step ({ratio:.2f}x)")
    csv_rows.append(("defense_step_n100_calm", t_calm, ""))
    csv_rows.append(("defense_step_n100_armed", t_armed, f"{ratio:.3f}x"))

    # --- (b) detection precision/recall per attack fault -----------------
    det_rounds = max(2 * rounds, 24)
    print(f"\n== defense: detection quality vs exposure ground truth "
          f"(n=100, ~25% attackers, rounds={det_rounds}) ==")
    csv_rows.append(_detection_row(
        task, "scale_attack", ("scale_attack",),
        {"scale_attack": {"factor": ATTACK_FACTOR,
                          "client_frac": ATTACK_FRAC}},
        det_rounds,
    ))
    csv_rows.append(_detection_row(
        task, "sign_flip", ("sign_flip",),
        {"sign_flip": {"client_frac": ATTACK_FRAC}},
        det_rounds,
    ))
    csv_rows.append(_detection_row(
        task, "scale_sign", ("scale_attack", "sign_flip"),
        {"scale_attack": {"factor": ATTACK_FACTOR, "client_frac": MIX_FRAC},
         "sign_flip": {"client_frac": MIX_FRAC}},
        det_rounds,
    ))

    # --- (c) convergence: adaptive vs static robust vs fedavg ------------
    conv_rounds = max(2 * rounds, 24)
    print(f"\n== defense: convergence under the replacement attack "
          f"(scale_attack x{ATTACK_FACTOR}, frac={ATTACK_FRAC}, "
          f"rounds={conv_rounds}) — adaptive vs static ==")

    def converge(label, **kw):
        cfg = RunConfig(
            n_clients=100, k=15, m=10, policy="markov", rounds=conv_rounds,
            local_epochs=2, batch_size=10,
            eval_every=max(conv_rounds // 4, 1),
            faults=("scale_attack",), fault_rate=1.0,
            fault_kwargs={"scale_attack": {"factor": ATTACK_FACTOR,
                                           "client_frac": ATTACK_FRAC}},
            **kw,
        )
        t0 = time.time()
        res = run_engine(SyncEngine(task, cfg))
        last = res.records[-1]
        extra = ""
        if res.load_stats.get("def_quarantine_inflow") is not None:
            extra = (f", quarantined {int(res.load_stats['def_quarantine_inflow'])}"
                     f", mtd level {int(res.load_stats['def_mtd_level'])}")
        print(f"  {label:14s}: eval_loss={last.eval_loss:.4f} "
              f"acc={last.accuracy:.4f} ({time.time() - t0:.1f}s{extra})")
        return last

    losses = {}
    for label, kw in (
        ("fedavg", {}),
        ("trimmed_mean", {"aggregator": "trimmed_mean",
                          "aggregator_kwargs": {"trim": 0.35}}),
        ("adaptive", {"defense": True,
                      "defense_kwargs": {**DEFENSE, **MTD}}),
    ):
        last = converge(label, **kw)
        losses[label] = last.eval_loss
        csv_rows.append((
            f"defense_convergence_attack_{label}", 0.0,
            f"loss={last.eval_loss:.4f};acc={last.accuracy:.4f}",
        ))
    static = losses["trimmed_mean"]
    adaptive = losses["adaptive"]
    # the defense must land within 10% of the static robust loss while
    # fedavg (mean cancelled by the attackers) does strictly worse
    within = np.isfinite(adaptive) and adaptive <= static * 1.10
    beats_fedavg = (adaptive < losses["fedavg"]
                    or not np.isfinite(losses["fedavg"]))
    ok = within and beats_fedavg
    print(f"  adaptive {'recovers' if ok else 'DOES NOT recover'}: "
          f"loss {adaptive:.4f} vs static {static:.4f} "
          f"vs fedavg {losses['fedavg']:.4f}")
    csv_rows.append((
        "defense_adaptive_recovers", 0.0,
        f"{'yes' if ok else 'NO'};adaptive={adaptive:.4f};"
        f"static={static:.4f};fedavg={losses['fedavg']:.4f}",
    ))
