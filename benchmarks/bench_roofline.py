"""Roofline table from the dry-run artifacts (launch/dryrun.py output).

Per (arch x shape x mesh): the three roofline terms, dominant bottleneck,
useful-flops ratio (MODEL_FLOPS / HLO_FLOPS x chips), and per-device memory
traffic — EXPERIMENTS.md §Roofline is generated from this.
"""
from __future__ import annotations

import glob
import json
import os

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def load_results(pattern: str = "dryrun_*.json"):
    rows = []
    for fn in sorted(glob.glob(os.path.join(ARTIFACTS, pattern))):
        with open(fn) as f:
            rows.append(json.load(f))
    return rows


def format_table(rows, mesh_filter=None):
    lines = []
    hdr = (f"{'arch':26s} {'shape':12s} {'mesh':8s} | {'comp ms':>9} {'mem ms':>9} "
           f"{'coll ms':>9} | {'dominant':10s} {'useful':>6} | {'flops/dev':>10}")
    lines.append(hdr)
    lines.append("-" * len(hdr))
    for r in rows:
        if mesh_filter and r.get("mesh") != mesh_filter:
            continue
        if r["status"] == "skipped":
            lines.append(f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:8s} | "
                         f"{'skipped: ' + r['reason'][:60]}")
            continue
        if r["status"] != "ok":
            lines.append(f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:8s} | ERROR")
            continue
        rf = r["roofline"]
        lines.append(
            f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:8s} | "
            f"{rf['compute_s'] * 1e3:9.2f} {rf['memory_s'] * 1e3:9.2f} "
            f"{rf['collective_s'] * 1e3:9.2f} | {rf['dominant'][:-2]:10s} "
            f"{r['useful_flops_ratio']:6.3f} | {r['flops_per_device']:.2e}"
        )
    return "\n".join(lines)


def run(csv_rows):
    rows = load_results()
    # keep the canonical (un-tagged) baselines for the table
    base = [r for r in rows if not r.get("tags")]
    if not base:
        print("\n== roofline: no dry-run artifacts found (run launch/dryrun.py) ==")
        return
    print("\n== roofline (single-pod 16x16, from dry-run artifacts) ==")
    print(format_table(base, mesh_filter="16x16"))
    print("\n== roofline (multi-pod 2x16x16) ==")
    print(format_table(base, mesh_filter="2x16x16"))
    for r in base:
        if r["status"] != "ok":
            continue
        rf = r["roofline"]
        csv_rows.append(
            (f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
             max(rf["compute_s"], rf["memory_s"], rf["collective_s"]) * 1e6,
             f"dominant={rf['dominant']};useful={r['useful_flops_ratio']:.3f};"
             f"compute_ms={rf['compute_s'] * 1e3:.2f};memory_ms={rf['memory_s'] * 1e3:.2f};"
             f"collective_ms={rf['collective_s'] * 1e3:.2f}")
        )
    n_ok = sum(r["status"] == "ok" for r in base)
    n_skip = sum(r["status"] == "skipped" for r in base)
    print(f"\npairs: ok={n_ok} documented-skips={n_skip} errors="
          f"{sum(r['status'] == 'error' for r in base)}")
