"""Paper theory table (Sec. III, Theorems 1-2, Remark 2): Var[X] of
random selection vs the optimal Markov policy, closed form vs Monte Carlo,
plus cohort statistics and scheduler communication volume.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import (
    load_metric as lm,
    make_policy,
    simulate_stats,
)
from repro.core.distributed import scheduler_comm_bytes


def run(csv_rows):
    print("\n== Var[X]: theory vs Monte Carlo (paper Sec. III) ==")
    print(f"{'n':>5} {'k':>4} {'m':>4} | {'rand thy':>9} {'rand MC':>9} | "
          f"{'mkv thy':>8} {'mkv MC':>8} | {'oldest MC':>9}")
    key = jax.random.PRNGKey(0)
    settings = [
        (100, 15, 10),  # the paper's simulation setting
        (100, 15, 3),   # m < floor(n/k): Theorem 2 case 1
        (100, 15, 1),   # Theorem 1
        (100, 50, 10),  # k >= n/2 regime
        (100, 20, 10),  # k | n: zero variance
        (500, 75, 12),
        (1000, 100, 20),
    ]
    for n, k, m in settings:
        rounds = 4000 if n <= 500 else 1500
        t0 = time.time()
        # fused scan + device accumulators: the (rounds, n) history never
        # exists, so Monte Carlo scales to fleets where it never could
        s_r = simulate_stats(make_policy("random", n, k), key, n, rounds, k)
        s_m = simulate_stats(make_policy("markov", n, k, m), key, n, rounds, k)
        s_o = simulate_stats(make_policy("oldest_age", n, k), key, n, rounds, k)
        dt = time.time() - t0
        thy_r = lm.random_selection_var(n, k)
        thy_m = lm.optimal_var(n, k, m)
        print(f"{n:5d} {k:4d} {m:4d} | {thy_r:9.3f} {s_r['var_X']:9.3f} | "
              f"{thy_m:8.3f} {s_m['var_X']:8.3f} | {s_o['var_X']:9.3f}")
        csv_rows.append(
            (f"varX_n{n}_k{k}_m{m}", dt / 3 * 1e6 / rounds,
             f"thy_markov={thy_m:.4f};mc_markov={s_m['var_X']:.4f};"
             f"thy_random={thy_r:.4f};mc_random={s_r['var_X']:.4f}")
        )

    n, k, m = 100, 15, 10
    s = simulate_stats(make_policy("markov", n, k, m), jax.random.PRNGKey(1),
                       n, 4000, k)
    print(f"\ncohort (markov n={n} k={k}): mean={s['mean_cohort']:.2f} "
          f"std={s['std_cohort']:.2f} range=[{s['min_cohort']},{s['max_cohort']}]")
    csv_rows.append(("markov_cohort_std", 0.0, f"{s['std_cohort']:.3f}"))

    print("\n== Remark 2 ablation: optimal Var[X] vs m (n=100, k=15) ==")
    n, k = 100, 15
    ms = [1, 2, 3, 4, 5, 6, 8, 10, 20]
    vals = [lm.optimal_var(n, k, m) for m in ms]
    print("  m      : " + " ".join(f"{m:7d}" for m in ms))
    print("  Var*[X]: " + " ".join(f"{v:7.3f}" for v in vals))
    print(f"  (random: {lm.random_selection_var(n, k):.3f}; saturates at "
          f"m >= floor(n/k) = {100 // 15})")
    csv_rows.append(("var_vs_m", 0.0,
                     ";".join(f"m{m}={v:.3f}" for m, v in zip(ms, vals))))

    print("\n== dropout robustness (Remark 1 / Conclusion): Var[X] vs "
          "P(update before dropout), d=5%/round ==")
    from repro.core.adaptive import tradeoff_curve

    eps, var, pup = tradeoff_curve(100, 15, 10, d=0.05,
                                   eps_grid=np.linspace(0, 1, 6))
    print(f"{'eps':>5} {'Var[X]':>8} {'P(update<drop)':>15}")
    for e, v, pu in zip(eps, var, pup):
        print(f"{e:5.2f} {v:8.3f} {pu:15.4f}")
    csv_rows.append(
        ("dropout_tradeoff", 0.0,
         ";".join(f"eps{e:.1f}:var={v:.3f},pup={pu:.4f}"
                  for e, v, pu in zip(eps, var, pup)))
    )

    print("\n== scheduler communication per round (decentralization claim) ==")
    for n_c, dev in ((1_000, 16), (1_000_000, 256), (100_000_000, 512)):
        mk, old = scheduler_comm_bytes(n_c, max(n_c * 15 // 100, 1), dev)
        print(f"n={n_c:>11,} devices={dev:4d}: markov {mk:6d} B  "
              f"oldest-age {old:>12,} B  ({old / mk:,.0f}x)")
        csv_rows.append((f"sched_comm_n{n_c}", 0.0, f"markov={mk};oldest={old}"))
