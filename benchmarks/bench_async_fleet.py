"""Async fleet simulator benchmarks: (a) the engine's step-driving hot
loop (admission -> dispatch -> pop -> re-arm, full event state, no
training) measured two ways — the legacy per-step pattern (one host
dispatch per step, non-donated state, one (n,) selection pull per step,
exactly what ``run_engine`` did before chunking) against the chunked
``ChunkRunner`` path (donated ``lax.scan``, device-resident load
accumulators, one transfer per chunk, counter-based RNG) — (b) sync
vs async federated training compared on *simulated* time-to-target
accuracy under a straggler-heavy profile — and (c) ``run_sharded``: the
mesh-sharded fleet state (per-device footprint + the O(devices * B) pop)
against the single-device chunked path, on fake CPU devices.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import load_metric as lm
from repro.core.aoi import age_update
from repro.engine.chunk import ChunkRunner, dealias_pytree, run_key
from repro.sim import events as ev_mod
from repro.sim import latency as lat_mod

KEY = jax.random.PRNGKey(0)

# chunked-path parameters: steps per donated scan dispatch, and the
# counter-based generator used for the fleet-scale fast path
CHUNK = 64
FAST_RNG = "unsafe_rbg"


def _make_sim_step(probs, m, profile, buffer_size, use_kernel, n=None, mesh=None):
    """One engine sim step over the *full* event state (the async
    engine's bookkeeping minus local training): markov admission ->
    dispatch with sampled latency/dropout -> pop next-k completions ->
    clock advance -> availability re-arm. ``step(state, key)`` with
    state = {sched, ev, speed, clock}.

    With ``mesh`` (a 1-D fleet mesh; ``n`` required, divisible by the
    mesh), the per-client state is sharded exactly like the
    ``ShardedAsyncEngine`` carry and the pop runs through the
    O(devices * B) ``sharded_next_k_events`` merge."""
    if mesh is None:
        def pop(ev):
            return ev_mod.pop_events(ev, buffer_size, use_kernel=use_kernel)

        def constrain(state):
            return state
    else:
        from repro.core import distributed as dist
        from repro.engine.sharded import fleet_state_sharding

        axis = mesh.axis_names[0]
        next_k = dist.sharded_next_k_events(mesh, n, buffer_size, axis=axis)

        def pop(ev):
            t, idx = next_k(ev["t_done"])
            return ev_mod.apply_pop(ev, t, idx)

        def constrain(state):
            return jax.tree.map(
                jax.lax.with_sharding_constraint,
                state,
                fleet_state_sharding(mesh, n, state, axis),
            )

    def step(state, key):
        ev, ages, clock = state["ev"], state["sched"], state["clock"]
        k_sel, k_lat = jax.random.split(key)
        k_gap = jax.random.fold_in(k_sel, 103)

        idle = jnp.isinf(ev["t_done"])
        available = ev["next_avail"] <= clock
        send_p = probs[jnp.minimum(ages, m)]
        want = jax.random.uniform(k_sel, ages.shape) < send_p
        send = want & idle & available
        ages = age_update(ages, send)

        latency = lat_mod.sample_latency(k_lat, profile, state["speed"])
        # zero-dropout profiles skip the 102 fold (the engine does too;
        # sample_dropout already skips the (n,) draw itself)
        if profile.dropout > 0:
            dropped = lat_mod.sample_dropout(
                jax.random.fold_in(k_sel, 102), profile, ages.shape[0]
            )
        else:
            dropped = jnp.zeros((ages.shape[0],), jnp.bool_)
        ev = ev_mod.schedule_completions(
            ev, send, clock, latency, jnp.zeros((), jnp.int32), dropped
        )
        t_ev, idx, valid, ev = pop(ev)
        clock = jnp.maximum(clock, jnp.max(jnp.where(valid, t_ev, -jnp.inf)))
        clock = jnp.where(
            valid.any(), clock, jnp.maximum(clock, jnp.min(ev["next_avail"]))
        )
        gaps = lat_mod.sample_avail_gap(k_gap, profile, buffer_size)
        ev = {
            **ev,
            "next_avail": ev["next_avail"]
            .at[ev_mod.scatter_idx(idx, valid)]
            .set(clock + gaps, mode="drop"),
            "last_done": ev["last_done"]
            .at[ev_mod.scatter_idx(idx, valid)]
            .set(t_ev, mode="drop"),
        }
        state = constrain({**state, "ev": ev, "sched": ages, "clock": clock})
        return state, {"send": send, "clock": clock}

    return step


def _sim_state(n, profile, key):
    return {
        "sched": jnp.zeros((n,), jnp.int32),
        "ev": ev_mod.init_event_state(n),
        "speed": lat_mod.client_speed(key, n, profile),
        "clock": jnp.zeros((), jnp.float32),
    }


def _bench_pure_engine(csv_rows, n, m, profile, trials=5):
    k = max(int(n * 0.15), 1)
    buf = min(max(n // 100, 16), 4096)
    probs = jnp.asarray(lm.optimal_probs(n, k, m), jnp.float32)
    on_cpu = jax.default_backend() == "cpu"
    # Pallas kernel path runs interpreted on CPU (too slow to time);
    # benchmark the jnp reference there, the kernel on real backends
    step_fn = _make_sim_step(probs, m, profile, buf, use_kernel=not on_cpu)

    # --- legacy hot loop: per-step dispatch + per-step (n,) host pull
    perstep = jax.jit(step_fn)

    # --- chunked hot loop: donated scan + device stats, one pull/chunk
    runner = ChunkRunner(step_fn, aux_keys=("clock",))

    # both paths must time the *same simulation regime*: the step's cost
    # is phase-dependent (top-k over a saturating in-flight set), so warm
    # the fleet towards steady state once and restart every timed trial
    # from copies of that snapshot
    snap = {
        **_sim_state(n, profile, KEY),
        "k_run": run_key(0, FAST_RNG),
        "load_acc": lm.init_selection_accum(n, k),
    }
    snap, _ = runner(dealias_pytree(snap), 0, CHUNK, with_history=False)
    snap, _ = runner(snap, CHUNK, CHUNK, with_history=False)
    jax.block_until_ready(snap["clock"])
    r0 = 2 * CHUNK

    def sim_only(st):
        return {k: v for k, v in st.items() if k not in ("k_run", "load_acc")}

    state_p = sim_only(snap)
    perstep(state_p, KEY)  # compile

    def time_perstep(iters):
        state = sim_only(snap)
        t0 = time.time()
        for i in range(iters):
            state, aux = perstep(state, jax.random.fold_in(KEY, r0 + i))
            _ = np.asarray(aux["send"])  # the old per-step history pull
        jax.block_until_ready(state["clock"])
        return (time.time() - t0) / iters * 1e6

    def time_chunked():
        state = jax.tree.map(jnp.copy, snap)  # donated below; keep snap
        t0 = time.time()
        state, aux = runner(state, r0, CHUNK, with_history=False)
        _ = jax.device_get(aux)  # one transfer per chunk
        return (time.time() - t0) / CHUNK * 1e6

    # interleaved trials + medians: shared boxes drift ~2x in throughput
    # over seconds, so the two paths must also sample the same machine
    # conditions for the ratio to mean anything
    iters = max(4, min(16, 2_000_000 // n))
    per_us, ch_us = [], []
    for _ in range(trials):
        per_us.append(time_perstep(iters))
        ch_us.append(time_chunked())
    per, ch = float(np.median(per_us)), float(np.median(ch_us))
    speedup = per / ch
    path = "jnp" if on_cpu else "kernel"
    print(f"  n={n:>9,} buffer={buf:5d} perstep {per / 1e3:8.2f} ms/step | "
          f"chunked {ch / 1e3:8.2f} ms/step  ({speedup:4.2f}x, {path})")
    csv_rows.append((f"async_engine_step_n{n}_perstep", per,
                     f"buffer={buf};path=perstep+pull;rng=threefry"))
    csv_rows.append((f"async_engine_step_n{n}", ch,
                     f"buffer={buf};path=chunked{CHUNK};rng={FAST_RNG};"
                     f"kernel={path};speedup={speedup:.2f}x"))


def _bench_var_x_workload(csv_rows, n, m, profile, steps):
    """The paper's telemetry workload, end to end: drive the engine for
    ``steps`` server steps *and produce the load statistics* (Var[X],
    cohort moments). The pre-chunking engine could only do this by
    materializing the (steps, n) selection history — one (n,) host pull
    per step plus an O(n)-per-client host gap extraction at finalize —
    while the chunked engine folds O(1)-per-step sufficient statistics
    into the scan and finalizes from scalars."""
    k = max(int(n * 0.15), 1)
    buf = min(max(n // 100, 16), 4096)
    probs = jnp.asarray(lm.optimal_probs(n, k, m), jnp.float32)
    on_cpu = jax.default_backend() == "cpu"
    step_fn = _make_sim_step(probs, m, profile, buf, use_kernel=not on_cpu)

    # legacy: per-step dispatch, history matrix, numpy finalize
    perstep = jax.jit(step_fn)
    state = _sim_state(n, profile, KEY)
    state, _ = perstep(state, KEY)  # compile
    jax.block_until_ready(state["clock"])
    hist = np.zeros((steps, n), dtype=bool)
    t0 = time.time()
    for r in range(steps):
        state, aux = perstep(state, jax.random.fold_in(KEY, r))
        hist[r] = np.asarray(aux["send"])
    stats_old = lm.empirical_load_stats(hist)
    per = (time.time() - t0) / steps * 1e6

    # chunked: donated scan, device accumulators, scalar finalize
    runner = ChunkRunner(step_fn, aux_keys=("clock",))
    state = dealias_pytree({
        **_sim_state(n, profile, KEY),
        "k_run": run_key(0, FAST_RNG),
        "load_acc": lm.init_selection_accum(n, k),
    })
    state, _ = runner(state, 0, steps, with_history=False)  # compile
    state = dealias_pytree({
        **_sim_state(n, profile, jax.random.fold_in(KEY, 1)),
        "k_run": run_key(1, FAST_RNG),
        "load_acc": lm.init_selection_accum(n, k),
    })
    jax.block_until_ready(state["clock"])
    t0 = time.time()
    state, aux = runner(state, 0, steps, with_history=False)
    _ = jax.device_get(aux)
    stats_new = lm.selection_stats_from_accum(state["load_acc"])
    ch = (time.time() - t0) / steps * 1e6

    speedup = per / ch
    print(f"  n={n:>9,} {steps:3d} steps: history+numpy {per / 1e3:8.2f} ms/step"
          f" | accumulators {ch / 1e3:8.2f} ms/step  ({speedup:5.1f}x)  "
          f"[Var[X] {stats_old['var_X']:.1f} vs {stats_new['var_X']:.1f}]")
    csv_rows.append((f"async_var_x_workload_n{n}", ch,
                     f"steps={steps};legacy_us={per:.1f};speedup={speedup:.2f}x"))


def _state_bytes(state) -> int:
    def nbytes(arr):
        try:
            return arr.nbytes
        except (NotImplementedError, AttributeError):
            return 0  # typed PRNG key arrays hide their buffer; negligible

    return sum(nbytes(leaf) for leaf in jax.tree.leaves(state))


def run_sharded(csv_rows, trials: int = 3):
    """ShardedAsyncEngine's hot loop vs the single-device chunked path:
    the same sim step with the fleet state sharded over every local
    device and the buffer pop routed through the O(devices * B)
    local-top-B + all_gather + merge.

    On fake CPU devices (XLA_FLAGS=--xla_force_host_platform_device_count=8,
    the recipe CI uses) all shards share one physical CPU, so wall time
    measures overhead, not the win — the decisive columns are the
    *per-device* footprint (state bytes on one device, compiled
    argument/temp sizes) and the O(devices * B) pop communication, which
    is what lets the fleet outgrow a single accelerator's memory.
    """
    from repro.core import distributed as dist
    from repro.engine.sharded import fleet_state_sharding, per_device_state_bytes

    n_devs = jax.local_device_count()
    print("\n== sharded fleet state: per-device footprint + chunked step ==")
    if n_devs < 2:
        print("  [single device: set "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8 for the "
              "sharded-vs-single-device comparison; skipping]")
        return
    m = 10
    profile = lat_mod.get_profile("lognormal")

    def build(n, mesh):
        k = max(int(n * 0.15), 1)
        buf = min(max(n // 100, 16), 4096)
        probs = jnp.asarray(lm.optimal_probs(n, k, m), jnp.float32)
        step_fn = _make_sim_step(probs, m, profile, buf, use_kernel=False,
                                 n=n, mesh=mesh)
        # dealias *before* device_put: putting the same constant-cache
        # buffer twice in one call can hand two leaves one buffer, which
        # the donated chunk then (fatally) donates twice
        state = dealias_pytree({
            **_sim_state(n, profile, KEY),
            "k_run": run_key(0, FAST_RNG),
            "load_acc": lm.init_selection_accum(n, k),
        })
        if mesh is not None:
            state = jax.device_put(
                state, fleet_state_sharding(mesh, n, state, mesh.axis_names[0])
            )
        return step_fn, state, buf

    def time_chunked(runner, snap):
        # warm towards steady state + compile, then timed trials from
        # copies of the snapshot (same regime for both paths)
        snap, _ = runner(snap, 0, CHUNK, with_history=False)
        snap, _ = runner(snap, CHUNK, CHUNK, with_history=False)
        jax.block_until_ready(snap["clock"])
        out = []
        for _ in range(trials):
            state = jax.tree.map(jnp.copy, snap)
            t0 = time.time()
            state, aux = runner(state, 2 * CHUNK, CHUNK, with_history=False)
            _ = jax.device_get(aux)
            out.append((time.time() - t0) / CHUNK * 1e6)
        return float(np.median(out)), snap

    def mem_line(step_fn, state):
        sim = {k: v for k, v in state.items() if k not in ("k_run", "load_acc")}
        stats = jax.jit(step_fn).lower(sim, KEY).compile().memory_analysis()
        return int(stats.argument_size_in_bytes), int(stats.temp_size_in_bytes)

    # --- timed comparison: one fleet size, sharded vs single device
    n = 262_144
    D = dist.resolve_fleet_shards(n, 0, n_devs)
    mesh = dist.fleet_mesh(D)
    dev0 = mesh.devices.flat[0]
    single_fn, single_state, buf = build(n, None)
    shard_fn, shard_state, _ = build(n, mesh)
    single_us, single_state = time_chunked(
        ChunkRunner(single_fn, aux_keys=("clock",)), single_state)
    shard_us, shard_state = time_chunked(
        ChunkRunner(shard_fn, aux_keys=("clock",)), shard_state)
    full_b = _state_bytes(single_state)
    per_dev_b = per_device_state_bytes(shard_state, dev0)
    s_arg, s_tmp = mem_line(shard_fn, shard_state)
    u_arg, u_tmp = mem_line(single_fn, single_state)
    print(f"  n={n:>9,} buffer={buf}: single {single_us / 1e3:8.2f} ms/step "
          f"state {full_b / 1e6:7.1f} MB | sharded x{D} "
          f"{shard_us / 1e3:8.2f} ms/step state/dev {per_dev_b / 1e6:7.1f} MB "
          f"(args {s_arg / 1e6:.1f} vs {u_arg / 1e6:.1f} MB, "
          f"temps {s_tmp / 1e6:.1f} vs {u_tmp / 1e6:.1f} MB)")
    csv_rows.append((
        f"async_engine_step_n{n}_sharded{D}", shard_us,
        f"buffer={buf};singledev_us={single_us:.1f};"
        f"state_per_dev_B={per_dev_b};state_full_B={full_b};"
        f"arg_B={s_arg};arg_full_B={u_arg};temp_B={s_tmp};temp_full_B={u_tmp}",
    ))

    # --- fleet size past a single accelerator's budget: sharded only
    n = 4_194_304
    D = dist.resolve_fleet_shards(n, 0, n_devs)
    mesh = dist.fleet_mesh(D)
    shard_fn, shard_state, buf = build(n, mesh)
    runner = ChunkRunner(shard_fn, aux_keys=("clock",))
    shard_state, _ = runner(shard_state, 0, 8, with_history=False)  # compile
    jax.block_until_ready(shard_state["clock"])
    t0 = time.time()
    shard_state, aux = runner(shard_state, 8, 8, with_history=False)
    _ = jax.device_get(aux)
    us = (time.time() - t0) / 8 * 1e6
    full_b = _state_bytes(shard_state)
    per_dev_b = per_device_state_bytes(shard_state, mesh.devices.flat[0])
    print(f"  n={n:>9,} buffer={buf}: sharded x{D} {us / 1e3:8.2f} ms/step | "
          f"state/dev {per_dev_b / 1e6:7.1f} MB of {full_b / 1e6:7.1f} MB total "
          f"({full_b / per_dev_b:.1f}x below the single-device footprint)")
    csv_rows.append((
        f"async_fleet_state_n{n}_sharded{D}", us,
        f"buffer={buf};state_per_dev_B={per_dev_b};state_full_B={full_b}",
    ))


def _mlp_task(n, n_eval=4096, d=16, hidden=128, classes=10, examples=2,
              seed=0):
    """A real FLTask at fleet scale whose cohort training is the step's
    dominant cost: tiny per-client shards (so a 262k-client fleet's data
    fits in memory) feeding an MLP big enough that the vmapped cohort of
    local updates dwarfs the event bookkeeping — the workload
    cohort-parallel execution is for."""
    from repro.fl.task import FLTask

    kd, ke, kw = jax.random.split(jax.random.PRNGKey(seed), 3)
    teacher = jax.random.normal(kw, (d, classes), jnp.float32)

    def draw(key, count):
        x = jax.random.normal(key, (count, d), jnp.float32)
        return x, jnp.argmax(x @ teacher, axis=-1)

    x, y = draw(kd, n * examples)
    cx, cy = x.reshape(n, examples, d), y.reshape(n, examples)
    tx, ty = draw(ke, n_eval)

    def init(key):
        k1, k2 = jax.random.split(key)
        return {
            "w1": (2.0 / d) ** 0.5
            * jax.random.normal(k1, (d, hidden), jnp.float32),
            "b1": jnp.zeros((hidden,), jnp.float32),
            "w2": (2.0 / hidden) ** 0.5
            * jax.random.normal(k2, (hidden, classes), jnp.float32),
            "b2": jnp.zeros((classes,), jnp.float32),
        }

    def logits_fn(p, xb):
        return jax.nn.relu(xb @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]

    def loss_fn(p, batch):
        logp = jax.nn.log_softmax(logits_fn(p, batch["x"]))
        return -jnp.take_along_axis(logp, batch["y"][:, None], axis=-1).mean()

    def eval_batch_fn(p, data):
        logits = logits_fn(p, data["x"])
        logp = jax.nn.log_softmax(logits)
        cnt = data["y"].shape[0]
        return {
            "loss": -jnp.take_along_axis(
                logp, data["y"][:, None], axis=-1
            ).sum() / cnt,
            "accuracy": (logits.argmax(-1) == data["y"]).sum() / cnt,
        }

    eval_data = {"x": tx, "y": ty}
    return FLTask(
        name=f"bench-mlp-n{n}", init=init, loss_fn=loss_fn,
        eval_fn=jax.jit(lambda p: eval_batch_fn(p, eval_data)),
        client_data={"x": cx, "y": cy}, examples_per_client=examples,
        eval_data=eval_data, eval_batch_fn=eval_batch_fn,
    )


def _time_engine_chunks(engines, chunk, trials):
    """Per-step medians for several engines driving the same workload,
    trials interleaved so every engine samples the same machine
    conditions (shared boxes drift)."""
    snaps = []
    for eng in engines:
        state = eng.init()
        state, _ = eng.run_chunk(state, 0, chunk, False)  # compile + warm
        state, _ = eng.run_chunk(state, chunk, chunk, False)
        jax.block_until_ready(jax.tree.leaves(state["params"])[0])
        snaps.append(state)
    times = [[] for _ in engines]
    for _ in range(trials):
        for i, eng in enumerate(engines):
            st = jax.tree.map(jnp.copy, snaps[i])  # run_chunk donates
            t0 = time.time()
            st, aux = eng.run_chunk(st, 2 * chunk, chunk, False)
            _ = jax.device_get(aux)
            times[i].append((time.time() - t0) / chunk * 1e6)
    return [float(np.median(t)) for t in times], snaps


def run_cohort(csv_rows, trials: int = 3):
    """Cohort-parallel execution (RunConfig.shard_cohort) vs the
    replicated-cohort layout, on the *real* engines with training in the
    step: flag-off pins every (B,)/(width,) intermediate replicated, so
    all devices redundantly run the full cohort vmap; flag-on partitions
    it, so each device trains cohort/devices clients and the aggregators
    merge with one psum of the accumulator pytree. Unlike the sim-only
    rows above, these rows measure what sharded fleets actually pay per
    step when the cohort work dominates — the case the flag exists for."""
    import dataclasses as dc

    from repro.core import distributed as dist
    from repro.engine import (
        AsyncEngine,
        RunConfig,
        ShardedAsyncEngine,
        SyncEngine,
        make_engine,
    )

    n_devs = jax.local_device_count()
    print("\n== cohort-parallel engine step: sharded vs replicated cohort ==")
    if n_devs < 2:
        print("  [single device: set "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8 for the "
              "cohort-sharded comparison; skipping]")
        return
    chunk = 8

    # --- async: 262k-client fleet, 2621-wide buffer (matches the sim-only
    # sharded row's shape), MLP cohort training in the step
    n = 262_144
    k = max(int(n * 0.15), 1)
    buf = min(max(n // 100, 16), 4096)
    D = dist.resolve_fleet_shards(n, 0, n_devs)
    task = _mlp_task(n)
    base = RunConfig(
        n_clients=n, k=k, m=10, policy="markov", rounds=4 * chunk,
        local_epochs=1, batch_size=2, mode="async", buffer_size=buf,
        profile="lognormal", steps_per_chunk=chunk, collect_history=False,
        rng_impl=FAST_RNG, eval_every=4 * chunk,
    )
    single = AsyncEngine(task, base)
    repl = ShardedAsyncEngine(task, dc.replace(base, mesh_shards=0))
    coh = make_engine(task, dc.replace(
        base, mesh_shards=0, shard_cohort=True
    ))
    (single_us, repl_us, coh_us), snaps = _time_engine_chunks(
        [single, repl, coh], chunk, trials
    )
    repl_dev_b = repl.per_device_state_bytes(snaps[1])
    coh_dev_b = coh.per_device_state_bytes(snaps[2])
    print(f"  async n={n:>9,} buffer={buf}: single {single_us / 1e3:8.2f} "
          f"ms/step | replicated x{D} {repl_us / 1e3:8.2f} ms/step | "
          f"cohort-sharded x{D} {coh_us / 1e3:8.2f} ms/step "
          f"({repl_us / coh_us:.2f}x vs replicated; state/dev "
          f"{coh_dev_b / 1e6:.1f} vs {repl_dev_b / 1e6:.1f} MB)")
    csv_rows.append((
        f"async_engine_step_n{n}_sharded{D}_cohort", coh_us,
        f"buffer={buf};replicated_us={repl_us:.1f};"
        f"singledev_us={single_us:.1f};"
        f"speedup_vs_replicated={repl_us / coh_us:.2f}x;"
        f"state_per_dev_B={coh_dev_b};state_per_dev_replicated_B={repl_dev_b}",
    ))

    # --- sync: same fleet, k sized so the padded cohort vmap is the round
    sk = 2048
    sbase = RunConfig(
        n_clients=n, k=sk, m=10, policy="markov", rounds=4 * chunk,
        local_epochs=1, batch_size=2, mode="sync",
        steps_per_chunk=chunk, collect_history=False, rng_impl=FAST_RNG,
        eval_every=4 * chunk,
    )
    width = sbase.cohort_width()
    ssingle = SyncEngine(task, sbase)
    scoh = make_engine(task, dc.replace(
        sbase, mesh_shards=0, shard_cohort=True
    ))
    (ssingle_us, scoh_us), _ = _time_engine_chunks(
        [ssingle, scoh], chunk, trials
    )
    print(f"  sync  n={n:>9,} width={width}: single {ssingle_us / 1e3:8.2f} "
          f"ms/round | cohort-sharded x{scoh.mesh_shards} "
          f"{scoh_us / 1e3:8.2f} ms/round "
          f"({ssingle_us / scoh_us:.2f}x vs single device)")
    csv_rows.append((
        f"sync_engine_round_n{n}_cohort{scoh.mesh_shards}", scoh_us,
        f"width={width};singledev_us={ssingle_us:.1f};"
        f"speedup_vs_single={ssingle_us / scoh_us:.2f}x",
    ))


def run_topo(csv_rows, trials: int = 3):
    """Topology-aware aggregation (``repro.topo``): the 2-tier
    hierarchical reduction (edge -> regional -> global) on the real
    async engine vs the flat star, single device and with the fleet
    state sharded over every local device. The tiered path segment-sums
    per-node aggregator accumulators up the tree and still merges
    cross-device with the one-psum pattern, so the decisive check is
    that the hierarchy's cost is a small constant over the star — the
    per-tier Var[X] telemetry and per-hop latency ride along in the
    same donated scan."""
    import dataclasses as dc

    from repro.core import distributed as dist
    from repro.engine import AsyncEngine, RunConfig, make_engine

    n_devs = jax.local_device_count()
    print("\n== hierarchical aggregation topology: 2-tier vs star ==")
    if n_devs < 2:
        print("  [single device: set "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8 for the "
              "sharded topology comparison; skipping]")
        return
    chunk = 8
    n = 262_144
    k = max(int(n * 0.15), 1)
    buf = min(max(n // 100, 16), 4096)
    D = dist.resolve_fleet_shards(n, 0, n_devs)
    tiers = (64, 8)
    task = _mlp_task(n)
    base = RunConfig(
        n_clients=n, k=k, m=10, policy="markov", rounds=4 * chunk,
        local_epochs=1, batch_size=2, mode="async", buffer_size=buf,
        profile="lognormal", steps_per_chunk=chunk, collect_history=False,
        rng_impl=FAST_RNG, eval_every=4 * chunk,
    )
    hcfg = dc.replace(base, topology="hierarchical",
                      topology_kwargs={"tiers": tiers})
    star = AsyncEngine(task, base)
    hier = AsyncEngine(task, hcfg)
    shard = make_engine(task, dc.replace(hcfg, mesh_shards=0))
    (star_us, hier_us, shard_us), snaps = _time_engine_chunks(
        [star, hier, shard], chunk, trials
    )
    # the per-tier load telemetry must have accumulated device-resident
    tier_stats = lm.tier_stats_from_accum(snaps[1]["tier_acc"])
    nodes = len(tier_stats["tier_var_X"])
    samples = int(sum(tier_stats["tier_num_samples"]))
    tag = "x".join(str(t) for t in tiers)
    print(f"  async n={n:>9,} buffer={buf} tiers={tiers}: star "
          f"{star_us / 1e3:8.2f} ms/step | hier {hier_us / 1e3:8.2f} ms/step "
          f"({hier_us / star_us:.2f}x) | hier sharded x{D} "
          f"{shard_us / 1e3:8.2f} ms/step "
          f"[{nodes} tier-0 nodes, {samples:,} gap samples]")
    csv_rows.append((
        f"async_engine_step_n{n}_hier{tag}", hier_us,
        f"buffer={buf};tiers={tag};star_us={star_us:.1f};"
        f"overhead_vs_star={hier_us / star_us:.2f}x;"
        f"tier0_nodes={nodes};tier_gap_samples={samples}",
    ))
    csv_rows.append((
        f"async_engine_step_n{n}_hier{tag}_sharded{D}", shard_us,
        f"buffer={buf};tiers={tag};singledev_us={hier_us:.1f};"
        f"star_us={star_us:.1f}",
    ))


def run(csv_rows, rounds: int = 12):
    print("\n== async engine hot loop: per-step+pull vs chunked scan ==")
    m = 10
    profile = lat_mod.get_profile("lognormal")
    for n in (10_000, 100_000, 1_000_000):
        _bench_pure_engine(csv_rows, n, m, profile)

    print("\n== Var[X] telemetry workload: history+numpy vs device accums ==")
    for n, steps in ((100_000, 64), (1_000_000, 16)):
        _bench_var_x_workload(csv_rows, n, m, profile, steps)

    print("\n== sync vs async: simulated time-to-target accuracy ==")
    from repro.configs.paper_cnn import MNIST_CNN
    from repro.data.synthetic import make_image_dataset
    from repro.engine import RunConfig, make_engine, run_engine
    from repro.fl import make_cnn_task

    small = dataclasses.replace(
        MNIST_CNN, name="paper-cnn-mnist-bench", image_size=16,
        conv_channels=(8, 16), fc_width=64,
    )
    train, test = make_image_dataset("mnist-bench", 10, 16, 1, 1200, 500, seed=0,
                                     difficulty=0.8)
    task = make_cnn_task(small, train, test, n_clients=40)
    base = RunConfig(n_clients=40, k=8, m=8, policy="markov", rounds=rounds,
                     local_epochs=2, batch_size=10, eval_every=1)
    profile_name = "lognormal"
    mean_lat = lat_mod.get_profile(profile_name).mean_latency()

    t0 = time.time()
    sync = run_engine(make_engine(task, base))
    sync_s = time.time() - t0
    sim_sync_t = lat_mod.simulate_sync_duration(
        sync.selection, lat_mod.get_profile(profile_name),
        jax.random.fold_in(KEY, 7),
    )

    t0 = time.time()
    acfg = dataclasses.replace(base, mode="async", buffer_size=base.k,
                               profile=profile_name)
    asy = run_engine(make_engine(task, acfg))
    async_s = time.time() - t0

    acc_sync = sync.records[-1].accuracy
    acc_async = asy.records[-1].accuracy
    sim_async_t = asy.wall_stats["sim_time"]
    print(f"  sync : acc={acc_sync:.3f} simulated {sim_sync_t:8.1f}s "
          f"(slowest-client rounds, mean client latency {mean_lat:.2f}s)")
    print(f"  async: acc={acc_async:.3f} simulated {sim_async_t:8.1f}s "
          f"(staleness mean {asy.wall_stats['mean_staleness']:.2f})")
    csv_rows.append(("async_vs_sync_sim_time", sim_async_t * 1e6,
                     f"sync={sim_sync_t:.1f}s;acc_async={acc_async:.3f};"
                     f"acc_sync={acc_sync:.3f}"))
    csv_rows.append(("async_train_steps", async_s / max(rounds, 1) * 1e6,
                     f"host_s={async_s:.1f};sync_host_s={sync_s:.1f}"))
