"""Async fleet simulator benchmarks: (a) event-engine + scheduler step
wall time vs fleet size (the simulator's own scalability — pure event
bookkeeping, no training), (b) sync vs async federated training compared
on *simulated* time-to-target-accuracy under a straggler-heavy profile.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core import load_metric as lm
from repro.core.aoi import age_update
from repro.sim import events as ev_mod
from repro.sim import latency as lat_mod

KEY = jax.random.PRNGKey(0)


def _sim_step(probs, m, profile, buffer_size, use_kernel):
    """One fused scheduler+event step: markov admission -> dispatch ->
    pop next-k completions -> re-arm. No local training (pure engine)."""

    @jax.jit
    def step(ages, t_done, clock, key):
        k_sel, k_lat = jax.random.split(key)
        idle = jnp.isinf(t_done)
        send_p = probs[jnp.minimum(ages, m)]
        send = (jax.random.uniform(k_sel, ages.shape) < send_p) & idle
        lat = lat_mod.sample_latency(k_lat, profile, jnp.ones(ages.shape, jnp.float32))
        t_done = jnp.where(send, clock + lat, t_done)
        ages = age_update(ages, send)
        t_ev, idx = ev_mod.next_k_events(t_done, buffer_size, use_kernel=use_kernel)
        valid = jnp.isfinite(t_ev)
        clock = jnp.maximum(clock, jnp.max(jnp.where(valid, t_ev, -jnp.inf)))
        t_done = t_done.at[ev_mod.scatter_idx(idx, valid)].set(jnp.inf, mode="drop")
        return ages, t_done, clock

    return step


def run(csv_rows, rounds: int = 10):
    print("\n== async event engine: scheduler+pop step vs fleet size ==")
    m = 10
    profile = lat_mod.get_profile("lognormal")
    on_cpu = jax.default_backend() == "cpu"
    for n in (10_000, 100_000, 1_000_000):
        k = max(int(n * 0.15), 1)
        buf = min(max(n // 100, 16), 4096)
        probs = jnp.asarray(lm.optimal_probs(n, k, m), jnp.float32)
        # Pallas kernel path runs interpreted on CPU (too slow to time);
        # benchmark the jnp reference there, the kernel on real backends
        step = _sim_step(probs, m, profile, buf, use_kernel=not on_cpu)
        ages = jnp.zeros((n,), jnp.int32)
        t_done = jnp.full((n,), jnp.inf, jnp.float32)
        clock = jnp.zeros((), jnp.float32)
        ages, t_done, clock = step(ages, t_done, clock, KEY)  # warm
        jax.block_until_ready(t_done)
        t0 = time.time()
        iters = 10
        for i in range(iters):
            ages, t_done, clock = step(ages, t_done, clock, jax.random.fold_in(KEY, i))
        jax.block_until_ready(t_done)
        us = (time.time() - t0) / iters * 1e6
        path = "jnp" if on_cpu else "kernel"
        print(f"  n={n:>9,} buffer={buf:5d} {us / 1e3:8.2f} ms/step ({path})")
        csv_rows.append((f"async_engine_step_n{n}", us, f"buffer={buf};path={path}"))

    print("\n== sync vs async: simulated time-to-target accuracy ==")
    from repro.configs.paper_cnn import MNIST_CNN
    from repro.data.synthetic import make_image_dataset
    from repro.engine import RunConfig, make_engine, run_engine
    from repro.fl import make_cnn_task

    small = dataclasses.replace(
        MNIST_CNN, name="paper-cnn-mnist-bench", image_size=16,
        conv_channels=(8, 16), fc_width=64,
    )
    train, test = make_image_dataset("mnist-bench", 10, 16, 1, 1200, 500, seed=0,
                                     difficulty=0.8)
    task = make_cnn_task(small, train, test, n_clients=40)
    base = RunConfig(n_clients=40, k=8, m=8, policy="markov", rounds=rounds,
                     local_epochs=2, batch_size=10, eval_every=1)
    profile_name = "lognormal"
    mean_lat = lat_mod.get_profile(profile_name).mean_latency()

    t0 = time.time()
    sync = run_engine(make_engine(task, base))
    sync_s = time.time() - t0
    sim_sync_t = lat_mod.simulate_sync_duration(
        sync.selection, lat_mod.get_profile(profile_name),
        jax.random.fold_in(KEY, 7),
    )

    t0 = time.time()
    acfg = dataclasses.replace(base, mode="async", buffer_size=base.k,
                               profile=profile_name)
    asy = run_engine(make_engine(task, acfg))
    async_s = time.time() - t0

    acc_sync = sync.records[-1].accuracy
    acc_async = asy.records[-1].accuracy
    sim_async_t = asy.wall_stats["sim_time"]
    print(f"  sync : acc={acc_sync:.3f} simulated {sim_sync_t:8.1f}s "
          f"(slowest-client rounds, mean client latency {mean_lat:.2f}s)")
    print(f"  async: acc={acc_async:.3f} simulated {sim_async_t:8.1f}s "
          f"(staleness mean {asy.wall_stats['mean_staleness']:.2f})")
    csv_rows.append(("async_vs_sync_sim_time", sim_async_t * 1e6,
                     f"sync={sim_sync_t:.1f}s;acc_async={acc_async:.3f};"
                     f"acc_sync={acc_sync:.3f}"))
    csv_rows.append(("async_train_steps", async_s / max(rounds, 1) * 1e6,
                     f"host_s={async_s:.1f};sync_host_s={sync_s:.1f}"))
