"""Learned online detection: a logistic head trained inside the scan.

``detector="learned"`` replaces the fixed OR-combination of anomaly
channels with a tiny logistic regression over the per-slot feature
vector — norm z, cosine z, clique score, flip score, shaped staleness,
shaped age-of-information, and a robust loss-delta z — trained one SGD
step per observed cohort, inside the jitted scan step.

Labels: when the run arms ``fault_exposure`` the engines pass the
per-slot fault-hit mask (evaluation mode — ground truth the defense
could never see in production); otherwise the head self-supervises
against its own quarantine outcomes (a slot is "bad" if its client is
already hot or benched), which bootstraps the head off whatever channel
first fires.

Cold start is safe by construction: a zero weight vector scores every
slot sigmoid(0) = 0.5, below the default 0.55 quarantine threshold, so
an untrained head never quarantines anyone.

State (shapes chosen to dodge the sharded engine's shape[0]==n rule —
a bare ``(F,)`` or ``(16,)`` leaf would be wrongly fleet-sharded on a
fleet of exactly that size):

  lw   (1, F)   f32  logistic head weights (feature order above + bias)
  auc  (2, 16)  f32  score histograms, row 0 fault/positive slots,
                     row 1 clean/negative — exact AUC at report time
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.defense.config import DefenseConfig

N_FEATURES = 8
N_BINS = 16


def _robust_one_sided_z(x, valid, floor):
    """z of x above the cohort's masked median, MAD-scaled (like the
    norm channel in :func:`repro.defense.reputation._slot_channels`)."""
    vcount = valid.astype(jnp.int32).sum()
    lo = jnp.maximum((vcount - 1) // 2, 0)
    hi = jnp.maximum(vcount // 2, 0)
    xs = jnp.sort(jnp.where(valid, x, jnp.inf))
    med = jnp.where(vcount > 0, (xs[lo] + xs[hi]) / 2.0, 0.0)
    ads = jnp.sort(jnp.where(valid, jnp.abs(x - med), jnp.inf))
    mad = jnp.where(vcount > 0, (ads[lo] + ads[hi]) / 2.0, 0.0)
    scale = jnp.maximum(1.4826 * mad, floor)
    return jnp.maximum((x - med) / scale, 0.0)


def feature_matrix(s_norm, s_dir, s_clique, s_flip, staleness, ages,
                   losses, valid):
    """(B, N_FEATURES) per-slot features, every channel in [0, 1]."""
    st = staleness.astype(jnp.float32)
    stale_f = 1.0 - (1.0 + st) ** -0.5
    if ages is None:
        age_f = jnp.zeros_like(s_norm)
    else:
        ag = jnp.maximum(ages.astype(jnp.float32), 0.0)
        age_f = 1.0 - (1.0 + ag) ** -0.5
    if losses is None:
        loss_f = jnp.zeros_like(s_norm)
    else:
        zl = _robust_one_sided_z(losses.astype(jnp.float32), valid, 0.05)
        loss_f = zl / (zl + 3.0)
    ones = jnp.ones_like(s_norm)
    return jnp.stack(
        [s_norm, s_dir, s_clique, s_flip, stale_f, age_f, loss_f, ones],
        axis=1)


def learned_observe(dstate, feats, valid, labels, cfg: DefenseConfig):
    """Score this cohort with the current head, then train one step.

    Returns ``(dstate, scores)`` where ``scores`` are the pre-update
    sigmoid probabilities — the online prediction, never contaminated
    by this cohort's own labels.
    """
    w = dstate["lw"][0]
    p = jax.nn.sigmoid(feats @ w)  # (B,)

    y = jnp.where(valid, labels.astype(jnp.float32), 0.0)
    grad = jnp.sum(
        jnp.where(valid[:, None], (p - y)[:, None] * feats, 0.0), axis=0)
    cnt = valid.sum(dtype=jnp.float32)
    w_new = w - cfg.learned_lr * grad / jnp.maximum(cnt, 1.0)

    bins = jnp.clip((p * N_BINS).astype(jnp.int32), 0, N_BINS - 1)
    auc = dstate["auc"]
    auc = auc.at[0, bins].add(jnp.where(valid & (y > 0.5), 1.0, 0.0))
    auc = auc.at[1, bins].add(jnp.where(valid & (y <= 0.5), 1.0, 0.0))

    dstate = {**dstate, "lw": w_new[None, :], "auc": auc}
    return dstate, p


def auc_from_hist(hist) -> float:
    """Exact ROC AUC from the (2, N_BINS) score histograms (host side).

    Ties within a bin count half, the standard rank-statistic handling;
    NaN when either class has not been observed yet.
    """
    h = np.asarray(hist, np.float64)
    pos, neg = h[0], h[1]
    p_tot, n_tot = pos.sum(), neg.sum()
    if p_tot <= 0 or n_tot <= 0:
        return float("nan")
    neg_below = np.concatenate([[0.0], np.cumsum(neg)[:-1]])
    return float((pos * (neg_below + 0.5 * neg)).sum() / (p_tot * n_tot))
