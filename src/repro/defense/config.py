"""Defense configuration — deliberately jax-free.

``RunConfig.resolved_defense()`` builds this eagerly in ``__post_init__``
(the same pattern as topology resolution), so a bad knob fails at config
time without importing jax; the jnp runtime in
:mod:`repro.defense.reputation` is only constructed by the engines.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class DefenseConfig:
    """Knobs for the detect -> quarantine -> adapt loop.

    Detection: per-client reputation is an EWMA (weight ``ewma`` on the
    newest observation) of per-cohort-slot anomaly scores in [0, 1].
    Quarantine: reputation above ``threshold`` moves a client to
    quarantined (excluded from selection AND aggregation); a quarantined
    client's reputation decays passively by ``q_decay`` per step and it
    moves to probation with per-step probability ``p_probation``.
    Probation clients are selectable again (so they generate fresh
    evidence) but stay excluded from aggregation until re-admitted with
    probability ``p_readmit`` while their reputation sits at or below the
    threshold; a probation client whose reputation crosses the threshold
    relapses to quarantine. ``threshold=inf`` arms the machinery without
    ever triggering it (bitwise-calm by construction).

    Moving-target defense (``mtd``): windowed attack pressure (suspect
    slot mass + quarantine inflow per observed slot over ``mtd_window``
    steps) walks a trim-fraction ladder ``mtd_trims``; level 0 is the
    engine's configured aggregator untouched, level L swaps in a trimmed
    mean at ``mtd_trims[L]``.
    """

    threshold: float = 0.55
    ewma: float = 0.8
    q_decay: float = 0.985
    p_probation: float = 0.15
    p_readmit: float = 0.5
    clip: float = 0.0        # >0: delta norms above this score 1.0 outright
    stale_gain: float = 0.0  # >0: staleness feeds the anomaly score
    mtd: bool = False
    mtd_window: int = 8
    mtd_trims: Tuple[float, ...] = (0.0, 0.1, 0.2, 0.35)
    mtd_up: float = 0.15
    mtd_down: float = 0.05

    def __post_init__(self):
        if not (self.threshold > 0.0):
            raise ValueError(
                f"defense threshold must be > 0 (inf disarms the trigger), "
                f"got {self.threshold}")
        if not (0.0 < self.ewma <= 1.0):
            raise ValueError(f"defense ewma must be in (0, 1], got {self.ewma}")
        if not (0.0 < self.q_decay <= 1.0):
            raise ValueError(
                f"defense q_decay must be in (0, 1], got {self.q_decay}")
        for nm in ("p_probation", "p_readmit"):
            v = getattr(self, nm)
            if not (0.0 <= v <= 1.0):
                raise ValueError(f"defense {nm} must be in [0, 1], got {v}")
        if self.clip < 0.0 or not math.isfinite(self.clip):
            raise ValueError(f"defense clip must be finite >= 0, got {self.clip}")
        if not (0.0 <= self.stale_gain <= 1.0):
            raise ValueError(
                f"defense stale_gain must be in [0, 1], got {self.stale_gain}")
        if self.mtd_window < 1:
            raise ValueError(
                f"defense mtd_window must be >= 1, got {self.mtd_window}")
        object.__setattr__(self, "mtd_trims", tuple(self.mtd_trims))
        if not self.mtd_trims:
            raise ValueError("defense mtd_trims must be non-empty")
        for t in self.mtd_trims:
            if not (0.0 <= t < 0.5):
                raise ValueError(
                    f"defense mtd_trims entries must be in [0, 0.5), got {t}")
        if not (0.0 <= self.mtd_down <= self.mtd_up <= 1.0):
            raise ValueError(
                f"defense needs 0 <= mtd_down <= mtd_up <= 1, got "
                f"down={self.mtd_down} up={self.mtd_up}")
