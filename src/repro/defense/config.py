"""Defense configuration — deliberately jax-free.

``RunConfig.resolved_defense()`` builds this eagerly in ``__post_init__``
(the same pattern as topology resolution), so a bad knob fails at config
time without importing jax; the jnp runtime in
:mod:`repro.defense.reputation` is only constructed by the engines.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

DETECTORS = ("zscore", "learned")
# aggregator families the moving-target ladder may rotate across; "base"
# is the engine's configured aggregator untouched (bitwise via the
# switch's branch 0) and must occupy level 0
MTD_FAMILIES = ("base", "trimmed_mean", "coordinate_median", "norm_clip")


@dataclasses.dataclass(frozen=True)
class DefenseConfig:
    """Knobs for the detect -> quarantine -> adapt loop.

    Detection: per-client reputation is an EWMA (weight ``ewma`` on the
    newest observation) of per-cohort-slot anomaly scores in [0, 1].
    Quarantine: reputation above ``threshold`` moves a client to
    quarantined (excluded from selection AND aggregation); a quarantined
    client's reputation decays passively by ``q_decay`` per step and it
    moves to probation with per-step probability ``p_probation``.
    Probation clients are selectable again (so they generate fresh
    evidence) but stay excluded from aggregation until re-admitted with
    probability ``p_readmit`` while their reputation sits at or below the
    threshold; a probation client whose reputation crosses the threshold
    relapses to quarantine. ``threshold=inf`` arms the machinery without
    ever triggering it (bitwise-calm by construction).

    Moving-target defense (``mtd``): windowed attack pressure (suspect
    slot mass + quarantine inflow per observed slot over ``mtd_window``
    steps) walks a trim-fraction ladder ``mtd_trims``; level 0 is the
    engine's configured aggregator untouched, level L swaps in a trimmed
    mean at ``mtd_trims[L]``. ``mtd_families`` upgrades the ladder to
    rotate across aggregator *families*: one name per rung (level 0 must
    be ``"base"``), selected inside the jitted step via ``lax.switch`` —
    ``trimmed_mean`` rungs read their trim from ``mtd_trims``,
    ``norm_clip`` clips to the cohort's median delta norm, and
    ``coordinate_median`` is parameter-free.

    Collusion scoring (``collusion``): every slot's update direction is
    count-sketched into ``d_sketch`` dims and EWMA'd (``sketch_ewma``)
    into a per-client historical-direction sketch. Clients whose
    sketches, after subtracting the cohort's coordinate-median sketch,
    still agree pairwise above ``clique_thresh`` form a clique
    (FoolsGold-style): their anomaly score and aggregation weight are
    jointly discounted. A client whose sketch *opposes* the cohort
    center scores the anti-alignment ("flip") channel — the signal a
    pure −1x sign-flip leaves that norm statistics cannot see. A sketch
    needs ``clique_min_obs`` observations before either channel fires.

    Learned detection (``detector="learned"``): a logistic head trained
    inside the scan on the per-slot feature vector (norm z, cosine z,
    clique, flip, staleness, AoI, loss delta) replaces the fixed
    OR-combination. Labels come from the per-slot fault-hit mask when
    ``RunConfig.fault_exposure`` is armed (evaluation mode) or from
    quarantine outcomes otherwise (self-supervised deployment mode);
    ``learned_lr`` is the head's SGD step size.
    """

    threshold: float = 0.55
    ewma: float = 0.8
    q_decay: float = 0.985
    p_probation: float = 0.15
    p_readmit: float = 0.5
    clip: float = 0.0        # >0: delta norms above this score 1.0 outright
    stale_gain: float = 0.0  # >0: staleness feeds the anomaly score
    detector: str = "zscore"  # zscore | learned
    learned_lr: float = 0.5   # logistic-head SGD step size
    collusion: bool = False
    d_sketch: int = 64        # historical-direction sketch width
    sketch_ewma: float = 0.25  # weight on the newest sketched direction
    clique_thresh: float = 0.6  # residual pairwise-cos clique threshold
    clique_min_obs: int = 3   # sketch observations before scoring fires
    mtd: bool = False
    mtd_window: int = 8
    mtd_trims: Tuple[float, ...] = (0.0, 0.1, 0.2, 0.35)
    mtd_families: Optional[Tuple[str, ...]] = None
    mtd_up: float = 0.15
    mtd_down: float = 0.05

    def __post_init__(self):
        if not (self.threshold > 0.0):
            raise ValueError(
                f"defense threshold must be > 0 (inf disarms the trigger), "
                f"got {self.threshold}")
        if not (0.0 < self.ewma <= 1.0):
            raise ValueError(f"defense ewma must be in (0, 1], got {self.ewma}")
        if not (0.0 < self.q_decay <= 1.0):
            raise ValueError(
                f"defense q_decay must be in (0, 1], got {self.q_decay}")
        for nm in ("p_probation", "p_readmit"):
            v = getattr(self, nm)
            if not (0.0 <= v <= 1.0):
                raise ValueError(f"defense {nm} must be in [0, 1], got {v}")
        if self.clip < 0.0 or not math.isfinite(self.clip):
            raise ValueError(f"defense clip must be finite >= 0, got {self.clip}")
        if not (0.0 <= self.stale_gain <= 1.0):
            raise ValueError(
                f"defense stale_gain must be in [0, 1], got {self.stale_gain}")
        if self.detector not in DETECTORS:
            raise ValueError(
                f"defense detector must be one of {DETECTORS}, got "
                f"{self.detector!r}")
        if not (0.0 < self.learned_lr <= 10.0):
            raise ValueError(
                f"defense learned_lr must be in (0, 10], got {self.learned_lr}")
        if self.d_sketch < 8:
            raise ValueError(
                f"defense d_sketch must be >= 8 (a narrower sketch aliases "
                f"honest directions into cliques), got {self.d_sketch}")
        if not (0.0 < self.sketch_ewma <= 1.0):
            raise ValueError(
                f"defense sketch_ewma must be in (0, 1], got "
                f"{self.sketch_ewma}")
        if not (0.0 < self.clique_thresh < 1.0):
            raise ValueError(
                f"defense clique_thresh must be in (0, 1), got "
                f"{self.clique_thresh}")
        if self.clique_min_obs < 1:
            raise ValueError(
                f"defense clique_min_obs must be >= 1, got "
                f"{self.clique_min_obs}")
        if self.mtd_window < 1:
            raise ValueError(
                f"defense mtd_window must be >= 1, got {self.mtd_window}")
        object.__setattr__(self, "mtd_trims", tuple(self.mtd_trims))
        if not self.mtd_trims:
            raise ValueError("defense mtd_trims must be non-empty")
        for t in self.mtd_trims:
            if not (0.0 <= t < 0.5):
                raise ValueError(
                    f"defense mtd_trims entries must be in [0, 0.5), got {t}")
        if self.mtd_families is not None:
            object.__setattr__(self, "mtd_families",
                               tuple(self.mtd_families))
            if not self.mtd:
                raise ValueError(
                    "defense mtd_families requires mtd=True (the family "
                    "ladder is driven by the mtd pressure window)")
            if len(self.mtd_families) != len(self.mtd_trims):
                raise ValueError(
                    f"defense mtd_families must have one family per rung "
                    f"of mtd_trims ({len(self.mtd_trims)}), got "
                    f"{len(self.mtd_families)}")
            if self.mtd_families[0] != "base":
                raise ValueError(
                    f"defense mtd_families[0] must be 'base' (level 0 is "
                    f"bitwise the configured aggregator), got "
                    f"{self.mtd_families[0]!r}")
            for f in self.mtd_families:
                if f not in MTD_FAMILIES:
                    raise ValueError(
                        f"defense mtd_families entries must be one of "
                        f"{MTD_FAMILIES}, got {f!r}")
        if not (0.0 <= self.mtd_down <= self.mtd_up <= 1.0):
            raise ValueError(
                f"defense needs 0 <= mtd_down <= mtd_up <= 1, got "
                f"down={self.mtd_down} up={self.mtd_up}")
