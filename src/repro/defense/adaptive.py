"""Moving-target aggregation: rotate the robust rule online.

``adaptive_aggregate`` wraps the engines' aggregate hook. Level 0 on
the mtd ladder is the configured base rule, selected through
``lax.cond``/``lax.switch`` so a calm fleet never pays for (or perturbs
— the taken branch is bitwise) the alternatives; level L >= 1 swaps in
a robust rule selected *inside* the jitted step — the rotation is carry
state, not a recompile.

Two ladder shapes. The default (``mtd_families=None``) walks trim
fractions of one rule: a trimmed mean whose traced ``trim`` is read
from ``mtd_trims[level]``. With ``mtd_families`` the rungs rotate
across aggregator *families* — an attacker who has tuned an evasion
against one robust rule (scale just under the trim quantile, collude
through the median's blind coordinates) finds the target moved:

  * ``base``              — the engine's configured rule, untouched
  * ``trimmed_mean``      — static per-rung trim from ``mtd_trims``
  * ``coordinate_median`` — parameter-free, maximum breakdown
  * ``norm_clip``         — per-slot L2 clip at the cohort's *median*
                            delta norm (dynamic; the static-clip twin
                            lives in ``engine.robust``)

Each family mirrors the sort/rank/clip arithmetic of its
``engine.robust`` registry twin. All of these are order statistics or
norm statistics over the whole cohort axis, hence non-additive —
config rejects mtd under tiered topologies and cohort-sharded
aggregation up front.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.engine.aggregators import tree_where


def _trimmed_mean_delta(g, updates, bases, w, trim):
    """g + per-coordinate trimmed mean of valid deltas, traced trim."""
    valid = w > 0
    c = valid.astype(jnp.int32).sum()
    cf = c.astype(jnp.float32)
    t = jnp.clip(jnp.floor(cf * trim).astype(jnp.int32), 0,
                 jnp.maximum((c - 1) // 2, 0))

    def one(gl, u, b):
        ws = (-1,) + (1,) * (u.ndim - 1)
        d = jnp.where(valid.reshape(ws), (u - b).astype(jnp.float32),
                      jnp.inf)
        d_sorted = jnp.sort(d, axis=0)
        ranks = jnp.arange(u.shape[0]).reshape(ws)
        keep = (ranks >= t) & (ranks < c - t)
        mean = jnp.where(keep, d_sorted, 0.0).sum(axis=0) \
            / jnp.maximum(c - 2 * t, 1)
        return (gl + mean.astype(gl.dtype)).astype(gl.dtype)

    moved = jax.tree.map(one, g, updates, bases)
    return tree_where(c > 0, moved, g)  # empty cohort: params stand


def _coordinate_median_delta(g, updates, bases, w):
    """g + per-coordinate median of valid deltas — the lo/hi sorted-rank
    pick of ``engine.robust.make_coordinate_median``, inlined."""
    valid = w > 0
    c = valid.astype(jnp.int32).sum()
    lo = jnp.maximum((c - 1) // 2, 0)
    hi = jnp.maximum(c // 2, 0)

    def one(gl, u, b):
        ws = (-1,) + (1,) * (u.ndim - 1)
        d = jnp.where(valid.reshape(ws), (u - b).astype(jnp.float32),
                      jnp.inf)
        d_sorted = jnp.sort(d, axis=0)
        ranks = jnp.arange(u.shape[0]).reshape(ws)
        pick = jnp.where(c > 0,
                         (ranks == lo).astype(jnp.float32)
                         + (ranks == hi).astype(jnp.float32), 0.0)
        med = jnp.where(
            c > 0, jnp.sum(jnp.where(pick > 0, d_sorted * pick, 0.0),
                           axis=0) / 2.0, 0.0)
        return (gl + med.astype(gl.dtype)).astype(gl.dtype)

    moved = jax.tree.map(one, g, updates, bases)
    return tree_where(c > 0, moved, g)


def _norm_clip_delta(g, updates, bases, w):
    """g + weighted mean of deltas L2-clipped at the cohort's *median*
    delta norm — ``engine.robust.make_norm_clip`` arithmetic with the
    static clip replaced by a per-cohort order statistic, so the rung
    needs no tuned radius."""
    valid = w > 0
    c = valid.astype(jnp.int32).sum()
    lo = jnp.maximum((c - 1) // 2, 0)
    hi = jnp.maximum(c // 2, 0)

    nonb = lambda d: tuple(range(1, d.ndim))  # noqa: E731
    deltas = jax.tree.map(
        lambda u, b: (u - b).astype(jnp.float32), updates, bases)
    sq = sum(jnp.sum(d * d, axis=nonb(d)) for d in jax.tree.leaves(deltas))
    norm = jnp.sqrt(sq)
    ns = jnp.sort(jnp.where(valid, norm, jnp.inf))
    clip = jnp.where(c > 0, (ns[lo] + ns[hi]) / 2.0, 0.0)
    scale = jnp.minimum(1.0, clip / jnp.maximum(norm, 1e-12))
    ws = w * scale
    wsum = w.sum()
    denom = jnp.maximum(wsum, 1e-9)

    def one(gl, d):
        ds = jnp.sum(d * ws.reshape((-1,) + (1,) * (d.ndim - 1)), axis=0)
        return (gl + (ds / denom).astype(gl.dtype)).astype(gl.dtype)

    moved = jax.tree.map(one, g, deltas)
    return tree_where(wsum > 0, moved, g)


def _family_branch(fam, trim):
    """One ``lax.switch`` rung: (g, updates, bases, w, base_params) ->
    params. ``trim`` is static per rung (read from ``mtd_trims``)."""
    if fam == "base":
        return lambda g, u, b, w, bp: bp
    if fam == "trimmed_mean":
        return lambda g, u, b, w, bp: _trimmed_mean_delta(g, u, b, w, trim)
    if fam == "coordinate_median":
        return lambda g, u, b, w, bp: _coordinate_median_delta(g, u, b, w)
    if fam == "norm_clip":
        return lambda g, u, b, w, bp: _norm_clip_delta(g, u, b, w)
    raise ValueError(f"unknown mtd family {fam!r}")  # config validated


def adaptive_aggregate(base_apply, trims, families=None):
    """Wrap an engine aggregate hook with the mtd ladder.

    Returns ``apply(g, updates, bases, w, idx, level)``; the base
    rule's stats are surfaced whatever the level, so counters like
    ``agg_clipped`` keep their meaning while the ladder is hot.
    ``families`` (validated upstream: same length as ``trims``, entry 0
    ``"base"``) switches the ladder from trim fractions to aggregator
    families; level 0 passes the base rule's params through untouched
    either way.
    """
    trims_dev = jnp.asarray(trims, jnp.float32)

    if families is None:
        def apply(g, updates, bases, w, idx, level):
            base_params, stats = base_apply(g, updates, bases, w, idx)
            params = jax.lax.cond(
                level > 0,
                lambda: _trimmed_mean_delta(g, updates, bases, w,
                                            trims_dev[level]),
                lambda: base_params,
            )
            return params, stats

        return apply

    branches = [_family_branch(f, float(t)) for f, t in zip(families, trims)]

    def apply(g, updates, bases, w, idx, level):
        base_params, stats = base_apply(g, updates, bases, w, idx)
        lvl = jnp.clip(level, 0, len(branches) - 1)
        params = jax.lax.switch(lvl, branches, g, updates, bases, w,
                                base_params)
        return params, stats

    return apply
