"""Moving-target aggregation: rotate the robust rule online.

``adaptive_aggregate`` wraps the engines' aggregate hook. Level 0 on
the mtd trim ladder is the configured base rule, selected through
``lax.cond`` so a calm fleet never pays for (or perturbs — the taken
branch is bitwise) the alternative; level L >= 1 swaps in a trimmed
mean whose trim fraction is read from the ladder *inside* the jitted
step — the rotation is carry state, not a recompile.

The trimmed mean here is the dynamic-trim twin of
``engine.robust.make_trimmed_mean``: identical sort/rank arithmetic,
but ``trim`` is a traced scalar. It is an order statistic over the
whole cohort axis, hence non-additive — config rejects mtd under
tiered topologies and cohort-sharded aggregation up front.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.engine.aggregators import tree_where


def _trimmed_mean_delta(g, updates, bases, w, trim):
    """g + per-coordinate trimmed mean of valid deltas, traced trim."""
    valid = w > 0
    c = valid.astype(jnp.int32).sum()
    cf = c.astype(jnp.float32)
    t = jnp.clip(jnp.floor(cf * trim).astype(jnp.int32), 0,
                 jnp.maximum((c - 1) // 2, 0))

    def one(gl, u, b):
        ws = (-1,) + (1,) * (u.ndim - 1)
        d = jnp.where(valid.reshape(ws), (u - b).astype(jnp.float32),
                      jnp.inf)
        d_sorted = jnp.sort(d, axis=0)
        ranks = jnp.arange(u.shape[0]).reshape(ws)
        keep = (ranks >= t) & (ranks < c - t)
        mean = jnp.where(keep, d_sorted, 0.0).sum(axis=0) \
            / jnp.maximum(c - 2 * t, 1)
        return (gl + mean.astype(gl.dtype)).astype(gl.dtype)

    moved = jax.tree.map(one, g, updates, bases)
    return tree_where(c > 0, moved, g)  # empty cohort: params stand


def adaptive_aggregate(base_apply, trims):
    """Wrap an engine aggregate hook with the mtd ladder.

    Returns ``apply(g, updates, bases, w, idx, level)``; the base
    rule's stats are surfaced whatever the level, so counters like
    ``agg_clipped`` keep their meaning while the ladder is hot.
    """
    trims_dev = jnp.asarray(trims, jnp.float32)

    def apply(g, updates, bases, w, idx, level):
        base_params, stats = base_apply(g, updates, bases, w, idx)
        params = jax.lax.cond(
            level > 0,
            lambda: _trimmed_mean_delta(g, updates, bases, w,
                                        trims_dev[level]),
            lambda: base_params,
        )
        return params, stats

    return apply
