"""Collusion scoring: historical-direction sketches and clique detection.

A coalition submitting a shared poisoned direction is invisible to
per-slot norm statistics and can steer the norm-clipped-mean center the
cosine score is measured against. What a coalition *cannot* hide is
agreement with itself over time: every member's update direction keeps
pointing the same way while honest clients' directions decorrelate
round to round (data heterogeneity + SGD noise).

The memory-bounded signal is a count-sketch: each slot's update delta is
projected into ``d_sketch`` dims (fixed random signed-bucket projection,
generated host-side from a hard-coded seed at trace time, so single- and
sharded-engine runs embed identical constants) and EWMA'd into a
per-client ``(n, d_sketch)`` historical sketch riding the scan carry —
O(n) memory like every other defense leaf, and sharded ``P(fleet)`` by
the usual shape[0]==n rule.

Scoring is FoolsGold-flavoured but *residual-centered*: the EWMA
averages away idiosyncratic noise, so raw pairwise cosine over histories
saturates near 1 for everyone once honest clients align. Subtracting
the cohort's coordinate-median sketch first makes honest residuals
decorrelate (cos ~ N(0, 1/d_sketch)) while clique members share the
(poison - center) residual (cos ~ 1). A residual-norm gate keeps
well-aligned honest clients (tiny residuals, direction dominated by
noise) out of the pairing entirely. A separate "flip" channel scores
anti-alignment of a history with the cohort center — the signature a
pure -1x sign-flip leaves even when acting alone.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.load_metric import ewma_scatter_update_rows
from repro.defense.config import DefenseConfig

# Host-side RNG seed for the signed-bucket projection. Fixed so the
# projection is a pure function of the leaf shapes: every engine (chunked,
# sharded, restarted) embeds bit-identical constants.
PROJECTION_SEED = 0x5EEDC11E

# residual L2-norm gate: unit-normalized histories sit within 2 of any
# center, honest residuals measure ~sqrt(1 - |center|^2) plus noise
RESID_GATE = 0.8
# center-norm gate for the flip channel: with no cohort consensus there
# is nothing to anti-align with
CENTER_GATE = 0.2
# flip-score half-point: a converged flipped sketch reads anti-alignment
# fx ~ 0.2-0.4 (honest late-training alignment is weak, never strong)
# while honest noise sits under ~0.05, so fx/(fx + FLIP_HALF) pushes
# real flips well past the noise floor
FLIP_HALF = 0.15

_PROJ_CACHE: dict = {}


def _projection(shapes, d_sketch: int):
    """Per-leaf (bucket, sign) projection constants, cached by shape."""
    key = (tuple(shapes), int(d_sketch))
    cached = _PROJ_CACHE.get(key)
    if cached is None:
        rng = np.random.default_rng(PROJECTION_SEED)
        cached = []
        for shp in shapes:
            m = int(np.prod(shp, dtype=np.int64)) if shp else 1
            h = rng.integers(0, d_sketch, size=m).astype(np.int32)
            s = (rng.integers(0, 2, size=m) * 2 - 1).astype(np.float32)
            cached.append((h, s))
        _PROJ_CACHE[key] = cached
    return cached


def project_deltas(updated, bases, d_sketch: int):
    """Count-sketch each slot's update delta into (B, d_sketch) unit rows.

    ``bases`` may be stacked ``(B, ...)`` dispatch snapshots (async) or
    the unstacked global params (sync); both broadcast. Zero deltas stay
    exact zero rows (they carry no direction evidence).
    """
    lu, lb = jax.tree.leaves(updated), jax.tree.leaves(bases)
    shapes = tuple(tuple(u.shape[1:]) for u in lu)
    planes = _projection(shapes, d_sketch)
    b = lu[0].shape[0]
    out = jnp.zeros((b, d_sketch), jnp.float32)
    for (h, s), u, base in zip(planes, lu, lb):
        d = (u - base).astype(jnp.float32).reshape(b, -1)
        out = out + jax.ops.segment_sum(
            (d * s[None, :]).T, jnp.asarray(h), num_segments=d_sketch).T
    nrm = jnp.sqrt(jnp.sum(out * out, axis=1, keepdims=True))
    return jnp.where(nrm > 1e-12, out / jnp.maximum(nrm, 1e-12), 0.0)


def clique_scores(hists, obs, valid, idx, cfg: DefenseConfig):
    """Per-slot (s_clique, s_flip) in [0, 1] from gathered history rows.

    Pure in its array arguments and slot-permutation equivariant:
    every reduction over the slot axis is a sort or a max, so permuting
    ``(hists, obs, valid, idx)`` permutes the outputs — exactly up to
    float reassociation in the two matmuls (GEMM tiling picks per-
    position micro-kernels, worth ~1 ulp). The engines' bitwise
    replay/sharding contracts are unaffected: they always present the
    cohort in the same slot order.

    ``idx`` guards self-pairing: duplicate slots of one client (async
    re-dispatch races) agree with themselves trivially and must not form
    a "clique" of one.
    """
    b = hists.shape[0]
    hn = jnp.sqrt(jnp.sum(hists * hists, axis=1, keepdims=True))
    hu = jnp.where(hn > 1e-12, hists / jnp.maximum(hn, 1e-12), 0.0)
    seen = valid & (obs >= cfg.clique_min_obs) & (hn[:, 0] > 1e-12)

    # masked coordinate median of seen histories -> cohort center sketch
    m = seen.astype(jnp.int32).sum()
    lo = jnp.maximum((m - 1) // 2, 0)
    hi = jnp.maximum(m // 2, 0)
    col = jnp.sort(jnp.where(seen[:, None], hu, jnp.inf), axis=0)
    center = jnp.where(m > 0, (col[lo] + col[hi]) / 2.0, 0.0)  # (d,)
    cn = jnp.sqrt(jnp.sum(center * center))
    cu = jnp.where(cn > 1e-12, center / jnp.maximum(cn, 1e-12), 0.0)

    # flip channel: anti-alignment with the consensus direction
    align = hu @ cu  # (B,)
    fx = jnp.maximum(-align, 0.0)
    s_flip = jnp.where(seen & (cn > CENTER_GATE), fx / (fx + FLIP_HALF), 0.0)

    # clique channel: pairwise agreement of *residual* directions
    resid = hu - center[None, :]
    rn = jnp.sqrt(jnp.sum(resid * resid, axis=1))
    elig = seen & (rn > RESID_GATE)
    ru = jnp.where(rn[:, None] > 1e-12,
                   resid / jnp.maximum(rn[:, None], 1e-12), 0.0)
    cs = ru @ ru.T  # (B, B)
    pair = elig[:, None] & elig[None, :] & (idx[:, None] != idx[None, :])
    maxcs = jnp.max(jnp.where(pair, cs, -1.0), axis=1)
    s_clique = jnp.where(
        elig,
        jnp.clip((maxcs - cfg.clique_thresh) / (1.0 - cfg.clique_thresh),
                 0.0, 1.0),
        0.0)
    return s_clique, s_flip


def collusion_observe(dstate, updated, bases, idx, valid,
                      cfg: DefenseConfig):
    """Update the sketches with this cohort and score it.

    Returns ``(dstate, s_clique, s_flip)``; the caller turns ``s_clique``
    into both a reputation term and the aggregation-weight discount
    ``1 - s_clique`` (exact 1.0 for every clique-free slot, so a calm
    armed run multiplies weights by exact ones).
    """
    rows = project_deltas(updated, bases, cfg.d_sketch)
    sketch = ewma_scatter_update_rows(
        dstate["sketch"], idx, rows, valid, cfg.sketch_ewma)
    sk_obs = dstate["sk_obs"].at[idx].add(
        jnp.where(valid, 1.0, 0.0), mode="drop")
    hists = sketch[idx]
    obs = sk_obs[idx]
    s_clique, s_flip = clique_scores(hists, obs, valid, idx, cfg)
    hits = jnp.sum(jnp.where(valid & (s_clique > 0.5), 1.0, 0.0))
    dstate = {**dstate, "sketch": sketch, "sk_obs": sk_obs,
              "clique_hits": dstate["clique_hits"] + hits}
    return dstate, s_clique, s_flip
