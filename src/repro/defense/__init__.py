"""Adaptive defense tier: per-client reputation, quarantine with a
probation Markov chain, and moving-target aggregation — all riding the
engines' donated scan carry (see :mod:`repro.defense.reputation`).

The package import is lazy so ``RunConfig``'s eager defense validation
(``repro.defense.config`` is a plain dataclass module) stays jax-free;
the jnp runtime loads only when an engine builds it.
"""
from repro.defense.config import DETECTORS, MTD_FAMILIES, DefenseConfig

__all__ = [
    "DEFENSE_FOLD",
    "DETECTORS",
    "Defense",
    "DefenseConfig",
    "MTD_FAMILIES",
    "adaptive_aggregate",
    "auc_from_hist",
    "clique_scores",
    "make_defense",
]


def __getattr__(name):
    if name in ("DEFENSE_FOLD", "Defense", "make_defense"):
        from repro.defense import reputation

        return getattr(reputation, name)
    if name == "adaptive_aggregate":
        from repro.defense.adaptive import adaptive_aggregate

        return adaptive_aggregate
    if name == "clique_scores":
        from repro.defense.collusion import clique_scores

        return clique_scores
    if name == "auc_from_hist":
        from repro.defense.learned import auc_from_hist

        return auc_from_hist
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
