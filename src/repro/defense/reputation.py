"""Per-client reputation, quarantine, and probation — the jnp runtime.

The ``Defense`` object is the engines' counterpart of ``FaultSet``: its
state dict rides the donated scan carry (``state["defense"]``), every
random draw lives on a dedicated key fold (108 off the per-step
selection key, sub-folds 0/1 for the probation/readmit coins), and every
armed effect is applied through ``jnp.where`` / ``& ~mask`` seams so an
armed-but-never-triggered defense leaves the training stream bit-for-bit
the calm run.

State layout (``(n,)`` leaves shard ``P(fleet)`` under the sharded
engine via the usual shape[0]==n rule; scalars replicate):

  rep         (n,) f32  EWMA anomaly score in [0, 1]
  status      (n,) i32  0 active / 1 quarantined / 2 probation
  quarantined ()   f32  cumulative quarantine inflow (incl. relapses)
  readmitted  ()   f32  cumulative probation -> active re-admissions
  pressure    ()   f32  windowed attack-pressure accumulator (mtd)
  win_obs     ()   f32  windowed observed-slot count (mtd)
  win         ()   i32  steps into the current mtd window
  level       ()   i32  current rung on the mtd trim ladder

armed only with ``collusion=True`` (see :mod:`repro.defense.collusion`):

  sketch      (n, d_sketch) f32  EWMA historical-direction sketches
  sk_obs      (n,) f32  sketch observation counts
  clique_hits ()   f32  cumulative clique-discounted slot count

armed only with ``detector="learned"`` (see :mod:`repro.defense.learned`):

  lw          (1, F)  f32  logistic-head weights
  auc         (2, 16) f32  pos/neg score histograms for exact AUC
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.load_metric import ewma_scatter_update
from repro.defense.collusion import collusion_observe
from repro.defense.config import DefenseConfig
from repro.defense.learned import (
    N_BINS, N_FEATURES, auc_from_hist, feature_matrix, learned_observe)

DEFENSE_FOLD = 108  # per-step key fold off k_sel, after faults (105) + rd


def _slot_channels(updated, bases, valid):
    """Raw per-cohort-slot anomaly channels ``(s_norm, s_dir, norm)``.

    (a) the slot delta's L2-norm z-score against the cohort's median/MAD
    norm, (b) misalignment (cosine) with the cohort's robust center — a
    norm-clipped mean, which a minority of scaled/flipped attackers
    cannot steer the way they cancel the plain mean. ``bases`` may be
    stacked ``(B, ...)`` (async dispatch snapshots) or the unstacked
    global params (sync); both broadcast.
    """
    lu, lb = jax.tree.leaves(updated), jax.tree.leaves(bases)
    deltas = [(u - b).astype(jnp.float32) for u, b in zip(lu, lb)]
    nonb = lambda d: tuple(range(1, d.ndim))  # noqa: E731
    sq = sum(jnp.sum(d * d, axis=nonb(d)) for d in deltas)
    norm = jnp.sqrt(sq)  # (B,)

    # median + MAD of valid slot norms (scalar sorts, invalid -> +inf)
    vcount = valid.astype(jnp.int32).sum()
    lo = jnp.maximum((vcount - 1) // 2, 0)
    hi = jnp.maximum(vcount // 2, 0)
    ns = jnp.sort(jnp.where(valid, norm, jnp.inf))
    nmed = jnp.where(vcount > 0, (ns[lo] + ns[hi]) / 2.0, 0.0)
    ads = jnp.sort(jnp.where(valid, jnp.abs(norm - nmed), jnp.inf))
    nmad = jnp.where(vcount > 0, (ads[lo] + ads[hi]) / 2.0, 0.0)
    scale = jnp.maximum(1.4826 * nmad, 0.05 * nmed + 1e-6)
    z = jnp.maximum((norm - nmed) / scale, 0.0)
    s_norm = z / (z + 3.0)

    # robust center: mean of deltas with norms clipped to the median —
    # O(B * params), no per-coordinate sort on the hot path
    cw = jnp.where(valid, jnp.minimum(1.0, nmed / jnp.maximum(norm, 1e-12)),
                   0.0) / jnp.maximum(vcount.astype(jnp.float32), 1.0)
    center = [jnp.tensordot(cw, d, axes=1) for d in deltas]
    dot = sum(jnp.sum(d * m, axis=nonb(d)) for d, m in zip(deltas, center))
    cnorm = jnp.sqrt(sum(jnp.sum(m * m) for m in center))
    cos = dot / (norm * cnorm + 1e-12)
    # one-sided robust z of the cosine: honest slots cluster around the
    # cohort's median alignment (whatever SGD noise makes it); suspicion
    # is pointing *away* from it. Raw cosine thresholds cannot separate
    # a sign-flipper from high-dimensional gradient noise — the z-score
    # against the cohort's own cosine spread can.
    cs = jnp.sort(jnp.where(valid, cos, jnp.inf))
    cmed = jnp.where(vcount > 0, (cs[lo] + cs[hi]) / 2.0, 0.0)
    cads = jnp.sort(jnp.where(valid, jnp.abs(cos - cmed), jnp.inf))
    cmad = jnp.where(vcount > 0, (cads[lo] + cads[hi]) / 2.0, 0.0)
    cscale = jnp.maximum(1.4826 * cmad, 0.05)
    # sharper shaping than the norm channel: a flipped delta's cosine z
    # saturates near 3-5 once honest alignment shrinks late in training
    # (the norm z of a scaled attack runs 10x that), so z/(z+3) would
    # plateau just under any usable threshold
    zc = jnp.maximum((cmed - cos) / cscale, 0.0)
    s_dir = zc / (zc + 1.5)
    return s_norm, s_dir, norm


def _shape_scores(score, norm, staleness, cfg: DefenseConfig):
    """Optional staleness and hard-clip terms on top of a raw score."""
    if cfg.stale_gain > 0.0:
        st = staleness.astype(jnp.float32)
        score = jnp.maximum(score, cfg.stale_gain * (1.0 - (1.0 + st) ** -0.5))
    if cfg.clip > 0.0:
        score = jnp.where(norm > cfg.clip, 1.0, score)
    return score


def _slot_scores(updated, bases, valid, staleness, cfg: DefenseConfig):
    """Per-cohort-slot anomaly scores in [0, 1]: the norm and cosine
    channels of :func:`_slot_channels`, OR-combined, with the optional
    staleness and hard-clip terms riding on top."""
    s_norm, s_dir, norm = _slot_channels(updated, bases, valid)
    score = 1.0 - (1.0 - s_norm) * (1.0 - s_dir)
    return _shape_scores(score, norm, staleness, cfg)


class Defense:
    """Stateful detect -> quarantine -> adapt loop for one fleet."""

    def __init__(self, n: int, cfg: DefenseConfig):
        self.n = int(n)
        self.cfg = cfg

    @property
    def mtd(self) -> bool:
        return self.cfg.mtd

    @property
    def collusion(self) -> bool:
        return self.cfg.collusion

    @property
    def learned(self) -> bool:
        return self.cfg.detector == "learned"

    @property
    def wants_labels(self) -> bool:
        """Whether the engines should pass fault-hit ground truth
        (only consumed by the learned head, only when exposure is on)."""
        return self.learned

    def init(self):
        n = self.n
        z = jnp.zeros(())
        state = {
            "rep": jnp.zeros((n,), jnp.float32),
            "status": jnp.zeros((n,), jnp.int32),
            "quarantined": z, "readmitted": z,
            "pressure": z, "win_obs": z,
            "win": jnp.zeros((), jnp.int32),
            "level": jnp.zeros((), jnp.int32),
        }
        if self.collusion:
            state["sketch"] = jnp.zeros((n, self.cfg.d_sketch), jnp.float32)
            state["sk_obs"] = jnp.zeros((n,), jnp.float32)
            state["clique_hits"] = z
        if self.learned:
            # (1, F) / (2, 16): a bare (F,) or (16,) leaf would collide
            # with the sharded engine's shape[0]==n fleet-leaf rule on
            # small test fleets
            state["lw"] = jnp.zeros((1, N_FEATURES), jnp.float32)
            state["auc"] = jnp.zeros((2, N_BINS), jnp.float32)
        return state

    def blocked(self, dstate):
        """(n,) bool — barred from selection (quarantined only;
        probation clients are selectable so they generate evidence)."""
        return dstate["status"] == 1

    def observe(self, dstate, key, updated, bases, idx, valid, staleness,
                losses=None, ages=None, labels=None):
        """Score the cohort, update reputation, run the quarantine
        chain, and advance the mtd pressure window.

        Returns ``(dstate, excluded, w_scale)``: ``excluded`` is the
        (n,) post-transition suspect mask (status != 0) the caller must
        apply to the aggregation validity — the same seam heartbeat dark
        clients use; ``w_scale`` is a (B,) per-slot aggregation-weight
        discount (``1 - s_clique``) when collusion scoring is armed,
        else None. ``losses``/``ages`` feed the learned head's feature
        vector; ``labels`` is the per-slot fault-hit ground truth when
        ``fault_exposure`` arms evaluation mode (None -> the head
        self-supervises against its own quarantine outcomes).
        """
        cfg = self.cfg
        w_scale = None
        if not self.collusion and not self.learned:
            # PR 9 path, bit-for-bit: same ops, same order
            scores = _slot_scores(updated, bases, valid, staleness, cfg)
        else:
            s_norm, s_dir, norm = _slot_channels(updated, bases, valid)
            if self.collusion:
                dstate, s_clique, s_flip = collusion_observe(
                    dstate, updated, bases, idx, valid, cfg)
                w_scale = 1.0 - s_clique
            else:
                s_clique = jnp.zeros_like(s_norm)
                s_flip = jnp.zeros_like(s_norm)
            if self.learned:
                feats = feature_matrix(s_norm, s_dir, s_clique, s_flip,
                                       staleness, ages, losses, valid)
                if labels is None:
                    # deployment mode: self-supervise against outcomes
                    labels = ((dstate["rep"][idx] > cfg.threshold)
                              | (dstate["status"][idx] != 0))
                dstate, scores = learned_observe(
                    dstate, feats, valid, labels, cfg)
                # staleness already sits in the feature vector; the
                # hard norm clip stays as a non-negotiable override
                if cfg.clip > 0.0:
                    scores = jnp.where(norm > cfg.clip, 1.0, scores)
            else:
                score = 1.0 - ((1.0 - s_norm) * (1.0 - s_dir)
                               * (1.0 - s_clique) * (1.0 - s_flip))
                scores = _shape_scores(score, norm, staleness, cfg)

        status = dstate["status"]
        # passive decay while benched, then fresh evidence (probation
        # clients can be observed; the scatter is add-of-zero for
        # invalid slots, so padded/duplicate idx slots are safe)
        rep = jnp.where(status != 0, dstate["rep"] * cfg.q_decay,
                        dstate["rep"])
        rep = ewma_scatter_update(rep, idx, scores, valid, cfg.ewma)

        k_prob, k_read = (jax.random.fold_in(key, 0),
                          jax.random.fold_in(key, 1))
        hot = rep > cfg.threshold
        to_quar = (status == 0) & hot
        relapse = (status == 2) & hot
        to_prob = (status == 1) & jax.random.bernoulli(
            k_prob, cfg.p_probation, (self.n,))
        to_active = ((status == 2) & ~hot
                     & jax.random.bernoulli(k_read, cfg.p_readmit, (self.n,)))
        status = jnp.where(
            to_quar | relapse, 1,
            jnp.where(to_prob, 2, jnp.where(to_active, 0, status)))
        inflow = (to_quar | relapse).sum(dtype=jnp.float32)
        readmits = to_active.sum(dtype=jnp.float32)

        out = {
            **dstate, "rep": rep, "status": status,
            "quarantined": dstate["quarantined"] + inflow,
            "readmitted": dstate["readmitted"] + readmits,
        }
        if cfg.mtd:
            press = dstate["pressure"] + inflow + jnp.sum(
                valid & (scores > cfg.threshold), dtype=jnp.float32)
            obs = dstate["win_obs"] + valid.sum(dtype=jnp.float32)
            win = dstate["win"] + 1
            done = win >= cfg.mtd_window
            ratio = press / jnp.maximum(obs, 1.0)
            step = ((ratio > cfg.mtd_up).astype(jnp.int32)
                    - (ratio < cfg.mtd_down).astype(jnp.int32))
            level = jnp.clip(dstate["level"] + jnp.where(done, step, 0),
                             0, len(cfg.mtd_trims) - 1)
            zero = jnp.zeros(())
            out.update(
                pressure=jnp.where(done, zero, press),
                win_obs=jnp.where(done, zero, obs),
                win=jnp.where(done, 0, win), level=level,
            )
        return out, out["status"] != 0, w_scale

    # ---- host-side reporting ------------------------------------------

    def report(self, dstate):
        """Scalar counters for ``load_stats`` (host side)."""
        import numpy as np

        status = np.asarray(dstate["status"])
        out = {
            "def_quarantine_inflow": float(dstate["quarantined"]),
            "def_readmitted": float(dstate["readmitted"]),
            "def_quarantined_now": int((status == 1).sum()),
            "def_probation_now": int((status == 2).sum()),
            "def_mtd_level": int(dstate["level"]),
        }
        if self.collusion:
            out["def_clique_hits"] = float(dstate["clique_hits"])
        if self.learned:
            out["def_detector_auc"] = auc_from_hist(dstate["auc"])
        return out

    def arrays(self, dstate):
        """Per-client reputation/status for ``RunResult.defense``."""
        import numpy as np

        return {
            "reputation": np.asarray(dstate["rep"]),
            "status": np.asarray(dstate["status"]),
        }


def make_defense(n: int, cfg: DefenseConfig) -> Defense:
    return Defense(n, cfg)
