"""The serving tier: a replica pool over the version ring, driven by a
router and a continuous-batching request loop.

One fleet both trains and serves: training advances the async engine's
ring of retained global versions; each serving *replica* pins one
retained version out of a ``VersionStore`` snapshot (replica i serves
``latest - i * stagger``, refreshed between training chunks) and decodes
up to ``slots`` request streams concurrently through the vmapped
continuous-batching pool (``repro.serve.batching``). A ``Router`` from
the ``@register_router`` registry decides which replica admits each
queued request — every routing decision is one epoch of the paper's
load metric, so Var[X] over replicas comes from the same Kahan
accumulators the training engines use (``load_metric.*_replica_accum``).

Reported per run (``ServeReport``): time-to-first-token (scheduler ticks
from arrival to the prefill's first emitted token), decode throughput in
tokens/s of host wall time, staleness-of-served-version (age of each
stream's pinned version relative to the ring head at join time), and
``serve_stats`` — fleet-wide and per-replica E[X]/Var[X] over routing
decisions.

Decoding is greedy (argmax): the serving loop's contract is bit-for-bit
stream isolation under join/evict churn, which sampling noise would mask.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.load_metric import (
    init_replica_accum,
    replica_stats_from_accum,
    update_replica_accum,
)
from repro.serve.batching import (
    init_slot_pool,
    prefill_tokens,
    slot_decode_fn,
    write_slot,
)
from repro.serve.router import Router, make_router, penalized_load
from repro.serve.store import VersionStore


@dataclasses.dataclass
class Request:
    """One inference request of the open-loop arrival process.

    ``resume`` carries the interrupted stream dict of a request being
    failed over from a crashed replica: the prompt is the original prompt
    plus every token already generated, ``gen_len`` the tokens still
    owed, and the join stitches the prior stream's history back on so the
    completed ``StreamResult`` is indistinguishable from an uninterrupted
    run (bit-for-bit when the new replica pins the same version)."""

    rid: int
    tick: int  # arrival tick
    prompt: np.ndarray  # (P,) int32 prompt tokens
    gen_len: int  # tokens to generate (>= 1)
    resume: Optional[Dict] = None  # interrupted stream being failed over


@dataclasses.dataclass
class StreamResult:
    """One completed request stream."""

    rid: int
    replica: int
    version: int  # global model version served
    staleness: int  # ring head - version, at join time
    arrival_tick: int
    first_token_tick: int
    done_tick: int
    tokens: List[int]
    migrations: int = 0  # replica crashes survived via failover

    @property
    def ttft_ticks(self) -> int:
        """Scheduler ticks from arrival to the first emitted token (the
        join tick's prefill emits it, so a same-tick join scores 1)."""
        return self.first_token_tick - self.arrival_tick + 1


class ReplicaPool:
    """``n_replicas`` serving replicas, each pinning one retained version
    and running a ``slots``-wide continuous-batching decode pool."""

    def __init__(self, model, n_replicas: int, slots: int, ctx: int,
                 stagger: int = 1):
        self.model = model
        self.n_replicas = n_replicas
        self.slots = slots
        self.ctx = ctx
        self.stagger = stagger
        self._tick_fn = slot_decode_fn(model)
        self._prefill = jax.jit(
            lambda params, caches, prompt: prefill_tokens(
                model.decode_step, params, caches, prompt
            )
        )
        pool0 = init_slot_pool(model, slots, ctx)
        self.pools = [pool0 for _ in range(n_replicas)]
        self.cur_tok = [
            jnp.zeros((slots, 1, 1), jnp.int32) for _ in range(n_replicas)
        ]
        self.active: List[List[Optional[Dict]]] = [
            [None] * slots for _ in range(n_replicas)
        ]
        self.params: List = [None] * n_replicas
        self.version = [0] * n_replicas
        self.staleness = [0] * n_replicas
        self.alive = [True] * n_replicas
        self.ring_miss = 0  # reads whose requested version fell off the ring

    def refresh(self, store: VersionStore) -> None:
        """Re-pin every replica against a fresh ring snapshot: replica i
        serves ``latest - i * stagger`` (clipped to the retained window),
        so a staggered pool covers a spread of stalenesses. In-flight
        streams keep decoding — their KV caches already embed the version
        they prefilled under, so only *new* joins see the new pin. Dead
        replicas stay dead and unpinned."""
        for i in range(self.n_replicas):
            if not self.alive[i]:
                continue
            read = store.read(store.latest - i * self.stagger)
            self.ring_miss += int(read.ring_miss)
            self.params[i] = read.params
            self.version[i] = int(read.read_ver)
            self.staleness[i] = int(read.staleness)

    def load(self) -> np.ndarray:
        """(R,) float32 in-flight streams per replica — the router's
        score. Dead replicas score +inf so every load-aware (and the
        dead-masked round-robin) router routes around them."""
        return np.asarray(
            [
                sum(s is not None for s in a) if self.alive[i] else np.inf
                for i, a in enumerate(self.active)
            ],
            np.float32,
        )

    def has_free(self, replica: int) -> bool:
        return self.alive[replica] and any(
            s is None for s in self.active[replica]
        )

    def total_free(self) -> int:
        return sum(
            s is None
            for i, a in enumerate(self.active) if self.alive[i]
            for s in a
        )

    def n_alive(self) -> int:
        return sum(self.alive)

    def crash(self, replica: int) -> List[Dict]:
        """Kill ``replica``: mark it dead and evict every in-flight
        stream, returning the interrupted stream dicts so the loop can
        re-queue them as failover resumes. The replica takes no further
        joins or decode ticks."""
        self.alive[replica] = False
        orphans = [s for s in self.active[replica] if s is not None]
        self.active[replica] = [None] * self.slots
        return orphans

    def revive(self, replica: int, store: VersionStore) -> None:
        """Restart a crashed replica: mark it alive with an empty slot
        pool and re-pin it against the current ring snapshot. In-flight
        state never survives the crash (the orphans already failed over
        at crash time), so a revived replica comes back cold and simply
        rejoins the router's candidate set."""
        if self.alive[replica]:
            return
        self.alive[replica] = True
        self.active[replica] = [None] * self.slots
        read = store.read(store.latest - replica * self.stagger)
        self.ring_miss += int(read.ring_miss)
        self.params[replica] = read.params
        self.version[replica] = int(read.read_ver)
        self.staleness[replica] = int(read.staleness)

    def join(self, replica: int, req: Request, tick: int):
        """Admit ``req`` on ``replica``: prefill its prompt into a fresh
        batch-1 cache, emit the first token, and (unless the request is
        already complete) write the cache into a free slot. Returns a
        ``StreamResult`` when the request finishes at join (gen_len == 1),
        else None. Caller must check ``has_free`` first."""
        slot = self.active[replica].index(None)
        caches = self.model.init_decode_caches(1, self.ctx)
        logits, one = self._prefill(
            self.params[replica], caches, jnp.asarray(req.prompt)[None, :]
        )
        first = int(jnp.argmax(logits[0, -1]))
        if req.resume is not None:
            # failover: the prompt already holds the original prompt plus
            # every generated token, so this prefill's argmax is exactly
            # the next token the dead replica owed. Stitch the prior
            # stream's history back on; the result keeps its original
            # arrival/first-token ticks and join-time version.
            prior = req.resume
            stream = {
                "rid": req.rid,
                "prompt": prior["prompt"],
                "arrival": prior["arrival"],
                "first_tick": prior["first_tick"],
                "tokens": prior["tokens"] + [first],
                "remaining": req.gen_len - 1,
                "version": prior["version"],
                "staleness": prior["staleness"],
                "migrations": prior["migrations"] + 1,
            }
        else:
            stream = {
                "rid": req.rid,
                "prompt": req.prompt,
                "arrival": req.tick,
                "first_tick": tick,
                "tokens": [first],
                "remaining": req.gen_len - 1,
                "version": self.version[replica],
                "staleness": self.staleness[replica],
                "migrations": 0,
            }
        if stream["remaining"] == 0:
            return self._result(replica, stream, tick)
        self.pools[replica] = write_slot(self.pools[replica], slot, one)
        self.cur_tok[replica] = (
            self.cur_tok[replica].at[slot].set(jnp.int32(first))
        )
        self.active[replica][slot] = stream
        return None

    def decode_tick(self, tick: int) -> List[StreamResult]:
        """One vmapped decode step per busy replica: every slot advances
        one token; active streams record theirs, finished streams evict."""
        done: List[StreamResult] = []
        for i in range(self.n_replicas):
            if not any(s is not None for s in self.active[i]):
                continue
            logits, self.pools[i] = self._tick_fn(
                self.params[i], self.pools[i], self.cur_tok[i]
            )
            nxt = jnp.argmax(logits[:, :, -1, :], axis=-1)  # (S, 1)
            self.cur_tok[i] = nxt[:, :, None].astype(jnp.int32)
            host_next = np.asarray(nxt)
            for s, stream in enumerate(self.active[i]):
                if stream is None:
                    continue
                stream["tokens"].append(int(host_next[s, 0]))
                stream["remaining"] -= 1
                if stream["remaining"] == 0:
                    done.append(self._result(i, stream, tick))
                    self.active[i][s] = None
        return done

    def _result(self, replica: int, stream: Dict, tick: int) -> StreamResult:
        return StreamResult(
            rid=stream["rid"],
            replica=replica,
            version=stream["version"],
            staleness=stream["staleness"],
            arrival_tick=stream["arrival"],
            first_token_tick=stream["first_tick"],
            done_tick=tick,
            tokens=stream["tokens"],
            migrations=stream.get("migrations", 0),
        )


@dataclasses.dataclass
class ServeReport:
    """Aggregate serving metrics for one loop run."""

    results: List[StreamResult]
    ticks: int
    decisions: int
    rejections: int
    queue_left: int
    tokens_out: int
    ttft_ticks_mean: float
    staleness_mean: float
    staleness_max: int
    decode_wall_s: float
    tok_s: float
    serve_stats: Dict  # fleet + per-replica E[X]/Var[X] over decisions

    def summary(self) -> str:
        ss = self.serve_stats
        return (
            f"served {len(self.results)} streams / {self.tokens_out} tokens "
            f"in {self.ticks} ticks ({self.tok_s:.0f} tok/s decode) | "
            f"ttft={self.ttft_ticks_mean:.2f} ticks | "
            f"staleness mean={self.staleness_mean:.2f} max={self.staleness_max} | "
            f"routing Var[X]={ss['var_X']:.3f} E[X]={ss['mean_X']:.3f} "
            f"({self.decisions} decisions, {self.rejections} rejected)"
        )


def run_serve_loop(
    model,
    store: VersionStore,
    requests: List[Request],
    *,
    router="round_robin",
    router_kwargs: Optional[Dict] = None,
    n_replicas: int = 2,
    slots: int = 4,
    ctx: Optional[int] = None,
    ticks: Optional[int] = None,
    stagger: int = 1,
    seed: int = 0,
    pool: Optional[ReplicaPool] = None,
    faults=None,
    restart_ticks: int = 0,
    reputation_penalty: float = 0.0,
) -> ServeReport:
    """Drive the continuous-batching loop over an open-loop request trace.

    Per tick: append the tick's arrivals to the FIFO queue; while free
    slots remain, ask the router for the head request's replica (one
    accumulator epoch per decision — a rejection, or a pick of a full
    replica, ends admission for the tick); then advance every busy
    replica one vmapped decode step. ``pool`` reuses an existing
    ``ReplicaPool`` (compiled ticks and in-flight streams survive across
    calls — pass the same pool between training chunks); otherwise one is
    built and pinned from ``store``.

    ``faults`` takes serve-scope :class:`repro.faults.Fault` records
    (``replica_crash``): each tick every alive replica crashes with the
    fault's rate under a dedicated key fold, except the last survivor
    (the pool must always be able to drain). A crash evicts the replica
    and re-queues its in-flight streams at the queue head as failover
    resumes — zero streams are dropped, counted in
    ``serve_stats["failed_over"]``.

    ``restart_ticks > 0`` arms graceful restarts: a crashed replica
    revives cold (``ReplicaPool.revive``) after that many ticks down,
    counted in ``serve_stats["revived"]``. ``reputation_penalty > 0``
    arms crash reputation: each replica carries a crash count decayed
    0.98x per tick, and ``penalty x count`` is added onto its routing
    load (``router.penalized_load``) so load-aware routers steer new
    joins away from recently flaky replicas. Both default off and add
    zero ops — the calm loop is bitwise unchanged.
    """
    if restart_ticks < 0:
        raise ValueError(
            f"restart_ticks must be >= 0, got {restart_ticks}"
        )
    if reputation_penalty < 0:
        raise ValueError(
            f"reputation_penalty must be >= 0, got {reputation_penalty}"
        )
    crash_rate = 0.0
    for f in tuple(faults) if faults is not None else ():
        if getattr(f, "scope", None) != "serve":
            raise ValueError(
                f"fault {f.name!r} is engine-scope: pass it to "
                "RunConfig(faults=...), not the serving loop"
            )
        if f.name != "replica_crash":
            raise ValueError(
                f"unknown serve-scope fault {f.name!r}; the serving loop "
                "handles: replica_crash"
            )
        crash_rate = float(f.rate)
    requests = sorted(requests, key=lambda r: (r.tick, r.rid))
    if ctx is None:
        ctx = max((len(r.prompt) + r.gen_len for r in requests), default=8)
    if ticks is None:
        last = requests[-1].tick if requests else 0
        ticks = last + sum(r.gen_len for r in requests) + 8
    if pool is None:
        pool = ReplicaPool(model, n_replicas, slots, ctx, stagger=stagger)
        pool.refresh(store)
    rt = router if isinstance(router, Router) else make_router(
        router, pool.n_replicas, **(router_kwargs or {})
    )
    key = jax.random.PRNGKey(seed)
    k_init, k_dec = jax.random.split(key)
    # crash draws fold far off k_dec's per-decision fold range so an
    # armed crash fault never perturbs the routing key stream
    k_crash = jax.random.fold_in(k_dec, 1 << 24)
    rstate = rt.init(k_init, pool.n_replicas)
    acc = init_replica_accum(pool.n_replicas)
    upd = jax.jit(update_replica_accum)
    no_assign = jnp.zeros((pool.n_replicas,), jnp.bool_)

    queue: collections.deque = collections.deque()
    pending = collections.deque(requests)
    results: List[StreamResult] = []
    decisions = rejections = 0
    crashes = failed_over = revived = 0
    crash_penalty = np.zeros((pool.n_replicas,), np.float32)
    down_since: Dict[int, int] = {}
    decode_wall = 0.0
    t = 0
    for t in range(ticks):
        # --- restarts: crashed replicas come back cold after their
        # restart window, before this tick's crash draw can re-kill them
        if restart_ticks > 0:
            for i, since in list(down_since.items()):
                if t - since >= restart_ticks:
                    pool.revive(i, store)
                    revived += 1
                    del down_since[i]
        if reputation_penalty > 0.0:
            crash_penalty *= np.float32(0.98)
        # --- fault injection: replica crashes, sparing the last survivor
        if crash_rate > 0.0 and pool.n_alive() > 1:
            hit = np.asarray(jax.random.bernoulli(
                jax.random.fold_in(k_crash, t), crash_rate,
                (pool.n_replicas,),
            ))
            for i in range(pool.n_replicas):
                if not (hit[i] and pool.alive[i]) or pool.n_alive() <= 1:
                    continue
                orphans = pool.crash(i)
                crashes += 1
                crash_penalty[i] += 1.0
                down_since[i] = t
                failed_over += len(orphans)
                # failover resumes go to the queue head, oldest first
                queue.extendleft(
                    _resume_request(s) for s in reversed(orphans)
                )
        while pending and pending[0].tick <= t:
            queue.append(pending.popleft())
        # --- admission: one router decision per queued head request
        while queue and pool.total_free() > 0:
            req = queue[0]
            load = jnp.asarray(pool.load())
            if reputation_penalty > 0.0:
                load = penalized_load(
                    load, np.float32(reputation_penalty) * crash_penalty
                )
            ridx, rstate = rt.step(
                rstate, load,
                jax.random.fold_in(k_dec, decisions),
            )
            decisions += 1
            ridx = int(ridx)
            if ridx >= 0 and pool.has_free(ridx):
                acc = upd(
                    acc, no_assign.at[ridx].set(True)
                )
                queue.popleft()
                res = pool.join(ridx, req, t)
                if res is not None:
                    results.append(res)
            else:
                # rejected (or full replica picked): the epoch still
                # advances every replica's age chain; head-of-line waits
                acc = upd(acc, no_assign)
                rejections += 1
                break
        # --- decode: every busy replica advances one token
        t0 = time.perf_counter()
        results.extend(pool.decode_tick(t))
        decode_wall += time.perf_counter() - t0
        if not pending and not queue and pool.total_free() == pool.n_alive() * pool.slots:
            break

    tokens_out = sum(len(r.tokens) for r in results)
    ttfts = [r.ttft_ticks for r in results]
    stal = [r.staleness for r in results]
    serve_stats = dict(replica_stats_from_accum(acc))
    serve_stats["ring_miss"] = pool.ring_miss
    serve_stats["crashes"] = crashes
    serve_stats["failed_over"] = failed_over
    serve_stats["revived"] = revived
    return ServeReport(
        results=results,
        ticks=t + 1,
        decisions=decisions,
        rejections=rejections,
        queue_left=len(queue) + len(pending),
        tokens_out=tokens_out,
        ttft_ticks_mean=float(np.mean(ttfts)) if ttfts else float("nan"),
        staleness_mean=float(np.mean(stal)) if stal else float("nan"),
        staleness_max=int(max(stal)) if stal else 0,
        decode_wall_s=decode_wall,
        tok_s=tokens_out / decode_wall if decode_wall > 0 else float("nan"),
        serve_stats=serve_stats,
    )


def _resume_request(stream: Dict) -> Request:
    """Rebuild a crashed replica's in-flight stream as a joinable
    request: the new prompt is the original prompt plus every token
    already generated, so the survivor's prefill reconstructs the exact
    decode context the dead replica held."""
    return Request(
        rid=stream["rid"],
        tick=stream["arrival"],
        prompt=np.concatenate([
            np.asarray(stream["prompt"], np.int32),
            np.asarray(stream["tokens"], np.int32),
        ]),
        gen_len=stream["remaining"],
        resume=stream,
    )
