"""Multi-version model store over the async engine's version ring.

The buffered async engine already retains the last ``max_versions``
global models in a ring buffer (``state["hist"]``, slot ``v % H`` holds
version ``v``) so stale clients can train from their dispatch-time
model. That ring *is* a multi-version model store; ``VersionStore``
wraps one ring snapshot behind a read API with explicit staleness
accounting so the serving tier can pin replicas to retained versions
while training keeps advancing the ring underneath.

``read`` applies the engine's exact clipping semantics (a requested
version older than the ring serves the oldest retained model — the same
``jnp.clip`` the engine applies to dispatch versions), and reports both
the version actually served and its staleness relative to the ring head.
The snapshot holds device arrays by reference: constructing a store
never pulls parameters to the host.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, NamedTuple

import jax
import jax.numpy as jnp


class VersionRead(NamedTuple):
    """One resolved read: the served parameters, the version they carry,
    its age relative to the newest version in the ring, and whether the
    *requested* version had already fallen off the ring (the read was
    silently upgraded to the oldest retained model)."""

    params: Any
    read_ver: jnp.ndarray  # () int32 — version actually served
    staleness: jnp.ndarray  # () int32 — latest - read_ver
    ring_miss: jnp.ndarray  # () bool — requested version not retained


@dataclasses.dataclass(frozen=True)
class VersionStore:
    """Read API over a ring of the last ``max_versions`` global models.

    ``hist`` is any pytree whose leaves carry a leading ``(H,)`` ring
    axis with version ``v`` in slot ``v % H``; ``version`` is the newest
    version present. Both come straight out of
    ``AsyncEngine.ring_snapshot(state)`` — a store is a cheap value
    object over live engine state, rebuilt after every training chunk.
    """

    hist: Any
    version: jnp.ndarray
    max_versions: int

    @classmethod
    def from_engine(cls, engine, state) -> "VersionStore":
        return cls(*engine.ring_snapshot(state))

    @property
    def latest(self) -> int:
        return int(self.version)

    @property
    def oldest_retained(self) -> int:
        """Oldest version still resident in the ring. Before the ring
        wraps for the first time every slot above ``version`` still holds
        the init params, so retention starts at version 0."""
        return max(self.latest - (self.max_versions - 1), 0)

    def retained_versions(self) -> List[int]:
        return list(range(self.oldest_retained, self.latest + 1))

    def read(self, ver) -> VersionRead:
        """Serve version ``ver``, clipped to the retained window.

        Same semantics as the engine's dispatch-version read: requests
        for versions that fell off the ring (staleness >= H) get the
        oldest retained model; requests newer than the head get the
        head. ``staleness`` is the age of the version actually served.
        """
        h = self.max_versions
        latest = jnp.asarray(self.version, jnp.int32)
        v = jnp.asarray(ver, jnp.int32)
        lo = jnp.maximum(latest - (h - 1), 0)
        read_ver = jnp.clip(v, lo, latest)
        params = jax.tree.map(lambda leaf: leaf[read_ver % h], self.hist)
        return VersionRead(params, read_ver, latest - read_ver, v < lo)
