"""Cohort-aware multi-version serving tier.

One fleet trains *and* serves: the async engine's ring of retained
global versions (``AsyncEngine.ring_snapshot``) becomes a
``VersionStore``; a ``ReplicaPool`` pins replicas to retained versions
and decodes request streams with continuous batching
(``repro.serve.batching``); a ``Router`` from the ``@register_router``
registry (round_robin / least_loaded / the paper's Markov admission
rule) decides which replica admits each request, with Var[X] over
replicas measured by the same Kahan accumulators as the training load
metric (``core.load_metric.*_replica_accum``).

    store = VersionStore.from_engine(engine, state)
    report = run_serve_loop(model, store, requests, router="markov",
                            n_replicas=4, slots=8)
    print(report.summary())   # ttft / tok/s / staleness / Var[X]
"""
from repro.serve.batching import (  # noqa: F401
    init_slot_pool,
    prefill_tokens,
    read_slot,
    slot_decode_fn,
    write_slot,
)
from repro.serve.loop import (  # noqa: F401
    ReplicaPool,
    Request,
    ServeReport,
    StreamResult,
    run_serve_loop,
)
from repro.serve.router import (  # noqa: F401
    Router,
    make_router,
    penalized_load,
    register_router,
    router_names,
)
from repro.serve.store import VersionRead, VersionStore  # noqa: F401

__all__ = [
    "VersionStore",
    "VersionRead",
    "Router",
    "make_router",
    "penalized_load",
    "register_router",
    "router_names",
    "ReplicaPool",
    "Request",
    "StreamResult",
    "ServeReport",
    "run_serve_loop",
    "prefill_tokens",
    "init_slot_pool",
    "slot_decode_fn",
    "write_slot",
    "read_slot",
]
