"""Request routers: which replica admits the next request.

A router is the serving-tier analogue of a selection policy — a pair of
pure functions wrapped in a ``Router`` record:

    state = router.init(key, n_replicas)
    replica, state = router.step(state, load, key)   # replica: () int32

``load`` is the (R,) float32 in-flight load per replica (occupied slots,
queue depth — whatever the pool scores with); ``replica`` is the chosen
replica index, or ``-1`` when the router rejects the admission this
decision (the request stays queued). Every ``step`` call is one decision
epoch: the paper's load metric X counts decisions between subsequent
assignments of a replica, so the Markov router's closed-form Var[X]
(``load_metric.optimal_var(R, 1, m)``) applies verbatim with n := R,
k := 1.

Routers are registry entries, not loop forks (mirrors
``repro.topo.register_topology`` / ``repro.engine.register_policy``):

    from repro.serve import register_router

    @register_router("my_router")
    def _make(n_replicas, **kw):
        return Router("my_router", init, step)

Built-ins:
  * ``round_robin``  — cursor over replicas, ignores load (Var[X] = 0).
  * ``least_loaded`` — argmin of the load vector, lowest index on ties.
  * ``markov``       — the paper's decentralized age-dependent admission
                       rule: each replica *independently* draws
                       willingness ~ Bernoulli(p_{min(age, m)}) from the
                       same chain as ``core.selection.make_markov`` (on a
                       1-replica pool the admission sequence is bit-for-bit
                       the policy's selection sequence); the request goes
                       to the least-loaded willing replica, or is rejected
                       when none is willing.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp

from repro.core import selection


@dataclasses.dataclass(frozen=True)
class Router:
    name: str
    init: Callable  # (key, n_replicas) -> state
    step: Callable  # (state, load, key) -> (replica () int32; -1 = reject, state)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_ROUTERS: Dict[str, Callable] = {}


def register_router(name: str) -> Callable:
    """Decorator: register ``factory(n_replicas, **kw) -> Router``."""

    def deco(factory: Callable) -> Callable:
        if name in _ROUTERS:
            raise ValueError(f"router {name!r} already registered")
        _ROUTERS[name] = factory
        return factory

    return deco


def make_router(name: str, n_replicas: int, **kw) -> Router:
    """Construct a registered router by name."""
    try:
        factory = _ROUTERS[name]
    except KeyError:
        raise ValueError(
            f"unknown router {name!r}; registered: {sorted(_ROUTERS)}"
        ) from None
    return factory(n_replicas, **kw)


def router_names() -> Tuple[str, ...]:
    return tuple(sorted(_ROUTERS))


def penalized_load(load, penalty):
    """Reputation-adjusted load vector: add a per-replica penalty (e.g.
    the serving loop's decayed crash count x weight) onto the finite
    entries so flaky-but-alive replicas lose routing ties, while the
    pool's +inf dead markers pass through untouched — every load-aware
    router keeps routing around the dead."""
    load = jnp.asarray(load, jnp.float32)
    pen = jnp.asarray(penalty, jnp.float32)
    return jnp.where(jnp.isfinite(load), load + pen, load)


# ---------------------------------------------------------------------------
# Built-ins
# ---------------------------------------------------------------------------


def make_round_robin(n_replicas: int) -> Router:
    """Deterministic cursor: decision d goes to replica d % R. Every
    replica's assignment gap is exactly R — Var[X] = 0, the serving-tier
    analogue of the ``round_robin`` selection policy.

    Dead replicas (load = +inf, the pool's crash marker) are skipped:
    the cursor's pick is the first *alive* replica at or after it, and
    the decision is -1 when the whole pool is dead. With every replica
    alive this is exactly ``cursor % R``."""

    def init(key, r=n_replicas):
        return {"cursor": jnp.zeros((), jnp.int32)}

    def step(state, load, key):
        order = (jnp.arange(n_replicas) - state["cursor"]) % n_replicas
        alive = jnp.isfinite(load)
        score = jnp.where(alive, -order.astype(jnp.float32), -jnp.inf)
        idx = jnp.argmax(score).astype(jnp.int32)
        idx = jnp.where(jnp.any(alive), idx, -1).astype(jnp.int32)
        return idx, {"cursor": state["cursor"] + 1}

    return Router("round_robin", init, step)


def make_least_loaded(n_replicas: int) -> Router:
    """Greedy: the replica with the least in-flight load (lowest index on
    ties). Centralized — it reads the whole load vector, the admission
    analogue of the ``oldest_age`` top-k policy. Dead replicas carry
    load = +inf and lose every argmin; a fully dead pool rejects (-1)."""

    def init(key, r=n_replicas):
        return {}

    def step(state, load, key):
        idx = jnp.argmin(load).astype(jnp.int32)
        idx = jnp.where(jnp.any(jnp.isfinite(load)), idx, -1)
        return idx.astype(jnp.int32), state

    return Router("least_loaded", init, step)


def make_markov_admission(
    n_replicas: int,
    m: int = 10,
    probs=None,
    steady_start: bool = True,
    target_gap: Optional[float] = None,
) -> Router:
    """The paper's age-dependent Markov rule as an admission policy.

    Each replica runs its own age chain (age = decisions since it last
    took a request) and draws willingness ~ Bernoulli(p_{min(age, m)}) —
    zero coordination, exactly ``core.selection.make_markov``'s draw over
    n := R replicas, k := 1 admission per decision (or ``probs`` /
    ``target_gap`` for explicit chains; ``target_gap`` is the desired
    E[X] in decisions, Theorem 2's n/k). The request is routed to the
    least-loaded willing replica; when no replica is willing the decision
    returns -1 and the request waits. On a degenerate 1-replica pool the
    admit/reject sequence is bit-for-bit the policy's selection sequence
    (pinned by ``tests/test_serve.py``).
    """
    if probs is None and target_gap is not None:
        import numpy as np

        from repro.core import load_metric

        probs = np.asarray(
            load_metric.optimal_probs_for_mean(float(target_gap), m)
        )
    policy = selection.make_markov(
        n_replicas, 1, m, probs=probs, steady_start=steady_start
    )

    def init(key, r=n_replicas):
        return policy.init(key, r)

    def step(state, load, key):
        willing, state = policy.step(state, key)
        # dead replicas (load = +inf) may be willing but can't serve
        usable = willing & jnp.isfinite(load)
        score = jnp.where(usable, load, jnp.inf)
        idx = jnp.argmin(score).astype(jnp.int32)
        return jnp.where(jnp.any(usable), idx, -1).astype(jnp.int32), state

    return Router("markov", init, step)


register_router("round_robin")(make_round_robin)
register_router("least_loaded")(make_least_loaded)
register_router("markov")(make_markov_admission)

ROUTER_NAMES = router_names()
