"""Continuous-batching primitives over the model decode path.

The unit of serving state is a *slot*: one batch-1 decode-cache pytree
(ring KV cache plus its own scalar write index). A pool stacks ``S``
slots on a leading axis and advances all of them with one vmapped
``decode_step`` per tick — because every slot carries its *own* cache
index, slots are fully independent: a request joining slot 3 or leaving
slot 0 cannot perturb the tokens slot 1 decodes (bit-for-bit, pinned by
``tests/test_serve.py``). Join = prefill the new request's prompt into a
fresh batch-1 cache and write it over the slot; evict = mark the slot
free (its stale cache is simply overwritten by the next join).

``prefill_tokens`` is the shared prompt-ingestion path: one
``lax.scan`` of ``decode_step`` over the prompt tokens — a single
compiled program instead of a Python per-token dispatch loop — used by
both ``repro.launch.serve`` and the serving loop's join path.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


def prefill_tokens(
    decode_step: Callable, params, caches, prompts: jnp.ndarray
) -> Tuple[jnp.ndarray, Any]:
    """Feed ``prompts`` (B, P) int32 through ``decode_step`` one token at
    a time under one ``lax.scan``; returns ``(logits, caches)`` where
    ``logits`` is the last step's (B, 1, V) output — bit-for-bit the
    Python loop ``for t: logits, caches = decode_step(..., prompts[:, t:t+1])``
    without the per-token host roundtrip."""
    toks = jnp.swapaxes(prompts, 0, 1)[:, :, None]  # (P, B, 1)

    def body(c, tok):
        logits, c = decode_step(params, c, tok)
        return c, logits

    caches, logits = jax.lax.scan(body, caches, toks)
    return logits[-1], caches


def init_slot_pool(model, slots: int, ctx: int):
    """(S,)-stacked batch-1 decode caches: ``slots`` independent streams,
    each with its own ring cache and scalar write index."""
    one = model.init_decode_caches(1, ctx)
    return jax.tree.map(lambda a: jnp.stack([a] * slots), one)


def slot_decode_fn(model) -> Callable:
    """The pool's decode tick: ``decode_step`` vmapped over the slot axis
    (params broadcast), jitted once per (slots, ctx) shape.

        logits, pool = tick(params, pool, tokens)   # tokens (S, 1, 1)
    """
    return jax.jit(jax.vmap(model.decode_step, in_axes=(None, 0, 0)))


def write_slot(pool, s: int, one):
    """Join: overwrite slot ``s`` with a freshly prefilled batch-1 cache."""
    return jax.tree.map(lambda p, o: p.at[s].set(o), pool, one)


def read_slot(pool, s: int):
    """The batch-1 cache pytree currently held by slot ``s``."""
    return jax.tree.map(lambda p: p[s], pool)
