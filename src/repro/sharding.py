"""Sharding rules: logical parameter/activation axes -> PartitionSpec.

Mesh axes:
  single pod : ("data", "model")            = (16, 16)
  multi-pod  : ("pod", "data", "model")     = (2, 16, 16)

Batch shards over ("pod","data"); tensor-parallel dims (heads / ffn hidden
/ experts / vocab) over "model"; the d_model dim of weight matrices over
"data" (FSDP-style). Every rule degrades gracefully: an axis is sharded
only if its size divides the mesh axis (e.g. whisper's vocab 51865 and
llama4's 40 query heads fall back to the next candidate or replicate).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def mesh_axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= mesh_axis_size(mesh, n)
        return out
    return mesh.shape[name] if name in mesh.shape else 1


def dp_axes(mesh: Mesh):
    """Axes used for batch/data parallelism."""
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def _fits(dim: int, mesh: Mesh, axis) -> bool:
    return dim % mesh_axis_size(mesh, axis) == 0


def _pick(dims: Dict[int, int], mesh: Mesh, prefs: Tuple[Tuple[int, object], ...]):
    """Build a spec list for an array with dims {axis_index: size}; prefs is
    a priority list of (axis_index, mesh_axis). Each mesh axis is used at
    most once; an axis is skipped unless it divides."""
    ndim = len(dims)
    spec = [None] * ndim
    used = set()
    for ax, mesh_axis in prefs:
        key = mesh_axis if isinstance(mesh_axis, str) else tuple(mesh_axis)
        if key in used or spec[ax] is not None:
            continue
        if _fits(dims[ax], mesh, mesh_axis):
            spec[ax] = mesh_axis
            used.add(key)
    return P(*spec)


# ---------------------------------------------------------------------------
# Parameter rules (by leaf name inside the layer structures)
# ---------------------------------------------------------------------------


def _param_spec(name: str, shape: Tuple[int, ...], mesh: Mesh, stacked: bool) -> P:
    """name = leaf key (e.g. 'w_q'); shape excludes the stacked repeat dim."""
    dims = dict(enumerate(shape))
    n = len(shape)

    def pick(*prefs):
        spec = _pick(dims, mesh, prefs)
        if stacked:
            return P(None, *spec)
        return spec

    if name in ("embed",):  # (V, d)
        return pick((0, "model"), (1, "data"))
    if name == "lm_head":  # (d, V)
        return pick((1, "model"), (0, "data"))
    if name in ("w_q", "w_k", "w_v"):  # (d, H, Dh)
        return pick((1, "model"), (2, "model"), (0, "data"))
    if name == "w_o":  # (H, Dh, d)
        return pick((0, "model"), (1, "model"), (2, "data"))
    if name in ("w_uq", "w_uk", "w_uv"):  # (r, H, e)
        return pick((1, "model"), (0, "data"))
    if name in ("w_dq", "w_dkv", "w_k_rope"):  # (d, r)
        return pick((0, "data"))
    if name in ("w_in", "w_gate"):
        if n == 2:  # dense (d, f)
            return pick((1, "model"), (0, "data"))
        return pick((0, "model"), (1, "data"))  # moe (E, d, f)
    if name == "w_out":
        if n == 2:  # dense (f, d) — or ssm (di, d)
            return pick((0, "model"), (1, "data"))
        return pick((0, "model"), (2, "data"))  # moe (E, f, d)
    if name in ("shared_in", "shared_gate"):  # (d, f)
        return pick((1, "model"), (0, "data"))
    if name == "shared_out":  # (f, d)
        return pick((0, "model"), (1, "data"))
    if name == "router":  # (d, E)
        return pick((0, "data"))
    if name == "conv_w":  # (W, ch)
        return pick((1, "model"))
    if name in ("conv_b", "norm_scale"):  # (ch,)
        return pick((0, "model"))
    if name in ("A_log", "dt_bias", "D"):  # (nh,)
        return pick((0, "model"))
    if name == "frontend_proj":  # (d, d)
        return pick((1, "model"), (0, "data"))
    # norms / scalars / small vectors: replicate
    return P(*([None] * (n + (1 if stacked else 0))))


def params_pspecs(params, mesh: Mesh):
    """PartitionSpec pytree matching a params pytree (stacked block leaves
    get a leading replicated repeat dim)."""

    def visit(path, leaf):
        names = [getattr(p, "key", getattr(p, "idx", None)) for p in path]
        name = None
        for part in reversed(names):
            if isinstance(part, str):
                name = part
                break
        # stacked iff under 'blocks' or (encdec) '*_layers'
        stacked = any(
            isinstance(p, str) and (p == "blocks" or p.endswith("_layers"))
            for p in names
        )
        shape = leaf.shape[1:] if stacked else leaf.shape
        return _param_spec(name or "", shape, mesh, stacked)

    return jax.tree_util.tree_map_with_path(visit, params)


# ---------------------------------------------------------------------------
# Activation / batch / cache rules
# ---------------------------------------------------------------------------


def batch_pspecs(batch, mesh: Mesh):
    dp = dp_axes(mesh)

    def visit(leaf):
        dims = dict(enumerate(leaf.shape))
        return _pick(dims, mesh, ((0, dp),))

    return jax.tree.map(visit, batch)


def cache_pspecs(caches, mesh: Mesh):
    """Decode caches. Layout conventions (possibly with a leading stacked
    repeat dim): k/v (B, L, Hk, D); c_kv/k_rope (B, L, r); ssm h
    (B, nh, hd, ds); conv (B, W-1, ch); cross_k/v (n_dec, B, T, Hk, D);
    index scalar. Batch shards over dp when divisible; otherwise the cache
    length L shards over ("data") and heads over "model"."""
    dp = dp_axes(mesh)

    def visit(path, leaf):
        names = [getattr(p, "key", None) for p in path]
        name = next((n for n in reversed(names) if isinstance(n, str)), "")
        stacked = any(n == "blocks" for n in names if isinstance(n, str))
        off = 1 if stacked else 0
        shape = leaf.shape
        dims = dict(enumerate(shape))
        if name == "index":
            return P(*([None] * leaf.ndim))
        if name in ("k", "v", "c_kv", "k_rope"):
            b_ax, l_ax = off, off + 1
            prefs = [(b_ax, dp)]
            if shape[b_ax] % mesh_axis_size(mesh, dp) != 0:
                prefs = [(l_ax, "data")]
            if len(shape) - off == 4:  # k/v with heads
                prefs.append((off + 2, "model"))
                prefs.append((l_ax, "model"))  # fallback: L over model too
            else:
                prefs.append((l_ax, "model"))
            return _pick(dims, mesh, tuple(prefs))
        if name in ("cross_k", "cross_v"):  # (n_dec, B, T, Hk, D)
            return _pick(dims, mesh, ((1, dp), (3, "model")))
        if name == "h":  # (B, nh, hd, ds)
            prefs = [(off, dp), (off + 1, "model")]
            return _pick(dims, mesh, tuple(prefs))
        if name == "conv":  # (B, W-1, ch)
            return _pick(dims, mesh, ((off, dp), (off + 2, "model")))
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(visit, caches)


def to_named(tree, mesh: Mesh):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        tree,
        is_leaf=lambda x: isinstance(x, P),
    )
