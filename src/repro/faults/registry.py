"""The fault registry: names -> fault factories, jax-free.

Mirrors ``repro.engine.registry``: a fault is a registry entry
(``@register_fault``), not a fork of an engine loop. This module is
deliberately import-light (no jax) so ``RunConfig`` can validate fault
names at construction time without touching the simulator — the actual
``Fault`` objects (jnp state + hooks) live in ``repro.faults.inject``
and are built lazily by ``make_fault``.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

_FAULTS: Dict[str, Callable] = {}

# names ``repro.faults.inject`` registers on import — listed statically so
# config validation can reject typos without importing jax
BUILTIN_FAULTS = (
    "collude",
    "corrupt",
    "dropout",
    "replica_crash",
    "scale_attack",
    "sign_flip",
    "stale_replay",
    "straggler",
)


def register_fault(name: str) -> Callable:
    """Decorator: register ``factory(n, rate, **kw) -> Fault``."""

    def deco(factory: Callable) -> Callable:
        if name in _FAULTS:
            raise ValueError(f"fault {name!r} already registered")
        _FAULTS[name] = factory
        return factory

    return deco


def _ensure_builtins() -> None:
    # the built-in faults self-register on import (like policies and
    # aggregators); lazy so make_fault works regardless of import order
    from repro.faults import inject  # noqa: F401


def known_fault_names() -> Tuple[str, ...]:
    """Every resolvable fault name, *without* importing jax: the static
    built-in list plus whatever plugins have registered so far."""
    return tuple(sorted(set(BUILTIN_FAULTS) | set(_FAULTS)))


def fault_names() -> Tuple[str, ...]:
    _ensure_builtins()
    return tuple(sorted(_FAULTS))


def make_fault(name: str, n: int, rate: float, **kw):
    """Construct a registered fault by name for an ``n``-client fleet at
    per-event injection probability ``rate``."""
    _ensure_builtins()
    try:
        factory = _FAULTS[name]
    except KeyError:
        raise ValueError(
            f"unknown fault {name!r}; registered: {', '.join(fault_names())}"
        ) from None
    return factory(n, rate, **kw)
