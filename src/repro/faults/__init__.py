"""Fault injection + graceful degradation (``repro.faults``).

    from repro.faults import make_fault, FaultSet

    fs = FaultSet([make_fault("dropout", n, 0.1),
                   make_fault("corrupt", n, 0.05, sigma=2.0)])

Engines take the set through ``RunConfig(faults=("dropout", "corrupt"),
fault_rate=...)``; the serving loop takes serve-scope faults directly
(``run_serve_loop(faults=[make_fault("replica_crash", R, 0.2)])``).
"""
from repro.faults.inject import (  # noqa: F401
    Effects,
    Fault,
    FaultSet,
    collude_updates,
    corrupt_updates,
    effects_hit,
    identity_effects,
    merge_effects,
)
from repro.faults.registry import (  # noqa: F401
    BUILTIN_FAULTS,
    fault_names,
    known_fault_names,
    make_fault,
    register_fault,
)

__all__ = [
    "BUILTIN_FAULTS",
    "Effects",
    "Fault",
    "FaultSet",
    "collude_updates",
    "corrupt_updates",
    "effects_hit",
    "fault_names",
    "identity_effects",
    "known_fault_names",
    "make_fault",
    "merge_effects",
    "register_fault",
]
