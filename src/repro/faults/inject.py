"""Deterministic PRNG-driven fault injection for the fleet engines.

A :class:`Fault` is a per-client state pytree plus up to two pure hooks
the engines call under *dedicated* key folds:

  * ``on_dispatch(fstate, key, send, latency)`` fires when clients pull a
    model (async engine only — sync rounds have no dispatch latency) and
    may perturb the sampled wall-clock latencies (straggler stalls);
  * ``on_pop(fstate, key, idx, valid)`` fires on the popped/selected
    cohort and returns an :class:`Effects` record — which slots to kill,
    how to corrupt their deltas, how far to replay their read version.

Per-fault state is a dict of ``(n,)`` arrays plus scalar counters, so it
rides the engines' donated scan carry like every other per-client array:
the same fault set works single-device, chunked, fleet-sharded, and
cohort-sharded with zero engine forks. Faults-off is *structurally*
bit-for-bit identical (no state keys, no key folds, no ops added), and a
rate-0 fault set is bitwise identity too — every effect application is a
per-slot ``jnp.where`` that selects the untouched input when the fault
missed (pinned by ``tests/test_faults.py``).

Hit selection is two-stage: ``init`` draws a persistent ``prone`` mask
(``client_frac`` of the fleet is susceptible at all — 1.0 skips the draw)
and each event Bernoulli-samples at ``rate`` among prone participants, so
a run can model "5% of devices are flaky" separately from "a flaky device
fails 30% of the time".

``replica_crash`` is scope="serve": the serving loop consumes its rate
directly (``serve/loop.py``); the engines reject serve-scope faults.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.faults.registry import register_fault

# replay shift meaning "as stale as the ring allows": the engine clips
# the shifted read version to the oldest retained model
MAX_REPLAY = 1 << 20


class Effects(NamedTuple):
    """Merged per-slot fault effects over one popped/selected cohort.

    Identity values (False / 1.0 / 0.0 / 0) leave a slot untouched
    bitwise — the engines apply every channel through a per-slot
    ``where`` keyed on the non-identity entries.
    """

    kill: jnp.ndarray  # (B,) bool — drop the slot's update mid-round
    delta_scale: jnp.ndarray  # (B,) f32 — multiply the slot's delta
    noise_sigma: jnp.ndarray  # (B,) f32 — gaussian noise added to the delta
    replay_shift: jnp.ndarray  # (B,) i32 — serve an older ring version
    collude: jnp.ndarray  # (B,) f32 — 0 = honest, else the coalition's
    #                         norm multiplier (update replaced by the
    #                         shared poisoned direction, norm-matched)


def identity_effects(shape) -> Effects:
    return Effects(
        kill=jnp.zeros(shape, jnp.bool_),
        delta_scale=jnp.ones(shape, jnp.float32),
        noise_sigma=jnp.zeros(shape, jnp.float32),
        replay_shift=jnp.zeros(shape, jnp.int32),
        collude=jnp.zeros(shape, jnp.float32),
    )


def merge_effects(a: Effects, b: Effects) -> Effects:
    """Compose two faults' effects on the same cohort: kills OR, delta
    scales multiply, noise variances add (sigmas here are per-fault and
    independent — summing sigma is the conservative upper envelope),
    replay shifts take the max, collusion multipliers take the max
    (two coalitions cannot both replace one slot's update)."""
    return Effects(
        kill=a.kill | b.kill,
        delta_scale=a.delta_scale * b.delta_scale,
        noise_sigma=a.noise_sigma + b.noise_sigma,
        replay_shift=jnp.maximum(a.replay_shift, b.replay_shift),
        collude=jnp.maximum(a.collude, b.collude),
    )


def effects_hit(eff: Effects) -> jnp.ndarray:
    """(B,) bool — slots some armed fault actually touched this pop.

    The per-slot ground-truth label the learned defense head trains
    against in ``fault_exposure`` evaluation mode."""
    return (eff.kill | (eff.delta_scale != 1.0) | (eff.noise_sigma > 0.0)
            | (eff.replay_shift > 0) | (eff.collude > 0.0))


@dataclasses.dataclass(frozen=True)
class Fault:
    """One registered fault: per-client state + pure injection hooks."""

    name: str
    channels: Tuple[str, ...]  # of: kill latency scale noise replay collude
    rate: float = 0.0
    scope: str = "engine"  # engine | serve
    async_only: bool = False
    init: Optional[Callable] = None  # (key) -> state dict
    # (fstate, key, send (n,), latency (n,)) -> (fstate, latency)
    on_dispatch: Optional[Callable] = None
    # (fstate, key, idx (B,), valid (B,)) -> (fstate, Effects)
    on_pop: Optional[Callable] = None


class FaultSet:
    """An ordered collection of engine-scope faults sharing one key fold.

    The engines talk to the set, never to individual faults: ``init``
    builds the per-fault state dict keyed by fault name, ``on_dispatch``/
    ``on_pop`` thread the state through every fault (sub-fold ``i`` per
    fault, so adding a fault never perturbs another's stream) and merge
    the effects.
    """

    def __init__(self, faults):
        faults = tuple(faults)
        names = [f.name for f in faults]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate fault names in set: {names}")
        serve = [f.name for f in faults if f.scope != "engine"]
        if serve:
            raise ValueError(
                f"fault(s) {', '.join(serve)} are serve-scope (replica "
                "crashes): pass them to run_serve_loop(faults=...), not "
                "to the training engines"
            )
        self.faults = faults
        self.channels = frozenset(c for f in faults for c in f.channels)

    def has(self, channel: str) -> bool:
        return channel in self.channels

    @property
    def has_dispatch(self) -> bool:
        return any(f.on_dispatch is not None for f in self.faults)

    @property
    def has_pop(self) -> bool:
        return any(f.on_pop is not None for f in self.faults)

    def async_only_names(self) -> Tuple[str, ...]:
        return tuple(f.name for f in self.faults if f.async_only)

    def names(self) -> Tuple[str, ...]:
        return tuple(f.name for f in self.faults)

    def init(self, key) -> Dict[str, Dict]:
        return {
            f.name: f.init(jax.random.fold_in(key, i))
            for i, f in enumerate(self.faults)
        }

    def on_dispatch(self, fstate, key, send, latency):
        for i, f in enumerate(self.faults):
            if f.on_dispatch is None:
                continue
            sub, latency = f.on_dispatch(
                fstate[f.name], jax.random.fold_in(key, i), send, latency
            )
            fstate = {**fstate, f.name: sub}
        return fstate, latency

    def on_pop(self, fstate, key, idx, valid):
        eff = identity_effects(idx.shape)
        for i, f in enumerate(self.faults):
            if f.on_pop is None:
                continue
            sub, e = f.on_pop(
                fstate[f.name], jax.random.fold_in(key, i), idx, valid
            )
            fstate = {**fstate, f.name: sub}
            eff = merge_effects(eff, e)
        return fstate, eff

    def counters(self, fstate) -> Dict[str, float]:
        return {
            f.name: float(fstate[f.name]["injected"]) for f in self.faults
        }

    def exposure(self, fstate) -> Dict[str, "np.ndarray"]:
        """Per-client hit tallies, one ``(n,)`` float array per fault.

        Host-side copy of the ``exposed`` counters — the ground-truth
        "which clients were actually attacked" labels that the defense
        benchmarks score detection precision/recall against. Surfaced on
        :class:`~repro.engine.config.RunResult` only when
        ``RunConfig.fault_exposure`` is set."""
        import numpy as np

        return {
            f.name: np.asarray(fstate[f.name]["exposed"])
            for f in self.faults
        }


def corrupt_updates(updated, bases, eff: Effects, key,
                    has_scale: bool, has_noise: bool):
    """Apply the scale/noise channels to the cohort's trained params.

    ``updated`` is cohort-stacked; ``bases`` is the params each slot
    trained from (stacked, or the unstacked global tree — broadcasts).
    Each channel is applied *independently* through its own per-slot
    ``where``: scale rewrites a hit slot's update as
    ``base + scale * delta``, noise adds ``sigma * N(0, 1)`` directly to
    the hit slot's params. A missed slot keeps its exact input buffer
    (``b + (u - b)`` is not bitwise ``u`` in floating point), which is
    what makes a rate-0 corrupting fault set bitwise identity — and the
    channels stay separate expressions rather than one fused
    ``scale * delta + noise`` chain, which empirically keeps XLA from
    re-fusing the downstream cohort reduction when several corrupting
    faults are armed at once.
    """
    lu = jax.tree.leaves(updated)
    lb = jax.tree.leaves(bases)

    def one(i, u, b):
        ws = (-1,) + (1,) * (u.ndim - 1)
        if has_scale:
            hit = (eff.delta_scale != 1.0).reshape(ws)
            d = (u - b).astype(jnp.float32) * eff.delta_scale.reshape(ws)
            u = jnp.where(hit, b + d.astype(u.dtype), u)
        if has_noise:
            hit = (eff.noise_sigma > 0.0).reshape(ws)
            noise = eff.noise_sigma.reshape(ws) * jax.random.normal(
                jax.random.fold_in(key, i), u.shape, jnp.float32
            )
            u = jnp.where(hit, u + noise.astype(u.dtype), u)
        return u

    out = [one(i, u, b) for i, (u, b) in enumerate(zip(lu, lb))]
    return jax.tree.unflatten(jax.tree.structure(updated), out)


# Host-side RNG seed for the coalition's shared poisoned direction —
# fixed across rounds (that persistence is the attack: a drifting poison
# direction would average itself away in the aggregate, and is exactly
# what the defense's historical-direction sketches converge on).
COLLUDE_SEED = 0xC0A11D0
_COLLUDE_CACHE: dict = {}


def _collude_direction(shapes):
    """Unit-norm (over the whole pytree) poison direction, cached by the
    per-slot leaf shapes so every engine embeds identical constants."""
    import numpy as np

    key = tuple(shapes)
    cached = _COLLUDE_CACHE.get(key)
    if cached is None:
        rng = np.random.default_rng(COLLUDE_SEED)
        leaves = [rng.standard_normal(shp).astype(np.float32)
                  for shp in shapes]
        gnorm = np.sqrt(sum(float((lv.astype(np.float64) ** 2).sum())
                            for lv in leaves)) or 1.0
        cached = [lv / np.float32(gnorm) for lv in leaves]
        _COLLUDE_CACHE[key] = cached
    return cached


def collude_updates(updated, bases, eff: Effects):
    """Apply the collude channel: a hit slot's update is replaced by
    ``base + mult * own_norm * shared_direction`` — the coalition's
    common poisoned direction, norm-matched to the slot's own honest
    delta (times the per-slot jitter multiplier), so per-slot norm
    statistics see nothing. Missed slots keep their exact input buffer
    (bitwise identity, like :func:`corrupt_updates`). No key needed:
    the direction is a trace-time constant and the jitter was drawn on
    the fault's own fold at pop time.
    """
    lu = jax.tree.leaves(updated)
    lb = jax.tree.leaves(bases)
    shapes = tuple(tuple(u.shape[1:]) for u in lu)
    dirs = _collude_direction(shapes)

    nonb = lambda d: tuple(range(1, d.ndim))  # noqa: E731
    sq = sum(jnp.sum(((u - b).astype(jnp.float32)) ** 2, axis=nonb(u))
             for u, b in zip(lu, lb))
    mag = jnp.sqrt(sq) * eff.collude  # (B,) target norms, 0 if missed
    hit = eff.collude > 0.0

    out = []
    for u, b, dv in zip(lu, lb, dirs):
        ws = (-1,) + (1,) * (u.ndim - 1)
        poison = b + (mag.reshape(ws) * jnp.asarray(dv)).astype(u.dtype)
        out.append(jnp.where(hit.reshape(ws), poison, u))
    return jax.tree.unflatten(jax.tree.structure(updated), out)


# ---------------------------------------------------------------------------
# Built-in faults
# ---------------------------------------------------------------------------


def _prone_init(n: int, client_frac: float):
    """Persistent susceptible-client mask + injection counter."""
    if not 0.0 <= client_frac <= 1.0:
        raise ValueError(f"client_frac must be in [0, 1], got {client_frac}")

    def init(key):
        if client_frac >= 1.0:
            prone = jnp.ones((n,), jnp.bool_)
        else:
            prone = jax.random.bernoulli(key, client_frac, (n,))
        return {
            "prone": prone,
            "injected": jnp.zeros((), jnp.float32),
            # per-client hit tally — ground truth for detection P/R
            # benchmarks and the opt-in RunResult.fault_exposure surface
            "exposed": jnp.zeros((n,), jnp.float32),
        }

    return init


def _check_rate(name: str, rate: float) -> None:
    if not 0.0 <= rate <= 1.0:
        raise ValueError(f"{name}: rate must be in [0, 1], got {rate}")


def _cohort_hit(fst, key, idx, valid, rate):
    """Per-slot injection coin among prone, valid cohort members."""
    hit = fst["prone"][idx] & valid
    if rate < 1.0:
        hit = hit & jax.random.bernoulli(key, rate, idx.shape)
    return hit


def _count(fst, hit, idx=None):
    """Bump the scalar injection counter and the per-client exposure
    tally. ``idx`` given means ``hit`` is cohort-shaped — scatter-add at
    the cohort's client indices (``mode="drop"`` so padded slots, which
    are never hit anyway, cannot write out of bounds); ``idx=None`` means
    ``hit`` is already fleet-shaped (dispatch-side faults)."""
    h = hit.astype(jnp.float32)
    if idx is None:
        exposed = fst["exposed"] + h
    else:
        exposed = fst["exposed"].at[idx].add(h, mode="drop")
    return {
        **fst,
        "injected": fst["injected"] + h.sum(),
        "exposed": exposed,
    }


@register_fault("dropout")
def make_dropout(n: int, rate: float, client_frac: float = 1.0) -> Fault:
    """Mid-round dropout: the client trained but its update never arrives
    — the slot is excluded from aggregation like a dropped buffer slot."""
    _check_rate("dropout", rate)

    def on_pop(fst, key, idx, valid):
        hit = _cohort_hit(fst, key, idx, valid, rate)
        eff = identity_effects(idx.shape)._replace(kill=hit)
        return _count(fst, hit, idx), eff

    return Fault("dropout", channels=("kill",), rate=rate,
                 init=_prone_init(n, client_frac), on_pop=on_pop)


@register_fault("straggler")
def make_straggler(n: int, rate: float, stall: float = 10.0,
                   client_frac: float = 1.0) -> Fault:
    """Straggler stall: a dispatched client's wall-clock latency is
    multiplied by ``stall`` — it completes eventually, arbitrarily stale
    (and past any re-dispatch deadline). Async only: sync rounds have no
    wall clock for the stall to act on."""
    _check_rate("straggler", rate)
    if stall <= 0:
        raise ValueError(f"straggler: stall must be > 0, got {stall}")

    def on_dispatch(fst, key, send, latency):
        hit = fst["prone"] & send
        if rate < 1.0:
            hit = hit & jax.random.bernoulli(key, rate, (n,))
        latency = jnp.where(hit, latency * jnp.float32(stall), latency)
        return _count(fst, hit), latency

    return Fault("straggler", channels=("latency",), rate=rate,
                 async_only=True, init=_prone_init(n, client_frac),
                 on_dispatch=on_dispatch)


@register_fault("stale_replay")
def make_stale_replay(n: int, rate: float, shift: int = MAX_REPLAY,
                      client_frac: float = 1.0) -> Fault:
    """Stale replay: the client ignores the model it was handed and
    trains from a version ``shift`` older (clipped to the oldest retained
    ring slot). Staleness *weighting* still sees the honest dispatch
    version — the attack is exactly that the discount does not know.
    Async only: the sync engine has no version ring to replay from."""
    _check_rate("stale_replay", rate)
    if shift < 1:
        raise ValueError(f"stale_replay: shift must be >= 1, got {shift}")

    def on_pop(fst, key, idx, valid):
        hit = _cohort_hit(fst, key, idx, valid, rate)
        eff = identity_effects(idx.shape)._replace(
            replay_shift=jnp.where(hit, jnp.int32(shift), 0)
        )
        return _count(fst, hit, idx), eff

    return Fault("stale_replay", channels=("replay",), rate=rate,
                 async_only=True, init=_prone_init(n, client_frac),
                 on_pop=on_pop)


@register_fault("corrupt")
def make_corrupt(n: int, rate: float, sigma: float = 1.0,
                 client_frac: float = 1.0) -> Fault:
    """Corrupted update: gaussian noise of scale ``sigma`` added to the
    slot's delta (bit flips, truncated uploads, garbage gradients)."""
    _check_rate("corrupt", rate)
    if sigma <= 0:
        raise ValueError(f"corrupt: sigma must be > 0, got {sigma}")

    def on_pop(fst, key, idx, valid):
        hit = _cohort_hit(fst, key, idx, valid, rate)
        eff = identity_effects(idx.shape)._replace(
            noise_sigma=jnp.where(hit, jnp.float32(sigma), 0.0)
        )
        return _count(fst, hit, idx), eff

    return Fault("corrupt", channels=("noise",), rate=rate,
                 init=_prone_init(n, client_frac), on_pop=on_pop)


@register_fault("sign_flip")
def make_sign_flip(n: int, rate: float, client_frac: float = 1.0) -> Fault:
    """Sign-flipping attacker: the slot submits ``-delta``, steering the
    aggregate away from its own descent direction."""
    _check_rate("sign_flip", rate)

    def on_pop(fst, key, idx, valid):
        hit = _cohort_hit(fst, key, idx, valid, rate)
        eff = identity_effects(idx.shape)._replace(
            delta_scale=jnp.where(hit, -1.0, 1.0)
        )
        return _count(fst, hit, idx), eff

    return Fault("sign_flip", channels=("scale",), rate=rate,
                 init=_prone_init(n, client_frac), on_pop=on_pop)


@register_fault("scale_attack")
def make_scale_attack(n: int, rate: float, factor: float = 10.0,
                      client_frac: float = 1.0) -> Fault:
    """Scaled-update (model replacement) attacker: the slot's delta is
    boosted ``factor``x to dominate the aggregate."""
    _check_rate("scale_attack", rate)
    if factor == 1.0:
        raise ValueError("scale_attack: factor=1.0 is a no-op")

    def on_pop(fst, key, idx, valid):
        hit = _cohort_hit(fst, key, idx, valid, rate)
        eff = identity_effects(idx.shape)._replace(
            delta_scale=jnp.where(hit, jnp.float32(factor), 1.0)
        )
        return _count(fst, hit, idx), eff

    return Fault("scale_attack", channels=("scale",), rate=rate,
                 init=_prone_init(n, client_frac), on_pop=on_pop)


@register_fault("collude")
def make_collude(n: int, rate: float, client_frac: float = 0.25,
                 jitter: float = 0.2) -> Fault:
    """Colluding coalition: ``client_frac`` of the fleet shares one
    fixed poisoned direction (see :data:`COLLUDE_SEED`); each hit slot
    submits it norm-matched to its own honest delta times a lognormal
    jitter ``exp(jitter * N(0, 1))`` — per-slot norm statistics see an
    ordinary update, only cross-client direction *agreement over time*
    gives the coalition away."""
    _check_rate("collude", rate)
    if jitter < 0:
        raise ValueError(f"collude: jitter must be >= 0, got {jitter}")

    def on_pop(fst, key, idx, valid):
        k_hit, k_jit = (jax.random.fold_in(key, 0),
                        jax.random.fold_in(key, 1))
        hit = _cohort_hit(fst, k_hit, idx, valid, rate)
        mult = jnp.exp(jnp.float32(jitter)
                       * jax.random.normal(k_jit, idx.shape, jnp.float32))
        eff = identity_effects(idx.shape)._replace(
            collude=jnp.where(hit, mult, 0.0))
        return _count(fst, hit, idx), eff

    return Fault("collude", channels=("collude",), rate=rate,
                 init=_prone_init(n, client_frac), on_pop=on_pop)


@register_fault("replica_crash")
def make_replica_crash(n: int, rate: float) -> Fault:
    """Serve-tier replica crash: each tick, each alive replica dies with
    probability ``rate`` (the last alive replica is spared so the pool
    can always drain). Consumed by ``serve.run_serve_loop`` — in-flight
    streams on a crashed replica re-enter the queue and resume on a
    survivor through the bit-for-bit join path."""
    _check_rate("replica_crash", rate)
    return Fault("replica_crash", channels=(), rate=rate, scope="serve")
