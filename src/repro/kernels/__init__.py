"""Pallas TPU kernels for the framework's compute hot-spots.

  flash_attention — GQA causal/sliding/chunked flash attention
  ssd_scan        — Mamba2 SSD chunked scan (state carried in VMEM)
  flash_decode    — one-token attention over a long KV cache (serving)
  fedavg_reduce   — FedAvg server aggregation (weighted cohort mean)
  aoi_topk        — fleet-scale oldest-age top-k (centralized baseline)

``ops`` holds the jit'd public wrappers (interpret=True on CPU);
``ref`` the pure-jnp oracles every kernel is tested against.
"""
