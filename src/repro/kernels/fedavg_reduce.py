"""Pallas TPU kernel for FedAvg server aggregation.

Weighted mean over the stacked-cohort axis of flattened parameters:
out[n] = sum_c w[c] * params[c, n]. The parameter axis is tiled so each
program streams a (cohort, block_n) tile through VMEM and contracts it
against the weight vector on the MXU — the server-side hot-spot when the
cohort or model is large.

VMEM per program at defaults (C<=64, block_n=16384, f32):
  tile 64*16384*4 = 4 MB + out 64 KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 16384


def _fedavg_kernel(p_ref, w_ref, o_ref):
    tile = p_ref[...]  # (C, bn)
    w = w_ref[...]  # (C,)
    o_ref[...] = jax.lax.dot_general(
        w[None].astype(jnp.float32),
        tile.astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def fedavg_reduce(
    params: jnp.ndarray,  # (C, N) stacked flattened cohort params
    weights: jnp.ndarray,  # (C,) normalized weights (sum to 1 over cohort)
    *,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
) -> jnp.ndarray:
    C, N = params.shape
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        params = jnp.pad(params, ((0, 0), (0, pad)))
    Np = params.shape[1]
    out = pl.pallas_call(
        _fedavg_kernel,
        grid=(Np // bn,),
        in_specs=[
            pl.BlockSpec((C, bn), lambda i: (0, i)),
            pl.BlockSpec((C,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Np,), params.dtype),
        interpret=interpret,
    )(params, weights.astype(params.dtype))
    return out[:N]
