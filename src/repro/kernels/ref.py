"""Pure-jnp oracles for every Pallas kernel (ground truth for tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, scale, kind="full", window=0):
    """q: (B,Hk,G,S,D); k/v: (B,Hk,S,D). Direct masked softmax attention."""
    S = q.shape[3]
    pos = jnp.arange(S)
    qp, kp = pos[:, None], pos[None, :]
    mask = kp <= qp
    if kind == "sliding" and window > 0:
        mask &= kp > qp - window
    elif kind == "chunked" and window > 0:
        mask &= (kp // window) == (qp // window)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", q, k).astype(jnp.float32) * scale
    s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgqk,bhkd->bhgqd", w.astype(v.dtype), v)


def ssd_scan_ref(x, dt, A, B_, C_):
    """Naive per-step SSM recurrence (oracle). Shapes as kernels.ssd_scan."""
    from repro.models.ssm import ssd_reference

    y, _ = ssd_reference(x, dt, A, B_, C_)
    return y.astype(x.dtype)


def flash_decode_ref(q, k, v, valid_len, *, scale):
    """q: (B,Hk,G,D); k/v: (B,Hk,L,D); one-token attention over the cache."""
    L = k.shape[2]
    s = jnp.einsum("bhgd,bhld->bhgl", q, k).astype(jnp.float32) * scale
    valid = jnp.arange(L)[None] < jnp.broadcast_to(
        jnp.asarray(valid_len), (q.shape[0],)
    )[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgl,bhld->bhgd", w.astype(v.dtype), v)


def fedavg_reduce_ref(params, weights):
    """out[n] = sum_c w[c] p[c,n]."""
    return jnp.einsum(
        "c,cn->n", weights.astype(jnp.float32), params.astype(jnp.float32)
    ).astype(params.dtype)


def topk_ref(ages, k):
    """Global top-k (values, indices) with highest-age-first order."""
    vals, idx = jax.lax.top_k(ages.astype(jnp.float32), k)
    return vals, idx
