"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid: (batch, heads, num_chunks); the chunk axis is sequential and carries
the (head_dim, d_state) SSM state in VMEM scratch. Each program computes
the within-chunk dual (attention-like) term on the MXU plus the
cross-chunk contribution of the carried state, then updates the state.

VMEM per program at defaults (L=256, hd=64, ds=128, f32):
  x (256,64) + B/C (256,128)x2 + scores (256,256) + state (64,128)
  ~= 0.6 MB << 16 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,  # (1, 1, L, hd)   chunk of head inputs
    dt_ref,  # (1, 1, L)
    a_ref,  # (1,)            A for this head (negative)
    b_ref,  # (1, L, ds)
    c_ref,  # (1, L, ds)
    y_ref,  # (1, 1, L, hd)
    h_scr,  # (hd, ds) f32    carried state
    *,
    chunk: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)  # (L, hd)
    dt = dt_ref[0, 0].astype(jnp.float32)  # (L,)
    A = a_ref[0]
    B = b_ref[0].astype(jnp.float32)  # (L, ds)
    C = c_ref[0].astype(jnp.float32)  # (L, ds)

    l = dt * A  # (L,) log-decay per step
    cs = jnp.cumsum(l)  # inclusive
    total = cs[-1]

    # intra-chunk dual form
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(cs[:, None] - cs[None, :])
    scores = jnp.where(lj <= li, cb * decay * dt[None, :], 0.0)
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (L, hd)

    # inter-chunk: contribution of carried state
    ch = jax.lax.dot_general(C, h_scr[...], (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, hd)
    y = y + jnp.exp(cs)[:, None] * ch

    # state update: h' = exp(total) h + sum_j exp(total - cs_j) dt_j x_j B_j^T
    w = jnp.exp(total - cs) * dt  # (L,)
    xw = x * w[:, None]  # (L, hd)
    h_new = jax.lax.dot_general(xw, B, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (hd, ds)
    h_scr[...] = jnp.exp(total) * h_scr[...] + h_new

    y_ref[0, 0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jnp.ndarray,  # (B, S, nh, hd)
    dt: jnp.ndarray,  # (B, S, nh)  post-softplus
    A: jnp.ndarray,  # (nh,) negative
    B_: jnp.ndarray,  # (B, S, ds)
    C_: jnp.ndarray,  # (B, S, ds)
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    Bb, S, nh, hd = x.shape
    ds = B_.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L

    # layouts: head-major for per-(batch, head) programs
    xr = x.transpose(0, 2, 1, 3)  # (B, nh, S, hd)
    dtr = dt.transpose(0, 2, 1)  # (B, nh, S)

    kernel = functools.partial(_ssd_kernel, chunk=L)
    yr = pl.pallas_call(
        kernel,
        grid=(Bb, nh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, L, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, L), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, L, ds), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, L, ds), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, L, hd), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((Bb, nh, S, hd), x.dtype),
        scratch_shapes=[pltpu.VMEM((hd, ds), jnp.float32)],
        interpret=interpret,
    )(xr, dtr, A.astype(jnp.float32), B_, C_)
    return yr.transpose(0, 2, 1, 3)
