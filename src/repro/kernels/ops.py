"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) kernels run with ``interpret=True`` — the kernel
body executes step-by-step with correct semantics, which is what the
allclose tests validate. On a real TPU backend ``interpret`` flips off
automatically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import aoi_topk as _topk
from repro.kernels import event_topk as _etopk
from repro.kernels import fedavg_reduce as _fedavg
from repro.kernels import flash_attention as _flash
from repro.kernels import flash_decode as _fdec
from repro.kernels import ssd_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def flash_attention(q, k, v, *, scale, kind="full", window=0, block_q=None, block_k=None):
    kw = {}
    if block_q:
        kw["block_q"] = block_q
    if block_k:
        kw["block_k"] = block_k
    return _flash.flash_attention(
        q, k, v, scale=scale, kind=kind, window=window, interpret=_interpret(), **kw
    )


def flash_decode(q, k, v, valid_len, *, scale, block_l=None):
    kw = {"block_l": block_l} if block_l else {}
    return _fdec.flash_decode(
        q, k, v, valid_len, scale=scale, interpret=_interpret(), **kw
    )


def ssd_scan(x, dt, A, B_, C_, *, chunk=256):
    return _ssd.ssd_scan(x, dt, A, B_, C_, chunk=chunk, interpret=_interpret())


def fedavg_reduce(params, weights, *, block_n=None):
    kw = {"block_n": block_n} if block_n else {}
    return _fedavg.fedavg_reduce(params, weights, interpret=_interpret(), **kw)


def oldest_age_topk(ages, k, *, block_n=None):
    """Fleet-scale oldest-age selection: tiled kernel phase + tiny global
    top-k over candidates. Returns (values, indices)."""
    kw = {"block_n": block_n} if block_n else {}
    vals, idx = _topk.tile_topk(ages, k=k, interpret=_interpret(), **kw)
    flat_v, flat_i = vals.reshape(-1), idx.reshape(-1)
    top_v, pos = jax.lax.top_k(flat_v, k)
    return top_v, flat_i[pos]


def event_next_k(times, k, *, block_n=None):
    """Fleet-scale next-k-completion extraction: tiled kernel phase + tiny
    global top-k over per-tile candidates. Returns (times (k,), indices
    (k,)) of the k earliest events; slots with no pending event carry
    ``+inf`` times (mask by finiteness)."""
    kw = {"block_n": block_n} if block_n else {}
    vals, idx = _etopk.tile_next_k(times, k=k, interpret=_interpret(), **kw)
    flat_v, flat_i = vals.reshape(-1), idx.reshape(-1)
    neg_v, pos = jax.lax.top_k(-flat_v, k)
    return -neg_v, flat_i[pos]
