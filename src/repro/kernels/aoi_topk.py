"""Pallas TPU kernel: oldest-age top-k candidate selection at fleet scale.

The centralized oldest-age policy (paper Remark 1) needs the k highest
ages among n clients, where n may be millions. Phase 1 (this kernel)
tiles the age vector and extracts each tile's local top-k by iterative
masked max (k iterations of a VPU max-reduce — no sort needed); phase 2
(ops.py) runs a tiny jnp top-k over the (num_tiles * k) candidates.

VMEM per program: ages tile (block_n,) f32 + (k,) outputs — trivially
small; block_n=65536 streams the fleet through VMEM once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 65536
NEG = -1e30


def _topk_kernel(ages_ref, vals_ref, idx_ref, *, k: int, block_n: int):
    ti = pl.program_id(0)
    a = ages_ref[...].astype(jnp.float32)  # (block_n,)
    base = ti * block_n

    def body(i, carry):
        a_cur, = carry
        m = jnp.max(a_cur)
        am = jnp.argmax(a_cur)
        vals_ref[i] = m
        idx_ref[i] = (base + am).astype(jnp.int32)
        a_cur = a_cur.at[am].set(NEG)
        return (a_cur,)

    jax.lax.fori_loop(0, k, body, (a,))


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def tile_topk(
    ages: jnp.ndarray,  # (n,) int32/float
    *,
    k: int,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
):
    """Returns (vals (tiles, k), idx (tiles, k)) per-tile top-k candidates."""
    n = ages.shape[0]
    bn = min(block_n, n)
    pad = (-n) % bn
    if pad:
        ages = jnp.pad(ages, (0, pad), constant_values=-1)
    tiles = ages.shape[0] // bn
    kernel = functools.partial(_topk_kernel, k=k, block_n=bn)
    vals, idx = pl.pallas_call(
        kernel,
        grid=(tiles,),
        in_specs=[pl.BlockSpec((bn,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((k,), lambda i: (i,)),
            pl.BlockSpec((k,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tiles * k,), jnp.float32),
            jax.ShapeDtypeStruct((tiles * k,), jnp.int32),
        ],
        interpret=interpret,
    )(ages)
    return vals.reshape(tiles, k), idx.reshape(tiles, k)
