"""Pallas TPU flash attention (GQA + causal / sliding-window / chunked).

Grid: (batch, kv_head, q_blocks, k_blocks); the k_blocks axis is the
innermost sequential ("arbitrary") dimension and carries the online-softmax
state (m, l, acc) in VMEM scratch. Query blocks carry all G = H/Hk query
heads of one kv head, so K/V tiles stream from HBM once per kv head (the
GQA bandwidth win). MXU dims (block_q, block_k, head_dim) are multiples
of 128 at the defaults.

VMEM working set per program at defaults (bf16, D=128, G<=8):
  q (G,256,128) + k/v 2x(512,128) + acc f32 (G,256,128) ~= 2.2 MB << 16 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # (1, 1, G, bq, D)
    k_ref,  # (1, 1, bk, D)
    v_ref,  # (1, 1, bk, D)
    o_ref,  # (1, 1, G, bq, D)
    m_scr,  # (G, bq) f32
    l_scr,  # (G, bq) f32
    acc_scr,  # (G, bq, D) f32
    *,
    scale: float,
    kind: str,
    window: int,
    block_q: int,
    block_k: int,
    num_k_blocks: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]  # (G, bq, D)
    k = k_ref[0, 0]  # (bk, D)
    v = v_ref[0, 0]

    s = jax.lax.dot_general(
        q, k, (((2,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (G, bq, bk)
    s = s * scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos <= q_pos  # causal
    if kind == "sliding" and window > 0:
        mask &= k_pos > q_pos - window
    elif kind == "chunked" and window > 0:
        mask &= (k_pos // window) == (q_pos // window)
    s = jnp.where(mask[None], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (G, bq, D)
    acc_scr[...] = acc_scr[...] * alpha[..., None] + pv
    m_scr[...] = m_new

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[..., None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("kind", "window", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # (B, Hk, G, S, D)
    k: jnp.ndarray,  # (B, Hk, S, D)
    v: jnp.ndarray,  # (B, Hk, S, D)
    *,
    scale: float,
    kind: str = "full",
    window: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hk, G, S, D = q.shape
    bq = min(block_q, S)
    bk = min(block_k, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nk = S // bq, S // bk

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        kind=kind,
        window=window,
        block_q=bq,
        block_k=bk,
        num_k_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(B, Hk, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, bq, D), lambda b, h, qi, ki: (b, h, 0, qi, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, qi, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, qi, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, bq, D), lambda b, h, qi, ki: (b, h, 0, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hk, G, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, bq), jnp.float32),
            pltpu.VMEM((G, bq), jnp.float32),
            pltpu.VMEM((G, bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
