"""Pallas TPU kernel: next-k-completion extraction at fleet scale.

The async event engine needs the k *earliest* pending completion times
among n in-flight clients, where n may be millions and idle clients carry
``+inf``. Same tiled masked-reduce idiom as ``aoi_topk``: phase 1 (this
kernel) tiles the time vector and extracts each tile's k earliest events
by iterative max over *negated* times (k VPU max-reduces, no sort);
phase 2 (ops.py) runs a tiny jnp top-k over the (num_tiles * k)
candidates.

Idle (+inf) entries negate to -inf and lose every max, so they are only
emitted when a tile holds fewer than k pending events; the caller masks
them out by finiteness. The selected-element sentinel is -inf (not a
finite floor) so an exhausted tile can never re-emit a real event.

VMEM per program: one (block_n,) f32 tile + two (k,) outputs — trivially
small; block_n=65536 streams the fleet through VMEM once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 65536


def _next_k_kernel(times_ref, vals_ref, idx_ref, *, k: int, block_n: int):
    ti = pl.program_id(0)
    neg = -times_ref[...].astype(jnp.float32)  # (block_n,) earliest = max
    base = ti * block_n

    def body(i, carry):
        cur, = carry
        m = jnp.max(cur)
        am = jnp.argmax(cur)
        vals_ref[i] = -m  # back to a time; +inf marks "no event"
        idx_ref[i] = (base + am).astype(jnp.int32)
        cur = cur.at[am].set(-jnp.inf)
        return (cur,)

    jax.lax.fori_loop(0, k, body, (neg,))


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def tile_next_k(
    times: jnp.ndarray,  # (n,) f32 completion times, +inf when idle
    *,
    k: int,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
):
    """Returns (vals (tiles, k), idx (tiles, k)) per-tile earliest events."""
    times = times.astype(jnp.float32)
    n = times.shape[0]
    bn = min(block_n, n)
    pad = (-n) % bn
    if pad:
        times = jnp.pad(times, (0, pad), constant_values=jnp.inf)
    tiles = times.shape[0] // bn
    kernel = functools.partial(_next_k_kernel, k=k, block_n=bn)
    vals, idx = pl.pallas_call(
        kernel,
        grid=(tiles,),
        in_specs=[pl.BlockSpec((bn,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((k,), lambda i: (i,)),
            pl.BlockSpec((k,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tiles * k,), jnp.float32),
            jax.ShapeDtypeStruct((tiles * k,), jnp.int32),
        ],
        interpret=interpret,
    )(times)
    return vals.reshape(tiles, k), idx.reshape(tiles, k)
