"""Pallas TPU flash-decode: one query token against a long KV cache.

The §Perf analysis of deepseek decode_32k identified fp32 score
temporaries (B x H x L per layer) as the residual memory-term gap after
MLA absorption. This kernel streams the cache through VMEM in blocks with
online-softmax state in scratch, so scores never round-trip to HBM:
grid (batch, kv_head, cache_blocks); the cache-block axis is sequential
and carries (m, l, acc).

Masking: slots beyond ``valid_len`` are ignored (ring caches pass the
number of valid slots; position-dependent window masks are applied by the
caller via valid_len because a warm ring holds exactly the window).

VMEM per program at defaults (bf16, D=128, G<=16, block 1024):
  k/v 2 x (1024,128) + acc f32 (G,128) ~= 0.6 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_L = 1024
NEG_INF = -1e30


def _decode_kernel(
    q_ref,  # (1, 1, G, D)
    k_ref,  # (1, 1, bl, D)
    v_ref,  # (1, 1, bl, D)
    vlen_ref,  # (1,) int32 — number of valid cache slots
    o_ref,  # (1, 1, G, D)
    m_scr,  # (G,) f32... stored as (G, 1)
    l_scr,  # (G, 1)
    acc_scr,  # (G, D)
    *,
    scale: float,
    block_l: int,
    num_blocks: int,
):
    li = pl.program_id(2)

    @pl.when(li == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]  # (G, D)
    k = k_ref[0, 0]  # (bl, D)
    v = v_ref[0, 0]
    vlen = vlen_ref[0]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (G, bl)
    slot = li * block_l + jax.lax.broadcasted_iota(jnp.int32, (1, block_l), 1)
    s = jnp.where(slot < vlen, s, NEG_INF)

    m_prev = m_scr[...][:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = (l_scr[...][:, 0] * alpha + p.sum(axis=-1))[:, None]
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
    m_scr[...] = m_new[:, None]

    @pl.when(li == num_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...][:, 0], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "block_l", "interpret"))
def flash_decode(
    q: jnp.ndarray,  # (B, Hk, G, D) one token's queries
    k: jnp.ndarray,  # (B, Hk, L, D) cache
    v: jnp.ndarray,  # (B, Hk, L, D)
    valid_len: jnp.ndarray,  # () or (B,) int32 valid slots
    *,
    scale: float,
    block_l: int = DEFAULT_BLOCK_L,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hk, G, D = q.shape
    L = k.shape[2]
    bl = min(block_l, L)
    pad = (-L) % bl
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    Lp = k.shape[2]
    nb = Lp // bl
    vlen = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (B,))

    kernel = functools.partial(
        _decode_kernel, scale=scale, block_l=bl, num_blocks=nb
    )
    return pl.pallas_call(
        kernel,
        grid=(B, Hk, nb),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, l: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bl, D), lambda b, h, l: (b, h, l, 0)),
            pl.BlockSpec((1, 1, bl, D), lambda b, h, l: (b, h, l, 0)),
            pl.BlockSpec((1,), lambda b, h, l: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, l: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hk, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, vlen)
