"""Unified federated engine API.

One contract for every run, sync or async:

  * **Registries** — ``@register_policy`` / ``@register_aggregator`` plus
    ``make_policy`` / ``make_aggregator`` dispatch: a new scheduling
    policy or aggregation rule is a registry entry, not a fork of a round
    loop.
  * **Protocols** — ``Policy`` (explicit state pytree, ``init/step``),
    ``Aggregator`` (pure ``weigh/init/accumulate/finalize``), ``Engine``
    (``init/step/finalize``).
  * **Contract** — ``RunConfig`` in (absorbing the legacy
    ``FLConfig``/``AsyncConfig`` pair), ``RunResult``/``RoundRecord`` out,
    with one JSON-safe serializer (``to_jsonable``/``dump_json``).

The paper's policies live in ``repro.core.selection`` and register
themselves on import; ``fedavg``/``fedbuff``/``fedprox`` aggregators in
``repro.engine.aggregators``. ``repro.fl.run_training`` and
``repro.sim.run_async_training`` remain as thin back-compat wrappers.
"""
from repro.engine.registry import (  # noqa: F401
    aggregator_names,
    make_aggregator,
    make_policy,
    policy_names,
    register_aggregator,
    register_policy,
)
from repro.engine.serialize import dump_json, to_jsonable  # noqa: F401
from repro.engine.aggregators import Aggregator, staleness_weight  # noqa: F401
from repro.engine import robust  # noqa: F401  (registers robust aggregators)
from repro.engine.config import (  # noqa: F401
    RoundRecord,
    RunConfig,
    RunResult,
    run_config_from_legacy,
)
from repro.engine.api import (  # noqa: F401
    HISTORY_CELL_CAP,
    Engine,
    make_engine,
    run_engine,
)
from repro.engine.sync import SyncEngine  # noqa: F401
from repro.engine.async_engine import AsyncEngine  # noqa: F401
from repro.engine.sharded import ShardedAsyncEngine  # noqa: F401
from repro.core.selection import Policy  # noqa: F401  (registers built-ins)
