"""Name-based registries for selection policies and aggregators.

A scenario is a *registry entry*, not a fork of a round loop: register a
factory under a name and every driver, benchmark, and engine can construct
it from a config string. Factories are normalized so dispatch needs no
per-policy special cases:

    policy factory      (n, k, m, **kwargs) -> Policy
    aggregator factory  (**kwargs)          -> Aggregator

Built-ins register themselves at import time (`repro.core.selection` for
the paper's policies, `repro.engine.aggregators` for fedavg / fedbuff /
fedprox); user code registers the same way:

    from repro.engine import register_policy

    @register_policy("my_sched")
    def _make(n, k, m, **kw):
        return Policy("my_sched", init, step, exact_k=True)

and ``RunConfig(policy="my_sched")`` just works — no engine edits.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

_POLICIES: Dict[str, Callable] = {}
_AGGREGATORS: Dict[str, Callable] = {}


def register_policy(name: str) -> Callable:
    """Decorator: register ``factory(n, k, m, **kw) -> Policy`` under ``name``."""

    def deco(factory: Callable) -> Callable:
        if name in _POLICIES:
            raise ValueError(f"policy {name!r} already registered")
        _POLICIES[name] = factory
        return factory

    return deco


def register_aggregator(name: str) -> Callable:
    """Decorator: register ``factory(**kw) -> Aggregator`` under ``name``."""

    def deco(factory: Callable) -> Callable:
        if name in _AGGREGATORS:
            raise ValueError(f"aggregator {name!r} already registered")
        _AGGREGATORS[name] = factory
        return factory

    return deco


def make_policy(name: str, n: int, k: int, m: int = 10, **kw):
    """Construct a registered policy by name."""
    try:
        factory = _POLICIES[name]
    except KeyError:
        raise ValueError(
            f"unknown policy {name!r}; registered: {', '.join(policy_names())}"
        ) from None
    return factory(n, k, m, **kw)


def make_aggregator(name: str, **kw):
    """Construct a registered aggregator by name."""
    try:
        factory = _AGGREGATORS[name]
    except KeyError:
        raise ValueError(
            f"unknown aggregator {name!r}; registered: "
            f"{', '.join(aggregator_names())}"
        ) from None
    return factory(**kw)


def policy_names() -> Tuple[str, ...]:
    return tuple(_POLICIES)


def aggregator_names() -> Tuple[str, ...]:
    return tuple(_AGGREGATORS)
