"""Robust aggregation: registry entries that survive corrupted updates.

Plain (weighted-)mean aggregation has breakdown point zero — one
sign-flipped or 10x-scaled delta moves the global params arbitrarily far.
These aggregators bound that influence, each through the seam that fits
its math:

  * ``norm_clip`` — per-slot L2 clipping of the delta *before* the
    staleness-weighted mean. Clipping is per-slot, so the accumulator is
    still a plain sum: ``additive=True``, and it runs unchanged under
    cohort sharding (``cohort_sharded_apply``) and tiered/DAG reductions
    (``topo.reduce.tiered_apply``). Carries a ``clipped`` counter in
    ``acc["stats"]`` (surfaced as ``agg_clipped``).
  * ``trimmed_mean`` — coordinate-wise trimmed mean of the deltas: the
    ``trim`` fraction of highest and lowest values per coordinate is
    discarded. Order statistics do not sum, so ``additive=False`` — it
    goes through the engines' inline (non-sharded-cohort) apply path and
    is rejected loudly by the psum/tier seams.
  * ``coordinate_median`` — coordinate-wise median of the deltas, the
    trim -> 50% limit; maximum breakdown, non-additive like above.

All three are delta aggregators (``finalize`` adds the robust mean delta
to the global params); ``trimmed_mean``/``coordinate_median`` treat
weights as validity only (order statistics are unweighted — documented
trade-off, counted per slot in the ``agg_unweighted`` stat and enforced
by rejecting staleness kwargs), while ``norm_clip`` keeps fedbuff's
staleness weighting.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.engine.aggregators import (
    Aggregator,
    _wshape,
    staleness_weight,
)
from repro.engine.registry import register_aggregator


@register_aggregator("norm_clip")
def make_norm_clip(clip: float = 10.0, staleness_mode: str = "poly",
                   staleness_exp: float = 0.5) -> Aggregator:
    """Per-slot L2 norm clipping of deltas, then the staleness-weighted
    mean: a slot whose delta exceeds ``clip`` is scaled down onto the
    ball, so a scaled-update attacker contributes at most a unit-norm
    vote. Additive — per-slot clipping commutes with the sum."""
    if clip <= 0:
        raise ValueError(f"norm_clip: clip must be > 0, got {clip}")

    def weigh(mask, staleness):
        return mask.astype(jnp.float32) * staleness_weight(
            staleness, staleness_mode, staleness_exp
        )

    def init(g):
        return {
            "dsum": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), g),
            "wsum": jnp.zeros((), jnp.float32),
            "stats": {"clipped": jnp.zeros((), jnp.float32)},
        }

    def accumulate(acc, updates, bases, w):
        deltas = jax.tree.map(
            lambda u, b: (u - b).astype(jnp.float32), updates, bases
        )
        # per-slot global L2 over the whole delta pytree
        sq = sum(
            jnp.sum(d * d, axis=tuple(range(1, d.ndim)))
            for d in jax.tree.leaves(deltas)
        )
        norm = jnp.sqrt(sq)
        scale = jnp.minimum(1.0, clip / jnp.maximum(norm, 1e-12))
        ws = w * scale
        dsum = jax.tree.map(
            lambda s, d: s + jnp.sum(d * ws.reshape(_wshape(d)), axis=0),
            acc["dsum"], deltas,
        )
        clipped = acc["stats"]["clipped"] + jnp.sum(
            ((norm > clip) & (w > 0)).astype(jnp.float32)
        )
        return {
            "dsum": dsum,
            "wsum": acc["wsum"] + w.sum(),
            "stats": {"clipped": clipped},
        }

    def finalize(g, acc):
        has = acc["wsum"] > 0
        denom = jnp.maximum(acc["wsum"], 1e-9)

        def fin(gl, s):
            return jnp.where(has, gl + (s / denom).astype(gl.dtype), gl)

        return jax.tree.map(fin, g, acc["dsum"])

    return Aggregator("norm_clip", weigh, init, accumulate, finalize,
                      additive=True, stat_names=("clipped",))


def _order_stat_aggregator(name: str, reduce_sorted) -> Aggregator:
    """Shared chassis of the order-statistic aggregators: per-coordinate
    sort of the valid deltas (invalid slots pushed to +inf at the top),
    then ``reduce_sorted(d_sorted, ranks, c)`` picks the robust center.
    Non-additive by construction."""

    def weigh(mask, staleness):
        # validity only: order statistics are unweighted
        return mask.astype(jnp.float32)

    def init(g):
        return {
            "delta": jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), g
            ),
            "count": jnp.zeros((), jnp.float32),
            "stats": {"unweighted": jnp.zeros((), jnp.float32)},
        }

    def accumulate(acc, updates, bases, w):
        valid = w > 0
        c = valid.astype(jnp.int32).sum()

        def one(u, b):
            ws = _wshape(u)
            d = jnp.where(
                valid.reshape(ws), (u - b).astype(jnp.float32), jnp.inf
            )
            d_sorted = jnp.sort(d, axis=0)
            ranks = jnp.arange(u.shape[0]).reshape(ws)
            return reduce_sorted(d_sorted, ranks, c)

        delta = jax.tree.map(one, updates, bases)
        return {
            "delta": jax.tree.map(jnp.add, acc["delta"], delta),
            "count": acc["count"] + c.astype(jnp.float32),
            # every slot that entered an order-stat reduction did so with
            # its staleness weight ignored — surfaced as agg_unweighted
            # so runs that silently drop fedbuff discounting are visible
            "stats": {
                "unweighted": acc["stats"]["unweighted"]
                + c.astype(jnp.float32)
            },
        }

    def finalize(g, acc):
        has = acc["count"] > 0

        def fin(gl, d):
            return jnp.where(has, gl + d.astype(gl.dtype), gl)

        return jax.tree.map(fin, g, acc["delta"])

    return Aggregator(name, weigh, init, accumulate, finalize,
                      additive=False, stat_names=("unweighted",))


def _reject_staleness(name: str, staleness_mode, staleness_exp) -> None:
    """Order statistics are unweighted: accepting fedbuff staleness knobs
    here and silently ignoring them has bitten before — refuse loudly."""
    if staleness_mode is not None or staleness_exp is not None:
        raise ValueError(
            f"{name}: staleness_mode/staleness_exp are not supported — "
            "order-statistic aggregators treat weights as validity only "
            "and ignore staleness discounting (use norm_clip for a "
            "robust aggregator that keeps staleness weighting)"
        )


@register_aggregator("trimmed_mean")
def make_trimmed_mean(trim: float = 0.2, staleness_mode=None,
                      staleness_exp=None) -> Aggregator:
    """Coordinate-wise trimmed mean of the deltas: per coordinate, drop
    the ``floor(c * trim)`` lowest and highest values among the ``c``
    valid slots and average the middle — robust to ``trim`` of the
    cohort colluding arbitrarily."""
    _reject_staleness("trimmed_mean", staleness_mode, staleness_exp)
    if not 0.0 <= trim < 0.5:
        raise ValueError(f"trimmed_mean: trim must be in [0, 0.5), got {trim}")

    def reduce_sorted(d_sorted, ranks, c):
        t = jnp.clip(
            jnp.floor(c.astype(jnp.float32) * trim).astype(jnp.int32),
            0, jnp.maximum((c - 1) // 2, 0),
        )
        keep = (ranks >= t) & (ranks < c - t)
        kept = jnp.where(keep, d_sorted, 0.0)
        return kept.sum(axis=0) / jnp.maximum(c - 2 * t, 1)

    return _order_stat_aggregator("trimmed_mean", reduce_sorted)


@register_aggregator("coordinate_median")
def make_coordinate_median(staleness_mode=None,
                           staleness_exp=None) -> Aggregator:
    """Coordinate-wise median of the deltas — the trim -> 50% limit of
    ``trimmed_mean`` (even counts average the two middle values)."""
    _reject_staleness("coordinate_median", staleness_mode, staleness_exp)

    def reduce_sorted(d_sorted, ranks, c):
        lo = jnp.maximum((c - 1) // 2, 0)
        hi = jnp.maximum(c // 2, 0)
        pick = jnp.where(c > 0,
                         (ranks == lo).astype(jnp.float32)
                         + (ranks == hi).astype(jnp.float32), 0.0)
        # lo == hi for odd c: pick sums to 2 either way, so /2 is the
        # median (odd) or the midpoint of the two middle values (even)
        return jnp.where(
            c > 0, jnp.sum(jnp.where(pick > 0, d_sorted * pick, 0.0),
                           axis=0) / 2.0, 0.0
        )

    return _order_stat_aggregator("coordinate_median", reduce_sorted)
