"""The synchronous FedAvg engine.

One jit'd step = policy step -> cohort gather -> vmapped local training ->
aggregator ``weigh/init/accumulate/finalize`` -> age update. This is the
round loop of ``fl/rounds.py`` re-expressed against the ``Engine``
protocol (`init/step/finalize`) with the aggregation seam opened up: the
default ``fedavg`` aggregator reproduces the pre-refactor weighted cohort
mean bit-for-bit (pinned by ``tests/test_engine_equivalence.py``), while
delta-based aggregators (``fedprox``) drop in without touching this file.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.load_metric import empirical_load_stats
from repro.core.selection import Policy
from repro.engine.aggregators import Aggregator
from repro.engine.config import RoundRecord, RunConfig, RunResult
from repro.engine.registry import make_aggregator, make_policy
from repro.fl.client import make_local_update
from repro.fl.server import broadcast_to_cohort, cohort_indices
from repro.fl.task import FLTask
from repro.optim.schedules import exponential_decay


class SyncEngine:
    """Synchronous rounds: every selected client trains from the current
    global params and the buffer is flushed once per round."""

    def __init__(
        self,
        task: FLTask,
        cfg: RunConfig,
        policy: Optional[Policy] = None,
        aggregator: Optional[Aggregator] = None,
    ):
        if cfg.mode != "sync":
            raise ValueError(f"SyncEngine needs mode='sync', got {cfg.mode!r}")
        self.task = task
        self.cfg = cfg
        self.policy = policy or make_policy(
            cfg.policy, cfg.n_clients, cfg.k, cfg.m, **dict(cfg.policy_kwargs)
        )
        self.aggregator = aggregator or make_aggregator(
            cfg.resolved_aggregator(), **dict(cfg.aggregator_kwargs)
        )
        self._round_fn = _make_round_fn(task, cfg, self.policy, self.aggregator)

    def init(self) -> Dict:
        cfg = self.cfg
        key = jax.random.PRNGKey(cfg.seed)
        k_init, k_policy, k_run = jax.random.split(key, 3)
        return {
            "params": self.task.init(k_init),
            "sched": self.policy.init(k_policy, cfg.n_clients),
            "k_run": k_run,
        }

    def step(self, state: Dict, r: int):
        params, sched, selected, loss = self._round_fn(
            state["params"], state["sched"],
            jax.random.fold_in(state["k_run"], r),
        )
        state = {**state, "params": params, "sched": sched}
        return state, {"send": selected, "loss": loss}

    def eval_params(self, state: Dict):
        return state["params"]

    def record(self, r: int, aux: Dict, ev: Dict) -> RoundRecord:
        return RoundRecord(
            round=r + 1,
            train_loss=float(aux["loss"]),
            eval_loss=float(ev["loss"]),
            accuracy=float(ev["accuracy"]),
        )

    def progress_line(self, rec: RoundRecord, elapsed: float) -> str:
        return (
            f"  [{self.policy.name}] round {rec.round:4d} "
            f"acc={rec.accuracy:.4f} loss={rec.eval_loss:.4f} ({elapsed:.1f}s)"
        )

    def finalize(self, state, records, sel_hist, wall_time_s) -> RunResult:
        return RunResult(
            config=self.cfg,
            records=records,
            selection=sel_hist,
            load_stats=empirical_load_stats(sel_hist) if sel_hist is not None else {},
            wall_stats=None,
            params=state["params"],
            wall_time_s=wall_time_s,
        )


def _make_round_fn(task: FLTask, cfg: RunConfig, policy: Policy, agg: Aggregator):
    width = cfg.cohort_width() if not policy.exact_k else cfg.k
    local_update = make_local_update(
        task.loss_fn, cfg.local_epochs, cfg.batch_size, task.examples_per_client
    )
    lr_fn = exponential_decay(cfg.lr0, cfg.lr_decay)

    @jax.jit
    def round_fn(params, sched_state, key):
        k_sel, k_local = jax.random.split(key)
        selected, sched_state = policy.step(sched_state, k_sel)
        idx, mask = cohort_indices(selected, width)
        shards = jax.tree.map(lambda a: a[idx], task.client_data)
        lr = lr_fn(sched_state["round"] - 1)
        cohort_params = broadcast_to_cohort(params, width)
        keys = jax.random.split(k_local, width)
        updated, losses = jax.vmap(local_update, in_axes=(0, 0, 0, None))(
            cohort_params, shards, keys, lr
        )
        # sync cohorts are never stale: staleness is identically zero
        w = agg.weigh(mask > 0, jnp.zeros_like(idx))
        acc = agg.accumulate(agg.init(params), updated, cohort_params, w)
        params = agg.finalize(params, acc)
        mean_loss = jnp.sum(losses * w) / jnp.maximum(w.sum(), 1.0)
        return params, sched_state, selected, mean_loss

    return round_fn
