"""The synchronous FedAvg engine.

One step = policy step -> cohort gather -> vmapped local training ->
aggregator ``weigh/init/accumulate/finalize`` -> age update. This is the
round loop of ``fl/rounds.py`` re-expressed against the ``Engine``
protocol (`init/step/run_chunk/finalize`) with the aggregation seam
opened up: the default ``fedavg`` aggregator reproduces the pre-refactor
weighted cohort mean bit-for-bit (pinned by
``tests/test_engine_equivalence.py``), while delta-based aggregators
(``fedprox``) drop in without touching this file.

The hot loop runs through ``ChunkRunner``: ``steps_per_chunk`` rounds per
host dispatch via a donated ``lax.scan``, with the selection-gap load
accumulators updated on device (``tests/test_engine_chunked.py`` pins
chunked == per-step bit-for-bit). Global params are *not* materialized
``width`` times per round: the cohort vmap broadcasts them lazily
(``in_axes=(None, ...)``) and aggregators receive the unstacked global
tree as ``bases``.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.load_metric import (
    empirical_load_stats,
    init_selection_accum,
    init_tier_accum,
    selection_stats_from_accum,
    tier_stats_from_accum,
    update_tier_accum,
)
from repro.core.selection import Policy
from repro.engine.aggregators import Aggregator
from repro.engine.chunk import ChunkRunner, dealias_pytree, run_key, step_once
from repro.engine.config import RoundRecord, RunConfig, RunResult
from repro.engine.registry import make_aggregator, make_policy
from repro.fl.client import make_local_update
from repro.fl.server import cohort_indices
from repro.fl.task import FLTask
from repro.optim.schedules import exponential_decay


class SyncEngine:
    """Synchronous rounds: every selected client trains from the current
    global params and the buffer is flushed once per round."""

    def __init__(
        self,
        task: FLTask,
        cfg: RunConfig,
        policy: Optional[Policy] = None,
        aggregator: Optional[Aggregator] = None,
    ):
        if cfg.mode != "sync":
            raise ValueError(f"SyncEngine needs mode='sync', got {cfg.mode!r}")
        self.task = task
        self.cfg = cfg
        self.policy = policy or make_policy(
            cfg.policy, cfg.n_clients, cfg.k, cfg.m, **dict(cfg.policy_kwargs)
        )
        self.aggregator = aggregator or make_aggregator(
            cfg.resolved_aggregator(), **dict(cfg.aggregator_kwargs)
        )
        self.topo = cfg.resolved_topology()
        if self.topo is not None and self.topo.heartbeat_timeout > 0:
            raise ValueError(
                "heartbeat churn is wall-clock-based and needs the async "
                "engine's event clock; sync rounds have no mid-round time "
                "for a client to go dark in — drop heartbeat_timeout or "
                "use mode='async'"
            )
        self.fault_set = cfg.resolved_faults()
        if self.fault_set is not None:
            only = self.fault_set.async_only_names()
            if only:
                raise ValueError(
                    f"fault(s) {', '.join(only)} act on the async engine's "
                    "wall clock / version ring; sync rounds have neither — "
                    "drop them or use mode='async'"
                )
        self.defense_cfg = cfg.resolved_defense()
        if self.defense_cfg is not None:
            from repro.defense import make_defense

            self.defense = make_defense(cfg.n_clients, self.defense_cfg)
        else:
            self.defense = None
        tiered = self.topo is not None and not self.topo.is_star
        self._assign = (
            jnp.asarray(self.topo.assign(cfg.n_clients)) if tiered else None
        )
        self._sharded_eval = None
        if cfg.shard_cohort:
            # cohort-parallel sync rounds: the cohort vmap (and the
            # aggregator accumulation) partitions over a device mesh —
            # sync has no per-client device state, so the mesh shards the
            # *cohort* axis only. mesh_shards=0 takes every local device.
            from repro.core import distributed as dist
            from repro.engine.aggregators import cohort_sharded_apply
            from repro.engine.sharded import (
                make_sharded_eval,
                require_cohort_mesh,
            )

            shards = cfg.mesh_shards or len(jax.devices())
            require_cohort_mesh(shards, f"mesh_shards={cfg.mesh_shards}")
            self.mesh = dist.fleet_mesh(shards, dist.FLEET_AXIS)
            self.mesh_shards = shards
            from jax.sharding import NamedSharding, PartitionSpec as P

            cohort = NamedSharding(self.mesh, P(dist.FLEET_AXIS))

            def cohort_layout(tree):
                return jax.tree.map(
                    lambda x: jax.lax.with_sharding_constraint(x, cohort),
                    tree,
                )

            if tiered:
                # the tiered reduction under the sharded cohort: slot
                # accumulation + the tier-0 segment sum run shard-locally
                # and merge with the same one-psum pattern
                from repro.topo.reduce import tiered_apply

                aggregate = tiered_apply(
                    self.aggregator, self.topo, cfg.n_clients,
                    mesh=self.mesh, axis=dist.FLEET_AXIS,
                    stacked_bases=False,
                )
            else:
                # sync passes the unstacked global tree as bases
                aggregate = cohort_sharded_apply(
                    self.aggregator, self.mesh, dist.FLEET_AXIS,
                    stacked_bases=False,
                )
            core = _make_round_core(
                task, cfg, self.policy, self.aggregator,
                cohort_layout=cohort_layout,
                aggregate=aggregate,
                cohort_shards=shards,
                faults=self.fault_set,
                defense=self.defense,
            )
            self._sharded_eval = make_sharded_eval(
                task, self.mesh, dist.FLEET_AXIS
            )
        elif tiered:
            from repro.topo.reduce import tiered_apply

            core = _make_round_core(
                task, cfg, self.policy, self.aggregator,
                aggregate=tiered_apply(
                    self.aggregator, self.topo, cfg.n_clients,
                    stacked_bases=False,
                ),
                faults=self.fault_set,
                defense=self.defense,
            )
        else:
            core = _make_round_core(task, cfg, self.policy, self.aggregator,
                                    faults=self.fault_set,
                                    defense=self.defense)

        assign = self._assign
        have_faults = self.fault_set is not None
        have_def = self.defense is not None
        stat_names = self.aggregator.stat_names

        def scan_step(state, key):
            params, sched, selected, loss, fstate, dstate, tel = core(
                state["params"], state["sched"], key,
                state["faults"] if have_faults else None,
                state["defense"] if have_def else None,
            )
            out = {"params": params, "sched": sched}
            if assign is not None:
                out["tier_acc"] = update_tier_accum(
                    state["tier_acc"], selected, assign
                )
            if have_faults:
                out["faults"] = fstate
            if have_def:
                out["defense"] = dstate
            if stat_names:
                out["agg_stats"] = {
                    s: state["agg_stats"][s] + tel[s] for s in stat_names
                }
            return out, {"send": selected, "loss": loss}

        self._chunk = ChunkRunner(scan_step, aux_keys=("loss",))

    def init(self) -> Dict:
        cfg = self.cfg
        key = run_key(cfg.seed, cfg.rng_impl)
        k_init, k_policy, k_run = jax.random.split(key, 3)
        # donation-safe from the start: step() routes through the donated
        # chunk runner even for single steps
        state = {
            "params": self.task.init(k_init),
            "sched": self.policy.init(k_policy, cfg.n_clients),
            "k_run": k_run,
            "load_acc": init_selection_accum(cfg.n_clients, cfg.k),
        }
        if self._assign is not None:
            state["tier_acc"] = init_tier_accum(
                cfg.n_clients, int(self.topo.tier_sizes[0])
            )
        if self.fault_set is not None:
            # off the far end of the round-index fold range so fault-prone
            # draws never collide with a per-round fold_in(k_run, r)
            state["faults"] = self.fault_set.init(
                jax.random.fold_in(k_run, 2**31)
            )
        if self.defense is not None:
            state["defense"] = self.defense.init()  # deterministic zeros
        if self.aggregator.stat_names:
            state["agg_stats"] = {
                s: jnp.zeros((), jnp.float32)
                for s in self.aggregator.stat_names
            }
        return dealias_pytree(state)

    def step(self, state: Dict, r: int):
        return step_once(self._chunk, state, r)

    def run_chunk(self, state: Dict, r0: int, length: int, with_history: bool):
        return self._chunk(state, r0, length, with_history)

    def eval_params(self, state: Dict):
        return state["params"]

    def evaluate(self, state: Dict) -> Dict:
        if self._sharded_eval is not None:
            return self._sharded_eval(self.eval_params(state))
        return self.task.eval_fn(self.eval_params(state))

    def record(self, r: int, aux: Dict, ev: Dict) -> RoundRecord:
        return RoundRecord(
            round=r + 1,
            train_loss=float(aux["loss"]),
            eval_loss=float(ev["loss"]),
            accuracy=float(ev["accuracy"]),
        )

    def progress_line(self, rec: RoundRecord, elapsed: float) -> str:
        tag = (
            f"/{self.topo.describe()}"
            if self.topo is not None and not self.topo.is_star else ""
        )
        return (
            f"  [{self.policy.name}{tag}] round {rec.round:4d} "
            f"acc={rec.accuracy:.4f} loss={rec.eval_loss:.4f} ({elapsed:.1f}s)"
        )

    def finalize(self, state, records, sel_hist, wall_time_s) -> RunResult:
        if sel_hist is not None:
            load_stats = empirical_load_stats(sel_hist)
        else:
            load_stats = selection_stats_from_accum(state["load_acc"])
        load_stats = dict(load_stats)
        if "tier_acc" in state:
            load_stats.update(tier_stats_from_accum(state["tier_acc"]))
        if "faults" in state:
            for nm, cnt in self.fault_set.counters(state["faults"]).items():
                load_stats[f"fault_{nm}_injected"] = cnt
        if "agg_stats" in state:
            for s in self.aggregator.stat_names:
                load_stats[f"agg_{s}"] = float(state["agg_stats"][s])
        if "defense" in state:
            load_stats.update(self.defense.report(state["defense"]))
            if "tier_acc" in state:
                from repro.topo.reduce import tier_suspect_counts

                load_stats["tier_suspects"] = tier_suspect_counts(
                    self.topo, self.cfg.n_clients,
                    state["defense"]["status"],
                )
        fault_exposure = None
        if "faults" in state and self.cfg.fault_exposure:
            fault_exposure = self.fault_set.exposure(state["faults"])
        return RunResult(
            config=self.cfg,
            records=records,
            selection=sel_hist,
            load_stats=load_stats,
            wall_stats=None,
            params=state["params"],
            wall_time_s=wall_time_s,
            fault_exposure=fault_exposure,
            defense=(self.defense.arrays(state["defense"])
                     if "defense" in state else None),
        )


def _make_round_core(task: FLTask, cfg: RunConfig, policy: Policy, agg: Aggregator,
                     cohort_layout=None, aggregate=None, cohort_shards: int = 1,
                     faults=None, defense=None):
    """The pure per-round function (no jit): shared by the legacy per-step
    path and the scan body of the chunked hot loop.

    The optional hooks are the cohort-parallel seam (mirroring
    ``_make_async_step``): ``cohort_layout`` lays the cohort-stacked
    intermediates out over the mesh, ``aggregate`` replaces the inline
    ``init/accumulate/finalize`` chain with the shard-local path, and
    ``cohort_shards`` pads the cohort axis with weight-0 slots to the
    next multiple of the mesh. Defaults reproduce the single-device
    round bit-for-bit.

    ``faults`` (a ``repro.faults.FaultSet``) threads per-client fault
    state through the round: fault keys fold off ``k_sel`` at 105 (the
    same schedule as the async engine — sub-fold 1 for ``on_pop``, 2 for
    update corruption), so with no faults armed no extra key material is
    drawn and the round is bit-for-bit the faultless one.

    ``defense`` (a ``repro.defense.Defense``) mirrors the async seams on
    the same fold schedule (108 off ``k_sel``): quarantined clients are
    masked out of ``selected`` right after the policy step (they still
    age — the policy's chain advanced; the defense vetoes the dispatch),
    every surviving slot is scored with staleness identically zero, and
    post-transition suspects lose their aggregation weight."""
    from repro.core.distributed import cohort_padding

    width = cfg.cohort_width() if not policy.exact_k else cfg.k
    cohort_pad = cohort_padding(width, cohort_shards)
    wp = width + cohort_pad
    if cohort_layout is None:
        cohort_layout = lambda tree: tree  # noqa: E731
    if aggregate is None:
        from repro.engine.aggregators import acc_stats

        def aggregate(g, updates, bases, w, idx=None):
            acc = agg.accumulate(agg.init(g), updates, bases, w)
            return agg.finalize(g, acc), acc_stats(acc)
    have_faults = faults is not None
    have_def = defense is not None
    mtd_on = have_def and defense.mtd
    if mtd_on:
        from repro.defense.adaptive import adaptive_aggregate

        aggregate_mtd = adaptive_aggregate(aggregate, defense.cfg.mtd_trims,
                                           families=defense.cfg.mtd_families)
    kill_on = have_faults and faults.has("kill")
    corrupt_on = have_faults and (faults.has("scale") or faults.has("noise"))
    if corrupt_on:
        from repro.faults.inject import corrupt_updates
    collude_on = have_faults and faults.has("collude")
    if collude_on:
        from repro.faults.inject import collude_updates
    col_on = have_def and defense.collusion
    sup_on = (have_def and defense.wants_labels and have_faults
              and faults.has_pop and cfg.fault_exposure)
    if sup_on:
        from repro.faults.inject import effects_hit
    local_update = make_local_update(
        task.loss_fn, cfg.local_epochs, cfg.batch_size, task.examples_per_client
    )
    lr_fn = exponential_decay(cfg.lr0, cfg.lr_decay)

    def round_fn(params, sched_state, key, fstate=None, dstate=None):
        k_sel, k_local = jax.random.split(key)
        selected, sched_state = policy.step(sched_state, k_sel)
        if have_def:
            selected = selected & ~defense.blocked(dstate)
        idx, mask = cohort_indices(selected, width)
        keys = jax.random.split(k_local, width)
        if cohort_pad:
            # pad to the mesh multiple with weight-0 slots; real slots
            # keep the exact unpadded key draws (split(k, wp) has a
            # different prefix than split(k, width))
            idx = jnp.concatenate([idx, jnp.zeros((cohort_pad,), idx.dtype)])
            mask = jnp.concatenate([mask, jnp.zeros((cohort_pad,), mask.dtype)])
            keys = keys[jnp.minimum(jnp.arange(wp), width - 1)]
        eff = None
        if have_faults:
            k_fault = jax.random.fold_in(k_sel, 105)
            fstate, eff = faults.on_pop(
                fstate, jax.random.fold_in(k_fault, 1), idx, mask > 0
            )
            eff = cohort_layout(eff)
        shards = cohort_layout(jax.tree.map(lambda a: a[idx], task.client_data))
        lr = lr_fn(sched_state["round"] - 1)
        # the cohort axis of the global params is a lazy vmap broadcast —
        # no (width, ...) copies are materialized; aggregators see the
        # unstacked global tree as ``bases`` and broadcast in their deltas
        updated, losses = cohort_layout(
            jax.vmap(local_update, in_axes=(None, 0, 0, None))(
                params, shards, keys, lr
            )
        )
        if corrupt_on:
            updated = corrupt_updates(
                updated, params, eff, jax.random.fold_in(k_fault, 2),
                faults.has("scale"), faults.has("noise"),
            )
        if collude_on:
            # after corrupt: the coalition's replacement is authoritative
            updated = collude_updates(updated, params, eff)
        valid = mask > 0
        if kill_on:
            # a dropped client's update never reaches the server: weight 0
            valid = valid & ~eff.kill
        if have_def:
            # fold 108 (same schedule as the async engine); staleness is
            # identically zero in a sync round
            ages = (cohort_layout(sched_state["ages"][idx])
                    if "ages" in sched_state else None)
            dstate, suspect, w_scale = defense.observe(
                dstate, jax.random.fold_in(k_sel, 108),
                updated, params, idx, valid, jnp.zeros_like(idx),
                losses=losses, ages=ages,
                labels=cohort_layout(effects_hit(eff)) if sup_on else None,
            )
            valid = valid & ~cohort_layout(suspect[idx])
        # sync cohorts are never stale: staleness is identically zero
        w = agg.weigh(valid, jnp.zeros_like(idx))
        if col_on:
            # exact 1.0 on clique-free slots: calm armed rounds multiply
            # the weights by ones
            w = w * w_scale
        if mtd_on:
            params, tel = aggregate_mtd(
                params, updated, params, w, idx, dstate["level"]
            )
        else:
            params, tel = aggregate(params, updated, params, w, idx)
        wsum = w.sum()
        # NaN, not a fake near-0 datapoint, when nobody was selected
        # (matching the async engine's empty-buffer convention)
        mean_loss = jnp.where(
            wsum > 0, jnp.sum(losses * w) / jnp.maximum(wsum, 1.0), jnp.nan
        )
        return params, sched_state, selected, mean_loss, fstate, dstate, tel

    return round_fn


def _make_round_fn(task: FLTask, cfg: RunConfig, policy: Policy, agg: Aggregator):
    """Jitted per-round step (legacy helper for ``fl/rounds.py``):
    the fault/telemetry-free 4-tuple view of the round core."""
    core = _make_round_core(task, cfg, policy, agg)

    def round_fn(params, sched_state, key):
        params, sched_state, selected, loss, _, _, _ = core(
            params, sched_state, key
        )
        return params, sched_state, selected, loss

    return jax.jit(round_fn)
