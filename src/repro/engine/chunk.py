"""Chunked, donated scan execution of an engine's step function.

``ChunkRunner`` turns a pure per-step function into a family of jitted
``lax.scan`` drivers that advance ``length`` steps per host dispatch:

  * the whole engine state (params, model ring buffer, event/sched state,
    accumulators, run key) is the scan carry and is **donated** to the
    compiled chunk, so XLA updates buffers in place instead of copying
    the fleet state every step;
  * the per-step key schedule stays ``fold_in(k_run, r)`` with the global
    step index threaded through the scan — a chunk is a pure function of
    ``(state, r0)``, so chunked execution is bit-for-bit identical to
    per-step execution (pinned by ``tests/test_engine_chunked.py``);
  * the device-resident selection accumulators
    (``core.load_metric.init/update_selection_accum``) are folded inside
    the scan body, killing the per-step device->host sync of the ``(n,)``
    selection vector that used to dominate fleet-scale runs;
  * per-step aux outputs are stacked on device and handed back as one
    pytree — the caller performs a single host transfer per chunk.

Compiled drivers are cached per ``(length, with_history)``; together with
``repro.engine.config.chunk_plan`` (at most three distinct chunk lengths
per run) this bounds recompilation to a handful of variants.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.load_metric import update_selection_accum

# state keys the runner owns; the engine step function never sees them
_RUNNER_KEYS = ("k_run", "load_acc")


class ChunkRunner:
    """Compile-once-per-shape chunked driver over ``step(state, key)``.

    ``step_fn`` is the engine's pure per-step function: it takes the
    engine's jittable state (without the runner-owned ``k_run`` /
    ``load_acc`` entries) and a folded key, and returns ``(state, aux)``
    where ``aux`` contains at least ``send`` (the (n,) bool selection
    vector) plus any per-step scalars. ``aux_keys`` names the aux entries
    stacked and returned per step; ``send`` is additionally stacked when
    the caller asks for history.
    """

    def __init__(self, step_fn: Callable, aux_keys: Tuple[str, ...]):
        self._step_fn = step_fn
        self._aux_keys = aux_keys
        self._compiled: Dict[Tuple[int, bool], Callable] = {}

    def _build(self, length: int, with_history: bool) -> Callable:
        step_fn, aux_keys = self._step_fn, self._aux_keys

        def body(carry, r):
            key = jax.random.fold_in(carry["k_run"], r)
            inner = {k: v for k, v in carry.items() if k not in _RUNNER_KEYS}
            inner, aux = step_fn(inner, key)
            carry = {
                **inner,
                "k_run": carry["k_run"],
                "load_acc": update_selection_accum(carry["load_acc"], aux["send"]),
            }
            ys = {k: aux[k] for k in aux_keys}
            if with_history:
                ys["send"] = aux["send"]
            return carry, ys

        def chunk(state, r0):
            return jax.lax.scan(body, state, r0 + jnp.arange(length))

        return jax.jit(chunk, donate_argnums=0)

    def __call__(self, state: Dict, r0: int, length: int, with_history: bool):
        """Advance ``length`` steps from global step ``r0``.

        Donates ``state``; returns ``(state', stacked_aux)`` with every
        ``stacked_aux`` leaf carrying a leading ``length`` axis, still on
        device (the caller decides when to transfer).
        """
        key = (length, with_history)
        fn = self._compiled.get(key)
        if fn is None:
            fn = self._compiled[key] = self._build(length, with_history)
        return fn(state, jnp.asarray(r0, jnp.int32))


def step_once(runner: ChunkRunner, state: Dict, r: int):
    """One engine step, driven through the chunked runner as a length-1
    donated scan — the per-step path and the chunk path share a single
    implementation of the ``_RUNNER_KEYS`` bookkeeping (key folding and
    the device-resident selection accumulators), so the two can never
    drift. Donates ``state`` like any chunk; engine ``init()`` states are
    dealiased up front to keep that legal. Returns ``(state', aux)`` with
    the leading length-1 axis squeezed off every aux leaf (history is
    always kept at length 1, so ``aux`` includes ``send``)."""
    state, aux = runner(state, r, 1, with_history=True)
    return state, {k: v[0] for k, v in aux.items()}


def dealias_pytree(tree):
    """Donation-safe copy of duplicated leaves.

    jax's constant cache can hand the *same* device buffer to multiple
    identical leaves (the scalar zeros of a fresh accumulator, say), and
    XLA refuses to donate one buffer twice. Engine init states pass
    through this once before the first donated chunk; chunk outputs are
    already alias-free.
    """
    seen = set()

    def uniq(x):
        if id(x) in seen:
            return jnp.copy(x)
        seen.add(id(x))
        return x

    return jax.tree.map(uniq, tree)


def run_key(seed: int, rng_impl) -> jax.Array:
    """The run's root PRNG key: legacy ``PRNGKey`` (bit-compatible with
    pre-chunking runs) unless a counter-based impl is configured."""
    if rng_impl is None:
        return jax.random.PRNGKey(seed)
    return jax.random.key(seed, impl=rng_impl)
