"""Pluggable server-side aggregation: pure ``init/accumulate/finalize``.

An ``Aggregator`` owns everything between "the cohort's local updates are
stacked on axis 0" and "here are the new global params", so the sync and
async engines share one aggregation seam instead of hardwiring their own:

    w     = agg.weigh(mask, staleness)        # (B,) float32 weights
    acc   = agg.init(global_params)           # accumulator pytree
    acc   = agg.accumulate(acc, updates, bases, w)
    new_g = agg.finalize(global_params, acc)

``updates`` is a pytree with a stacked cohort axis; ``bases`` is the
params each cohort member trained *from* (the dispatch-time ring-buffer
version in the async engine), which is what lets delta-based aggregators
express staleness correctly. ``bases`` may also be the *unstacked* global
tree — the sync engine passes the global params directly and the cohort
axis broadcasts lazily inside ``accumulate`` (``updates - bases``), so no
``(width, ...)`` copies are ever materialized. All functions are
jit-compatible and safe to call with an all-zero weight vector (an empty
buffer leaves the global params untouched).

Built-ins:
  * ``fedavg``  — weighted mean of the updated params (the paper's FedAvg
                  step (iii)); ignores staleness.
  * ``fedbuff`` — staleness-discounted mean of *deltas* added to the
                  global params (FedBuff/FedAsync style, ``(1+s)^-a``).
  * ``fedprox`` — fedbuff with server-side proximal damping: the mean
                  delta is scaled by ``1/(1+mu)``, i.e. the new params
                  minimize ``||p - (g + d)||^2 + mu * ||p - g||^2``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.engine.registry import register_aggregator


@dataclasses.dataclass(frozen=True)
class Aggregator:
    """The aggregation protocol both engines dispatch through.

    ``additive`` declares that the accumulator is a plain sum over cohort
    members: ``init`` is the zero element and accumulating two disjoint
    cohort slices then adding the accumulators leaf-wise equals
    accumulating the full cohort. It is what lets the cohort-sharded
    execution mode run ``accumulate`` shard-locally and merge with a
    single ``psum`` of the accumulator pytree
    (``cohort_sharded_apply``). The default is False — psum-merging an
    accumulator is only sound when the author has checked the property
    (a non-zero ``init`` or a max/median-style statistic would be
    silently wrong), so every aggregator opts in explicitly; all
    built-ins do.
    """

    name: str
    weigh: Callable  # (mask bool (B,), staleness i32 (B,)) -> f32 (B,)
    init: Callable  # (global_params) -> acc pytree
    accumulate: Callable  # (acc, updates, bases, weights) -> acc
    finalize: Callable  # (global_params, acc) -> new global_params
    additive: bool = False
    # scalar telemetry names the accumulator carries under acc["stats"]
    # (e.g. norm_clip's "clipped" count). Engines surface each as an
    # ``agg_<name>`` counter in RunResult.load_stats; () (every
    # non-robust built-in) adds no stats key and no per-step ops.
    stat_names: tuple = ()


def tree_where(cond, a, b):
    """Leaf-wise ``jnp.where`` under one scalar predicate — select a
    whole params/accumulator pytree without leaving jit (the defense
    tier's moving-target rule swap and empty-cohort guards use this)."""
    return jax.tree.map(lambda x, y: jnp.where(cond, x, y), a, b)


def acc_stats(acc) -> dict:
    """The scalar telemetry dict a finished accumulator carries (empty
    for aggregators that declare no ``stat_names``). Stats live *inside*
    the accumulator so they merge for free along every reduction path —
    psum under cohort sharding, segment-sum up a tier DAG."""
    return acc.get("stats", {}) if isinstance(acc, dict) else {}


def cohort_sharded_apply(
    agg: Aggregator, mesh, axis: str, stacked_bases: bool = True
) -> Callable:
    """The aggregator seam's shard-local path for cohort-parallel
    execution: ``apply(global_params, updates, bases, w) -> (new params,
    stats)`` with the cohort axis of ``updates``/``w`` (and ``bases``
    when stacked) laid out over ``axis`` of ``mesh``; ``stats`` is the
    merged accumulator's scalar telemetry (``acc_stats``).

    Each device runs ``agg.init``/``agg.accumulate`` over its own
    ``B/devices`` cohort slice, the accumulator pytrees are merged by one
    ``psum`` — O(params) cross-device traffic instead of shipping the
    ``B x params`` update stack through replication — and ``finalize``
    runs on the replicated merged accumulator. Requires ``agg.additive``
    and a cohort length divisible by the mesh (engines pad the cohort
    with zero-weight slots to the next multiple).

    ``stacked_bases=False`` is the sync engine's convention: ``bases`` is
    the *unstacked* global tree, replicated, broadcast lazily inside
    ``accumulate``.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    if not agg.additive:
        raise ValueError(
            f"aggregator {agg.name!r} is not additive: its accumulator "
            "cannot be merged by psum, so it cannot run cohort-sharded "
            "(drop shard_cohort for this aggregator)"
        )
    spec = P(axis)

    def apply(g, updates, bases, w, idx=None):
        # ``idx`` (the cohort -> client map) is part of the engines'
        # aggregate-hook signature for topology-aware reductions; the
        # star-shaped single-server reduction has no use for it
        def local(g_l, u_l, b_l, w_l):
            acc = agg.accumulate(agg.init(g_l), u_l, b_l, w_l)
            return jax.lax.psum(acc, axis)

        merged = shard_map(
            local,
            mesh=mesh,
            in_specs=(P(), spec, spec if stacked_bases else P(), spec),
            out_specs=P(),
        )(g, updates, bases, w)
        return agg.finalize(g, merged), acc_stats(merged)

    return apply


def staleness_weight(
    s: jnp.ndarray, mode: str = "poly", exp: float = 0.5
) -> jnp.ndarray:
    """Aggregation discount for an update of staleness ``s`` versions."""
    s = jnp.maximum(s.astype(jnp.float32), 0.0)
    if mode == "const":
        return jnp.ones_like(s)
    if mode == "poly":
        return (1.0 + s) ** (-exp)
    raise ValueError(f"unknown staleness mode {mode!r}")


def _wshape(u: jnp.ndarray) -> tuple:
    return (-1,) + (1,) * (u.ndim - 1)


@register_aggregator("fedavg")
def make_fedavg() -> Aggregator:
    """Weighted mean of updated params; empty cohorts keep the old params."""

    def weigh(mask, staleness):
        return mask.astype(jnp.float32)

    def init(g):
        return {
            "usum": jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), g),
            "wsum": jnp.zeros((), jnp.float32),
        }

    def accumulate(acc, updates, bases, w):
        usum = jax.tree.map(
            lambda s, u: s + jnp.sum(u * w.reshape(_wshape(u)).astype(u.dtype), axis=0),
            acc["usum"], updates,
        )
        return {"usum": usum, "wsum": acc["wsum"] + w.sum()}

    def finalize(g, acc):
        empty = acc["wsum"] == 0.0
        denom = jnp.maximum(acc["wsum"], 1.0)

        def fin(gl, s):
            return jnp.where(empty, gl, (s / denom.astype(s.dtype)).astype(gl.dtype))

        return jax.tree.map(fin, g, acc["usum"])

    return Aggregator("fedavg", weigh, init, accumulate, finalize,
                      additive=True)


def _delta_aggregator(name: str, staleness_mode: str, staleness_exp: float,
                      scale: float) -> Aggregator:
    """Shared core of fedbuff/fedprox: staleness-weighted mean delta,
    scaled by ``scale`` and added to the global params."""

    def weigh(mask, staleness):
        return mask.astype(jnp.float32) * staleness_weight(
            staleness, staleness_mode, staleness_exp
        )

    def init(g):
        return {
            "dsum": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), g),
            "wsum": jnp.zeros((), jnp.float32),
        }

    def accumulate(acc, updates, bases, w):
        dsum = jax.tree.map(
            lambda s, u, b: s
            + jnp.sum((u - b).astype(jnp.float32) * w.reshape(_wshape(u)), axis=0),
            acc["dsum"], updates, bases,
        )
        return {"dsum": dsum, "wsum": acc["wsum"] + w.sum()}

    def finalize(g, acc):
        has = acc["wsum"] > 0
        denom = jnp.maximum(acc["wsum"], 1e-9)

        def fin(gl, s):
            d = s / denom
            if scale != 1.0:
                d = d * scale
            upd = gl + d.astype(gl.dtype)
            return jnp.where(has, upd, gl)

        return jax.tree.map(fin, g, acc["dsum"])

    return Aggregator(name, weigh, init, accumulate, finalize,
                      additive=True)


@register_aggregator("fedbuff")
def make_fedbuff(staleness_mode: str = "poly", staleness_exp: float = 0.5) -> Aggregator:
    """Staleness-discounted buffered delta aggregation (FedBuff-style)."""
    return _delta_aggregator("fedbuff", staleness_mode, staleness_exp, scale=1.0)


@register_aggregator("fedprox")
def make_fedprox(prox_mu: float = 0.1, staleness_mode: str = "poly",
                 staleness_exp: float = 0.5) -> Aggregator:
    """Proximally damped delta aggregation: mean delta scaled by 1/(1+mu)."""
    if prox_mu < 0:
        raise ValueError(f"prox_mu must be >= 0, got {prox_mu}")
    return _delta_aggregator(
        "fedprox", staleness_mode, staleness_exp, scale=1.0 / (1.0 + prox_mu)
    )
