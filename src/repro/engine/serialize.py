"""The one JSON-safe serializer for run payloads.

Every driver and benchmark that writes results to disk goes through
``to_jsonable`` so strict-JSON consumers (``allow_nan=False``) never see
NaN/Inf (empty-aggregation async steps carry NaN losses), numpy scalars,
or dataclasses. ``dump_json`` is the matching one-line file writer.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Any

import numpy as np


def to_jsonable(x: Any) -> Any:
    """Recursively convert ``x`` into strict-JSON-safe builtins.

    NaN/Inf -> None; numpy scalars/arrays -> builtins/lists; dataclasses
    and mappings -> dicts; tuples/sets -> lists. Unknown objects fall back
    to ``str`` rather than failing a whole results dump.
    """
    if x is None or isinstance(x, (bool, int, str)):
        return x
    if isinstance(x, float):
        return x if math.isfinite(x) else None
    if isinstance(x, np.bool_):
        return bool(x)
    if isinstance(x, np.integer):
        return int(x)
    if isinstance(x, np.floating):
        return to_jsonable(float(x))
    if isinstance(x, np.ndarray):
        # 0-d arrays tolist() to a bare scalar, n-d to nested lists
        return to_jsonable(x.tolist())
    if dataclasses.is_dataclass(x) and not isinstance(x, type):
        return {f.name: to_jsonable(getattr(x, f.name)) for f in dataclasses.fields(x)}
    if isinstance(x, dict):
        return {str(k): to_jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple, set)):
        return [to_jsonable(v) for v in x]
    if hasattr(x, "tolist"):  # jax arrays without importing jax here
        return to_jsonable(np.asarray(x))
    return str(x)


def dump_json(path: str, payload: Any, indent: int = 1) -> None:
    """Write ``payload`` through ``to_jsonable`` as strict JSON."""
    with open(path, "w") as f:
        json.dump(to_jsonable(payload), f, indent=indent, allow_nan=False)
