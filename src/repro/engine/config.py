"""One run contract for synchronous and asynchronous federated training.

``RunConfig`` absorbs the old ``FLConfig`` + ``AsyncConfig`` pair: every
field the sync round loop and the event-driven async loop need, plus the
registry names (and kwargs) of the selection policy and the aggregator.
``RunResult`` / ``RoundRecord`` are the typed output schema both engines
emit identically; ``repro.engine.serialize.to_jsonable`` is the one
JSON-safe serializer for all of it (NaN -> null, numpy -> builtin).

This module is deliberately dependency-free (dataclasses + numpy only) so
configs can be built, validated, and serialized without importing jax or
the simulator.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional

import numpy as np

MODES = ("sync", "async")
RNG_IMPLS = ("threefry2x32", "rbg", "unsafe_rbg")
# largest scan chunk the auto heuristic will pick (bounds the stacked
# per-chunk aux/history buffers at chunk_len * n cells)
MAX_AUTO_CHUNK = 64


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Everything needed to reproduce one federated run, either engine."""

    # --- fleet + schedule (paper Sec. IV defaults) ---
    n_clients: int = 100
    k: int = 15  # paper: 15% participation
    m: int = 10  # max permissible age (Markov policy)
    policy: str = "markov"  # any name in repro.engine.policy_names()
    policy_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    rounds: int = 100  # sync rounds / async server steps
    local_epochs: int = 5
    batch_size: int = 50
    lr0: float = 0.1
    lr_decay: float = 0.998
    seed: int = 0
    # cohort padding for variable-size policies (markov): vmap width
    max_cohort: Optional[int] = None
    eval_every: int = 1

    # --- hot loop ---
    # steps advanced per host dispatch (jitted, donated lax.scan chunk).
    # None -> auto: min(eval_every, MAX_AUTO_CHUNK). Chunked execution is
    # bit-for-bit identical to per-step execution (pinned by
    # tests/test_engine_chunked.py); chunks never straddle an eval step.
    steps_per_chunk: Optional[int] = None
    # materialize the (rounds, n) selection matrix on the host. None ->
    # legacy heuristic (sync always; async below the history cell cap).
    # False drops it: load stats then come from the device-resident
    # accumulators and the hot loop performs one transfer per chunk.
    collect_history: Optional[bool] = None
    # PRNG implementation for the run key. None -> jax.random.PRNGKey
    # (threefry2x32), bit-compatible with every pre-chunking run. "rbg" /
    # "unsafe_rbg" are counter-based generators that are substantially
    # faster at fleet scale; same per-step key-folding schedule, different
    # random stream.
    rng_impl: Optional[str] = None

    # --- engine ---
    mode: str = "sync"  # sync | async
    # None -> per-mode default: fedavg (sync) / fedbuff (async)
    aggregator: Optional[str] = None
    aggregator_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # --- async engine only ---
    buffer_size: Optional[int] = None  # aggregation buffer; default k
    max_versions: int = 8  # ring of retained global models
    profile: Any = "lognormal"  # name or sim.latency.LatencyProfile
    use_kernel: Optional[bool] = None  # None: kernel when fleet is large
    # shard the per-client fleet state over a 1-D device mesh
    # (ShardedAsyncEngine). None -> single-device AsyncEngine; 0 ->
    # auto-detect (largest divisor of n_clients <= local device count);
    # d > 0 -> exactly d shards (must divide n_clients). Bit-for-bit
    # identical to the unsharded engine for the same seed
    # (tests/test_sharded_engine.py). With mode="sync" it is only
    # meaningful together with ``shard_cohort`` (the mesh then shards the
    # cohort axis; sync has no per-client device state).
    mesh_shards: Optional[int] = None
    # --- aggregation topology (repro.topo) ---
    # None / "star" -> today's single-server reduction, bit-for-bit
    # unchanged. A registered topology name ("hierarchical", "gossip",
    # or anything added via @register_topology) or a ready
    # ``repro.topo.Topology`` instance routes the aggregation through
    # the tiered reduction (additive aggregators only), prices each
    # cross-tier hop with a sim.latency profile, and — when the topology
    # arms ``heartbeat_timeout`` — excludes clients that went dark from
    # their tier's reduction (async engine; the sync engine has no
    # mid-round clock and rejects a heartbeat).
    topology: Any = None
    topology_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # --- fault injection (repro.faults) ---
    # fault names from the @register_fault registry ("dropout,corrupt" or
    # a sequence). Empty -> no fault state, no key folds, no ops: the
    # engines are structurally bit-for-bit unchanged. Armed faults ride
    # the donated scan carry as (n,) per-client state, so injection works
    # single-device, chunked, fleet-sharded, and cohort-sharded.
    faults: Any = ()
    fault_rate: float = 0.05  # per-event injection probability
    # per-fault kwargs, keyed by fault name: {"corrupt": {"sigma": 2.0}}
    fault_kwargs: Dict[str, Dict[str, Any]] = dataclasses.field(
        default_factory=dict
    )
    # deadline-based re-dispatch (async engine): a dispatch still in
    # flight this many simulated seconds later is re-issued at the
    # current version with a fresh latency draw, at most
    # redispatch_retries times; then it is written off. None/0 -> the
    # expiry check and its (n,) state are absent entirely.
    redispatch_timeout: Optional[float] = None
    redispatch_retries: int = 1

    # cohort-parallel execution: partition the popped cohort (async) /
    # the round's cohort vmap (sync) across the device mesh instead of
    # replicating it, with shard-local aggregator accumulation merged by
    # one psum of the accumulator pytree. Trades bit-exactness for
    # throughput: flag-off is bit-for-bit identical to the single-device
    # engines; flag-on is allclose-equivalent (cross-device reduction
    # order differs; see tests/test_cohort_engine.py for the pinned
    # tolerance). Requires mesh_shards (and >= 2 devices at engine
    # construction).
    shard_cohort: bool = False

    # --- adaptive defense (repro.defense) ---
    # False -> no defense state, no key folds, no ops: the engines are
    # structurally bit-for-bit the calm run. True arms per-client
    # reputation + quarantine (and, via defense_kwargs={"mtd": True},
    # moving-target aggregation); the state rides the donated scan carry
    # like fault state, so it works per-step, chunked, fleet-sharded,
    # and cohort-sharded, and checkpoints/restores bitwise.
    defense: bool = False
    defense_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # surface per-client fault-exposure counts ((n,) per armed fault) in
    # RunResult.fault_exposure — the detector benchmark's ground truth.
    fault_exposure: bool = False

    def __post_init__(self) -> None:
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode!r}")
        if not 0 < self.k <= self.n_clients:
            raise ValueError(
                f"k={self.k} must be in 1..n_clients={self.n_clients}"
            )
        if self.max_cohort is not None and self.max_cohort < self.k:
            raise ValueError(
                f"max_cohort={self.max_cohort} < k={self.k}: the cohort "
                "buffer could not hold even an exact-k selection; raise "
                "max_cohort (or leave it None for the binomial-tail default)"
            )
        if self.steps_per_chunk is not None and self.steps_per_chunk < 1:
            raise ValueError(
                f"steps_per_chunk must be >= 1, got {self.steps_per_chunk}"
            )
        if self.rng_impl is not None and self.rng_impl not in RNG_IMPLS:
            raise ValueError(
                f"rng_impl must be one of {RNG_IMPLS} (or None for the "
                f"default PRNGKey), got {self.rng_impl!r}"
            )
        if self.mesh_shards is not None:
            if self.mode != "async" and not self.shard_cohort:
                raise ValueError(
                    "mesh_shards requires mode='async' (fleet sharding is "
                    "an async-engine feature) or shard_cohort=True (the "
                    "mesh then shards the sync cohort axis), got "
                    f"mode={self.mode!r}"
                )
            if self.mesh_shards < 0:
                raise ValueError(
                    f"mesh_shards must be >= 0 (0 = auto-detect devices), "
                    f"got {self.mesh_shards}"
                )
            if (self.mode == "async" and self.mesh_shards > 0
                    and self.n_clients % self.mesh_shards):
                raise ValueError(
                    f"mesh_shards={self.mesh_shards} must divide "
                    f"n_clients={self.n_clients} (every device owns an "
                    "equal client block); use 0 to auto-detect"
                )
        if self.shard_cohort and self.mesh_shards is None:
            raise ValueError(
                "shard_cohort=True needs a device mesh: set mesh_shards "
                "(0 = auto-detect) — without one the cohort would silently "
                "stay replicated"
            )
        if self.topology is not None:
            # resolve eagerly so a typo'd name or an invalid tier shape
            # fails at config construction, not mid-run inside jit
            self.resolved_topology()
        elif self.topology_kwargs:
            raise ValueError(
                "topology_kwargs given without a topology name"
            )
        names = self.fault_names()
        if names:
            if not 0.0 <= self.fault_rate <= 1.0:
                raise ValueError(
                    f"fault_rate must be in [0, 1], got {self.fault_rate}"
                )
            # jax-free name check (known_fault_names is import-light) so
            # a typo fails at config construction, matching the eager
            # topology resolution above; registry-plugin names resolve too
            from repro.faults.registry import known_fault_names

            known = known_fault_names()
            bad = [nm for nm in names if nm not in known]
            if bad:
                raise ValueError(
                    f"unknown fault(s) {', '.join(repr(b) for b in bad)}; "
                    f"registered: {', '.join(known)}"
                )
            stray = set(self.fault_kwargs) - set(names)
            if stray:
                raise ValueError(
                    f"fault_kwargs for fault(s) not in faults: "
                    f"{', '.join(sorted(stray))}"
                )
        elif self.fault_kwargs:
            raise ValueError("fault_kwargs given without faults")
        if self.fault_exposure and not names:
            raise ValueError(
                "fault_exposure=True records per-client fault hits, but "
                "no faults are configured — arm faults or drop the flag"
            )
        if self.defense:
            # resolve eagerly (jax-free DefenseConfig) so a bad knob
            # fails at config construction, like topology resolution
            dcfg = self.resolved_defense()
            if self.shard_cohort and (dcfg.collusion
                                      or dcfg.detector != "zscore"):
                raise ValueError(
                    "collusion scoring and the learned detector keep "
                    "whole-cohort state (pairwise similarity, one "
                    "logistic head) that is not psum-mergeable under "
                    "shard_cohort — drop shard_cohort (fleet sharding "
                    "via --mesh-shards *without* --shard-cohort works: "
                    "the (n, d_sketch) sketches shard over the fleet "
                    "axis like every other per-client leaf), or keep "
                    "the default detector='zscore' without collusion"
                )
            if dcfg.mtd:
                topo = self.resolved_topology()
                if topo is not None and not topo.is_star:
                    raise ValueError(
                        "moving-target defense (mtd) swaps in an "
                        "order-statistic trimmed mean, which is not "
                        "additive: it cannot ride a tiered topology's "
                        "segment-sum reduction — disable mtd or use the "
                        "star topology (reputation/quarantine alone work "
                        "everywhere)"
                    )
                if self.shard_cohort:
                    raise ValueError(
                        "moving-target defense (mtd) swaps in an "
                        "order-statistic trimmed mean, which is not "
                        "additive: it cannot be psum-merged under "
                        "shard_cohort — disable mtd or shard_cohort "
                        "(reputation/quarantine alone work everywhere)"
                    )
        elif self.defense_kwargs:
            raise ValueError("defense_kwargs given without defense=True")
        if self.redispatch_timeout is not None:
            if self.mode != "async":
                raise ValueError(
                    "redispatch_timeout re-issues expired dispatches on "
                    "the async engine's event clock; sync rounds have no "
                    "in-flight dispatches — drop it or use mode='async'"
                )
            if self.redispatch_timeout <= 0:
                raise ValueError(
                    f"redispatch_timeout must be > 0 (or None to disable),"
                    f" got {self.redispatch_timeout}"
                )
            if self.redispatch_retries < 0:
                raise ValueError(
                    f"redispatch_retries must be >= 0, got "
                    f"{self.redispatch_retries}"
                )

    def cohort_width(self) -> int:
        """Padded cohort buffer width for variable-size policies."""
        if self.max_cohort is not None:
            return self.max_cohort
        return default_cohort_width(self.n_clients, self.k)

    def resolved_aggregator(self) -> str:
        if self.aggregator is not None:
            return self.aggregator
        return "fedavg" if self.mode == "sync" else "fedbuff"

    def resolved_buffer_size(self) -> int:
        return self.buffer_size or self.k

    def resolved_steps_per_chunk(self) -> int:
        if self.steps_per_chunk is not None:
            return self.steps_per_chunk
        return max(1, min(self.eval_every, MAX_AUTO_CHUNK))

    def profile_name(self) -> str:
        return self.profile if isinstance(self.profile, str) else self.profile.name

    def resolved_topology(self):
        """The ``repro.topo.Topology`` this run aggregates through, or
        None for the default star. The import is lazy (``repro.topo.graph``
        is numpy-only, like this module) and the topology is validated
        against ``n_clients``."""
        if self.topology is None:
            return None
        from repro.topo.graph import Topology, make_topology

        if isinstance(self.topology, Topology):
            topo = self.topology
            if self.topology_kwargs:
                raise ValueError(
                    "topology_kwargs only apply to registry names; got a "
                    "ready Topology instance"
                )
        else:
            topo = make_topology(self.topology, **dict(self.topology_kwargs))
        topo.validate(self.n_clients)
        return topo

    def topology_name(self) -> str:
        topo = self.resolved_topology()
        return "star" if topo is None else topo.describe()

    def fault_names(self) -> tuple:
        """Normalized tuple of configured fault names ("a,b" or any
        sequence of names; () / None / "" -> no faults)."""
        if not self.faults:
            return ()
        if isinstance(self.faults, str):
            return tuple(
                nm.strip() for nm in self.faults.split(",") if nm.strip()
            )
        return tuple(self.faults)

    def resolved_faults(self):
        """The ``repro.faults.FaultSet`` this run injects, or None when
        no faults are configured (lazy import, mirroring
        ``resolved_topology``)."""
        names = self.fault_names()
        if not names:
            return None
        from repro.faults import FaultSet, make_fault

        return FaultSet(
            make_fault(
                nm, self.n_clients, self.fault_rate,
                **dict(self.fault_kwargs.get(nm, {})),
            )
            for nm in names
        )

    def resolved_defense(self):
        """The ``repro.defense.DefenseConfig`` this run arms, or None.
        The import is lazy but jax-free (``repro.defense.config`` is a
        plain dataclass module), so eager validation in ``__post_init__``
        keeps this module importable without jax."""
        if not self.defense:
            return None
        import dataclasses as _dc

        from repro.defense.config import DefenseConfig

        accepted = tuple(f.name for f in _dc.fields(DefenseConfig))
        stray = sorted(set(self.defense_kwargs) - set(accepted))
        if stray:
            raise ValueError(
                f"unknown defense_kwargs key(s) "
                f"{', '.join(repr(s) for s in stray)}; accepted: "
                f"{', '.join(accepted)}"
            )
        return DefenseConfig(**dict(self.defense_kwargs))


def chunk_plan(rounds: int, eval_every: int, steps_per_chunk: int):
    """Split ``rounds`` steps into scan chunks of at most ``steps_per_chunk``
    that never straddle an eval step, as ``(start, length, do_eval)``.

    Eval steps are exactly the pre-chunking cadence — every step ``r`` with
    ``(r + 1) % eval_every == 0`` plus the final step — so a chunked run
    evaluates (and records) at identical rounds to a per-step run. At most
    three distinct chunk lengths occur (full chunks, the eval-boundary
    remainder, and the final-rounds remainder), bounding jit recompilation.
    """
    plan = []
    r = 0
    while r < rounds:
        next_eval = min((r // eval_every + 1) * eval_every, rounds)
        end = min(r + steps_per_chunk, next_eval)
        plan.append((r, end - r, end == next_eval))
        r = end
    return plan


def default_cohort_width(n_clients: int, k: int) -> int:
    """Markov cohort is ~Binomial(n, k/n): pad to k + 4*sigma (overflow
    beyond the buffer is dropped, so the tail allowance matters)."""
    q = k / n_clients
    sigma = math.sqrt(n_clients * q * (1 - q))
    return min(n_clients, int(k + 4 * sigma) + 1)


def run_config_from_legacy(fl, acfg=None, **overrides) -> RunConfig:
    """Build a RunConfig from the legacy ``FLConfig`` (+ ``AsyncConfig``)
    pair. ``acfg`` switches the mode to async and maps its staleness
    knobs onto the fedbuff aggregator's kwargs."""
    kw: Dict[str, Any] = dict(
        n_clients=fl.n_clients, k=fl.k, m=fl.m, policy=fl.policy,
        rounds=fl.rounds, local_epochs=fl.local_epochs,
        batch_size=fl.batch_size, lr0=fl.lr0, lr_decay=fl.lr_decay,
        seed=fl.seed, max_cohort=fl.max_cohort, eval_every=fl.eval_every,
    )
    if acfg is not None:
        kw.update(
            mode="async",
            aggregator="fedbuff",
            aggregator_kwargs={
                "staleness_mode": acfg.staleness_mode,
                "staleness_exp": acfg.staleness_exp,
            },
            buffer_size=acfg.buffer_size,
            max_versions=acfg.max_versions,
            profile=acfg.profile,
            use_kernel=acfg.use_kernel,
        )
    kw.update(overrides)
    return RunConfig(**kw)


# ---------------------------------------------------------------------------
# Result schema
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RoundRecord:
    """One evaluated round / server step, identical for both engines.

    ``clock``/``version``/``buffer_fill`` are simulator quantities and stay
    None under the sync engine.
    """

    round: int
    train_loss: float
    eval_loss: float
    accuracy: float
    clock: Optional[float] = None
    version: Optional[int] = None
    buffer_fill: Optional[int] = None


@dataclasses.dataclass
class RunResult:
    """Typed output of ``repro.engine.run_engine`` for either mode."""

    config: RunConfig
    records: List[RoundRecord]
    selection: Optional[np.ndarray]  # (rounds, n) bool, None above cell cap
    load_stats: Dict[str, float]  # empirical Var[X] etc. from selection
    wall_stats: Optional[Dict[str, float]]  # async-only simulator stats
    params: Any
    wall_time_s: float
    # per-fault (n,) exposure counts, only when cfg.fault_exposure
    fault_exposure: Optional[Dict[str, np.ndarray]] = None
    # per-client defense arrays ({"reputation", "status"}), only when armed
    defense: Optional[Dict[str, np.ndarray]] = None

    def history(self) -> Dict[str, list]:
        """Legacy column-oriented history view of the records."""
        cols = ["round", "accuracy", "eval_loss", "train_loss"]
        if self.config.mode == "async":
            cols = ["round", "clock", "version", "accuracy", "eval_loss",
                    "train_loss", "buffer_fill"]
        return {c: [getattr(r, c) for r in self.records] for c in cols}

    def to_jsonable(self) -> Dict[str, Any]:
        """JSON-safe payload (excludes params and the raw selection matrix)."""
        from repro.engine.serialize import to_jsonable

        return to_jsonable({
            "config": dataclasses.asdict(self.config),
            "history": self.history(),
            "load_stats": self.load_stats,
            "wall_stats": self.wall_stats,
            "wall_time_s": self.wall_time_s,
        })
