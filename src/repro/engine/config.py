"""One run contract for synchronous and asynchronous federated training.

``RunConfig`` absorbs the old ``FLConfig`` + ``AsyncConfig`` pair: every
field the sync round loop and the event-driven async loop need, plus the
registry names (and kwargs) of the selection policy and the aggregator.
``RunResult`` / ``RoundRecord`` are the typed output schema both engines
emit identically; ``repro.engine.serialize.to_jsonable`` is the one
JSON-safe serializer for all of it (NaN -> null, numpy -> builtin).

This module is deliberately dependency-free (dataclasses + numpy only) so
configs can be built, validated, and serialized without importing jax or
the simulator.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional

import numpy as np

MODES = ("sync", "async")


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Everything needed to reproduce one federated run, either engine."""

    # --- fleet + schedule (paper Sec. IV defaults) ---
    n_clients: int = 100
    k: int = 15  # paper: 15% participation
    m: int = 10  # max permissible age (Markov policy)
    policy: str = "markov"  # any name in repro.engine.policy_names()
    policy_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    rounds: int = 100  # sync rounds / async server steps
    local_epochs: int = 5
    batch_size: int = 50
    lr0: float = 0.1
    lr_decay: float = 0.998
    seed: int = 0
    # cohort padding for variable-size policies (markov): vmap width
    max_cohort: Optional[int] = None
    eval_every: int = 1

    # --- engine ---
    mode: str = "sync"  # sync | async
    # None -> per-mode default: fedavg (sync) / fedbuff (async)
    aggregator: Optional[str] = None
    aggregator_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # --- async engine only ---
    buffer_size: Optional[int] = None  # aggregation buffer; default k
    max_versions: int = 8  # ring of retained global models
    profile: Any = "lognormal"  # name or sim.latency.LatencyProfile
    use_kernel: Optional[bool] = None  # None: kernel when fleet is large

    def __post_init__(self) -> None:
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode!r}")
        if not 0 < self.k <= self.n_clients:
            raise ValueError(
                f"k={self.k} must be in 1..n_clients={self.n_clients}"
            )
        if self.max_cohort is not None and self.max_cohort < self.k:
            raise ValueError(
                f"max_cohort={self.max_cohort} < k={self.k}: the cohort "
                "buffer could not hold even an exact-k selection; raise "
                "max_cohort (or leave it None for the binomial-tail default)"
            )

    def cohort_width(self) -> int:
        """Padded cohort buffer width for variable-size policies."""
        if self.max_cohort is not None:
            return self.max_cohort
        return default_cohort_width(self.n_clients, self.k)

    def resolved_aggregator(self) -> str:
        if self.aggregator is not None:
            return self.aggregator
        return "fedavg" if self.mode == "sync" else "fedbuff"

    def resolved_buffer_size(self) -> int:
        return self.buffer_size or self.k

    def profile_name(self) -> str:
        return self.profile if isinstance(self.profile, str) else self.profile.name


def default_cohort_width(n_clients: int, k: int) -> int:
    """Markov cohort is ~Binomial(n, k/n): pad to k + 4*sigma (overflow
    beyond the buffer is dropped, so the tail allowance matters)."""
    q = k / n_clients
    sigma = math.sqrt(n_clients * q * (1 - q))
    return min(n_clients, int(k + 4 * sigma) + 1)


def run_config_from_legacy(fl, acfg=None, **overrides) -> RunConfig:
    """Build a RunConfig from the legacy ``FLConfig`` (+ ``AsyncConfig``)
    pair. ``acfg`` switches the mode to async and maps its staleness
    knobs onto the fedbuff aggregator's kwargs."""
    kw: Dict[str, Any] = dict(
        n_clients=fl.n_clients, k=fl.k, m=fl.m, policy=fl.policy,
        rounds=fl.rounds, local_epochs=fl.local_epochs,
        batch_size=fl.batch_size, lr0=fl.lr0, lr_decay=fl.lr_decay,
        seed=fl.seed, max_cohort=fl.max_cohort, eval_every=fl.eval_every,
    )
    if acfg is not None:
        kw.update(
            mode="async",
            aggregator="fedbuff",
            aggregator_kwargs={
                "staleness_mode": acfg.staleness_mode,
                "staleness_exp": acfg.staleness_exp,
            },
            buffer_size=acfg.buffer_size,
            max_versions=acfg.max_versions,
            profile=acfg.profile,
            use_kernel=acfg.use_kernel,
        )
    kw.update(overrides)
    return RunConfig(**kw)


# ---------------------------------------------------------------------------
# Result schema
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RoundRecord:
    """One evaluated round / server step, identical for both engines.

    ``clock``/``version``/``buffer_fill`` are simulator quantities and stay
    None under the sync engine.
    """

    round: int
    train_loss: float
    eval_loss: float
    accuracy: float
    clock: Optional[float] = None
    version: Optional[int] = None
    buffer_fill: Optional[int] = None


@dataclasses.dataclass
class RunResult:
    """Typed output of ``repro.engine.run_engine`` for either mode."""

    config: RunConfig
    records: List[RoundRecord]
    selection: Optional[np.ndarray]  # (rounds, n) bool, None above cell cap
    load_stats: Dict[str, float]  # empirical Var[X] etc. from selection
    wall_stats: Optional[Dict[str, float]]  # async-only simulator stats
    params: Any
    wall_time_s: float

    def history(self) -> Dict[str, list]:
        """Legacy column-oriented history view of the records."""
        cols = ["round", "accuracy", "eval_loss", "train_loss"]
        if self.config.mode == "async":
            cols = ["round", "clock", "version", "accuracy", "eval_loss",
                    "train_loss", "buffer_fill"]
        return {c: [getattr(r, c) for r in self.records] for c in cols}

    def to_jsonable(self) -> Dict[str, Any]:
        """JSON-safe payload (excludes params and the raw selection matrix)."""
        from repro.engine.serialize import to_jsonable

        return to_jsonable({
            "config": dataclasses.asdict(self.config),
            "history": self.history(),
            "load_stats": self.load_stats,
            "wall_stats": self.wall_stats,
            "wall_time_s": self.wall_time_s,
        })
