"""The buffered asynchronous engine over the event-driven fleet simulator.

One jit'd server step = admission control (idle+available clients consult
their selection policy — the Markov chain decides *locally* whether to
pull the model, preserving the paper's zero-coordination property) ->
dispatch with sampled wall-clock latencies -> pop the next ``buffer_size``
completions (event_topk kernel at fleet scale) -> vmapped local training
from each client's *dispatch-time* model version (a ring buffer of the
last ``max_versions`` global models) -> aggregator
``weigh/init/accumulate/finalize`` over the buffered deltas -> clock/
version advance.

This is ``sim/async_rounds.py`` re-expressed against the ``Engine``
protocol with the aggregation seam opened up: the default ``fedbuff``
aggregator reproduces the pre-refactor staleness-discounted delta mean
bit-for-bit (pinned by ``tests/test_engine_equivalence.py``). With the
degenerate ``uniform`` latency profile (zero spread, always available, no
dropout) and ``buffer_size = k`` every dispatch completes inside its own
step with staleness 0, and the loop reproduces the synchronous FedAvg
round of ``SyncEngine`` exactly.

The load metric is reported on two clocks: X in decision epochs (the
paper's round-indexed Var[X]) and X in simulated seconds (wall-clock
inter-update gaps per client), which is where stragglers and availability
windows actually show up.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.aoi import age_update, peak_age_accumulate
from repro.core.load_metric import (
    empirical_load_stats,
    init_selection_accum,
    selection_stats_from_accum,
    tier_stats_from_accum,
)
from repro.core.selection import Policy
from repro.engine.aggregators import Aggregator, acc_stats
from repro.engine.chunk import ChunkRunner, dealias_pytree, run_key, step_once
from repro.engine.config import RoundRecord, RunConfig, RunResult
from repro.engine.registry import make_aggregator, make_policy
from repro.fl.client import make_local_update
from repro.fl.task import FLTask
from repro.optim.schedules import exponential_decay
from repro.sim import events as ev_mod
from repro.sim import latency as lat_mod


def _resolved_profile(profile) -> lat_mod.LatencyProfile:
    if isinstance(profile, lat_mod.LatencyProfile):
        return profile
    return lat_mod.get_profile(profile)


def _init_stats(heartbeat: bool = False, redispatch: bool = False,
                agg_stats: tuple = ()) -> Dict[str, jnp.ndarray]:
    z = jnp.zeros((), jnp.float32)
    out = {
        "wall_sx": z, "wall_sx2": z, "wall_cnt": z,  # X in simulated seconds
        "ep_sx": z, "ep_sx2": z, "ep_cnt": z,  # X in decision epochs
        "stale_sum": z, "stale_cnt": z,
        "stale_max": jnp.zeros((), jnp.int32),
        "updates": z,  # successful updates aggregated
        "aggs": z,  # server versions produced
    }
    if heartbeat:
        out["hb_expired"] = z  # updates excluded by heartbeat churn
    if redispatch:
        out["redispatched"] = z  # expired dispatches re-issued
        out["rd_expired"] = z  # deadline expiries (incl. written off)
    for s in agg_stats:
        out[f"agg_{s}"] = z  # aggregator telemetry (e.g. norm_clip)
    return out


class AsyncEngine:
    """Asynchronous server steps: one buffer flush per step, clients train
    from (possibly stale) ring-buffered model versions."""

    def __init__(
        self,
        task: FLTask,
        cfg: RunConfig,
        policy: Optional[Policy] = None,
        aggregator: Optional[Aggregator] = None,
    ):
        if cfg.mode != "async":
            raise ValueError(f"AsyncEngine needs mode='async', got {cfg.mode!r}")
        self.task = task
        self.cfg = cfg
        self.policy = policy or make_policy(
            cfg.policy, cfg.n_clients, cfg.k, cfg.m, **dict(cfg.policy_kwargs)
        )
        self.aggregator = aggregator or make_aggregator(
            cfg.resolved_aggregator(), **dict(cfg.aggregator_kwargs)
        )
        self.profile = _resolved_profile(cfg.profile)
        self.topo = cfg.resolved_topology()
        self.fault_set = cfg.resolved_faults()
        self.defense_cfg = cfg.resolved_defense()
        if self.defense_cfg is not None:
            from repro.defense import make_defense

            self.defense = make_defense(cfg.n_clients, self.defense_cfg)
        else:
            self.defense = None
        self._init_state, core = self._build_step()
        self._chunk = ChunkRunner(
            core, aux_keys=("loss", "clock", "version", "buffer_fill")
        )

    def _build_step(self):
        """Step-builder hook: ``ShardedAsyncEngine`` overrides this to
        inject the mesh-sharded pop and sharding constraints."""
        return _make_async_step(
            self.task, self.cfg, self.policy, self.aggregator, self.profile,
            topo=self.topo, faults=self.fault_set, defense=self.defense,
        )

    def init(self) -> Dict:
        cfg = self.cfg
        key = run_key(cfg.seed, cfg.rng_impl)
        k_init, k_policy, k_run = jax.random.split(key, 3)
        params = self.task.init(k_init)
        sched = self.policy.init(k_policy, cfg.n_clients)
        state = self._init_state(params, sched, jax.random.fold_in(k_run, 2**31))
        state["k_run"] = k_run
        state["load_acc"] = init_selection_accum(cfg.n_clients, cfg.k)
        # donation-safe from the start: step() routes through the donated
        # chunk runner even for single steps
        return dealias_pytree(state)

    def step(self, state: Dict, r: int):
        return step_once(self._chunk, state, r)

    def run_chunk(self, state: Dict, r0: int, length: int, with_history: bool):
        return self._chunk(state, r0, length, with_history)

    def eval_params(self, state: Dict):
        return state["params"]

    def ring_snapshot(self, state: Dict):
        """Device-resident view of the retained-version ring for the
        serving tier (``repro.serve.VersionStore``): ``(hist, version,
        max_versions)``. No host pull and no copy — the leaves stay
        wherever the engine keeps them (the sharded engines replicate
        ``hist``/``version``, so the same snapshot works unchanged), and
        the serving tier reads versions without synchronizing training."""
        return state["hist"], state["version"], self.cfg.max_versions

    def evaluate(self, state: Dict) -> Dict:
        """Held-out eval on the current global params. Cohort-sharded
        engines override this to shard the eval-batch axis over the mesh
        (params stay replicated)."""
        return self.task.eval_fn(self.eval_params(state))

    def record(self, r: int, aux: Dict, ev: Dict) -> RoundRecord:
        return RoundRecord(
            round=r + 1,
            train_loss=float(aux["loss"]),
            eval_loss=float(ev["loss"]),
            accuracy=float(ev["accuracy"]),
            clock=float(aux["clock"]),
            version=int(aux["version"]),
            buffer_fill=int(aux["buffer_fill"]),
        )

    def _topo_tag(self) -> str:
        if self.topo is None or self.topo.is_star:
            return ""
        return f"/{self.topo.describe()}"

    def progress_line(self, rec: RoundRecord, elapsed: float) -> str:
        return (
            f"  [{self.policy.name}/{self.profile.name}{self._topo_tag()}] "
            f"step {rec.round:4d} t={rec.clock:9.2f}s v={rec.version:4d} "
            f"acc={rec.accuracy:.4f} loss={rec.eval_loss:.4f} ({elapsed:.1f}s)"
        )

    def finalize(self, state, records, sel_hist, wall_time_s) -> RunResult:
        st = {k: float(v) for k, v in state["stats"].items()}

        def _mv(sx, sx2, cnt):
            if cnt <= 0:
                return float("nan"), float("nan")
            mean = sx / cnt
            return mean, max(sx2 / cnt - mean * mean, 0.0)

        mean_w, var_w = _mv(st["wall_sx"], st["wall_sx2"], st["wall_cnt"])
        mean_e, var_e = _mv(st["ep_sx"], st["ep_sx2"], st["ep_cnt"])
        wall_stats = {
            "mean_X_wall": mean_w, "var_X_wall": var_w,
            "num_samples_wall": int(st["wall_cnt"]),
            "mean_X_epoch": mean_e, "var_X_epoch": var_e,
            "num_samples_epoch": int(st["ep_cnt"]),
            "mean_staleness": st["stale_sum"] / max(st["stale_cnt"], 1.0),
            "max_staleness": int(st["stale_max"]),
            "updates_applied": int(st["updates"]),
            "aggregations": int(st["aggs"]),
            "sim_time": float(state["clock"]),
        }
        if "hb_expired" in st:
            wall_stats["hb_expired"] = int(st["hb_expired"])
        if sel_hist is not None:
            load_stats = empirical_load_stats(sel_hist)
        else:
            load_stats = selection_stats_from_accum(state["load_acc"])
        load_stats = dict(load_stats)
        if "tier_acc" in state:
            load_stats.update(tier_stats_from_accum(state["tier_acc"]))
        if "faults" in state:
            for nm, cnt in self.fault_set.counters(state["faults"]).items():
                load_stats[f"fault_{nm}_injected"] = cnt
        if "redispatched" in st:
            load_stats["redispatched"] = int(st["redispatched"])
            load_stats["rd_expired"] = int(st["rd_expired"])
        for s in self.aggregator.stat_names:
            load_stats[f"agg_{s}"] = float(st[f"agg_{s}"])
        if "defense" in state:
            load_stats.update(self.defense.report(state["defense"]))
            if "tier_acc" in state:
                from repro.topo.reduce import tier_suspect_counts

                load_stats["tier_suspects"] = tier_suspect_counts(
                    self.topo, self.cfg.n_clients,
                    state["defense"]["status"],
                )
        fault_exposure = None
        if "faults" in state and self.cfg.fault_exposure:
            fault_exposure = self.fault_set.exposure(state["faults"])
        return RunResult(
            config=self.cfg,
            records=records,
            selection=sel_hist,
            load_stats=load_stats,
            wall_stats=wall_stats,
            params=state["params"],
            wall_time_s=wall_time_s,
            fault_exposure=fault_exposure,
            defense=(self.defense.arrays(state["defense"])
                     if "defense" in state else None),
        )


def _make_async_step(
    task: FLTask, cfg: RunConfig, policy: Policy, agg: Aggregator,
    profile: lat_mod.LatencyProfile,
    pop=None, cohort_layout=None, constrain_state=None,
    aggregate=None, cohort_pad: int = 0, topo=None, faults=None,
    defense=None,
):
    """Builds ``(init_state, step core)`` with ``step(state, key) ->
    (state, aux)`` — the pure function the chunked scan body folds over
    (``ChunkRunner`` also drives single steps through a length-1 chunk).

    The optional hooks are the mesh-sharding seam (``repro.engine.sharded``
    supplies them; the single-device engine runs with identity defaults):

      * ``pop(ev) -> (t, idx, valid, ev')`` replaces the buffer pop;
      * ``cohort_layout(tree)`` decides the device layout of every
        cohort-sized (B,) intermediate. The bit-exact sharded engine pins
        them *replicated* so cross-device reduction order — and therefore
        bitwise results — cannot drift from the single-device engine; the
        cohort-parallel mode (``RunConfig.shard_cohort``) lays them out
        ``P(fleet)`` instead so each device trains only its slice of the
        cohort;
      * ``aggregate(params, updates, bases, w, idx) -> params`` replaces
        the inline ``init/accumulate/finalize`` chain (the cohort-parallel
        mode routes it through ``aggregators.cohort_sharded_apply``:
        shard-local accumulation merged by one psum; ``idx`` is the
        cohort -> client map, which topology-aware reductions use to
        route each slot to its tier-0 node);
      * ``cohort_pad`` appends that many zero-weight slots to the popped
        cohort so the padded axis divides the mesh (invalid slots, masked
        everywhere exactly like an under-filled buffer);
      * ``constrain_state(state)`` re-asserts the fleet sharding of the
        carry so the donated scan aliases buffers instead of resharding.

    ``topo`` (a ``repro.topo.Topology``) reshapes the aggregation: the
    default aggregate becomes the tiered reduction, every dispatch pays
    the per-hop DAG latency under a dedicated key fold, the per-tier
    load accumulators ride the state, and a non-zero
    ``heartbeat_timeout`` excludes dark clients from their tier's
    reduction. A star (or ``topo=None``) leaves every code path — state
    keys, key folds, ops — untouched, so the degenerate case is
    structurally bit-for-bit identical (pinned by ``tests/test_topo.py``).

    ``faults`` (a ``repro.faults.FaultSet``) and a non-zero
    ``cfg.redispatch_timeout`` follow the same structural-gating rule:
    armed, they add their ``(n,)`` state to the carry and draw under
    dedicated key folds (105 with sub-folds 0=dispatch/1=pop/2=corrupt;
    106/107 for re-dispatch latency); absent, no state key, no fold, no
    op exists and the engine is bit-for-bit today's
    (``tests/test_faults.py`` pins both the structural and the rate-0
    golden).

    ``defense`` (a ``repro.defense.Defense``) closes the detect ->
    quarantine -> adapt loop inside this same step under the same rule:
    armed, it adds its ``(n,)`` reputation/status state to the carry,
    draws its probation/readmit coins under dedicated fold 108, vetoes
    quarantined clients at the selection seam (``send &= ~blocked``) and
    suspect updates at the aggregation seam (``succ &= ~suspect`` — the
    exact seam heartbeat dark-clients use), and, with mtd configured,
    swaps the aggregate hook for the moving-target wrapper. Disarmed:
    no state key, no fold, no op (``tests/test_defense.py`` pins the
    structural golden and the armed-but-never-triggered bitwise one).
    """
    n = cfg.n_clients
    B = cfg.resolved_buffer_size()
    Bp = B + cohort_pad
    H = cfg.max_versions
    tiered = topo is not None and not topo.is_star
    hb_timeout = float(topo.heartbeat_timeout) if topo is not None else 0.0
    have_faults = faults is not None
    have_def = defense is not None
    rd_on = (cfg.redispatch_timeout or 0) > 0
    kill_on = have_faults and faults.has("kill")
    if have_faults and (faults.has("scale") or faults.has("noise")):
        from repro.faults.inject import corrupt_updates
    collude_on = have_faults and faults.has("collude")
    if collude_on:
        from repro.faults.inject import collude_updates
    col_on = have_def and defense.collusion
    # supervised labels for the learned detector head: only when the run
    # opted into exposure ground truth AND some fault actually pops
    sup_on = (have_def and defense.wants_labels and have_faults
              and faults.has_pop and cfg.fault_exposure)
    if sup_on:
        from repro.faults.inject import effects_hit
    if tiered:
        from repro.core.load_metric import init_tier_accum, update_tier_accum
        from repro.topo.reduce import make_hop_latency, tiered_apply

        assign_dev = jnp.asarray(topo.assign(n))
        hop_fn = make_hop_latency(topo, n)
    if hb_timeout > 0 or rd_on:
        # re-dispatch deadlines reuse the heartbeat liveness predicate:
        # "no completion for longer than the timeout" is the same signal
        from repro.topo import heartbeat as hb_mod
    if pop is None:
        def pop(ev):
            return ev_mod.pop_events(ev, B, use_kernel=cfg.use_kernel)
    if cohort_layout is None:
        cohort_layout = lambda tree: tree  # noqa: E731
    if constrain_state is None:
        constrain_state = lambda state: state  # noqa: E731
    if aggregate is None:
        if tiered:
            aggregate = tiered_apply(agg, topo, n)
        else:
            def aggregate(g, updates, bases, w, idx=None):
                acc = agg.accumulate(agg.init(g), updates, bases, w)
                return agg.finalize(g, acc), acc_stats(acc)
    mtd_on = have_def and defense.mtd
    if mtd_on:
        # config rejects mtd under tiered/cohort-sharded aggregation, so
        # the wrapped hook is always the inline (or bit-exact sharded)
        # default; level 0 routes through it untouched via lax.cond
        from repro.defense.adaptive import adaptive_aggregate

        aggregate_mtd = adaptive_aggregate(aggregate, defense.cfg.mtd_trims,
                                           families=defense.cfg.mtd_families)
    local_update = make_local_update(
        task.loss_fn, cfg.local_epochs, cfg.batch_size, task.examples_per_client
    )
    lr_fn = exponential_decay(cfg.lr0, cfg.lr_decay)

    def init_state(params, sched_state, key):
        state = {
            "params": params,
            # ring buffer of the last H global models; slot v % H = version v
            "hist": jax.tree.map(
                lambda p: jnp.broadcast_to(p[None], (H,) + p.shape), params
            ),
            "sched": sched_state,
            "ev": ev_mod.init_event_state(n),
            "speed": lat_mod.client_speed(key, n, profile),
            "clock": jnp.zeros((), jnp.float32),
            "version": jnp.zeros((), jnp.int32),
            "stats": _init_stats(heartbeat=hb_timeout > 0, redispatch=rd_on,
                                 agg_stats=agg.stat_names),
        }
        if hb_timeout > 0:
            state["hb"] = hb_mod.init_heartbeat(n)
        if tiered:
            state["tier_acc"] = init_tier_accum(n, int(topo.tier_sizes[0]))
        if have_faults:
            # fold 7 off the init key: independent of the speed draw
            state["faults"] = faults.init(jax.random.fold_in(key, 7))
        if have_def:
            state["defense"] = defense.init()  # deterministic zeros
        if rd_on:
            state["rd"] = {
                "t_disp": jnp.zeros((n,), jnp.float32),
                "retries": jnp.zeros((n,), jnp.int32),
            }
        return state

    def step(state, key):
        ev, sched, stats = state["ev"], state["sched"], state["stats"]
        clock, version = state["clock"], state["version"]
        # same key split as the sync round so the degenerate case is
        # bit-for-bit comparable; latency/dropout/gap keys are fresh folds
        k_sel, k_local = jax.random.split(key)
        k_lat = jax.random.fold_in(k_sel, 101)
        k_gap = jax.random.fold_in(k_sel, 103)

        # --- admission control: idle+available clients consult the policy
        prev_ages = sched["ages"]
        idle = jnp.isinf(ev["t_done"])
        available = ev["next_avail"] <= clock
        want, sched = policy.step(sched, k_sel)
        send = want & idle & available
        if have_def:
            # quarantined clients are vetoed at the admission seam (they
            # still age); probation clients stay selectable so they keep
            # generating evidence for re-admission
            dstate = state["defense"]
            send = send & ~defense.blocked(dstate)
        # only actual dispatches reset the AoI clock; everyone else ages
        sched = {**sched, "ages": age_update(prev_ages, send)}
        ep_sx, ep_sx2, ep_cnt = peak_age_accumulate(
            prev_ages, send, stats["ep_sx"], stats["ep_sx2"], stats["ep_cnt"]
        )

        # --- dispatch: sample wall-clock latencies, mark in flight.
        # zero-dropout profiles skip the dropout path entirely — the 102
        # key fold here plus the constant-folding of the zeros mask
        # (sample_dropout already skips the (n,) draw itself). No other
        # key depends on the 102 fold, so results are unchanged — pinned
        # by tests/test_cohort_engine.py
        latency = lat_mod.sample_latency(k_lat, profile, state["speed"])
        if tiered:
            # fold 104: per-hop DAG latency. Only drawn when a multi-tier
            # topology is armed, so the star key schedule is untouched
            latency = latency + hop_fn(jax.random.fold_in(k_sel, 104))
        if have_faults:
            fstate = state["faults"]
            # fold 105: the fault set's dedicated key (sub-folds:
            # 0 dispatch, 1 pop, 2 corruption noise) — armed only when
            # faults are, so the fault-free key schedule is untouched
            k_fault = jax.random.fold_in(k_sel, 105)
            if faults.has_dispatch:
                fstate, latency = faults.on_dispatch(
                    fstate, jax.random.fold_in(k_fault, 0), send, latency
                )
        if hb_timeout > 0:
            # dispatch is a heartbeat: the client pulled the model at
            # the current clock
            hb = hb_mod.beat(state["hb"], send, clock)
        if profile.dropout > 0:
            dropped = lat_mod.sample_dropout(
                jax.random.fold_in(k_sel, 102), profile, n
            )
        else:
            dropped = jnp.zeros((n,), jnp.bool_)
        ev = ev_mod.schedule_completions(ev, send, clock, latency, version, dropped)

        # --- deadline-based re-dispatch of expired in-flight dispatches:
        # a dispatch the server has not heard back from within the
        # timeout is re-issued at the current version with a fresh
        # latency (folds 106/107), at most redispatch_retries times —
        # then written off (t_done=inf frees the client to be selected
        # again). The original dispatch's dropout coin is preserved: a
        # retry re-attempts delivery, not the client's fate.
        if rd_on:
            rd_t = jnp.where(send, clock, state["rd"]["t_disp"])
            rd_cnt = jnp.where(send, 0, state["rd"]["retries"])
            inflight = ~jnp.isinf(ev["t_done"])
            exp = inflight & hb_mod.expired(
                rd_t, clock, float(cfg.redispatch_timeout)
            )
            retry = exp & (rd_cnt < cfg.redispatch_retries)
            give_up = exp & ~retry
            rd_lat = lat_mod.sample_latency(
                jax.random.fold_in(k_sel, 106), profile, state["speed"]
            )
            if tiered:
                rd_lat = rd_lat + hop_fn(jax.random.fold_in(k_sel, 107))
            ev = {
                **ev,
                "t_done": jnp.where(
                    retry, clock + rd_lat,
                    jnp.where(give_up, jnp.inf, ev["t_done"]),
                ),
                "disp_ver": jnp.where(retry, version, ev["disp_ver"]),
            }
            rd = {
                "t_disp": jnp.where(retry, clock, rd_t),
                "retries": rd_cnt + retry.astype(jnp.int32),
            }
            rd_retried = retry.astype(jnp.float32).sum()
            rd_expired = exp.astype(jnp.float32).sum()

        # --- pop the next B completions, advance the simulated clock
        t_ev, idx, valid, ev = pop(ev)
        if cohort_pad:
            # pad the cohort to the mesh multiple with invalid slots:
            # t=+inf/valid=False masks them out of the clock advance, the
            # weights, the telemetry, and both scatters, exactly like an
            # under-filled buffer slot
            t_ev = jnp.concatenate(
                [t_ev, jnp.full((cohort_pad,), jnp.inf, t_ev.dtype)]
            )
            idx = jnp.concatenate([idx, jnp.zeros((cohort_pad,), idx.dtype)])
            valid = jnp.concatenate(
                [valid, jnp.zeros((cohort_pad,), valid.dtype)]
            )
        if have_faults and faults.has_pop:
            # fold 105/1: per-slot injection coins over the popped cohort
            fstate, eff = faults.on_pop(
                fstate, jax.random.fold_in(k_fault, 1), idx, valid
            )
            eff = cohort_layout(eff)
        new_clock = jnp.maximum(clock, jnp.max(jnp.where(valid, t_ev, -jnp.inf)))
        # an all-idle fleet inside availability gaps must not freeze the
        # clock: with nothing in flight to pop, jump to the earliest
        # window opening so availability can recover next step
        new_clock = jnp.where(
            valid.any(), new_clock,
            jnp.maximum(new_clock, jnp.min(ev["next_avail"])),
        )

        # --- local training from each client's dispatch-time model
        disp_ver = cohort_layout(ev["disp_ver"][idx])
        # versions older than the ring are trained from the oldest retained
        # model; staleness for weighting still uses the true dispatch version
        read_ver = jnp.clip(disp_ver, jnp.maximum(version - (H - 1), 0), version)
        if have_faults and faults.has("replay"):
            # stale replay: hit slots read an older retained version than
            # they were dispatched (shift 0 elsewhere is exact identity on
            # ints); the staleness *weight* below still sees the honest
            # dispatch version — precisely the attack
            read_ver = jnp.maximum(
                read_ver - eff.replay_shift,
                jnp.maximum(version - (H - 1), 0),
            )
        disp_params = cohort_layout(
            jax.tree.map(lambda h: h[read_ver % H], state["hist"])
        )
        shards = cohort_layout(jax.tree.map(lambda a: a[idx], task.client_data))
        keys = jax.random.split(k_local, B)
        if cohort_pad:
            # the first B keys must stay the exact draws of the unpadded
            # engine (split(k, Bp) has a different prefix); padded slots
            # reuse the last real key — their updates carry weight 0
            keys = keys[jnp.minimum(jnp.arange(Bp), B - 1)]
        lr = lr_fn(jnp.maximum(disp_ver, 0))
        updated, losses = cohort_layout(jax.vmap(local_update, in_axes=(0, 0, 0, 0))(
            disp_params, shards, keys, lr
        ))
        if have_faults and (faults.has("scale") or faults.has("noise")):
            # fold 105/2: corruption noise. Missed slots keep their exact
            # input buffers (per-slot where inside corrupt_updates), so a
            # rate-0 set is bitwise identity
            updated = corrupt_updates(
                updated, disp_params, eff, jax.random.fold_in(k_fault, 2),
                faults.has("scale"), faults.has("noise"),
            )
        if collude_on:
            # after corrupt: a coalition member's replacement is
            # authoritative over any scale/noise it also drew. Keyless —
            # the direction is a trace-time constant, the jitter rode
            # the fault's own pop fold
            updated = collude_updates(updated, disp_params, eff)

        # --- buffered aggregation of deltas through the aggregator seam
        succ = valid & ~ev["dropped"][idx]
        if kill_on:
            # mid-round dropout: the update never arrived — excluded from
            # aggregation and from heartbeat contact below
            succ = succ & ~eff.kill
        if hb_timeout > 0:
            # an update landing more than the timeout after its client's
            # last contact looks dead to its tier coordinator: excluded
            # from the reduction exactly like a dropped slot. All valid
            # completions still count as contact (the client did return)
            dark = succ & hb_mod.expired(
                hb["last_beat"][idx], t_ev, hb_timeout
            )
            succ = succ & ~dark
            arrived = valid & ~eff.kill if kill_on else valid
            hb = hb_mod.beat_at(hb, ev_mod.scatter_idx(idx, arrived), t_ev)
        staleness = jnp.maximum(version - disp_ver, 0)
        if have_def:
            # fold 108: the defense tier's dedicated key (sub-folds
            # 0 probation / 1 readmit coins). Every update that arrived
            # (pre-exclusion succ) is scored — including probation
            # clients — then post-transition suspects are excluded from
            # the reduction through the exact seam heartbeat dark
            # clients use, closing the detect->quarantine loop within
            # the step
            dstate, suspect, w_scale = defense.observe(
                dstate, jax.random.fold_in(k_sel, 108),
                updated, disp_params, idx, succ, staleness,
                losses=losses, ages=cohort_layout(sched["ages"][idx]),
                labels=cohort_layout(effects_hit(eff)) if sup_on else None,
            )
            succ = succ & ~cohort_layout(suspect[idx])
        w = agg.weigh(succ, staleness)
        if col_on:
            # clique members keep a (discounted) vote rather than a
            # binary exclusion: w_scale is exact 1.0 on clique-free
            # slots, so a calm armed run multiplies by ones
            w = w * w_scale
        wsum = w.sum()
        has = wsum > 0
        denom = jnp.maximum(wsum, 1e-9)
        if mtd_on:
            params, agg_tel = aggregate_mtd(
                state["params"], updated, disp_params, w, idx,
                dstate["level"],
            )
        else:
            params, agg_tel = aggregate(
                state["params"], updated, disp_params, w, idx
            )
        version = version + has.astype(jnp.int32)
        hist = jax.tree.map(
            lambda h, p: h.at[version % H].set(p), state["hist"], params
        )
        # NaN, not a fake 0.0 datapoint, when nothing was aggregated
        mean_loss = jnp.where(has, jnp.sum(losses * w) / denom, jnp.nan)

        # --- completed clients go idle; wall-clock AoI samples
        # gaps are i.i.d. — draw only the B popped clients' worth
        gaps = lat_mod.sample_avail_gap(k_gap, profile, B)
        if cohort_pad:
            gaps = jnp.concatenate(
                [gaps, jnp.zeros((cohort_pad,), gaps.dtype)]
            )
        ev = {
            **ev,
            "next_avail": ev["next_avail"]
            .at[ev_mod.scatter_idx(idx, valid)]
            .set(new_clock + gaps, mode="drop"),
        }
        last_done = cohort_layout(ev["last_done"][idx])
        x_wall = t_ev - last_done
        wall_ok = succ & (last_done >= 0.0)
        wall_okf = wall_ok.astype(jnp.float32)
        ev = {
            **ev,
            "last_done": ev["last_done"]
            .at[ev_mod.scatter_idx(idx, succ)]
            .set(t_ev, mode="drop"),
        }

        stats = {
            "wall_sx": stats["wall_sx"] + jnp.sum(jnp.where(wall_ok, x_wall, 0.0)),
            "wall_sx2": stats["wall_sx2"] + jnp.sum(jnp.where(wall_ok, x_wall**2, 0.0)),
            "wall_cnt": stats["wall_cnt"] + wall_okf.sum(),
            "ep_sx": ep_sx, "ep_sx2": ep_sx2, "ep_cnt": ep_cnt,
            "stale_sum": stats["stale_sum"]
            + jnp.sum(jnp.where(succ, staleness, 0).astype(jnp.float32)),
            "stale_cnt": stats["stale_cnt"] + succ.astype(jnp.float32).sum(),
            "stale_max": jnp.maximum(
                stats["stale_max"], jnp.max(jnp.where(succ, staleness, 0))
            ),
            "updates": stats["updates"] + succ.astype(jnp.float32).sum(),
            "aggs": stats["aggs"] + has.astype(jnp.float32),
        }
        if hb_timeout > 0:
            stats["hb_expired"] = (
                state["stats"]["hb_expired"] + dark.astype(jnp.float32).sum()
            )
        if rd_on:
            stats["redispatched"] = state["stats"]["redispatched"] + rd_retried
            stats["rd_expired"] = state["stats"]["rd_expired"] + rd_expired
        for s in agg.stat_names:
            stats[f"agg_{s}"] = state["stats"][f"agg_{s}"] + agg_tel[s]
        new_state = {
            **state,
            "params": params, "hist": hist, "sched": sched, "ev": ev,
            "clock": new_clock, "version": version, "stats": stats,
        }
        if hb_timeout > 0:
            new_state["hb"] = hb
        if have_faults:
            new_state["faults"] = fstate
        if have_def:
            new_state["defense"] = dstate
        if rd_on:
            new_state["rd"] = rd
        if tiered:
            new_state["tier_acc"] = update_tier_accum(
                state["tier_acc"], send, assign_dev
            )
        state = constrain_state(new_state)
        aux = {
            "send": send,
            "loss": mean_loss,
            "buffer_fill": valid.astype(jnp.int32).sum(),
            "clock": new_clock,
            "version": version,
        }
        return state, aux

    return init_state, step
