"""The ``Engine`` protocol and the one run loop both engines share.

An engine is anything with ``init/step/finalize`` (plus the small
``eval_params/record/progress_line`` hooks the loop uses); ``run_engine``
drives it for ``cfg.rounds`` steps, collects the selection history and
eval records on the configured cadence, and returns a typed ``RunResult``
— identical schema for sync and async.

    cfg = RunConfig(mode="async", policy="markov", aggregator="fedbuff")
    result = run_engine(make_engine(task, cfg), progress=True)
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Protocol, Tuple, runtime_checkable

import numpy as np

from repro.engine.config import RoundRecord, RunConfig, RunResult

# collect the full (steps, n) selection matrix only below this cell count
HISTORY_CELL_CAP = 4_000_000


@runtime_checkable
class Engine(Protocol):
    """The contract ``run_engine`` drives."""

    task: object
    cfg: RunConfig

    def init(self) -> Dict: ...

    def step(self, state: Dict, r: int) -> Tuple[Dict, Dict]: ...

    def eval_params(self, state: Dict): ...

    def record(self, r: int, aux: Dict, ev: Dict) -> RoundRecord: ...

    def progress_line(self, rec: RoundRecord, elapsed: float) -> str: ...

    def finalize(self, state, records, sel_hist, wall_time_s) -> RunResult: ...


def make_engine(task, cfg: RunConfig, policy=None, aggregator=None) -> Engine:
    """Instantiate the engine matching ``cfg.mode``."""
    if cfg.mode == "sync":
        from repro.engine.sync import SyncEngine

        return SyncEngine(task, cfg, policy=policy, aggregator=aggregator)
    from repro.engine.async_engine import AsyncEngine

    return AsyncEngine(task, cfg, policy=policy, aggregator=aggregator)


def run_engine(engine: Engine, progress: bool = False) -> RunResult:
    """Drive an engine for ``cfg.rounds`` steps and package the result."""
    cfg = engine.cfg
    steps = cfg.rounds
    state = engine.init()
    # sync runs always keep the selection matrix (load_stats depend on it,
    # matching the pre-engine loop); async fleets can be orders of
    # magnitude larger, so they cap as the old async loop did
    keep_hist = cfg.mode == "sync" or steps * cfg.n_clients <= HISTORY_CELL_CAP
    sel_hist: Optional[np.ndarray] = (
        np.zeros((steps, cfg.n_clients), dtype=bool) if keep_hist else None
    )
    records = []
    t0 = time.time()
    for r in range(steps):
        state, aux = engine.step(state, r)
        if keep_hist:
            sel_hist[r] = np.asarray(aux["send"])
        if (r + 1) % cfg.eval_every == 0 or r == steps - 1:
            ev = engine.task.eval_fn(engine.eval_params(state))
            rec = engine.record(r, aux, ev)
            records.append(rec)
            if progress:
                print(engine.progress_line(rec, time.time() - t0), flush=True)
    return engine.finalize(state, records, sel_hist, time.time() - t0)
