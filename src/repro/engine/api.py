"""The ``Engine`` protocol and the one run loop both engines share.

An engine is anything with ``init/step/run_chunk/finalize`` (plus the
small ``eval_params/record/progress_line`` hooks the loop uses);
``run_engine`` drives it for ``cfg.rounds`` steps in jitted, donated
``lax.scan`` chunks of ``cfg.resolved_steps_per_chunk()`` steps per host
dispatch, collects the selection history (when configured) and eval
records on the configured cadence, and returns a typed ``RunResult`` —
identical schema for sync and async.

The hot loop performs **one host transfer per chunk**: per-step aux
scalars (and, when history is kept, the chunk's stacked selection rows)
come back as one device pytree. Load statistics never require the
materialized history — both engines fold device-resident sufficient
statistics (``core.load_metric``) inside the scan body, so Var[X] is
available even for fleet-scale runs where the ``(rounds, n)`` matrix
could never be stored. Chunked execution is bit-for-bit identical to
per-step execution (``tests/test_engine_chunked.py``), and chunks never
straddle an eval step, so records land on exactly the legacy cadence.

    cfg = RunConfig(mode="async", policy="markov", aggregator="fedbuff")
    result = run_engine(make_engine(task, cfg), progress=True)
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Protocol, Tuple, runtime_checkable

import jax
import numpy as np

from repro.engine.config import RoundRecord, RunConfig, RunResult, chunk_plan

# collect the full (steps, n) selection matrix only below this cell count
HISTORY_CELL_CAP = 4_000_000


@runtime_checkable
class Engine(Protocol):
    """The contract ``run_engine`` drives."""

    task: object
    cfg: RunConfig

    def init(self) -> Dict: ...

    def step(self, state: Dict, r: int) -> Tuple[Dict, Dict]: ...

    def run_chunk(
        self, state: Dict, r0: int, length: int, with_history: bool
    ) -> Tuple[Dict, Dict]: ...

    def eval_params(self, state: Dict): ...

    def evaluate(self, state: Dict) -> Dict: ...

    def record(self, r: int, aux: Dict, ev: Dict) -> RoundRecord: ...

    def progress_line(self, rec: RoundRecord, elapsed: float) -> str: ...

    def finalize(self, state, records, sel_hist, wall_time_s) -> RunResult: ...


def make_engine(task, cfg: RunConfig, policy=None, aggregator=None) -> Engine:
    """Instantiate the engine matching ``cfg.mode`` (and, for async runs
    with ``mesh_shards`` set, the fleet-sharded variant)."""
    if cfg.mode == "sync":
        from repro.engine.sync import SyncEngine

        return SyncEngine(task, cfg, policy=policy, aggregator=aggregator)
    if cfg.mesh_shards is not None:
        from repro.engine.sharded import ShardedAsyncEngine

        return ShardedAsyncEngine(task, cfg, policy=policy, aggregator=aggregator)
    from repro.engine.async_engine import AsyncEngine

    return AsyncEngine(task, cfg, policy=policy, aggregator=aggregator)


def keep_history(cfg: RunConfig) -> bool:
    """Whether a run materializes the (rounds, n) selection matrix.

    ``cfg.collect_history`` wins when set; the legacy heuristic otherwise
    (sync runs always kept it, async fleets cap at ``HISTORY_CELL_CAP``
    cells). Load statistics no longer depend on it — the device
    accumulators cover runs of any size.
    """
    if cfg.collect_history is not None:
        return cfg.collect_history
    return cfg.mode == "sync" or cfg.rounds * cfg.n_clients <= HISTORY_CELL_CAP


def run_engine(engine: Engine, progress: bool = False) -> RunResult:
    """Drive an engine for ``cfg.rounds`` steps and package the result."""
    from repro.engine.chunk import dealias_pytree

    cfg = engine.cfg
    steps = cfg.rounds
    state = dealias_pytree(engine.init())
    keep_hist = keep_history(cfg)
    sel_hist: Optional[np.ndarray] = (
        np.zeros((steps, cfg.n_clients), dtype=bool) if keep_hist else None
    )
    records = []
    t0 = time.time()
    for r0, length, do_eval in chunk_plan(
        steps, cfg.eval_every, cfg.resolved_steps_per_chunk()
    ):
        state, aux = engine.run_chunk(state, r0, length, keep_hist)
        aux = jax.device_get(aux)  # the chunk's one device -> host transfer
        if keep_hist:
            sel_hist[r0:r0 + length] = aux.pop("send")
        if do_eval:
            r = r0 + length - 1
            # engines own their eval: cohort-sharded engines score the
            # held-out set with the eval-batch axis sharded over the mesh
            ev = engine.evaluate(state)
            rec = engine.record(r, {k: v[-1] for k, v in aux.items()}, ev)
            records.append(rec)
            if progress:
                print(engine.progress_line(rec, time.time() - t0), flush=True)
    return engine.finalize(state, records, sel_hist, time.time() - t0)
