"""Mesh-sharded asynchronous engine: the fleet state split across devices.

``AsyncEngine`` holds every per-client array — the ``(n,)`` event-engine
vectors, the policy ages, persistent speeds, the selection/load
accumulators, and the client data shards — on a single device, which caps
the fleet at one device's memory. ``ShardedAsyncEngine`` is the same
engine (same step math, same RNG schedule, the identical
``_make_async_step`` body) with that state laid out over a 1-D ``fleet``
device mesh:

  * **sharded** over ``fleet``: ``ev`` (completion times, dispatch
    versions, availability, dropout, last-done), ``sched`` ages,
    ``speed``, the ``load_acc`` per-client last-selection vector, and
    ``task.client_data`` — every array with a leading client axis;
  * **replicated**: the global params, the ``max_versions`` ring buffer
    of retained models, the run key, and all scalar telemetry.

The one operation that fundamentally crosses shards is the buffer pop.
It runs through ``core.distributed.sharded_next_k_events``: each shard
extracts its local top-B earliest completions, the ``devices x B``
candidates are ``all_gather``-ed, and a single stable merge picks the
global B — O(devices * B) communication per step instead of
materializing the ``(n,)`` completion-time vector on one device. The
decentralized Markov admission step stays elementwise over the shard
(zero cross-device traffic — the paper's coordination-free property,
realized in the partitioning), while scalar statistics and the load
accumulators reduce with the all-reduces GSPMD inserts for ``jnp.sum``
over sharded arrays.

**Bit-for-bit equivalence.** Every random draw keeps the exact ``(n,)``
shape and key schedule of the single-device engine, jit results are
sharding-independent, and all cohort-sized ``(B,)`` intermediates are
pinned to a replicated layout (so floating-point reduction order over the
cohort cannot drift). The engine therefore reproduces ``AsyncEngine``
exactly — same selections, same losses, same final params — for the same
``RunConfig`` seed, pinned per-step and chunked by
``tests/test_sharded_engine.py``. The ``(n,)``-wide float sums folded
into the load accumulators are sums of integer-valued float32 and stay
exact under any partial-sum order at test scales.

Shard counts must divide ``n_clients`` so every device owns an equal
client block (``mesh_shards=0`` auto-detects: the largest divisor of the
fleet size at most the local device count). On CPU,
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` fakes an 8-device
mesh — the recipe the sharded benchmarks and CI smoke job use. The whole
sharded carry runs inside the donated ``ChunkRunner`` scan, so chunked
multi-device execution still performs one host transfer per chunk.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import distributed as dist
from repro.core.selection import Policy
from repro.engine.aggregators import Aggregator, cohort_sharded_apply
from repro.engine.async_engine import AsyncEngine, _make_async_step
from repro.engine.config import RunConfig
from repro.fl.task import FLTask
from repro.sim import events as ev_mod

# state entries whose leading-``n`` leaves shard over the fleet axis
# ("hb" heartbeats and the "tier_acc" per-client last-selection vector
# are (n,)-leading too; their (E,) per-tier moments stay replicated via
# the shape[0] == n check in fleet_state_sharding — same check that
# keeps the fault sets' scalar "injected" counters replicated while
# their (n,) prone masks and the re-dispatch deadline vectors shard —
# and the defense tier's scalar counters/mtd level replicated while its
# (n,) reputation/status vectors shard)
FLEET_STATE_KEYS = ("ev", "sched", "speed", "load_acc", "hb", "tier_acc",
                    "faults", "rd", "defense")


def per_device_state_bytes(state, dev) -> int:
    """Measured bytes of a state pytree resident on device ``dev`` — the
    sharded-vs-single-device footprint the benchmarks and the engine's
    accounting report. Typed PRNG key arrays hide their buffer (their
    ``nbytes`` is not exposed); they are probed for explicitly and
    counted as 0, which is negligible — any other failure to read a
    shard's size is a real bug and raises."""
    total = 0
    for leaf in jax.tree.leaves(state):
        dt = getattr(leaf, "dtype", None)
        if dt is not None and jax.dtypes.issubdtype(dt, jax.dtypes.prng_key):
            continue
        for shard in getattr(leaf, "addressable_shards", []):
            if shard.device == dev:
                total += shard.data.nbytes
    return total


def fleet_state_sharding(mesh: Mesh, n: int, state: Dict, axis: str) -> Dict:
    """A matching tree of ``NamedSharding``s for an engine state pytree:
    leaves with a leading client axis under the per-client entries get
    ``P(axis)``, everything else (params, ring buffer, scalars, the run
    key) is replicated."""
    fleet = NamedSharding(mesh, P(axis))
    rep = NamedSharding(mesh, P())

    def leaf_spec(is_fleet):
        def spec(x):
            if is_fleet and getattr(x, "ndim", 0) >= 1 and x.shape[0] == n:
                return fleet
            return rep

        return spec

    return {
        key: jax.tree.map(leaf_spec(key in FLEET_STATE_KEYS), sub)
        for key, sub in state.items()
    }


def require_cohort_mesh(shards: int, what: str) -> None:
    """``shard_cohort=True`` on a 1-device mesh would be a silent no-op
    (the "sharded" cohort is the whole cohort) — reject it loudly."""
    if shards < 2:
        raise ValueError(
            f"shard_cohort=True but {what} resolves to a {shards}-device "
            "mesh — cohort-parallel execution needs >= 2 devices. On CPU, "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8 fakes an "
            "8-device mesh; otherwise drop shard_cohort."
        )


def make_sharded_eval(task: FLTask, mesh: Mesh, axis: str):
    """Eval with the held-out batch axis sharded over ``axis`` (params
    replicated): each device scores ``1/devices`` of the eval set and the
    metric reductions all-reduce. Returns None — caller falls back to the
    replicated ``task.eval_fn`` — when the task lacks the batched-eval
    interface (``eval_data``/``eval_batch_fn``) or the eval prefix does
    not divide the mesh. Metrics are allclose to, not bitwise identical
    with, the replicated eval (reduction order differs)."""
    if task.eval_data is None or task.eval_batch_fn is None:
        return None
    leaves = jax.tree.leaves(task.eval_data)
    n_eval = leaves[0].shape[0]
    devices = mesh.shape[axis]
    if n_eval % devices or any(
        getattr(a, "ndim", 0) < 1 or a.shape[0] != n_eval for a in leaves
    ):
        return None
    data = jax.device_put(
        task.eval_data, NamedSharding(mesh, P(axis))
    )
    fn = jax.jit(task.eval_batch_fn)
    return lambda params: fn(params, data)


class ShardedAsyncEngine(AsyncEngine):
    """``AsyncEngine`` with the fleet state sharded over a device mesh.

    Drop-in behind the ``Engine`` protocol: ``make_engine`` routes here
    whenever ``RunConfig.mesh_shards`` is set (0 = auto-detect devices).
    An explicit ``mesh`` overrides the config-driven one (its single axis
    size must divide ``n_clients``).
    """

    def __init__(
        self,
        task: FLTask,
        cfg: RunConfig,
        policy: Optional[Policy] = None,
        aggregator: Optional[Aggregator] = None,
        mesh: Optional[Mesh] = None,
    ):
        if mesh is not None:
            if len(mesh.axis_names) != 1:
                raise ValueError(
                    f"ShardedAsyncEngine needs a 1-D mesh, got axes "
                    f"{mesh.axis_names}"
                )
            self.fleet_axis = mesh.axis_names[0]
            shards = mesh.shape[self.fleet_axis]
            if cfg.n_clients % shards:
                raise ValueError(
                    f"mesh has {shards} devices but n_clients="
                    f"{cfg.n_clients} is not divisible by it"
                )
            self.mesh = mesh
        else:
            shards = dist.resolve_fleet_shards(
                cfg.n_clients, cfg.mesh_shards or 0, len(jax.devices())
            )
            self.fleet_axis = dist.FLEET_AXIS
            self.mesh = dist.fleet_mesh(shards, self.fleet_axis)
        self.mesh_shards = shards
        if cfg.shard_cohort:
            require_cohort_mesh(shards, f"mesh_shards={cfg.mesh_shards}")
        # client data is per-client state too — shard its leading axis
        data_spec = jax.tree.map(
            lambda a: NamedSharding(
                self.mesh,
                P(self.fleet_axis)
                if a.shape[:1] == (cfg.n_clients,)
                else P(),
            ),
            task.client_data,
        )
        task = dataclasses.replace(
            task, client_data=jax.device_put(task.client_data, data_spec)
        )
        self._sharded_eval = (
            make_sharded_eval(task, self.mesh, self.fleet_axis)
            if cfg.shard_cohort else None
        )
        super().__init__(task, cfg, policy=policy, aggregator=aggregator)

    def evaluate(self, state: Dict) -> Dict:
        if self._sharded_eval is not None:
            return self._sharded_eval(self.eval_params(state))
        return super().evaluate(state)

    def _build_step(self):
        cfg = self.cfg
        next_k = dist.sharded_next_k_events(
            self.mesh, cfg.n_clients, cfg.resolved_buffer_size(),
            axis=self.fleet_axis,
        )
        rep = NamedSharding(self.mesh, P())

        def pop(ev):
            t, idx = next_k(ev["t_done"])
            return ev_mod.apply_pop(ev, t, idx)

        def constrain_state(state):
            return jax.tree.map(
                jax.lax.with_sharding_constraint,
                state,
                fleet_state_sharding(
                    self.mesh, cfg.n_clients, state, self.fleet_axis
                ),
            )

        if cfg.shard_cohort:
            # cohort-parallel: (B,) intermediates lay out over the mesh —
            # each device gathers, trains, and accumulates only its
            # B/devices cohort slice; the aggregator merges with one psum
            # of the accumulator pytree (allclose, not bitwise, to the
            # replicated layout: cross-device reduction order differs)
            cohort = NamedSharding(self.mesh, P(self.fleet_axis))

            def cohort_layout(tree):
                return jax.tree.map(
                    lambda x: jax.lax.with_sharding_constraint(x, cohort),
                    tree,
                )

            if self.topo is not None and not self.topo.is_star:
                # the tiered reduction in cohort-parallel form: slot
                # accumulation + the tier-0 segment sum run shard-locally
                # inside the same shard_map-and-one-psum pattern
                from repro.topo.reduce import tiered_apply

                aggregate = tiered_apply(
                    self.aggregator, self.topo, cfg.n_clients,
                    mesh=self.mesh, axis=self.fleet_axis,
                )
            else:
                aggregate = cohort_sharded_apply(
                    self.aggregator, self.mesh, self.fleet_axis
                )
            return _make_async_step(
                self.task, cfg, self.policy, self.aggregator, self.profile,
                pop=pop, cohort_layout=cohort_layout,
                constrain_state=constrain_state,
                aggregate=aggregate,
                cohort_pad=dist.cohort_padding(
                    cfg.resolved_buffer_size(), self.mesh_shards
                ),
                topo=self.topo, faults=self.fault_set,
                defense=self.defense,
            )

        # bit-exact default: cohort-sized (B,) intermediates pinned to a
        # replicated layout so reduction order cannot drift from the
        # single-device engine
        def replicate(tree):
            return jax.tree.map(
                lambda x: jax.lax.with_sharding_constraint(x, rep), tree
            )

        return _make_async_step(
            self.task, cfg, self.policy, self.aggregator, self.profile,
            pop=pop, cohort_layout=replicate, constrain_state=constrain_state,
            topo=self.topo, faults=self.fault_set, defense=self.defense,
        )

    def init(self) -> Dict:
        state = super().init()
        return jax.device_put(
            state,
            fleet_state_sharding(
                self.mesh, self.cfg.n_clients, state, self.fleet_axis
            ),
        )

    def per_device_state_bytes(self, state: Dict) -> int:
        """Measured bytes of the engine state resident on one device —
        the sharded-vs-single-device memory comparison the benchmarks
        report."""
        return per_device_state_bytes(state, self.mesh.devices.flat[0])

    def progress_line(self, rec, elapsed: float) -> str:
        return (
            f"  [{self.policy.name}/{self.profile.name}"
            f"/x{self.mesh_shards}] "
            f"step {rec.round:4d} t={rec.clock:9.2f}s v={rec.version:4d} "
            f"acc={rec.accuracy:.4f} loss={rec.eval_loss:.4f} ({elapsed:.1f}s)"
        )
