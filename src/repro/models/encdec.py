"""Whisper-style encoder-decoder backbone.

The mel-spectrogram + conv feature extractor is a STUB per the assignment:
``input_specs`` provides precomputed frame embeddings (B, src, d_model).
Encoder = bidirectional self-attention stack; decoder = causal self-attn +
cross-attn + MLP, scanned over stacked layers.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, AttentionSpec
from repro.models import attention as attn_mod
from repro.models.common import (
    apply_norm,
    dense_init,
    dtype_of,
    embed_init,
    init_norm,
    sinusoid_at,
    sinusoid_positions,
)
from repro.models.mlp import init_mlp, mlp_fwd


def _enc_spec(cfg: ArchConfig) -> AttentionSpec:
    e = cfg.encoder
    return AttentionSpec(
        num_heads=e.num_heads,
        num_kv_heads=e.num_heads,
        head_dim=cfg.d_model // e.num_heads,
        causal=False,
        rope=False,
    )


def _dec_spec(cfg: ArchConfig) -> AttentionSpec:
    return cfg.pattern[0].attn


def init_params(key, cfg: ArchConfig) -> Dict:
    dtype = dtype_of(cfg.param_dtype)
    e = cfg.encoder
    keys = jax.random.split(key, 6)
    espec = _enc_spec(cfg)
    dspec = _dec_spec(cfg)
    mlp_spec = cfg.pattern[0].mlp

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": init_norm(cfg.d_model, cfg.norm, dtype),
            "attn": attn_mod.init_attention(k1, cfg.d_model, espec, dtype),
            "ln2": init_norm(cfg.d_model, cfg.norm, dtype),
            "mlp": init_mlp(k2, cfg.d_model, mlp_spec, dtype),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "ln1": init_norm(cfg.d_model, cfg.norm, dtype),
            "attn": attn_mod.init_attention(k1, cfg.d_model, dspec, dtype),
            "ln_x": init_norm(cfg.d_model, cfg.norm, dtype),
            "cross": attn_mod.init_cross_attention(k2, cfg.d_model, dspec, dtype),
            "ln2": init_norm(cfg.d_model, cfg.norm, dtype),
            "mlp": init_mlp(k3, cfg.d_model, mlp_spec, dtype),
        }

    n_dec = len(cfg.pattern) * cfg.repeats
    return {
        "frontend_proj": dense_init(keys[0], (cfg.d_model, cfg.d_model), 0, dtype),
        "enc_layers": jax.vmap(enc_layer)(jax.random.split(keys[1], e.num_layers)),
        "enc_ln": init_norm(cfg.d_model, cfg.norm, dtype),
        "embed": embed_init(keys[2], (cfg.vocab_size, cfg.d_model), dtype),
        "dec_layers": jax.vmap(dec_layer)(jax.random.split(keys[3], n_dec)),
        "final_norm": init_norm(cfg.d_model, cfg.norm, dtype),
    }


def encode(params, cfg: ArchConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, src, d_model) stub embeddings -> encoder memory."""
    espec = _enc_spec(cfg)
    mlp_spec = cfg.pattern[0].mlp
    x = jnp.einsum("btd,de->bte", frames, params["frontend_proj"])
    x = x + sinusoid_positions(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(x, p):
        h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
        x = x + attn_mod.attention_fwd(p["attn"], h, espec, None, positions)
        h = apply_norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
        x = x + mlp_fwd(p["mlp"], h, mlp_spec)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return apply_norm(params["enc_ln"], x, cfg.norm, cfg.norm_eps)


def decode_train(params, cfg: ArchConfig, memory, tokens) -> jnp.ndarray:
    """Teacher-forced decoder forward -> final hidden (B, S, d)."""
    dspec = _dec_spec(cfg)
    mlp_spec = cfg.pattern[0].mlp
    x = params["embed"][tokens]
    x = x + sinusoid_positions(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(x, p):
        h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
        x = x + attn_mod.attention_fwd(p["attn"], h, dspec, None, positions)
        h = apply_norm(p["ln_x"], x, cfg.norm, cfg.norm_eps)
        x = x + attn_mod.cross_attention_fwd(p["cross"], h, memory, dspec)
        h = apply_norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
        x = x + mlp_fwd(p["mlp"], h, mlp_spec)
        return x, None

    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)


def unembed(params, x):
    return jnp.einsum("bsd,vd->bsv", x, params["embed"])


# ---------------------------------------------------------------------------
# Decode with cache
# ---------------------------------------------------------------------------


def init_decode_caches(cfg: ArchConfig, batch: int, seq_len: int) -> Dict:
    dtype = dtype_of(cfg.compute_dtype)
    dspec = _dec_spec(cfg)
    n_dec = len(cfg.pattern) * cfg.repeats
    e = cfg.encoder
    Hk, D = dspec.num_kv_heads, dspec.head_dim

    def stack(t):
        return jnp.stack([t] * n_dec)

    self_cache = jax.tree.map(stack, attn_mod.init_cache(dspec, batch, seq_len, dtype))
    return {
        "self": self_cache,
        "cross_k": jnp.zeros((n_dec, batch, e.source_len, Hk, D), dtype),
        "cross_v": jnp.zeros((n_dec, batch, e.source_len, Hk, D), dtype),
    }


def precompute_cross(params, cfg: ArchConfig, memory) -> Tuple[jnp.ndarray, jnp.ndarray]:
    def per_layer(p):
        k = jnp.einsum("btd,dhe->bthe", memory, p["cross"]["w_k"])
        v = jnp.einsum("btd,dhe->bthe", memory, p["cross"]["w_v"])
        return k, v

    return jax.vmap(per_layer)(params["dec_layers"])


def decode_step(params, cfg: ArchConfig, caches: Dict, token: jnp.ndarray):
    """One decoder token against self-cache + precomputed cross K/V."""
    dspec = _dec_spec(cfg)
    mlp_spec = cfg.pattern[0].mlp
    index = caches["self"]["index"][0]
    x = params["embed"][token]
    x = x + sinusoid_at(index, cfg.d_model).astype(x.dtype)[None, None]

    def body(x, xs):
        p, self_c, ck, cv = xs
        h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
        y, new_self = attn_mod.attention_decode(p["attn"], h, dspec, None, self_c)
        x = x + y
        h = apply_norm(p["ln_x"], x, cfg.norm, cfg.norm_eps)
        x = x + _cross_decode(p["cross"], h, dspec, ck, cv)
        h = apply_norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
        x = x + mlp_fwd(p["mlp"], h, mlp_spec)
        return x, new_self

    x, new_self = jax.lax.scan(
        body, x, (params["dec_layers"], caches["self"], caches["cross_k"], caches["cross_v"])
    )
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    logits = unembed(params, x)
    return logits, {**caches, "self": new_self}


def _cross_decode(p, x, spec, k, v):
    """x: (B,1,d); k/v: (B,T,Hk,D) precomputed."""
    H, Hk, D = spec.num_heads, spec.num_kv_heads, spec.head_dim
    G = H // Hk
    B = x.shape[0]
    q = jnp.einsum("bsd,dhe->bshe", x, p["w_q"])
    qg = q.reshape(B, 1, Hk, G, D).transpose(0, 2, 3, 1, 4)
    kg = k.transpose(0, 2, 1, 3)
    vg = v.transpose(0, 2, 1, 3)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kg).astype(jnp.float32) / D**0.5
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", w.astype(vg.dtype), vg)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, 1, H, D).astype(x.dtype)
    return jnp.einsum("bshe,hed->bsd", out, p["w_o"])
