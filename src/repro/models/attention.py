"""Attention layers: GQA/MHA, sliding-window / chunked variants, MLA
(multi-head latent attention, deepseek-v2), with train/prefill and
cached-decode paths.

Long sequences use a flash-style blocked attention written in pure jnp
(query-block vmap x key-block scan with online softmax) so the (S, S)
score matrix never materializes; ``repro.kernels.flash_attention`` is the
Pallas TPU version of the same schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionSpec
from repro.models import pshard
from repro.models.common import apply_rope, dense_init, rms_norm_headwise

BLOCK_Q = 1024
BLOCK_K = 1024
FLASH_THRESHOLD = 2048  # use blocked attention above this seq length

# When enabled (TPU deployments / kernel-integration tests), full-sequence
# attention runs through the Pallas flash kernel instead of the jnp
# blocked path. Positions must be 0..S-1 (train/prefill), S % 128 == 0.
_USE_PALLAS_KERNEL = False


def set_kernel_attention(enabled: bool) -> None:
    global _USE_PALLAS_KERNEL
    _USE_PALLAS_KERNEL = enabled


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_attention(key, d_model: int, spec: AttentionSpec, dtype) -> Dict:
    ks = jax.random.split(key, 10)
    H, Hk, D = spec.num_heads, spec.num_kv_heads, spec.head_dim
    p: Dict = {}
    if spec.is_mla:
        r, dr = spec.kv_lora, spec.rope_dim
        if spec.q_lora:
            p["w_dq"] = dense_init(ks[0], (d_model, spec.q_lora), 0, dtype)
            p["w_uq"] = dense_init(ks[1], (spec.q_lora, H, D + dr), 0, dtype)
        else:
            p["w_uq"] = dense_init(ks[1], (d_model, H, D + dr), 0, dtype)
        p["w_dkv"] = dense_init(ks[2], (d_model, r), 0, dtype)
        p["w_k_rope"] = dense_init(ks[3], (d_model, dr), 0, dtype)
        p["w_uk"] = dense_init(ks[4], (r, H, D), 0, dtype)
        p["w_uv"] = dense_init(ks[5], (r, H, D), 0, dtype)
        p["w_o"] = dense_init(ks[6], (H, D, d_model), 0, dtype)
    else:
        p["w_q"] = dense_init(ks[0], (d_model, H, D), 0, dtype)
        p["w_k"] = dense_init(ks[1], (d_model, Hk, D), 0, dtype)
        p["w_v"] = dense_init(ks[2], (d_model, Hk, D), 0, dtype)
        p["w_o"] = dense_init(ks[3], (H, D, d_model), 0, dtype)
    if spec.qk_norm:
        p["q_norm"] = jnp.ones((D,), dtype)
        p["k_norm"] = jnp.ones((D,), dtype)
    return p


# ---------------------------------------------------------------------------
# Mask helpers
# ---------------------------------------------------------------------------


def _pair_mask(spec: AttentionSpec, q_pos, k_pos):
    """(..., Q, K) boolean validity from absolute positions."""
    q = q_pos[..., :, None]
    k = k_pos[..., None, :]
    ok = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), jnp.bool_)
    if spec.causal:
        ok &= k <= q
    if spec.kind == "sliding" and spec.window > 0:
        ok &= k > q - spec.window
    elif spec.kind == "chunked" and spec.window > 0:
        ok &= (k // spec.window) == (q // spec.window)
    return ok


# ---------------------------------------------------------------------------
# Core grouped attention (q already (B, Hk, G, Sq, D))
# ---------------------------------------------------------------------------


def _attend_direct(q, k, v, mask, scale):
    """Materialized-scores attention (short sequences / decode)."""
    s = jnp.einsum("bhgqd,bhkd->bhgqk", q, k).astype(jnp.float32) * scale
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgqk,bhkd->bhgqd", w.astype(v.dtype), v)


def _attend_flash_jnp(q, k, v, spec: AttentionSpec, q_pos, k_pos, scale):
    """Blocked online-softmax attention; never materializes (Sq, Sk).
    Supports distinct K and V head dims (MLA)."""
    B, Hk, G, Sq, D = q.shape
    Sk = k.shape[2]
    Dv = v.shape[-1]
    bq = min(BLOCK_Q, Sq)
    bk = min(BLOCK_K, Sk)
    nq, nk = Sq // bq, Sk // bk
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)

    qb = q.reshape(B, Hk, G, nq, bq, D).transpose(3, 0, 1, 2, 4, 5)  # (nq,B,Hk,G,bq,D)
    qp = q_pos.reshape(nq, bq)
    kb = k.reshape(B, Hk, nk, bk, D).transpose(2, 0, 1, 3, 4)  # (nk,B,Hk,bk,D)
    vb = v.reshape(B, Hk, nk, bk, Dv).transpose(2, 0, 1, 3, 4)
    kp = k_pos.reshape(nk, bk)

    def per_qblock(q_i, qp_i):
        m0 = jnp.full((B, Hk, G, bq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hk, G, bq), jnp.float32)
        a0 = jnp.zeros((B, Hk, G, bq, Dv), jnp.float32)

        def body(carry, kv):
            m, l, acc = carry
            k_j, v_j, kp_j = kv
            s = jnp.einsum("bhgqd,bhkd->bhgqk", q_i, k_j).astype(jnp.float32) * scale
            mask = _pair_mask(spec, qp_i, kp_j)  # (bq, bk)
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(v_j.dtype), v_j
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, kp))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = jax.vmap(per_qblock)(qb, qp)  # (nq,B,Hk,G,bq,Dv)
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hk, G, Sq, Dv)
    return out


def _grouped_attention(q, k, v, spec, q_pos, k_pos, scale, force_direct=False):
    Sq, Sk = q.shape[3], k.shape[2]
    if (
        _USE_PALLAS_KERNEL
        and not force_direct
        and spec.causal
        and Sq == Sk
        and Sq % 128 == 0
        and q.shape[-1] == k.shape[-1] == v.shape[-1]
    ):
        from repro.kernels import ops as kops

        bq = min(BLOCK_Q, 128 if Sq <= 512 else 256)
        bk = min(BLOCK_K, 128 if Sq <= 512 else 512)
        return kops.flash_attention(
            q, k, v, scale=scale, kind=spec.kind, window=spec.window,
            block_q=bq, block_k=bk,
        ).astype(v.dtype)
    if force_direct or max(Sq, Sk) <= FLASH_THRESHOLD or Sq % 128 != 0:
        mask = _pair_mask(spec, q_pos, k_pos)[None, None, None]
        return _attend_direct(q, k, v, mask, scale)
    return _attend_flash_jnp(q, k, v, spec, q_pos, k_pos, scale)


# ---------------------------------------------------------------------------
# Standard (GQA) attention forward
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RopeTable:
    inv_freq: jnp.ndarray
    rot: int


def _project_qkv(p, x, spec):
    q = jnp.einsum("bsd,dhe->bshe", x, p["w_q"])
    k = jnp.einsum("bsd,dhe->bshe", x, p["w_k"])
    v = jnp.einsum("bsd,dhe->bshe", x, p["w_v"])
    if spec.qk_norm:
        q = rms_norm_headwise(p["q_norm"], q)
        k = rms_norm_headwise(p["k_norm"], k)
    return q, k, v


def attention_fwd(
    p: Dict,
    x: jnp.ndarray,  # (B, S, d)
    spec: AttentionSpec,
    rope: Optional[RopeTable],
    positions: jnp.ndarray,  # (S,)
) -> jnp.ndarray:
    """Full-sequence (train / prefill) attention."""
    if spec.is_mla:
        return _mla_fwd(p, x, spec, rope, positions)
    H, Hk, D = spec.num_heads, spec.num_kv_heads, spec.head_dim
    G = H // Hk
    q, k, v = _project_qkv(p, x, spec)
    if spec.rope and rope is not None:
        q = apply_rope(q, positions[None], rope.inv_freq, rope.rot)
        k = apply_rope(k, positions[None], rope.inv_freq, rope.rot)
    B, S = x.shape[0], x.shape[1]
    # --- tensor-parallel strategy (see pshard) -----------------------------
    # heads-sharded when kv heads divide the model axis; else repeat kv to
    # full MHA when q heads divide; else shard the query sequence (context
    # parallel). Degrades to no-op without a mesh.
    tp = pshard.axis_size("model")
    dpax = pshard.dp()
    if tp > 1 and Hk % tp != 0 and H % tp == 0:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
        Hk_eff, G_eff = H, 1
    else:
        Hk_eff, G_eff = Hk, G
    qg = q.reshape(B, S, Hk_eff, G_eff, D).transpose(0, 2, 3, 1, 4)  # (B,Hk,G,S,D)
    kg = k.transpose(0, 2, 1, 3)  # (B,Hk,S,D)
    vg = v.transpose(0, 2, 1, 3)
    if tp > 1:
        if Hk_eff % tp == 0:
            qg = pshard.constrain(qg, dpax, "model", None, None, None)
            kg = pshard.constrain(kg, dpax, "model", None, None)
            vg = pshard.constrain(vg, dpax, "model", None, None)
        else:  # context-parallel queries (e.g. llama4's 40 heads)
            qg = pshard.constrain(qg, dpax, None, None, "model", None)
            kg = pshard.constrain(kg, dpax, None, None, None)
            vg = pshard.constrain(vg, dpax, None, None, None)
    scale = spec.softmax_scale or (1.0 / D**0.5)
    out = _grouped_attention(qg, kg, vg, spec, positions, positions, scale)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, D).astype(x.dtype)
    return jnp.einsum("bshe,hed->bsd", out, p["w_o"])


# ---------------------------------------------------------------------------
# KV cache (ring buffer)
# ---------------------------------------------------------------------------


def init_cache(spec: AttentionSpec, batch: int, seq_len: int, dtype) -> Dict:
    """Cache sized for a context of ``seq_len`` (bounded by window/chunk)."""
    L = spec.cache_len(seq_len)
    if spec.is_mla:
        return {
            "c_kv": jnp.zeros((batch, L, spec.kv_lora), dtype),
            "k_rope": jnp.zeros((batch, L, spec.rope_dim), dtype),
            "index": jnp.zeros((), jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, L, spec.num_kv_heads, spec.head_dim), dtype),
        "v": jnp.zeros((batch, L, spec.num_kv_heads, spec.head_dim), dtype),
        "index": jnp.zeros((), jnp.int32),
    }


def _slot_positions(spec: AttentionSpec, L: int, index):
    """Absolute position held in each ring slot when writing at ``index``.

    Slot s holds the newest position p <= index with p == s (mod L);
    the slot being written now holds ``index`` itself.
    """
    s = jnp.arange(L)
    return index - ((index - s) % L)


def _slot_valid(spec: AttentionSpec, slot_pos, index):
    ok = (slot_pos >= 0) & (slot_pos <= index)
    if spec.kind == "sliding" and spec.window > 0:
        ok &= slot_pos > index - spec.window
    elif spec.kind == "chunked" and spec.window > 0:
        ok &= (slot_pos // spec.window) == (index // spec.window)
    return ok


def attention_decode(
    p: Dict,
    x: jnp.ndarray,  # (B, 1, d)
    spec: AttentionSpec,
    rope: Optional[RopeTable],
    cache: Dict,
    mla_absorb: bool = True,
) -> Tuple[jnp.ndarray, Dict]:
    """Single-token decode with ring-buffer cache update."""
    if spec.is_mla:
        return _mla_decode(p, x, spec, rope, cache, absorb=mla_absorb)
    H, Hk, D = spec.num_heads, spec.num_kv_heads, spec.head_dim
    G = H // Hk
    B = x.shape[0]
    index = cache["index"]
    L = cache["k"].shape[1]
    q, k, v = _project_qkv(p, x, spec)
    pos = index[None]  # (1,)
    if spec.rope and rope is not None:
        q = apply_rope(q, pos[None], rope.inv_freq, rope.rot)
        k = apply_rope(k, pos[None], rope.inv_freq, rope.rot)
    slot = index % L
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    slot_pos = _slot_positions(spec, L, index)
    valid = _slot_valid(spec, slot_pos, index)
    qg = q.reshape(B, 1, Hk, G, D).transpose(0, 2, 3, 1, 4)  # (B,Hk,G,1,D)
    kg = k_cache.transpose(0, 2, 1, 3)
    vg = v_cache.transpose(0, 2, 1, 3)
    scale = spec.softmax_scale or (1.0 / D**0.5)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kg).astype(jnp.float32) * scale
    s = jnp.where(valid[None, None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", w.astype(vg.dtype), vg)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, 1, H, D).astype(x.dtype)
    y = jnp.einsum("bshe,hed->bsd", out, p["w_o"])
    return y, {"k": k_cache, "v": v_cache, "index": index + 1}


# ---------------------------------------------------------------------------
# MLA (deepseek-v2)
# ---------------------------------------------------------------------------


def _mla_q(p, x, spec, rope, positions):
    H, D, dr = spec.num_heads, spec.head_dim, spec.rope_dim
    if spec.q_lora:
        cq = jnp.einsum("bsd,dr->bsr", x, p["w_dq"])
        q = jnp.einsum("bsr,rhe->bshe", cq, p["w_uq"])  # (B,S,H,D+dr)
    else:
        q = jnp.einsum("bsd,dhe->bshe", x, p["w_uq"])
    q_nope, q_rope = q[..., :D], q[..., D:]
    if rope is not None:
        q_rope = apply_rope(q_rope, positions[None], rope.inv_freq, rope.rot)
    return q_nope, q_rope


def _mla_fwd(p, x, spec, rope, positions):
    """Prefill/train MLA: decompress K/V and run standard attention (MHA)."""
    B, S, _ = x.shape
    H, D, dr, r = spec.num_heads, spec.head_dim, spec.rope_dim, spec.kv_lora
    q_nope, q_rope = _mla_q(p, x, spec, rope, positions)
    c_kv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    k_rope = jnp.einsum("bsd,de->bse", x, p["w_k_rope"])  # single shared head
    if rope is not None:
        k_rope = apply_rope(k_rope[:, :, None, :], positions[None], rope.inv_freq, rope.rot)[
            :, :, 0
        ]
    k_nope = jnp.einsum("bsr,rhe->bshe", c_kv, p["w_uk"])
    v = jnp.einsum("bsr,rhe->bshe", c_kv, p["w_uv"])
    # fold shared rope head into per-head keys; MHA (G=1, Hk=H)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None], (B, S, H, dr))], axis=-1)
    scale = spec.softmax_scale or (1.0 / (D + dr) ** 0.5)
    qg = q.transpose(0, 2, 1, 3)[:, :, None]  # (B,H,1,S,D+dr)
    kg = k.transpose(0, 2, 1, 3)
    vg = v.transpose(0, 2, 1, 3)
    dpax = pshard.dp()
    qg = pshard.constrain(qg, dpax, "model", None, None, None)
    kg = pshard.constrain(kg, dpax, "model", None, None)
    vg = pshard.constrain(vg, dpax, "model", None, None)
    out = _grouped_attention(qg, kg, vg, spec, positions, positions, scale)
    out = out[:, :, 0].transpose(0, 2, 1, 3).astype(x.dtype)  # (B,S,H,D)
    return jnp.einsum("bshe,hed->bsd", out, p["w_o"])


def _mla_decode(p, x, spec, rope, cache, absorb: bool):
    """Cached decode against the *compressed* latent cache.

    absorb=True uses the matrix-absorption identity: scores over the latent
    cache directly via q' = q @ W_uk (per head), and output via
    (w @ c_kv) @ W_uv — O(L*r) per head instead of decompressing O(L*H*D)
    keys/values every step.  absorb=False is the naive (paper-orderd)
    decompression path, kept as the roofline baseline.
    """
    B = x.shape[0]
    H, D, dr, r = spec.num_heads, spec.head_dim, spec.rope_dim, spec.kv_lora
    index = cache["index"]
    L = cache["c_kv"].shape[1]
    pos = index[None]
    q_nope, q_rope = _mla_q(p, x, spec, rope, pos)  # (B,1,H,D), (B,1,H,dr)
    c_new = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    kr_new = jnp.einsum("bsd,de->bse", x, p["w_k_rope"])
    if rope is not None:
        kr_new = apply_rope(kr_new[:, :, None, :], pos[None], rope.inv_freq, rope.rot)[:, :, 0]
    slot = index % L
    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_new, (0, slot, 0))
    k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], kr_new, (0, slot, 0))
    slot_pos = _slot_positions(spec, L, index)
    valid = (slot_pos >= 0) & (slot_pos <= index)
    scale = spec.softmax_scale or (1.0 / (D + dr) ** 0.5)
    if absorb:
        qc = jnp.einsum("bshe,rhe->bshr", q_nope, p["w_uk"])  # (B,1,H,r)
        s = jnp.einsum("bshr,blr->bhsl", qc, c_kv)
        s = s + jnp.einsum("bshe,ble->bhsl", q_rope, k_rope)
        s = jnp.where(valid[None, None, None], s.astype(jnp.float32) * scale, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        wc = jnp.einsum("bhsl,blr->bshr", w.astype(c_kv.dtype), c_kv)
        out = jnp.einsum("bshr,rhe->bshe", wc, p["w_uv"])  # (B,1,H,D)
    else:
        k_nope = jnp.einsum("blr,rhe->blhe", c_kv, p["w_uk"])  # (B,L,H,D)
        v = jnp.einsum("blr,rhe->blhe", c_kv, p["w_uv"])
        s = jnp.einsum("bshe,blhe->bhsl", q_nope, k_nope)
        s = s + jnp.einsum("bshe,ble->bhsl", q_rope, k_rope)
        s = jnp.where(valid[None, None, None], s.astype(jnp.float32) * scale, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhsl,blhe->bshe", w.astype(v.dtype), v)
    y = jnp.einsum("bshe,hed->bsd", out.astype(x.dtype), p["w_o"])
    return y, {"c_kv": c_kv, "k_rope": k_rope, "index": index + 1}


# ---------------------------------------------------------------------------
# Bidirectional / cross attention (whisper encoder & decoder)
# ---------------------------------------------------------------------------


def init_cross_attention(key, d_model: int, spec: AttentionSpec, dtype) -> Dict:
    return init_attention(key, d_model, spec, dtype)


def cross_attention_fwd(p, x, kv_src, spec: AttentionSpec):
    """Decoder->encoder cross attention; kv_src: (B, T, d); no masking."""
    H, Hk, D = spec.num_heads, spec.num_kv_heads, spec.head_dim
    G = H // Hk
    B, S = x.shape[0], x.shape[1]
    T = kv_src.shape[1]
    q = jnp.einsum("bsd,dhe->bshe", x, p["w_q"])
    k = jnp.einsum("bsd,dhe->bshe", kv_src, p["w_k"])
    v = jnp.einsum("bsd,dhe->bshe", kv_src, p["w_v"])
    qg = q.reshape(B, S, Hk, G, D).transpose(0, 2, 3, 1, 4)
    kg = k.transpose(0, 2, 1, 3)
    vg = v.transpose(0, 2, 1, 3)
    scale = 1.0 / D**0.5
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kg).astype(jnp.float32) * scale
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", w.astype(vg.dtype), vg)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, D).astype(x.dtype)
    return jnp.einsum("bshe,hed->bsd", out, p["w_o"])
