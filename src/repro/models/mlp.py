"""Dense MLP blocks: gated (SwiGLU) and ungated (GELU).

Two tensor-parallel execution paths:
  * GSPMD (default): einsums + sharding constraints; the partitioner
    inserts the row-parallel all-reduce. On the CPU pipeline
    float-normalization widens bf16 dot outputs to f32 *before* SPMD, so
    the AR moves 2x the bytes (§Perf finding).
  * explicit_tp: shard_map with a hand-written ``psum`` placed AFTER the
    cast to the activation dtype — collectives are guaranteed bf16, and
    the backward ``psum`` (cotangent of the replicated input) is bf16 too.
"""
from __future__ import annotations

from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import MLPSpec
from repro.models import pshard
from repro.models.common import activation, dense_init


def init_mlp(key, d_model: int, spec: MLPSpec, dtype) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_in": dense_init(k1, (d_model, spec.d_ff), 0, dtype),
        "w_out": dense_init(k2, (spec.d_ff, d_model), 0, dtype),
    }
    if spec.activation == "silu":  # gated
        p["w_gate"] = dense_init(k3, (d_model, spec.d_ff), 0, dtype)
    return p


def mlp_fwd(p: Dict, x: jnp.ndarray, spec: MLPSpec, explicit_tp: bool = False) -> jnp.ndarray:
    mesh = pshard.current_mesh()
    if (
        explicit_tp
        and x.ndim == 3
        and mesh is not None
        and "model" in mesh.shape
        and spec.d_ff % mesh.shape["model"] == 0
        and "w_gate" in p
    ):
        return _mlp_fwd_explicit_tp(p, x, spec, mesh)
    act = activation(spec.activation)
    dpax = pshard.dp()
    h = jnp.einsum("...d,df->...f", x, p["w_in"])
    if x.ndim == 3:
        h = pshard.constrain(h, dpax, None, "model")
    if "w_gate" in p:
        g = jnp.einsum("...d,df->...f", x, p["w_gate"])
        if x.ndim == 3:
            g = pshard.constrain(g, dpax, None, "model")
        h = act(g) * h
    else:
        h = act(h)
    return jnp.einsum("...f,fd->...d", h, p["w_out"])


def _mlp_fwd_explicit_tp(p: Dict, x: jnp.ndarray, spec: MLPSpec, mesh) -> jnp.ndarray:
    """Column-parallel in/gate + row-parallel out with an explicit bf16
    psum over the model axis (Megatron TP with hand-placed collectives)."""
    act = activation(spec.activation)
    dp = pshard.dp() or None

    def local(x_l, win_l, wg_l, wo_l):
        h = jnp.einsum("bsd,df->bsf", x_l, win_l)
        g = jnp.einsum("bsd,df->bsf", x_l, wg_l)
        y = jnp.einsum("bsf,fd->bsd", act(g) * h, wo_l)
        # the cast happens BEFORE the collective: psum moves x.dtype bytes
        return jax.lax.psum(y.astype(x_l.dtype), "model")

    xspec = P(dp, None, None) if dp else P(None, None, None)
    return shard_map(
        local,
        mesh=mesh,
        in_specs=(xspec, P(None, "model"), P(None, "model"), P("model", None)),
        out_specs=xspec,
        check_rep=False,
    )(x, p["w_in"], p["w_gate"], p["w_out"])
