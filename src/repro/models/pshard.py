"""Activation-sharding context.

GSPMD propagates input/param shardings but, left unconstrained, may pick
pathological layouts (e.g. replicating the batch across the data axis
inside GQA attention when kv_heads < model-axis size — observed in the
dry-run profile). The launchers install a mesh context; model code calls
``constrain(x, axis0, axis1, ...)`` at layer boundaries. Every axis
request degrades gracefully: it is applied only if the mesh has the axis
and the dim divides, so the same model code runs unsharded in CPU tests.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P

_CTX = {"mesh": None}


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh]):
    prev = _CTX["mesh"]
    _CTX["mesh"] = mesh
    try:
        yield
    finally:
        _CTX["mesh"] = prev


def current_mesh() -> Optional[Mesh]:
    return _CTX["mesh"]


def axis_size(name) -> int:
    mesh = _CTX["mesh"]
    if mesh is None:
        return 1
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= axis_size(n)
        return out
    return mesh.shape.get(name, 1)


def dp() -> Tuple[str, ...]:
    mesh = _CTX["mesh"]
    if mesh is None:
        return ()
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def constrain(x, *axes):
    """with_sharding_constraint with per-axis divisibility fallback.

    ``axes`` entries: None | axis-name | tuple of axis names. Trailing dims
    may be omitted (replicated).
    """
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    spec = []
    used = set()
    for i, a in enumerate(x.shape[: len(axes)]):
        req = axes[i]
        if req is None:
            spec.append(None)
            continue
        names = req if isinstance(req, tuple) else (req,)
        names = tuple(n for n in names if n in mesh.shape)
        if not names or any(n in used for n in names):
            spec.append(None)
            continue
        size = 1
        for n in names:
            size *= mesh.shape[n]
        if a % size != 0:
            spec.append(None)
            continue
        spec.append(names if len(names) > 1 else names[0])
        used.update(names)
    return jax.lax.with_sharding_constraint(x, P(*spec))
