"""Uniform model API over all assigned architectures.

    model = build(cfg)
    params = model.init(key)
    loss, metrics = model.loss(params, batch)
    new_params, metrics = model.sgd_train_step(params, batch, lr)
    logits, caches = model.prefill(params, batch)
    logits, caches = model.decode_step(params, caches, token)

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
input of the step the shape exercises (train/prefill/decode) — the dry-run
lowers against these without allocating anything.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import encdec, transformer
from repro.models.common import dtype_of

MOE_AUX_WEIGHT = 0.01


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable
    loss: Callable  # (params, batch) -> (scalar, metrics)
    sgd_train_step: Callable  # (params, batch, lr) -> (params, metrics)
    prefill: Callable  # (params, batch) -> (logits, caches)
    decode_step: Callable  # (params, caches, token) -> (logits, caches)
    init_decode_caches: Callable  # (batch, seq_len) -> caches pytree


def _vocab_chunk(cfg: ArchConfig, seq_len: int) -> int:
    return 512 if cfg.vocab_size * seq_len > 2**27 else 0


# ---------------------------------------------------------------------------
# Decoder-only family
# ---------------------------------------------------------------------------


def _build_decoder(
    cfg: ArchConfig,
    mla_absorb: bool = True,
    remat: bool = True,
    seq_parallel: bool = False,
    explicit_tp: bool = False,
    remat_save_outputs: bool = False,
) -> Model:
    def init(key):
        return transformer.init_params(key, cfg)

    def loss(params, batch):
        tokens = batch["tokens"]
        extra = batch.get("frontend")
        x, aux, _ = transformer.forward(
            params, cfg, tokens, extra_embeds=extra, mode="train", remat=remat,
            seq_shard=seq_parallel, explicit_tp=explicit_tp,
            remat_save_outputs=remat_save_outputs,
        )
        ce = transformer.lm_loss(
            params, cfg, x, batch["labels"], vocab_chunk=_vocab_chunk(cfg, x.shape[1])
        )
        total = ce + MOE_AUX_WEIGHT * aux
        return total, {"loss": ce, "moe_aux": aux}

    def sgd_train_step(params, batch, lr):
        (total, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params, batch)
        new_params = jax.tree.map(
            lambda p, g: (p - lr * g.astype(p.dtype)).astype(p.dtype), params, grads
        )
        return new_params, {**metrics, "total_loss": total}

    def prefill(params, batch):
        tokens = batch["tokens"]
        extra = batch.get("frontend")
        x, _, caches = transformer.forward(
            params, cfg, tokens, extra_embeds=extra, mode="prefill", remat=False,
            seq_shard=seq_parallel,
        )
        logits = transformer.unembed(params, cfg, x[:, -1:])
        return logits, caches

    def decode_step(params, caches, token):
        return transformer.decode_step(params, cfg, caches, token, mla_absorb=mla_absorb)

    def init_decode_caches(batch, seq_len):
        return transformer.init_decode_caches(cfg, batch, seq_len)

    return Model(cfg, init, loss, sgd_train_step, prefill, decode_step, init_decode_caches)


# ---------------------------------------------------------------------------
# Encoder-decoder family (whisper)
# ---------------------------------------------------------------------------


def _build_encdec(cfg: ArchConfig) -> Model:
    def init(key):
        return encdec.init_params(key, cfg)

    def loss(params, batch):
        memory = encdec.encode(params, cfg, batch["frames"])
        x = encdec.decode_train(params, cfg, memory, batch["tokens"])
        ce = transformer.lm_loss(
            {"embed": params["embed"]},
            dataclasses.replace(cfg, tie_embeddings=True),
            x,
            batch["labels"],
            vocab_chunk=_vocab_chunk(cfg, x.shape[1]),
        )
        return ce, {"loss": ce, "moe_aux": jnp.zeros(())}

    def sgd_train_step(params, batch, lr):
        (total, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params, batch)
        new_params = jax.tree.map(
            lambda p, g: (p - lr * g.astype(p.dtype)).astype(p.dtype), params, grads
        )
        return new_params, {**metrics, "total_loss": total}

    def prefill(params, batch):
        memory = encdec.encode(params, cfg, batch["frames"])
        x = encdec.decode_train(params, cfg, memory, batch["tokens"])
        caches = encdec.init_decode_caches(cfg, batch["tokens"].shape[0], batch["seq_len"])
        ck, cv = encdec.precompute_cross(params, cfg, memory)
        caches = {**caches, "cross_k": ck, "cross_v": cv}
        logits = encdec.unembed(params, x[:, -1:])
        return logits, caches

    def decode_step(params, caches, token):
        return encdec.decode_step(params, cfg, caches, token)

    def init_decode_caches(batch, seq_len):
        return encdec.init_decode_caches(cfg, batch, seq_len)

    return Model(cfg, init, loss, sgd_train_step, prefill, decode_step, init_decode_caches)


def build(
    cfg: ArchConfig,
    mla_absorb: bool = True,
    remat: bool = True,
    seq_parallel: bool = False,
    explicit_tp: bool = False,
    remat_save_outputs: bool = False,
) -> Model:
    if cfg.encoder is not None:
        return _build_encdec(cfg)
    return _build_decoder(
        cfg, mla_absorb=mla_absorb, remat=remat, seq_parallel=seq_parallel,
        explicit_tp=explicit_tp, remat_save_outputs=remat_save_outputs,
    )


# ---------------------------------------------------------------------------
# ShapeDtypeStruct input specs for the dry-run
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict:
    """Stand-ins for every model input of the step this shape lowers."""
    B, S = shape.global_batch, shape.seq_len
    cdtype = dtype_of(cfg.compute_dtype)
    i32 = jnp.int32

    def sds(shp, dt):
        return jax.ShapeDtypeStruct(shp, dt)

    if cfg.encoder is not None:  # whisper
        if shape.mode in ("train", "prefill"):
            return {
                "frames": sds((B, cfg.encoder.source_len, cfg.d_model), cdtype),
                "tokens": sds((B, S), i32),
                "labels": sds((B, S), i32),
            }
        caches = jax.eval_shape(
            lambda: encdec.init_decode_caches(cfg, B, S)
        )
        return {"caches": caches, "token": sds((B, 1), i32)}

    if shape.mode in ("train", "prefill"):
        s_text = S - (cfg.frontend_tokens if cfg.frontend != "none" else 0)
        batch = {
            "tokens": sds((B, s_text), i32),
            "labels": sds((B, S), i32),
        }
        if cfg.frontend != "none":
            batch["frontend"] = sds((B, cfg.frontend_tokens, cfg.d_model), cdtype)
        if shape.mode == "prefill":
            batch.pop("labels")
        return batch

    # decode
    caches = jax.eval_shape(lambda: transformer.init_decode_caches(cfg, B, S))
    return {"caches": caches, "token": sds((B, 1), i32)}


def synth_batch(key, cfg: ArchConfig, batch: int, seq_len: int) -> Dict:
    """Random concrete batch matching input_specs (for smoke tests)."""
    cdtype = dtype_of(cfg.compute_dtype)
    k1, k2 = jax.random.split(key)
    if cfg.encoder is not None:
        return {
            "frames": jax.random.normal(k2, (batch, cfg.encoder.source_len, cfg.d_model), cdtype),
            "tokens": jax.random.randint(k1, (batch, seq_len), 0, cfg.vocab_size),
            "labels": jax.random.randint(k1, (batch, seq_len), 0, cfg.vocab_size),
        }
    ft = cfg.frontend_tokens if cfg.frontend != "none" else 0
    s_text = seq_len - ft
    out = {
        "tokens": jax.random.randint(k1, (batch, s_text), 0, cfg.vocab_size),
        "labels": jnp.concatenate(
            [
                -jnp.ones((batch, ft), jnp.int32),
                jax.random.randint(k1, (batch, s_text), 0, cfg.vocab_size),
            ],
            axis=1,
        ),
    }
    if ft:
        out["frontend"] = jax.random.normal(k2, (batch, ft, cfg.d_model), cdtype)
    return out
