"""The paper's simulation model: CNN of McMahan et al. [1].

Two 5x5 conv layers (32, 64 channels) each followed by 2x2 max-pool, a
512-unit fully-connected layer, and a softmax output — exactly the model
used for the MNIST/CIFAR convergence experiments (paper Sec. IV).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.paper_cnn import CNNConfig


def init_params(key, cfg: CNNConfig) -> Dict:
    ks = jax.random.split(key, 4)
    c1, c2 = cfg.conv_channels
    kk = cfg.kernel
    # output spatial size after two stride-2 pools with SAME conv
    s = cfg.image_size // 4
    flat = s * s * c2

    def he(k, shape, fan_in):
        return jax.random.normal(k, shape) * (2.0 / fan_in) ** 0.5

    return {
        "conv1": {
            "w": he(ks[0], (kk, kk, cfg.channels, c1), kk * kk * cfg.channels),
            "b": jnp.zeros((c1,)),
        },
        "conv2": {"w": he(ks[1], (kk, kk, c1, c2), kk * kk * c1), "b": jnp.zeros((c2,))},
        "fc1": {"w": he(ks[2], (flat, cfg.fc_width), flat), "b": jnp.zeros((cfg.fc_width,))},
        "fc2": {
            "w": he(ks[3], (cfg.fc_width, cfg.num_classes), cfg.fc_width),
            "b": jnp.zeros((cfg.num_classes,)),
        },
    }


def _conv(x, p):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + p["b"]


def _pool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def forward(params: Dict, images: jnp.ndarray) -> jnp.ndarray:
    """images: (B, H, W, C) -> logits (B, classes)."""
    x = _pool(jax.nn.relu(_conv(images, params["conv1"])))
    x = _pool(jax.nn.relu(_conv(x, params["conv2"])))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return x @ params["fc2"]["w"] + params["fc2"]["b"]


def loss_and_acc(params: Dict, images, labels):
    logits = forward(params, images)
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    acc = (logits.argmax(-1) == labels).mean()
    return loss, acc
