"""Shared layer primitives: norms, RoPE, initializers."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}[
        name
    ]


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    """Scaled normal (fan-in) initializer."""
    fan_in = np.prod([shape[i] for i in range(len(shape)) if i <= in_axis]) if False else shape[in_axis]
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(d: int, kind: str, dtype=jnp.float32):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_norm(params, x, kind: str, eps: float):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    else:
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_headwise(scale, x, eps: float = 1e-6):
    """RMSNorm over the last (head) dim — gemma3 qk-norm."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float, rope_frac: float = 1.0):
    """Inverse frequencies for the rotated sub-dimension."""
    rot = int(head_dim * rope_frac) // 2 * 2
    inv = 1.0 / (theta ** (np.arange(0, rot, 2, dtype=np.float64) / rot))
    return jnp.asarray(inv, jnp.float32), rot


def apply_rope(x, positions, inv_freq, rot: int):
    """x: (..., S, H, D); positions: (..., S) int32."""
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    ang = positions[..., :, None, None].astype(jnp.float32) * inv_freq  # (...,S,1,rot/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    y = jnp.stack([y1, y2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([y.astype(x.dtype), x_pass], axis=-1)


def sinusoid_positions(length: int, d_model: int):
    """Whisper-style fixed sinusoidal embedding table."""
    pos = np.arange(length)[:, None]
    dim = np.arange(d_model // 2)[None, :]
    ang = pos / (10000.0 ** (dim / max(d_model // 2 - 1, 1)))
    table = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(table, jnp.float32)


def sinusoid_at(pos, d_model: int):
    """Sinusoidal embedding at (dynamic) integer position(s). pos: ()->(d,)"""
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / (10000.0 ** (dim / max(d_model // 2 - 1, 1)))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]
