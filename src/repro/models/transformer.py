"""Pattern-scan decoder transformer.

An ``ArchConfig`` describes layers as ``prefix + pattern*repeats +
remainder``.  The repeated pattern is executed with ``jax.lax.scan`` over
stacked parameters (HLO size O(|pattern|), not O(layers)); prefix/remainder
are unrolled. This one stack expresses every assigned decoder arch: dense
GQA (llama/tinyllama/stablelm/pixtral), local:global interleave (gemma3),
chunked:global + MoE interleave (llama4), MLA+MoE (deepseek-v2),
mamba:attention hybrid (jamba), and pure SSD (mamba2).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ArchConfig, LayerSpec
from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import pshard
from repro.models import ssm as ssm_mod
from repro.models.attention import RopeTable
from repro.models.common import (
    apply_norm,
    dense_init,
    dtype_of,
    embed_init,
    init_norm,
    rope_frequencies,
)


# ---------------------------------------------------------------------------
# Rope tables
# ---------------------------------------------------------------------------


def build_ropes(cfg: ArchConfig) -> Dict[str, RopeTable]:
    tables = {}
    specs = [s.attn for s in cfg.all_layers() if s.attn is not None]
    if not specs:
        return tables
    a = specs[0]
    inv, rot = rope_frequencies(a.head_dim, cfg.rope_theta, a.rope_frac)
    tables["global"] = RopeTable(inv, rot)
    if cfg.rope_theta_local:
        inv_l, rot_l = rope_frequencies(a.head_dim, cfg.rope_theta_local, a.rope_frac)
        tables["local"] = RopeTable(inv_l, rot_l)
    mla = [s for s in specs if s.is_mla]
    if mla:
        inv_m, rot_m = rope_frequencies(mla[0].rope_dim, cfg.rope_theta, 1.0)
        tables["mla"] = RopeTable(inv_m, rot_m)
    return tables


def _rope_for(cfg: ArchConfig, spec: LayerSpec, ropes) -> Optional[RopeTable]:
    a = spec.attn
    if a is None or not a.rope and not a.is_mla:
        return None
    if a.is_mla:
        return ropes.get("mla")
    if not a.rope:
        return None
    if a.kind == "sliding" and "local" in ropes:
        return ropes["local"]
    return ropes.get("global")


# ---------------------------------------------------------------------------
# Per-layer init / apply
# ---------------------------------------------------------------------------


def init_layer(key, cfg: ArchConfig, spec: LayerSpec) -> Dict:
    ks = jax.random.split(key, 4)
    dtype = dtype_of(cfg.param_dtype)
    p: Dict = {"ln1": init_norm(cfg.d_model, cfg.norm, dtype)}
    if spec.kind == "attn":
        p["attn"] = attn_mod.init_attention(ks[0], cfg.d_model, spec.attn, dtype)
    else:
        p["ssm"] = ssm_mod.init_ssm(ks[0], cfg.d_model, spec.ssm, dtype)
    if spec.mlp.kind != "none":
        p["ln2"] = init_norm(cfg.d_model, cfg.norm, dtype)
        if spec.mlp.kind == "dense":
            p["mlp"] = mlp_mod.init_mlp(ks[1], cfg.d_model, spec.mlp, dtype)
        else:
            p["moe"] = moe_mod.init_moe(ks[1], cfg.d_model, spec.mlp.moe, dtype)
    return p


def apply_layer(
    p: Dict,
    x: jnp.ndarray,
    cfg: ArchConfig,
    spec: LayerSpec,
    ropes,
    positions,
    mode: str,
    cache: Optional[Dict] = None,
    mla_absorb: bool = True,
    seq_shard: bool = False,
    explicit_tp: bool = False,
    name_outputs: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    """Returns (x, new_cache, moe_aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if mode != "decode":
        # residual stream: optionally Megatron-style sequence-parallel —
        # sharded over ("model", sequence) between layers so the per-layer
        # boundary collective is a reduce-scatter + all-gather pair instead
        # of a full all-reduce (§Perf iteration; see EXPERIMENTS.md).
        if seq_shard:
            x = pshard.constrain(x, pshard.dp(), "model", None)
        else:
            x = pshard.constrain(x, pshard.dp(), None, None)
    h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
    rope = _rope_for(cfg, spec, ropes)
    new_cache = cache
    if spec.kind == "attn":
        if mode == "decode":
            y, new_cache = attn_mod.attention_decode(
                p["attn"], h, spec.attn, rope, cache, mla_absorb=mla_absorb
            )
        else:
            y = attn_mod.attention_fwd(p["attn"], h, spec.attn, rope, positions)
            if mode == "prefill":
                new_cache = _write_prefill_cache(p["attn"], h, spec, rope, positions)
    else:
        if mode == "decode":
            y, new_cache = ssm_mod.ssm_decode(p["ssm"], h, spec.ssm, cache)
        elif mode == "prefill":
            y, hstate, conv_tail = _ssm_prefill(p["ssm"], h, spec)
            new_cache = {"h": hstate, "conv": conv_tail}
        else:
            y = ssm_mod.ssm_fwd(p["ssm"], h, spec.ssm)
    if name_outputs and mode == "train":
        # sequence-shard the saved branch output so the remat residual is
        # 1/TP-sized, then mark it saveable: the backward replay reuses it
        # instead of re-running the branch matmuls AND their all-reduces
        y = pshard.constrain(y, pshard.dp(), "model", None)
        y = checkpoint_name(y, "branch_out")
    x = x + y
    if spec.mlp.kind != "none":
        h = apply_norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
        if spec.mlp.kind == "dense":
            y = mlp_mod.mlp_fwd(
                p["mlp"], h, spec.mlp,
                explicit_tp=explicit_tp and mode != "decode",
            )
        else:
            y, metrics = moe_mod.moe_fwd(p["moe"], h, spec.mlp.moe)
            aux = metrics["aux_loss"]
        if name_outputs and mode == "train":
            y = pshard.constrain(y, pshard.dp(), "model", None)
            y = checkpoint_name(y, "branch_out")
        x = x + y
    return x, new_cache, aux


# --- prefill-cache writers --------------------------------------------------


def _write_prefill_cache(p, h, spec: LayerSpec, rope, positions):
    """Compute K/V (or latents) for the whole prompt and lay them out in
    ring order so decode can continue."""
    a = spec.attn
    S = h.shape[1]
    L = a.cache_len(S)
    if a.is_mla:
        c_kv = jnp.einsum("bsd,dr->bsr", h, p["w_dkv"])
        k_rope = jnp.einsum("bsd,de->bse", h, p["w_k_rope"])
        if rope is not None:
            k_rope = attn_mod.apply_rope(
                k_rope[:, :, None, :], positions[None], rope.inv_freq, rope.rot
            )[:, :, 0]
        c_kv, k_rope = (_ring_layout(t, L) for t in (c_kv, k_rope))
        return {"c_kv": c_kv, "k_rope": k_rope, "index": jnp.asarray(S, jnp.int32)}
    k = jnp.einsum("bsd,dhe->bshe", h, p["w_k"])
    v = jnp.einsum("bsd,dhe->bshe", h, p["w_v"])
    if a.qk_norm:
        k = attn_mod.rms_norm_headwise(p["k_norm"], k)
    if a.rope and rope is not None:
        k = attn_mod.apply_rope(k, positions[None], rope.inv_freq, rope.rot)
    k, v = _ring_layout(k, L), _ring_layout(v, L)
    return {"k": k, "v": v, "index": jnp.asarray(S, jnp.int32)}


def _ring_layout(t: jnp.ndarray, L: int) -> jnp.ndarray:
    """Keep the last L positions of (B, S, ...) laid out so that position p
    sits in slot p % L (matching the decode ring buffer)."""
    S = t.shape[1]
    if L >= S:
        return t if L == S else jnp.pad(t, [(0, 0), (0, L - S)] + [(0, 0)] * (t.ndim - 2))
    tail = t[:, S - L :]
    return jnp.roll(tail, shift=(S - L) % L, axis=1)


def _ssm_prefill(p, h, spec: LayerSpec):
    out, hstate = ssm_mod.ssm_fwd(p, h, spec.ssm, return_state=True)
    # conv tail: last (W-1) pre-activation conv inputs
    z, xbc, _ = ssm_mod._split_in(p, h, spec.ssm)
    tail = xbc[:, -(spec.ssm.conv_width - 1) :]
    return out, hstate, tail


# ---------------------------------------------------------------------------
# Whole-model params
# ---------------------------------------------------------------------------


def init_params(key, cfg: ArchConfig) -> Dict:
    dtype = dtype_of(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    params: Dict = {
        "embed": embed_init(keys[0], (cfg.vocab_size, cfg.d_model), dtype),
        "final_norm": init_norm(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], (cfg.d_model, cfg.vocab_size), 0, dtype)
    if cfg.prefix:
        params["prefix"] = tuple(
            init_layer(jax.random.fold_in(keys[2], i), cfg, s)
            for i, s in enumerate(cfg.prefix)
        )
    # stacked pattern blocks: leaf shape (repeats, ...)
    blocks = []
    for pi, spec in enumerate(cfg.pattern):
        def one(k, spec=spec):
            return init_layer(k, cfg, spec)

        ks = jax.random.split(jax.random.fold_in(keys[3], pi), cfg.repeats)
        blocks.append(jax.vmap(one)(ks))
    params["blocks"] = tuple(blocks)
    if cfg.remainder:
        params["remainder"] = tuple(
            init_layer(jax.random.fold_in(keys[4], i), cfg, s)
            for i, s in enumerate(cfg.remainder)
        )
    if cfg.frontend != "none":
        # projector stub: frontend embeddings are already d_model-sized; a
        # learned affine keeps the projector trainable without a real ViT.
        params["frontend_proj"] = dense_init(
            keys[5], (cfg.d_model, cfg.d_model), 0, dtype
        )
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill) with pattern scan
# ---------------------------------------------------------------------------


def _embed_tokens(params, cfg: ArchConfig, tokens, extra_embeds):
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    if extra_embeds is not None:
        fe = jnp.einsum("bpd,de->bpe", extra_embeds.astype(x.dtype), params["frontend_proj"])
        x = jnp.concatenate([fe, x], axis=1)
    return pshard.constrain(x, pshard.dp(), None, None)


def forward(
    params: Dict,
    cfg: ArchConfig,
    tokens: jnp.ndarray,  # (B, S_text)
    extra_embeds: Optional[jnp.ndarray] = None,  # (B, P, d) stub frontend
    mode: str = "train",
    remat: bool = True,
    caches: Optional[Dict] = None,
    seq_shard: bool = False,
    explicit_tp: bool = False,
    remat_save_outputs: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[Dict]]:
    """Returns (final_hidden (B,S,d), total_moe_aux, caches|None)."""
    x = _embed_tokens(params, cfg, tokens, extra_embeds)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    ropes = build_ropes(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    out_caches: Dict = {}

    def run_layer(p, x, spec, cache=None):
        return apply_layer(
            p, x, cfg, spec, ropes, positions, mode, cache,
            seq_shard=seq_shard, explicit_tp=explicit_tp,
            name_outputs=remat_save_outputs,
        )

    for i, spec in enumerate(cfg.prefix):
        x, c, aux = run_layer(params["prefix"][i], x, spec)
        aux_total += aux
        if mode == "prefill":
            out_caches.setdefault("prefix", []).append(c)

    def scan_body(carry, block_params):
        x, aux = carry
        caches_out = []
        for pi, spec in enumerate(cfg.pattern):
            x, c, a = run_layer(block_params[pi], x, spec)
            aux = aux + a
            caches_out.append(c)
        outs = tuple(caches_out) if mode == "prefill" else None
        return (x, aux), outs

    if remat and mode == "train":
        if remat_save_outputs:
            policy = jax.checkpoint_policies.save_only_these_names("branch_out")
            body = jax.checkpoint(scan_body, policy=policy)
        else:
            body = jax.checkpoint(scan_body)
    else:
        body = scan_body
    (x, aux_total), block_caches = jax.lax.scan(
        body, (x, aux_total), params["blocks"]
    )
    if mode == "prefill":
        out_caches["blocks"] = block_caches

    for i, spec in enumerate(cfg.remainder):
        x, c, aux = run_layer(params["remainder"][i], x, spec)
        aux_total += aux
        if mode == "prefill":
            out_caches.setdefault("remainder", []).append(c)

    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    return x, aux_total, (out_caches if mode == "prefill" else None)


# ---------------------------------------------------------------------------
# Logits / loss
# ---------------------------------------------------------------------------


def unembed(params, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    if cfg.logits_softcap:
        c = cfg.logits_softcap
        logits = jnp.tanh(logits / c) * c
    return logits


def lm_loss(
    params: Dict,
    cfg: ArchConfig,
    x_final: jnp.ndarray,  # (B, S, d)
    labels: jnp.ndarray,  # (B, S) int32; -1 = ignore
    vocab_chunk: int = 0,
) -> jnp.ndarray:
    """Mean causal-LM cross entropy. ``vocab_chunk`` > 0 scans over sequence
    chunks so only (B, chunk, V) logits are ever live (needed for 256k-vocab
    archs at 4k sequence)."""
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    valid = (labels >= 0).astype(jnp.float32)
    safe_labels = jnp.maximum(labels, 0)

    def chunk_loss(xc, lc, vc):
        logits = jnp.einsum("bsd,dv->bsv", xc, w)
        logits = pshard.constrain(logits, pshard.dp(), None, "model")
        logits = logits.astype(jnp.float32)
        if cfg.logits_softcap:
            logits = jnp.tanh(logits / cfg.logits_softcap) * cfg.logits_softcap
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * vc)

    S = x_final.shape[1]
    if vocab_chunk and S > vocab_chunk and S % vocab_chunk == 0:
        nc = S // vocab_chunk
        xcs = x_final.reshape(x_final.shape[0], nc, vocab_chunk, -1).swapaxes(0, 1)
        lcs = safe_labels.reshape(labels.shape[0], nc, vocab_chunk).swapaxes(0, 1)
        vcs = valid.reshape(valid.shape[0], nc, vocab_chunk).swapaxes(0, 1)

        def body(tot, inp):
            xc, lc, vc = inp
            return tot + chunk_loss(xc, lc, vc), None

        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xcs, lcs, vcs))
    else:
        total = chunk_loss(x_final, safe_labels, valid)
    return total / jnp.maximum(valid.sum(), 1.0)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def init_decode_caches(cfg: ArchConfig, batch: int, seq_len: int) -> Dict:
    """Caches for every layer at context length seq_len (ShapeDtypeStruct-
    compatible: built from jnp.zeros; dryrun uses jax.eval_shape on this)."""
    dtype = dtype_of(cfg.compute_dtype)

    def one(spec: LayerSpec):
        if spec.kind == "attn":
            return attn_mod.init_cache(spec.attn, batch, seq_len, dtype)
        return ssm_mod.init_ssm_cache(spec.ssm, batch, dtype)

    caches: Dict = {}
    if cfg.prefix:
        caches["prefix"] = [one(s) for s in cfg.prefix]
    blocks = []
    for spec in cfg.pattern:
        c = one(spec)
        blocks.append(jax.tree.map(lambda t: jnp.stack([t] * cfg.repeats), c))
    caches["blocks"] = tuple(blocks)
    if cfg.remainder:
        caches["remainder"] = [one(s) for s in cfg.remainder]
    return caches


def decode_step(
    params: Dict,
    cfg: ArchConfig,
    caches: Dict,
    token: jnp.ndarray,  # (B, 1) int32
    mla_absorb: bool = True,
) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode. Returns (logits (B,1,V), new caches)."""
    x = params["embed"][token]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    ropes = build_ropes(cfg)
    positions = None  # decode positions come from cache indices
    new_caches: Dict = {}

    def run_layer(p, x, spec, cache):
        return apply_layer(
            p, x, cfg, spec, ropes, positions, "decode", cache, mla_absorb=mla_absorb
        )

    if cfg.prefix:
        new_caches["prefix"] = []
        for i, spec in enumerate(cfg.prefix):
            x, c, _ = run_layer(params["prefix"][i], x, spec, caches["prefix"][i])
            new_caches["prefix"].append(c)

    def scan_body(x, xs):
        block_params, block_caches = xs
        new_cs = []
        for pi, spec in enumerate(cfg.pattern):
            x, c, _ = run_layer(block_params[pi], x, spec, block_caches[pi])
            new_cs.append(c)
        return x, tuple(new_cs)

    x, block_caches = jax.lax.scan(
        scan_body, x, (params["blocks"], caches["blocks"])
    )
    new_caches["blocks"] = block_caches

    if cfg.remainder:
        new_caches["remainder"] = []
        for i, spec in enumerate(cfg.remainder):
            x, c, _ = run_layer(params["remainder"][i], x, spec, caches["remainder"][i])
            new_caches["remainder"].append(c)

    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    logits = unembed(params, cfg, x)
    return logits, new_caches
