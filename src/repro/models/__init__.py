"""Model zoo: pattern-scan transformer, SSD, enc-dec, CNN, factory API."""
from repro.models.factory import Model, build, input_specs, synth_batch  # noqa: F401
