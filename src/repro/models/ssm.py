"""Mamba2 (SSD — state-space duality) block. [arXiv:2405.21060]

Train/prefill uses the chunked dual form: quadratic attention-like compute
within chunks of length ``chunk`` + a linear recurrence across chunks
(`lax.scan` carrying the (heads, head_dim, d_state) state). Decode is the
O(1) single-step recurrence. ``repro.kernels.ssd_scan`` is the Pallas TPU
kernel of the same chunked schedule; this module is its reference
semantics (shared with kernels/ref.py).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SSMSpec
from repro.models import pshard
from repro.models.common import dense_init


def init_ssm(key, d_model: int, spec: SSMSpec, dtype) -> Dict:
    ks = jax.random.split(key, 6)
    di, ds, nh = spec.d_inner, spec.d_state, spec.num_heads
    conv_ch = di + 2 * ds
    return {
        # in_proj -> [z (di), x (di), B (ds), C (ds), dt (nh)]
        "w_in": dense_init(ks[0], (d_model, 2 * di + 2 * ds + nh), 0, dtype),
        "conv_w": (jax.random.normal(ks[1], (spec.conv_width, conv_ch)) * 0.1).astype(
            dtype
        ),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.full((nh,), np.log(np.expm1(0.01)), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "w_out": dense_init(ks[2], (di, d_model), 0, dtype),
    }


def _split_in(p, x, spec: SSMSpec):
    di, ds, nh = spec.d_inner, spec.d_state, spec.num_heads
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z = proj[..., :di]
    xbc = proj[..., di : di + di + 2 * ds]
    dt_raw = proj[..., di + di + 2 * ds :]
    return z, xbc, dt_raw


def _causal_conv(p, xbc, spec: SSMSpec):
    """Depthwise causal conv via shifted adds (width is tiny)."""
    w = p["conv_w"]  # (W, ch)
    W = w.shape[0]
    out = xbc * w[W - 1]
    for i in range(W - 1):
        shift = W - 1 - i
        shifted = jnp.pad(xbc, ((0, 0), (shift, 0), (0, 0)))[:, : xbc.shape[1]]
        out = out + shifted * w[i]
    return jax.nn.silu(out + p["conv_b"])


def _gated_norm(p, y, z, eps=1e-5):
    g = y * jax.nn.silu(z)
    gf = g.astype(jnp.float32)
    var = jnp.mean(gf * gf, axis=-1, keepdims=True)
    return (gf * jax.lax.rsqrt(var + eps)).astype(y.dtype) * p["norm_scale"]


def ssd_chunked(
    x: jnp.ndarray,  # (B, S, nh, hd)
    dt: jnp.ndarray,  # (B, S, nh)  post-softplus
    A: jnp.ndarray,  # (nh,) negative
    B_: jnp.ndarray,  # (B, S, ds)
    C_: jnp.ndarray,  # (B, S, ds)
    chunk: int,
    h0: Optional[jnp.ndarray] = None,  # (B, nh, hd, ds)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan. Returns (y (B,S,nh,hd), h_final)."""
    Bb, S, nh, hd = x.shape
    ds = B_.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L

    xr = x.reshape(Bb, nc, L, nh, hd).transpose(1, 0, 2, 3, 4)  # (nc,B,L,nh,hd)
    dtr = dt.reshape(Bb, nc, L, nh).transpose(1, 0, 2, 3)
    Br = B_.reshape(Bb, nc, L, ds).transpose(1, 0, 2, 3)
    Cr = C_.reshape(Bb, nc, L, ds).transpose(1, 0, 2, 3)

    if h0 is None:
        h0 = jnp.zeros((Bb, nh, hd, ds), jnp.float32)

    mask = jnp.tril(jnp.ones((L, L), jnp.bool_))

    def per_chunk(h, inp):
        xc, dtc, Bc, Cc = inp  # (B,L,nh,hd) (B,L,nh) (B,L,ds) (B,L,ds)
        l = dtc.astype(jnp.float32) * A  # (B,L,nh), negative
        cs = jnp.cumsum(l, axis=1)  # inclusive
        total = cs[:, -1]  # (B,nh)
        # intra-chunk (dual / attention-like) term
        cb = jnp.einsum("bin,bjn->bij", Cc.astype(jnp.float32), Bc.astype(jnp.float32))
        decay = jnp.exp(cs[:, :, None, :] - cs[:, None, :, :])  # (B,i,j,nh)
        scores = cb[..., None] * decay * dtc[:, None, :, :]  # (B,i,j,nh)
        scores = jnp.where(mask[None, :, :, None], scores, 0.0)
        y_intra = jnp.einsum("bijh,bjhp->bihp", scores, xc.astype(jnp.float32))
        # inter-chunk term from carried state
        y_inter = jnp.exp(cs)[:, :, :, None] * jnp.einsum(
            "bin,bhpn->bihp", Cc.astype(jnp.float32), h
        )
        # state update
        w = jnp.exp(total[:, None, :] - cs) * dtc  # (B,L,nh)
        h_chunk = jnp.einsum("blh,blhp,bln->bhpn", w, xc.astype(jnp.float32), Bc.astype(jnp.float32))
        h_new = jnp.exp(total)[:, :, None, None] * h + h_chunk
        return h_new, y_intra + y_inter

    h_final, yr = jax.lax.scan(per_chunk, h0, (xr, dtr, Br, Cr))
    y = yr.transpose(1, 0, 2, 3, 4).reshape(Bb, S, nh, hd)
    return y, h_final


def ssd_reference(x, dt, A, B_, C_, h0=None):
    """Naive step-by-step recurrence (oracle for tests)."""
    Bb, S, nh, hd = x.shape
    ds = B_.shape[-1]
    h = jnp.zeros((Bb, nh, hd, ds), jnp.float32) if h0 is None else h0

    def step(h, inp):
        xt, dtt, Bt, Ct = inp  # (B,nh,hd) (B,nh) (B,ds) (B,ds)
        a = jnp.exp(dtt.astype(jnp.float32) * A)  # (B,nh)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dtt.astype(jnp.float32), xt.astype(jnp.float32), Bt.astype(jnp.float32))
        h = a[:, :, None, None] * h + upd
        y = jnp.einsum("bn,bhpn->bhp", Ct.astype(jnp.float32), h)
        return h, y

    xs = (
        x.transpose(1, 0, 2, 3),
        dt.transpose(1, 0, 2),
        B_.transpose(1, 0, 2),
        C_.transpose(1, 0, 2),
    )
    h, ys = jax.lax.scan(step, h, xs)
    return ys.transpose(1, 0, 2, 3), h


def ssm_fwd(
    p: Dict, x: jnp.ndarray, spec: SSMSpec, h0=None, return_state: bool = False
):
    """Full-sequence mamba2 block. x: (B,S,d_model)."""
    di, ds, nh, hd = spec.d_inner, spec.d_state, spec.num_heads, spec.head_dim
    z, xbc, dt_raw = _split_in(p, x, spec)
    dpax = pshard.dp()
    z = pshard.constrain(z, dpax, None, "model")
    # depthwise conv: channel-sharded is fine (no cross-channel mixing)
    xbc = pshard.constrain(xbc, dpax, None, "model")
    xbc = _causal_conv(p, xbc, spec)
    xin = xbc[..., :di]
    B_ = xbc[..., di : di + ds]
    C_ = xbc[..., di + ds :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xin.reshape(*xin.shape[:2], nh, hd)
    xh = pshard.constrain(xh, dpax, None, "model", None)  # head parallel
    dt = pshard.constrain(dt, dpax, None, "model")
    y, h = ssd_chunked(xh, dt, A, B_, C_, spec.chunk, h0)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(*x.shape[:2], di).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", _gated_norm(p, y, z), p["w_out"])
    if return_state:
        return out, h
    return out


# ---------------------------------------------------------------------------
# Decode (O(1) recurrence)
# ---------------------------------------------------------------------------


def init_ssm_cache(spec: SSMSpec, batch: int, dtype) -> Dict:
    conv_ch = spec.d_inner + 2 * spec.d_state
    return {
        "h": jnp.zeros((batch, spec.num_heads, spec.head_dim, spec.d_state), jnp.float32),
        "conv": jnp.zeros((batch, spec.conv_width - 1, conv_ch), dtype),
    }


def ssm_decode(p: Dict, x: jnp.ndarray, spec: SSMSpec, cache: Dict):
    """x: (B, 1, d_model) -> (y, cache)."""
    di, ds, nh, hd = spec.d_inner, spec.d_state, spec.num_heads, spec.head_dim
    z, xbc, dt_raw = _split_in(p, x, spec)  # (B,1,·)
    # conv over [cache, current]
    w = p["conv_w"]
    hist = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B, W, ch)
    conv_out = jnp.einsum("bwc,wc->bc", hist, w) + p["conv_b"]
    xbc1 = jax.nn.silu(conv_out)[:, None]  # (B,1,ch)
    xin = xbc1[..., :di]
    B_ = xbc1[..., di : di + ds][:, 0]
    C_ = xbc1[..., di + ds :][:, 0]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,nh)
    A = -jnp.exp(p["A_log"])
    xh = xin.reshape(x.shape[0], nh, hd)
    a = jnp.exp(dt * A)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xh.astype(jnp.float32), B_.astype(jnp.float32))
    h = a[:, :, None, None] * cache["h"] + upd
    y = jnp.einsum("bn,bhpn->bhp", C_.astype(jnp.float32), h)
    y = y + p["D"][None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(x.shape[0], 1, di).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", _gated_norm(p, y, z), p["w_out"])
    return out, {"h": h, "conv": hist[:, 1:]}
