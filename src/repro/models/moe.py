"""Mixture-of-experts layer with capacity-based dispatch (GShard/Switch
style), shared experts (deepseek-v2 / llama4), and the Switch load-balance
auxiliary loss.

Dispatch layout: tokens are reshaped to (nb, G, d) — ``nb`` group-batches
sharded over the data axis, G = ``group_size`` tokens each. The dispatch /
combine one-hots are (nb, G, E, C) with per-group capacity
C = max(G*top_k*capacity_factor/E, top_k), built with a top_k-step loop so
no (·, K, E, C) intermediate exists, in bf16. Expert compute is batched
einsums with E sharded over the "model" axis (expert parallelism); the
token<->expert exchange lowers to all-to-all-style collectives under
GSPMD. Dispatch-einsum flop overhead vs expert flops is reported by the
roofline (see EXPERIMENTS.md).

The router is the *load-balancing* twin of the paper's scheduler: both
equalize work across parallel workers; benchmarks compare the router
balance metrics with the client-selection Var[X] metric.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoESpec
from repro.models import pshard
from repro.models.common import activation, dense_init

DEFAULT_GROUP = 128


def init_moe(key, d_model: int, spec: MoESpec, dtype) -> Dict:
    ks = jax.random.split(key, 8)
    E, F = spec.num_experts, spec.d_ff_expert
    p = {
        "router": dense_init(ks[0], (d_model, E), 0, jnp.float32),
        "w_in": dense_init(ks[1], (E, d_model, F), 1, dtype),
        "w_gate": dense_init(ks[2], (E, d_model, F), 1, dtype),
        "w_out": dense_init(ks[3], (E, F, d_model), 1, dtype),
    }
    if spec.num_shared:
        Fs = spec.d_ff_shared * spec.num_shared
        p["shared_in"] = dense_init(ks[4], (d_model, Fs), 0, dtype)
        p["shared_gate"] = dense_init(ks[5], (d_model, Fs), 0, dtype)
        p["shared_out"] = dense_init(ks[6], (Fs, d_model), 0, dtype)
    return p


def _capacity(group: int, spec: MoESpec) -> int:
    c = int(group * spec.top_k * spec.capacity_factor / spec.num_experts)
    return max(min(c, group), spec.top_k)


def moe_fwd(
    p: Dict, x: jnp.ndarray, spec: MoESpec, group_size: int = DEFAULT_GROUP
) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, S, d) -> (y, metrics). Tokens beyond expert capacity are
    dropped (they still contribute through shared experts + residual)."""
    B, S, d = x.shape
    T = B * S
    G = min(group_size, T)
    assert T % G == 0, (T, G)
    nb = T // G
    E, K = spec.num_experts, spec.top_k
    C = _capacity(G, spec)
    dpax = pshard.dp()

    xt = x.reshape(T, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gate_k, idx_k = jax.lax.top_k(probs, K)  # (T, K)
    gate_k = gate_k / jnp.maximum(gate_k.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss over the whole batch
    me = probs.mean(axis=0)
    onehot_any = jax.nn.one_hot(idx_k, E, dtype=jnp.float32).sum(axis=1)
    ce = onehot_any.mean(axis=0) / K
    aux_loss = E * jnp.sum(me * ce)

    cdt = x.dtype
    xg = pshard.constrain(xt.reshape(nb, G, d), dpax, None, None)
    idx_g = idx_k.reshape(nb, G, K)
    gate_g = gate_k.reshape(nb, G, K)

    # build dispatch/combine (nb, G, E, C) via a K-step loop
    counts = jnp.zeros((nb, 1, E), jnp.float32)
    dispatch = jnp.zeros((nb, G, E, C), cdt)
    combine = jnp.zeros((nb, G, E, C), cdt)
    for k in range(K):
        oh = jax.nn.one_hot(idx_g[..., k], E, dtype=jnp.float32)  # (nb,G,E)
        pos = counts + jnp.cumsum(oh, axis=1) - oh  # exclusive position
        pos = jnp.where(oh > 0, pos, C)  # out-of-range -> one_hot gives 0
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=cdt)  # (nb,G,E,C)
        dispatch = dispatch + pos_oh
        combine = combine + gate_g[..., k, None, None].astype(cdt) * pos_oh
        counts = counts + oh.sum(axis=1, keepdims=True)
    dispatch = pshard.constrain(dispatch, dpax, None, "model", None)
    combine = pshard.constrain(combine, dpax, None, "model", None)

    xe = jnp.einsum("ngec,ngd->necd", dispatch, xg)  # (nb,E,C,d)
    xe = pshard.constrain(xe, dpax, "model", None, None)  # expert parallel
    act = activation("silu")
    h = jnp.einsum("necd,edf->necf", xe, p["w_in"])
    g = jnp.einsum("necd,edf->necf", xe, p["w_gate"])
    ye = jnp.einsum("necf,efd->necd", act(g) * h, p["w_out"])
    ye = pshard.constrain(ye, dpax, "model", None, None)
    y = jnp.einsum("ngec,necd->ngd", combine, ye).reshape(B, S, d)

    if "shared_in" in p:
        h = pshard.constrain(jnp.einsum("bsd,df->bsf", x, p["shared_in"]), dpax, None, "model")
        g = pshard.constrain(jnp.einsum("bsd,df->bsf", x, p["shared_gate"]), dpax, None, "model")
        y = y + jnp.einsum("bsf,fd->bsd", act(g) * h, p["shared_out"])

    dispatched = dispatch.astype(jnp.float32).sum()
    metrics = {
        "aux_loss": aux_loss,
        "drop_frac": 1.0 - dispatched / (T * K),
        "router_entropy": -jnp.sum(me * jnp.log(me + 1e-9)),
    }
    return y.astype(x.dtype), metrics
