"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any device query).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e pod slice: 16x16 = 256 chips per pod; 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Tiny mesh over whatever devices exist (CPU tests)."""
    n = len(jax.devices())
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))
