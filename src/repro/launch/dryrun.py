import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) pair.

Proves the distribution config is coherent without hardware: builds the
production mesh from 512 placeholder host devices, lowers the appropriate
step (train / prefill / decode) against ShapeDtypeStruct inputs with the
framework's sharding rules, compiles, and records memory_analysis(),
cost_analysis() and the HLO collective schedule into a JSON artifact that
benchmarks/bench_roofline.py and EXPERIMENTS.md consume.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import sharding as shard_rules  # noqa: E402
from repro.configs import INPUT_SHAPES, all_archs, get_arch, shape_applicable  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import factory  # noqa: E402
from repro.roofline import collective_bytes_from_hlo, model_flops, roofline_terms  # noqa: E402
from repro.roofline import hlo_cost  # noqa: E402

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts")


def _params_sds(model):
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def _named(tree_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs, is_leaf=lambda x: isinstance(x, P)
    )


def lower_pair(
    arch_name: str,
    shape_name: str,
    multi_pod: bool,
    mla_absorb: bool = True,
    seq_parallel: bool = False,
    explicit_tp: bool = False,
    remat_save_outputs: bool = False,
    extra_tags: str = "",
) -> Dict:
    cfg = get_arch(arch_name)
    shape = INPUT_SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {
            "arch": arch_name, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "status": "skipped", "reason": why,
        }
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = factory.build(
        cfg, mla_absorb=mla_absorb, seq_parallel=seq_parallel,
        explicit_tp=explicit_tp, remat_save_outputs=remat_save_outputs,
    )
    specs = factory.input_specs(cfg, shape)
    p_sds = _params_sds(model)
    p_spec = shard_rules.params_pspecs(p_sds, mesh)

    t0 = time.time()
    if shape.mode == "train":
        b_spec = shard_rules.batch_pspecs(specs, mesh)
        fn = lambda params, batch, lr: model.sgd_train_step(params, batch, lr)
        in_sh = (_named(p_spec, mesh), _named(b_spec, mesh), None)
        out_sh = (_named(p_spec, mesh), None)
        args = (p_sds, specs, jax.ShapeDtypeStruct((), jnp.float32))
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
    elif shape.mode == "prefill":
        b_spec = shard_rules.batch_pspecs(specs, mesh)
        if cfg.encoder is not None:
            specs = dict(specs)
            specs.pop("labels", None)
            specs["seq_len"] = shape.seq_len
            b_spec = shard_rules.batch_pspecs(
                {k: v for k, v in specs.items() if k != "seq_len"}, mesh
            )
            fn = lambda params, batch: model.prefill(params, {**batch, "seq_len": shape.seq_len})
            args = (p_sds, {k: v for k, v in specs.items() if k != "seq_len"})
        else:
            fn = lambda params, batch: model.prefill(params, batch)
            args = (p_sds, specs)
        in_sh = (_named(p_spec, mesh), _named(b_spec, mesh))
        jitted = jax.jit(fn, in_shardings=in_sh)
    else:  # decode
        cache_sds = specs["caches"]
        c_spec = shard_rules.cache_pspecs(cache_sds, mesh)
        tok_spec = shard_rules.batch_pspecs({"token": specs["token"]}, mesh)["token"]
        fn = lambda params, caches, token: model.decode_step(params, caches, token)
        in_sh = (_named(p_spec, mesh), _named(c_spec, mesh), NamedSharding(mesh, tok_spec))
        out_sh = (None, _named(c_spec, mesh))
        args = (p_sds, cache_sds, specs["token"])
        # donate the cache: serving updates it in place (without donation
        # XLA copies the full stacked cache every scanned layer — §Perf)
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=(1,))

    from repro.models import pshard

    with mesh, pshard.mesh_context(mesh):
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_info = {}
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        v = getattr(mem, attr, None)
        if v is not None:
            mem_info[attr] = int(v)
    cost = compiled.cost_analysis() or {}
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    # while-loop-aware HLO cost model (cost_analysis counts loop bodies
    # once; see roofline/hlo_cost.py) — primary source for the roofline.
    hc = hlo_cost.analyze(hlo)
    flops = hc["flops"]
    bytes_acc = hc["bytes"]
    coll = {k: int(v) for k, v in hc["collectives"].items()}

    # roofline
    chips = 512 if multi_pod else 256
    terms = roofline_terms(flops, bytes_acc, coll)
    tokens = shape.global_batch * (shape.seq_len if shape.mode == "train" else 1)
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
    n_params = cfg.active_param_count()
    mf = model_flops(n_params, tokens, "train" if shape.mode == "train" else "serve")
    useful = mf / (flops * chips) if flops else 0.0

    result = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "tags": extra_tags,
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": flops,
        "bytes_per_device": bytes_acc,
        "raw_cost_analysis": {"flops": raw_flops, "bytes_accessed": raw_bytes},
        "memory_analysis": mem_info,
        "collectives": coll,
        "roofline": terms,
        "model_flops_global": mf,
        "useful_flops_ratio": useful,
        "params_total": cfg.param_count(),
        "params_active": n_params,
    }
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true", help="2x16x16 mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-mla-absorb", action="store_true",
                    help="naive MLA decode (roofline baseline)")
    ap.add_argument("--seq-parallel", action="store_true",
                    help="sequence-parallel residual (Megatron SP; §Perf)")
    ap.add_argument("--explicit-tp", action="store_true",
                    help="shard_map MLP with explicit bf16 psum (§Perf)")
    ap.add_argument("--remat-save-outputs", action="store_true",
                    help="remat policy: save seq-sharded branch outputs so the "
                         "backward replay skips forward matmuls + ARs (§Perf)")
    ap.add_argument("--tags", default="")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    os.makedirs(args.out or ARTIFACT_DIR, exist_ok=True)
    outdir = args.out or ARTIFACT_DIR

    pairs = []
    if args.all:
        for a in sorted(all_archs()):
            for s in INPUT_SHAPES:
                pairs.append((a, s))
    else:
        pairs.append((args.arch, args.shape))
    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    results = []
    for a, s in pairs:
        for mp in meshes:
            tag = f"{a}.{s}.{'mp' if mp else 'sp'}"
            if args.no_mla_absorb:
                tag += ".noabsorb"
            if args.seq_parallel:
                tag += ".seqpar"
            if args.explicit_tp:
                tag += ".exptp"
            if args.remat_save_outputs:
                tag += ".rematout"
            if args.tags:
                tag += f".{args.tags}"  # keep tagged runs from clobbering baselines
            print(f"=== {tag} ===", flush=True)
            try:
                r = lower_pair(a, s, mp, mla_absorb=not args.no_mla_absorb,
                               seq_parallel=args.seq_parallel,
                               explicit_tp=args.explicit_tp,
                               remat_save_outputs=args.remat_save_outputs,
                               extra_tags=args.tags or
                               ("rematout" if args.remat_save_outputs else "") or
                               ("seqpar" if args.seq_parallel else "") or
                               ("exptp" if args.explicit_tp else "") or
                               ("noabsorb" if args.no_mla_absorb else ""))
            except Exception as e:
                traceback.print_exc()
                r = {"arch": a, "shape": s,
                     "mesh": "2x16x16" if mp else "16x16",
                     "status": "error", "error": f"{type(e).__name__}: {e}"}
            results.append(r)
            fn = os.path.join(outdir, f"dryrun_{tag}.json")
            with open(fn, "w") as f:
                json.dump(r, f, indent=1)
            if r["status"] == "ok":
                rf = r["roofline"]
                print(
                    f"  ok: lower {r['lower_s']}s compile {r['compile_s']}s | "
                    f"flops/dev {r['flops_per_device']:.3e} bytes/dev {r['bytes_per_device']:.3e} | "
                    f"compute {rf['compute_s']*1e3:.2f}ms memory {rf['memory_s']*1e3:.2f}ms "
                    f"collective {rf['collective_s']*1e3:.2f}ms -> {rf['dominant']}",
                    flush=True,
                )
            else:
                print(f"  {r['status']}: {r.get('reason', r.get('error',''))[:300]}", flush=True)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"DONE ok={n_ok} skipped={n_skip} errors={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
