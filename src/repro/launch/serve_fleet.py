"""Train-and-serve driver: one fleet, one version ring, both workloads.

Interleaves async federated training (``AsyncEngine`` chunks over a
reduced LLM arch as the FL workload) with the continuous-batching
serving loop (``repro.serve``) against the *same* ring of retained
global versions: after every training chunk the serving replicas re-pin
against a fresh ``VersionStore`` snapshot and answer an open-loop burst
of inference traffic. Reports TTFT, decode tokens/s,
staleness-of-served-version, and Var[X] over replicas per chunk.

  PYTHONPATH=src python -m repro.launch.serve_fleet --arch tinyllama-1.1b \
      --clients 32 --k 8 --rounds 8 --replicas 2 --slots 4 --router markov
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.engine import AsyncEngine, RunConfig, dump_json
from repro.fl.task import make_lm_task
from repro.models import factory
from repro.serve import ReplicaPool, VersionStore, router_names, run_serve_loop
from repro.sim import PROFILES, arrivals as arr_mod, get_profile


def main() -> None:
    ap = argparse.ArgumentParser()
    # --- training fleet ---
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    help="model zoo arch (reduced) trained federated and served")
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--m", type=int, default=10)
    ap.add_argument("--policy", default="markov")
    ap.add_argument("--rounds", type=int, default=8,
                    help="total async server steps")
    ap.add_argument("--chunk", type=int, default=4,
                    help="training steps per chunk (serving runs between chunks)")
    ap.add_argument("--max-versions", type=int, default=8)
    ap.add_argument("--latency-profile", default="lognormal",
                    choices=sorted(PROFILES))
    # --- serving tier ---
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--slots", type=int, default=4,
                    help="concurrent decode streams per replica")
    ap.add_argument("--router", default="markov", choices=sorted(router_names()))
    ap.add_argument("--stagger", type=int, default=1,
                    help="replica i pins version latest - i * stagger")
    ap.add_argument("--rate", type=float, default=1.0,
                    help="mean requests per serving tick (Poisson)")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=8,
                    help="median tokens generated per request")
    ap.add_argument("--ticks-per-chunk", type=int, default=12,
                    help="serving-trace ticks issued after each training chunk")
    ap.add_argument("--crash-rate", type=float, default=0.0,
                    help="per-tick replica crash probability (replica_crash "
                         "fault; in-flight streams fail over to survivors, "
                         "the last alive replica is spared)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg_arch = get_arch(args.arch).reduced()
    task = make_lm_task(cfg_arch, args.clients, seq_len=32, docs_per_client=4,
                        seed=args.seed)
    model = factory.build(cfg_arch)
    cfg = RunConfig(
        mode="async", n_clients=args.clients, k=args.k, m=args.m,
        policy=args.policy, rounds=args.rounds, local_epochs=1, batch_size=4,
        lr0=0.05, seed=args.seed, eval_every=args.rounds,
        max_versions=args.max_versions, profile=args.latency_profile,
        collect_history=False,
    )
    engine = AsyncEngine(task, cfg)
    state = engine.init()
    proc = arr_mod.from_profile(
        get_profile(args.latency_profile), args.rate, args.prompt_len, args.gen
    )
    # request lengths spread up to 2x the median generation length
    ctx = args.prompt_len + max(1, 2 * args.gen)
    pool = ReplicaPool(model, args.replicas, args.slots, ctx,
                       stagger=args.stagger)
    serve_faults = None
    if args.crash_rate > 0:
        from repro.faults import make_fault

        serve_faults = [make_fault("replica_crash", args.replicas,
                                   args.crash_rate)]
    print(f"train: arch={cfg_arch.name} n={args.clients} k={args.k} "
          f"policy={args.policy} steps={args.rounds} ring H={args.max_versions}")
    print(f"serve: {args.replicas} replicas x {args.slots} slots, "
          f"router={args.router}, {proc.name} rate={args.rate}/tick "
          f"prompt={args.prompt_len} gen~{args.gen}")

    key = jax.random.PRNGKey(args.seed)
    reports = []
    t_start = time.time()
    for ci, r0 in enumerate(range(0, args.rounds, args.chunk)):
        length = min(args.chunk, args.rounds - r0)
        state, aux = engine.run_chunk(state, r0, length, False)
        store = VersionStore.from_engine(engine, state)
        pool.refresh(store)
        reqs = arr_mod.sample_requests(
            jax.random.fold_in(key, ci), proc, args.ticks_per_chunk,
            cfg_arch.vocab_size,
        )
        rep = run_serve_loop(
            model, store, reqs, router=args.router, pool=pool,
            seed=args.seed + ci, faults=serve_faults,
        )
        reports.append(rep)
        loss = float(np.asarray(aux["loss"])[-1])
        print(f"  chunk {ci}: trained to v{store.latest} "
              f"(loss {loss:.4f}) | {rep.summary()}")

    results = [r for rep in reports for r in rep.results]
    ttft = [r.ttft_ticks for r in results]
    stal = [r.staleness for r in results]
    tokens = sum(rep.tokens_out for rep in reports)
    decode_wall = sum(rep.decode_wall_s for rep in reports)
    var_x = [rep.serve_stats["var_X"] for rep in reports]
    print(f"\n== serving summary ({time.time() - t_start:.1f}s wall) ==")
    print(f"streams served: {len(results)} ({tokens} tokens, "
          f"{tokens / decode_wall if decode_wall else float('nan'):.0f} tok/s decode)")
    print(f"ttft: mean={np.mean(ttft) if ttft else float('nan'):.2f} ticks "
          f"p95={np.percentile(ttft, 95) if ttft else float('nan'):.1f}")
    print(f"staleness of served version: mean={np.mean(stal) if stal else float('nan'):.2f} "
          f"max={max(stal) if stal else 0}")
    print(f"routing Var[X] per chunk: "
          f"{', '.join(f'{v:.3f}' for v in var_x)}")
    last = reports[-1].serve_stats
    print(f"per-replica E[X]: "
          f"{', '.join(f'{v:.2f}' for v in last['replica_mean_X'])}")
    crashes = sum(rep.serve_stats["crashes"] for rep in reports)
    failed_over = sum(rep.serve_stats["failed_over"] for rep in reports)
    ring_miss = reports[-1].serve_stats["ring_miss"]
    if crashes or ring_miss:
        print(f"degradation: {crashes} replica crashes, {failed_over} "
              f"streams failed over ({pool.n_alive()}/{args.replicas} "
              f"replicas alive), {ring_miss} ring-miss reads")
    if args.out:
        dump_json(args.out, {
            "cli_args": vars(args),
            "streams": len(results),
            "tokens": tokens,
            "tok_s": tokens / decode_wall if decode_wall else float("nan"),
            "ttft_ticks_mean": float(np.mean(ttft)) if ttft else float("nan"),
            "staleness_mean": float(np.mean(stal)) if stal else float("nan"),
            "staleness_max": int(max(stal)) if stal else 0,
            "serve_stats": [rep.serve_stats for rep in reports],
        })
        print("wrote", args.out)


if __name__ == "__main__":
    main()
