"""Federated training driver — the paper's experiment, end to end.

Runs FedAvg on a synthetic MNIST/CIFAR-like dataset (or a reduced LLM
workload) under a chosen selection policy and reports accuracy-vs-round
plus the load-metric statistics (Var[X], cohort sizes) against theory.

Examples:
  PYTHONPATH=src python -m repro.launch.fl_train --dataset mnist \
      --policy markov --rounds 60
  PYTHONPATH=src python -m repro.launch.fl_train --dataset mnist --noniid \
      --policy random --rounds 60
  PYTHONPATH=src python -m repro.launch.fl_train --arch tinyllama-1.1b \
      --policy markov --rounds 20        # reduced-LLM federated workload
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs.paper_cnn import CNN_CONFIGS
from repro.core import load_metric
from repro.data.synthetic import load_dataset
from repro.fl import FLConfig, make_cnn_task, make_lm_task, run_training
from repro.fl.rounds import rounds_to_target


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="mnist", choices=["mnist", "cifar10", "cifar100"])
    ap.add_argument("--arch", default=None, help="use a reduced LLM arch as the FL workload")
    ap.add_argument("--policy", default="markov")
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--k", type=int, default=15)
    ap.add_argument("--m", type=int, default=10)
    ap.add_argument("--local-epochs", type=int, default=5)
    ap.add_argument("--batch-size", type=int, default=50)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--noniid", action="store_true", help="Dirichlet(0.6) label skew")
    ap.add_argument("--data-scale", type=float, default=0.25)
    ap.add_argument("--target-acc", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.arch:
        from repro.configs import get_arch

        cfg = get_arch(args.arch).reduced()
        task = make_lm_task(cfg, args.clients, seq_len=64, docs_per_client=8, seed=args.seed)
    else:
        train, test = load_dataset(args.dataset, seed=args.seed, scale=args.data_scale)
        cnn = CNN_CONFIGS[f"paper-cnn-{args.dataset}"]
        task = make_cnn_task(
            cnn, train, test, args.clients,
            noniid_alpha=0.6 if args.noniid else None, seed=args.seed,
        )

    fl = FLConfig(
        n_clients=args.clients, k=args.k, m=args.m, policy=args.policy,
        rounds=args.rounds, local_epochs=args.local_epochs,
        batch_size=args.batch_size, lr0=args.lr, seed=args.seed,
        eval_every=max(args.rounds // 30, 1),
    )
    print(f"policy={args.policy} n={fl.n_clients} k={fl.k} m={fl.m} rounds={fl.rounds}")
    out = run_training(task, fl, progress=True)

    stats = out["load_stats"]
    print("\n== load metric X ==")
    print(f"empirical: E[X]={stats['mean_X']:.3f} Var[X]={stats['var_X']:.3f} "
          f"(samples {stats['num_samples']})")
    print(f"theory   : E[X]={fl.n_clients / fl.k:.3f} "
          f"Var random={load_metric.random_selection_var(fl.n_clients, fl.k):.3f} "
          f"Var markov*={load_metric.optimal_var(fl.n_clients, fl.k, fl.m):.3f}")
    print(f"cohort   : mean={stats['mean_cohort']:.2f} std={stats['std_cohort']:.2f} "
          f"range [{stats['min_cohort']}, {stats['max_cohort']}]")
    if args.target_acc:
        r = rounds_to_target(out["history"], args.target_acc)
        print(f"rounds to {args.target_acc:.0%}: {r}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(
                {"history": out["history"], "load_stats": stats,
                 "config": vars(args), "wall_time_s": out["wall_time_s"]},
                f, indent=1,
            )
        print("wrote", args.out)


if __name__ == "__main__":
    main()
