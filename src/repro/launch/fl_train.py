"""Federated training driver — the paper's experiment, end to end.

Runs FedAvg on a synthetic MNIST/CIFAR-like dataset (or a reduced LLM
workload) under a chosen selection policy and reports accuracy-vs-round
plus the load-metric statistics (Var[X], cohort sizes) against theory.
Driven through the unified engine API: any registered policy or
aggregator name works here without touching the round loop.

Examples:
  PYTHONPATH=src python -m repro.launch.fl_train --dataset mnist \
      --policy markov --rounds 60
  PYTHONPATH=src python -m repro.launch.fl_train --dataset mnist --noniid \
      --policy random --rounds 60
  PYTHONPATH=src python -m repro.launch.fl_train --policy markov_hetero \
      --rounds 40                        # per-client-rate Markov chains
  PYTHONPATH=src python -m repro.launch.fl_train --arch tinyllama-1.1b \
      --policy markov --rounds 20        # reduced-LLM federated workload
"""
from __future__ import annotations

import argparse

from repro.core import load_metric
from repro.engine import SyncEngine, run_engine
from repro.fl.rounds import rounds_to_target
from repro.launch._fl_cli import (
    add_common_args,
    build_run_config,
    build_task,
    print_defense_stats,
    print_tier_stats,
    write_result,
)

DEFAULTS = {"rounds": 60, "clients": 100, "local_epochs": 5, "lr": 0.1}


def main() -> None:
    ap = argparse.ArgumentParser()
    add_common_args(ap, DEFAULTS)
    ap.add_argument("--target-acc", type=float, default=None)
    args = ap.parse_args()

    task = build_task(args)
    cfg = build_run_config(args, mode="sync", eval_div=30)
    engine = SyncEngine(task, cfg)
    print(f"policy={cfg.policy} n={cfg.n_clients} k={cfg.k} m={cfg.m} "
          f"rounds={cfg.rounds} aggregator={cfg.resolved_aggregator()} "
          f"chunk={cfg.resolved_steps_per_chunk()}"
          + (f" cohort=sharded/x{engine.mesh_shards}"
             if cfg.shard_cohort else "")
          + (f" topology={cfg.topology_name()}" if cfg.topology else ""))
    res = run_engine(engine, progress=True)

    stats = res.load_stats
    print("\n== load metric X ==")
    print(f"empirical: E[X]={stats['mean_X']:.3f} Var[X]={stats['var_X']:.3f} "
          f"(samples {stats['num_samples']})")
    print(f"theory   : E[X]={cfg.n_clients / cfg.k:.3f} "
          f"Var random={load_metric.random_selection_var(cfg.n_clients, cfg.k):.3f} "
          f"Var markov*={load_metric.optimal_var(cfg.n_clients, cfg.k, cfg.m):.3f}")
    print(f"cohort   : mean={stats['mean_cohort']:.2f} std={stats['std_cohort']:.2f} "
          f"range [{stats['min_cohort']}, {stats['max_cohort']}]")
    injected = {k[len("fault_"):-len("_injected")]: v for k, v in stats.items()
                if k.startswith("fault_") and k.endswith("_injected")}
    if injected:
        print("faults injected: " + ", ".join(
            f"{nm}={int(v)}" for nm, v in injected.items()))
    agg_stats = {k[len("agg_"):]: v for k, v in stats.items()
                 if k.startswith("agg_")}
    if agg_stats:
        print("robust aggregation: " + ", ".join(
            f"{nm}={int(v)}" for nm, v in agg_stats.items()))
    print_defense_stats(res.load_stats)
    print_tier_stats(res.load_stats)
    if args.target_acc:
        r = rounds_to_target(res.history(), args.target_acc)
        print(f"rounds to {args.target_acc:.0%}: {r}")
    write_result(args.out, res, args)


if __name__ == "__main__":
    main()
