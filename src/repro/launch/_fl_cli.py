"""Shared CLI plumbing for the federated training drivers.

``fl_train`` (sync) and ``fl_async`` differ only in mode-specific flags
and reporting; the argparse skeleton, task construction, RunConfig
assembly, and JSON output all live here so the two drivers cannot drift.
"""
from __future__ import annotations

import argparse
from typing import Any, Dict, Optional

from repro.engine import RunConfig, dump_json, policy_names
from repro.engine.config import RNG_IMPLS
from repro.fl.task import FLTask


def add_common_args(ap: argparse.ArgumentParser, defaults: Dict[str, Any]) -> None:
    """Flags shared by both drivers; ``defaults`` carries the per-driver
    defaults (sync trains longer per round, async favors frequent small
    local updates)."""
    ap.add_argument("--dataset", default="mnist",
                    choices=["mnist", "cifar10", "cifar100"])
    ap.add_argument("--arch", default=None,
                    help="use a reduced LLM arch as the FL workload")
    ap.add_argument("--policy", default="markov", choices=sorted(policy_names()))
    ap.add_argument("--rounds", type=int, default=defaults["rounds"],
                    help=defaults.get("rounds_help", "training rounds"))
    ap.add_argument("--clients", type=int, default=defaults["clients"])
    ap.add_argument("--k", type=int, default=15)
    ap.add_argument("--m", type=int, default=10)
    ap.add_argument("--aggregator", default=None,
                    help="aggregation rule (default: fedavg sync / fedbuff async)")
    ap.add_argument("--local-epochs", type=int, default=defaults["local_epochs"])
    ap.add_argument("--batch-size", type=int, default=50)
    ap.add_argument("--lr", type=float, default=defaults["lr"])
    ap.add_argument("--noniid", action="store_true", help="Dirichlet(0.6) label skew")
    ap.add_argument("--data-scale", type=float, default=0.25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    # --- hot loop ---
    ap.add_argument("--steps-per-chunk", type=int, default=None,
                    help="rounds advanced per host dispatch (donated scan "
                         "chunk); default: auto, min(eval cadence, 64). "
                         "Bit-for-bit identical to per-step execution.")
    ap.add_argument("--no-history", action="store_true",
                    help="skip materializing the (rounds, n) selection "
                         "matrix; load stats come from the device-resident "
                         "accumulators (required at fleet scale)")
    ap.add_argument("--rng-impl", default=None, choices=sorted(RNG_IMPLS),
                    help="PRNG implementation for the run key (default: "
                         "threefry PRNGKey, bit-compatible with older runs; "
                         "rbg/unsafe_rbg are faster at fleet scale)")
    # --- device mesh ---
    ap.add_argument("--mesh-shards", type=int, default=None, metavar="D",
                    help="1-D device mesh size. Async: shard the per-client "
                         "fleet state over D devices (ShardedAsyncEngine; D "
                         "must divide --clients; 0 auto-detects; bit-for-bit "
                         "identical to the single-device engine). Sync: only "
                         "meaningful with --shard-cohort (the mesh shards "
                         "the cohort axis). On CPU, XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8 fakes an "
                         "8-device mesh.")
    # --- aggregation topology (repro.topo) ---
    ap.add_argument("--topology", default=None, metavar="NAME",
                    help="aggregation topology from the @register_topology "
                         "registry (star | hierarchical | gossip). Default: "
                         "the star, bit-for-bit identical to not passing "
                         "the flag. Multi-tier topologies need an additive "
                         "aggregator and report per-tier Var[X].")
    ap.add_argument("--tiers", default=None, metavar="E0[,E1,...]",
                    help="aggregation nodes per tier, bottom-up, e.g. "
                         "'64,8' for edge->regional->global (hierarchical) "
                         "or '8' for the peer-node count (gossip)")
    ap.add_argument("--heartbeat-timeout", type=float, default=None,
                    metavar="SECONDS",
                    help="simulated-seconds liveness timeout: updates from "
                         "clients dark for longer are excluded from their "
                         "tier's reduction (async engine only)")
    ap.add_argument("--shard-cohort", action="store_true",
                    help="cohort-parallel execution: partition the cohort "
                         "training vmap (and eval) across the mesh instead "
                         "of replicating it — each device trains "
                         "cohort/devices clients and aggregation merges "
                         "with one psum. Needs --mesh-shards and >= 2 "
                         "devices. Allclose-equivalent to the replicated "
                         "layout (reduction order differs), measurably "
                         "faster on real multi-device hosts.")
    # --- fault injection & graceful degradation (repro.faults) ---
    ap.add_argument("--faults", default=None, metavar="NAME[,NAME...]",
                    help="comma-separated fault injections from the "
                         "@register_fault registry (e.g. dropout,corrupt). "
                         "Deterministic per-seed; omitting the flag is "
                         "bit-for-bit identical to a fault-free run.")
    ap.add_argument("--fault-rate", type=float, default=0.05,
                    help="per-event injection probability shared by every "
                         "armed fault (default 0.05)")
    ap.add_argument("--robust-agg", default=None, metavar="NAME",
                    help="shorthand for --aggregator with a robust rule "
                         "(norm_clip | trimmed_mean | coordinate_median); "
                         "conflicts with --aggregator")
    ap.add_argument("--redispatch-timeout", type=float, default=None,
                    metavar="SECONDS",
                    help="deadline-based re-dispatch: an in-flight client "
                         "past this simulated-seconds deadline is re-sent "
                         "the current model (async engine only)")
    ap.add_argument("--redispatch-retries", type=int, default=1,
                    help="re-dispatch attempts per dispatch before the "
                         "slot is abandoned (default 1)")
    # --- adaptive defense tier (repro.defense) ---
    ap.add_argument("--defense", action="store_true",
                    help="arm the adaptive defense tier: per-client EWMA "
                         "reputation scoring, quarantine with a probation "
                         "Markov chain, and exclusion of flagged clients "
                         "from selection and aggregation. Omitting the "
                         "flag is bit-for-bit identical to a defense-free "
                         "run.")
    ap.add_argument("--quarantine-threshold", type=float, default=None,
                    metavar="T",
                    help="reputation score above which a client is "
                         "quarantined (default 0.55; 'inf' arms the "
                         "scoring pipeline without ever quarantining)")
    ap.add_argument("--mtd-window", type=int, default=None, metavar="STEPS",
                    help="arm moving-target aggregation: re-decide the "
                         "trimmed-mean trim fraction from windowed attack "
                         "pressure every STEPS aggregations (needs "
                         "--defense; star topology only)")
    ap.add_argument("--detector", default=None, metavar="NAME",
                    help="per-slot anomaly detector (zscore | learned). "
                         "'learned' trains a logistic head online over the "
                         "defense telemetry and reports its running AUC "
                         "(needs --defense; default zscore is bit-for-bit "
                         "the PR 9 scoring pipeline)")
    ap.add_argument("--collusion", action="store_true",
                    help="arm collusion-aware scoring: per-client historical "
                         "update-direction sketches plus similarity-clique "
                         "detection of coordinated (norm-invisible) "
                         "coalitions (needs --defense)")


def build_task(args: argparse.Namespace) -> FLTask:
    """The federated workload: the paper's CNN or a reduced LLM arch."""
    from repro.fl import make_cnn_task, make_lm_task

    if args.arch:
        from repro.configs import get_arch

        cfg = get_arch(args.arch).reduced()
        return make_lm_task(cfg, args.clients, seq_len=64, docs_per_client=8,
                            seed=args.seed)
    from repro.configs.paper_cnn import CNN_CONFIGS
    from repro.data.synthetic import load_dataset

    train, test = load_dataset(args.dataset, seed=args.seed, scale=args.data_scale)
    cnn = CNN_CONFIGS[f"paper-cnn-{args.dataset}"]
    return make_cnn_task(
        cnn, train, test, args.clients,
        noniid_alpha=0.6 if args.noniid else None, seed=args.seed,
    )


def topology_args(args: argparse.Namespace) -> Dict[str, Any]:
    """``topology``/``topology_kwargs`` RunConfig fields from the shared
    ``--topology``/``--tiers``/``--heartbeat-timeout`` flags."""
    if args.topology is None:
        if args.tiers is not None or args.heartbeat_timeout is not None:
            raise SystemExit(
                "--tiers/--heartbeat-timeout need --topology"
            )
        return {}
    kw: Dict[str, Any] = {}
    if args.tiers is not None:
        tiers = tuple(int(t) for t in args.tiers.split(","))
        # gossip is a flat peer graph: one tier, named 'nodes'
        if args.topology == "gossip":
            if len(tiers) != 1:
                raise SystemExit("gossip takes a single --tiers value")
            kw["nodes"] = tiers[0]
        else:
            kw["tiers"] = tiers
    if args.heartbeat_timeout is not None:
        kw["heartbeat_timeout"] = args.heartbeat_timeout
    return {"topology": args.topology, "topology_kwargs": kw}


def fault_args(args: argparse.Namespace) -> Dict[str, Any]:
    """``faults``/``redispatch_*`` RunConfig fields from the shared fault
    flags; ``--robust-agg`` is folded into ``args.aggregator`` so the
    drivers' aggregator handling sees one source of truth."""
    if args.robust_agg is not None:
        if args.aggregator is not None:
            raise SystemExit(
                "--robust-agg is shorthand for --aggregator: pass one"
            )
        args.aggregator = args.robust_agg
    kw: Dict[str, Any] = {}
    if args.faults is not None:
        from repro.faults import known_fault_names

        names = tuple(s.strip() for s in args.faults.split(",") if s.strip())
        unknown = [n for n in names if n not in known_fault_names()]
        if unknown:
            raise SystemExit(
                f"unknown fault(s) {', '.join(unknown)}; registered: "
                f"{', '.join(known_fault_names())}"
            )
        kw["faults"] = names
        kw["fault_rate"] = args.fault_rate
    if args.redispatch_timeout is not None:
        kw["redispatch_timeout"] = args.redispatch_timeout
        kw["redispatch_retries"] = args.redispatch_retries
    return kw


def defense_args(args: argparse.Namespace) -> Dict[str, Any]:
    """``defense``/``defense_kwargs`` RunConfig fields from the shared
    ``--defense``/``--quarantine-threshold``/``--mtd-window``/
    ``--detector``/``--collusion`` flags."""
    if not args.defense:
        if (args.quarantine_threshold is not None or args.mtd_window is not None
                or args.detector is not None or args.collusion):
            raise SystemExit(
                "--quarantine-threshold/--mtd-window/--detector/--collusion "
                "need --defense"
            )
        return {}
    kw: Dict[str, Any] = {}
    if args.quarantine_threshold is not None:
        kw["threshold"] = args.quarantine_threshold
    if args.mtd_window is not None:
        kw["mtd"] = True
        kw["mtd_window"] = args.mtd_window
    if args.detector is not None:
        kw["detector"] = args.detector
    if args.collusion:
        kw["collusion"] = True
    return {"defense": True, "defense_kwargs": kw}


def build_run_config(args: argparse.Namespace, mode: str, eval_div: int,
                     **extra) -> RunConfig:
    extra = {**topology_args(args), **fault_args(args), **defense_args(args),
             **extra}
    return RunConfig(
        mode=mode,
        n_clients=args.clients, k=args.k, m=args.m, policy=args.policy,
        aggregator=args.aggregator,
        rounds=args.rounds, local_epochs=args.local_epochs,
        batch_size=args.batch_size, lr0=args.lr, seed=args.seed,
        eval_every=max(args.rounds // eval_div, 1),
        steps_per_chunk=args.steps_per_chunk,
        collect_history=False if args.no_history else None,
        rng_impl=args.rng_impl,
        mesh_shards=args.mesh_shards,
        shard_cohort=args.shard_cohort,
        **extra,
    )


def print_defense_stats(load_stats: Optional[Dict[str, Any]]) -> None:
    """Defense-tier report (present when ``--defense`` ran): quarantine
    flow, current suspect census, and the moving-target trim level."""
    ls = load_stats or {}
    if "def_quarantined_now" not in ls:
        return
    line = (f"defense: quarantined={int(ls['def_quarantined_now'])} "
            f"probation={int(ls['def_probation_now'])} "
            f"(inflow {int(ls['def_quarantine_inflow'])}, "
            f"readmitted {int(ls['def_readmitted'])})")
    if "def_mtd_level" in ls:
        line += f" mtd_level={int(ls['def_mtd_level'])}"
    if "def_clique_hits" in ls:
        line += f" clique_hits={int(ls['def_clique_hits'])}"
    if "def_detector_auc" in ls:
        import math

        auc = float(ls["def_detector_auc"])
        line += (" detector_auc=n/a" if math.isnan(auc)
                 else f" detector_auc={auc:.3f}")
    print(line)
    if "tier_suspects" in ls:
        counts = ls["tier_suspects"]
        print("  suspects by tier-0 node: "
              + ", ".join(f"{i}:{int(c)}" for i, c in enumerate(counts)))


def print_tier_stats(load_stats: Optional[Dict[str, Any]]) -> None:
    """Per-tier load metric report (present when a multi-tier topology
    ran): Var[X] per tier-0 aggregation node next to the fleet-wide
    figure, which is where inter-tier imbalance shows up."""
    if not load_stats or "tier_var_X" not in load_stats:
        return
    mean = load_stats["tier_mean_X"]
    var = load_stats["tier_var_X"]
    ns = load_stats["tier_num_samples"]
    print(f"per-tier X ({len(var)} tier-0 nodes):")
    show = range(len(var)) if len(var) <= 8 else list(range(4)) + [-1]
    for i in show:
        node = i if i >= 0 else len(var) - 1
        if node != i and len(var) > 8:
            print("  ...")
        print(f"  node {node:3d}: E[X]={mean[node]:.3f} "
              f"Var[X]={var[node]:.3f} (samples {ns[node]})")


def write_result(path: Optional[str], result, args: argparse.Namespace) -> None:
    """One strict-JSON results dump for every driver (NaN-safe)."""
    if not path:
        return
    payload = result.to_jsonable()
    payload["cli_args"] = vars(args)
    dump_json(path, payload)
    print("wrote", path)
