"""Centralized LM training driver (~100M-class model for a few hundred
steps on CPU; the same step function the dry-run lowers at production
scale). Used by examples/train_lm.py.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data.synthetic import make_token_stream
from repro.models import factory
from repro.optim.schedules import warmup_cosine
from repro.checkpoint import save_checkpoint


def build_sized(arch: str, target_params: float):
    """Reduced variant scaled up toward ~target_params (CPU trainable)."""
    cfg = get_arch(arch)
    red = cfg.reduced()
    # widen/deepen the reduced config until close to target
    d = red.d_model
    layers = 2
    while True:
        test = dataclasses.replace(red, d_model=d, vocab_size=min(cfg.vocab_size, 8192))
        if test.param_count() * (layers / test.num_layers) >= target_params or d >= 1024:
            break
        d *= 2
    return dataclasses.replace(red, d_model=d, vocab_size=min(cfg.vocab_size, 8192))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--target-params", type=float, default=20e6)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = build_sized(args.arch, args.target_params)
    model = factory.build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n / 1e6:.1f}M layers={cfg.num_layers} d={cfg.d_model}")

    stream = make_token_stream(cfg.vocab_size, args.steps * args.batch * (args.seq + 1) + 1)
    lr_fn = warmup_cosine(args.lr, args.steps // 10, args.steps)
    step_fn = jax.jit(model.sgd_train_step)

    t0 = time.time()
    losses = []
    for step in range(args.steps):
        off = step * args.batch * (args.seq + 1)
        chunk = stream[off : off + args.batch * (args.seq + 1)].reshape(
            args.batch, args.seq + 1
        )
        batch = factory.synth_batch(key, cfg, args.batch, args.seq)
        batch["tokens"] = jnp.asarray(chunk[:, :-1])
        labels = jnp.asarray(chunk[:, 1:])
        ft = cfg.frontend_tokens if cfg.frontend != "none" else 0
        if ft:
            labels = jnp.concatenate(
                [-jnp.ones((args.batch, ft), jnp.int32), labels], axis=1
            )
        batch["labels"] = labels
        params, metrics = step_fn(params, batch, lr_fn(jnp.asarray(step)))
        losses.append(float(metrics["loss"]))
        if (step + 1) % args.log_every == 0:
            rate = (step + 1) * args.batch * args.seq / (time.time() - t0)
            print(f"step {step + 1:5d} loss {np.mean(losses[-args.log_every:]):.4f} "
                  f"({rate:.0f} tok/s)", flush=True)
    print(f"final loss {np.mean(losses[-10:]):.4f} (initial {np.mean(losses[:10]):.4f})")
    if args.checkpoint:
        save_checkpoint(args.checkpoint, params, step=args.steps)
        print("checkpoint ->", args.checkpoint)


if __name__ == "__main__":
    main()
