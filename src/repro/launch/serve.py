"""Batched serving driver: prefill a batch of prompts, then decode with
the cached serve_step — the same decode path the dry-run lowers at 32k/500k.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import factory
from repro.serve.batching import prefill_tokens


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = factory.build(cfg)
    # one split up front: params init, prompt draw, encoder frames, and the
    # sampling loop each get an independent key
    key, k_init, k_prompt, k_frames = jax.random.split(jax.random.PRNGKey(0), 4)
    params = model.init(k_init)

    ctx = args.prompt_len + args.gen
    prompts = jax.random.randint(
        k_prompt, (args.batch, args.prompt_len), 0, cfg.vocab_size
    )

    if cfg.encoder is not None:
        frames = jax.random.normal(
            k_frames, (args.batch, cfg.encoder.source_len, cfg.d_model), jnp.float32
        )
        batch = {"frames": frames, "tokens": prompts, "seq_len": ctx}
        logits, caches = model.prefill(params, batch)
    else:
        # decode-from-scratch over the prompt to fill a ctx-sized ring
        # cache: one scanned prefill program, not a per-token dispatch loop
        caches = model.init_decode_caches(args.batch, ctx)
        logits, caches = jax.jit(
            lambda p, c, toks: prefill_tokens(model.decode_step, p, c, toks)
        )(params, caches, prompts)

    step = jax.jit(model.decode_step)
    out_tokens = []
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t0 = time.time()
    for i in range(args.gen):
        out_tokens.append(np.asarray(tok)[:, 0])
        logits, caches = step(params, caches, tok)
        key, sub = jax.random.split(key)
        if args.temperature > 0:
            tok = jax.random.categorical(
                sub, logits[:, -1] / args.temperature, axis=-1
            )[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    dt = time.time() - t0
    gen = np.stack(out_tokens, axis=1)
    print(f"arch={cfg.name} generated {args.batch}x{args.gen} tokens "
          f"in {dt:.2f}s ({args.batch * args.gen / dt:.1f} tok/s)")
    for b in range(min(args.batch, 2)):
        print(f"  sample {b}: {gen[b][:16].tolist()} ...")


if __name__ == "__main__":
    main()
