"""Batched serving driver: prefill a batch of prompts, then decode with
the cached serve_step — the same decode path the dry-run lowers at 32k/500k.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import factory


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = factory.build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    ctx = args.prompt_len + args.gen
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)

    if cfg.encoder is not None:
        frames = jax.random.normal(
            key, (args.batch, cfg.encoder.source_len, cfg.d_model), jnp.float32
        )
        batch = {"frames": frames, "tokens": prompts, "seq_len": ctx}
        logits, caches = model.prefill(params, batch)
    else:
        # decode-from-scratch over the prompt to fill a ctx-sized ring cache
        caches = model.init_decode_caches(args.batch, ctx)
        step = jax.jit(model.decode_step)
        logits = None
        for t in range(args.prompt_len):
            logits, caches = step(params, caches, prompts[:, t : t + 1])

    step = jax.jit(model.decode_step)
    out_tokens = []
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t0 = time.time()
    for i in range(args.gen):
        out_tokens.append(np.asarray(tok)[:, 0])
        logits, caches = step(params, caches, tok)
        key, sub = jax.random.split(key)
        if args.temperature > 0:
            tok = jax.random.categorical(
                sub, logits[:, -1] / args.temperature, axis=-1
            )[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    dt = time.time() - t0
    gen = np.stack(out_tokens, axis=1)
    print(f"arch={cfg.name} generated {args.batch}x{args.gen} tokens "
          f"in {dt:.2f}s ({args.batch * args.gen / dt:.1f} tok/s)")
    for b in range(min(args.batch, 2)):
        print(f"  sample {b}: {gen[b][:16].tolist()} ...")


if __name__ == "__main__":
    main()
