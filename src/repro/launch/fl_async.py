"""Asynchronous federated training driver — the paper's experiment under
wall-clock heterogeneity (stragglers, dropouts, availability windows).

Mirrors ``fl_train`` but runs the event-driven simulator: clients that
become available consult their Markov chain (admission control), train on
the model version they pulled, and the server aggregates a staleness-
discounted buffer of k updates per step. Load-metric statistics are
reported in *simulated seconds* alongside the round-indexed theory.

Examples:
  PYTHONPATH=src python -m repro.launch.fl_async --policy markov \
      --rounds 40 --clients 200
  PYTHONPATH=src python -m repro.launch.fl_async --latency-profile mobile \
      --policy markov --buffer-size 10 --staleness-weight 0.5
  PYTHONPATH=src python -m repro.launch.fl_async --latency-profile uniform \
      --policy random --rounds 30     # degenerate: reduces to sync FedAvg
"""
from __future__ import annotations

import argparse
import json
import math

from repro.configs.paper_cnn import CNN_CONFIGS
from repro.core import load_metric
from repro.core.load_metric import empirical_load_stats
from repro.data.synthetic import load_dataset
from repro.fl import FLConfig, make_cnn_task, make_lm_task
from repro.sim import PROFILES, AsyncConfig, run_async_training


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="mnist", choices=["mnist", "cifar10", "cifar100"])
    ap.add_argument("--arch", default=None, help="use a reduced LLM arch as the FL workload")
    ap.add_argument("--policy", default="markov")
    ap.add_argument("--rounds", type=int, default=40, help="server steps (buffer flushes)")
    ap.add_argument("--clients", type=int, default=200)
    ap.add_argument("--k", type=int, default=15)
    ap.add_argument("--m", type=int, default=10)
    ap.add_argument("--buffer-size", type=int, default=None,
                    help="updates aggregated per server step (default k)")
    ap.add_argument("--latency-profile", default="lognormal", choices=sorted(PROFILES))
    ap.add_argument("--staleness-weight", type=float, default=0.5,
                    help="polynomial discount exponent a in (1+s)^-a; 0 = constant")
    ap.add_argument("--max-versions", type=int, default=8)
    # async default: frequent small local updates (FedBuff-style) — with
    # per-client shards this small, 5 epochs at lr 0.1 diverges (sync too)
    ap.add_argument("--local-epochs", type=int, default=2)
    ap.add_argument("--batch-size", type=int, default=50)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--noniid", action="store_true", help="Dirichlet(0.6) label skew")
    ap.add_argument("--data-scale", type=float, default=0.25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.arch:
        from repro.configs import get_arch

        cfg = get_arch(args.arch).reduced()
        task = make_lm_task(cfg, args.clients, seq_len=64, docs_per_client=8, seed=args.seed)
    else:
        train, test = load_dataset(args.dataset, seed=args.seed, scale=args.data_scale)
        cnn = CNN_CONFIGS[f"paper-cnn-{args.dataset}"]
        task = make_cnn_task(
            cnn, train, test, args.clients,
            noniid_alpha=0.6 if args.noniid else None, seed=args.seed,
        )

    fl = FLConfig(
        n_clients=args.clients, k=args.k, m=args.m, policy=args.policy,
        rounds=args.rounds, local_epochs=args.local_epochs,
        batch_size=args.batch_size, lr0=args.lr, seed=args.seed,
        eval_every=max(args.rounds // 20, 1),
    )
    acfg = AsyncConfig(
        buffer_size=args.buffer_size,
        staleness_mode="const" if args.staleness_weight == 0 else "poly",
        staleness_exp=args.staleness_weight,
        max_versions=args.max_versions,
        profile=args.latency_profile,
    )
    print(
        f"async policy={args.policy} profile={args.latency_profile} "
        f"n={fl.n_clients} k={fl.k} m={fl.m} buffer={acfg.buffer_size or fl.k} "
        f"steps={fl.rounds} staleness=(1+s)^-{args.staleness_weight}"
    )
    out = run_async_training(task, fl, acfg, progress=True)

    ws = out["wall_stats"]
    print("\n== load metric X (wall clock) ==")
    print(f"simulated time: {ws['sim_time']:.2f}s over {ws['aggregations']} aggregations "
          f"({ws['updates_applied']} client updates)")
    print(f"X_wall : E[X]={ws['mean_X_wall']:.3f}s Var[X]={ws['var_X_wall']:.3f} "
          f"(samples {ws['num_samples_wall']})")
    print(f"X_epoch: E[X]={ws['mean_X_epoch']:.3f} Var[X]={ws['var_X_epoch']:.3f} "
          f"(samples {ws['num_samples_epoch']})")
    print(f"theory (sync rounds): E[X]={fl.n_clients / fl.k:.3f} "
          f"Var random={load_metric.random_selection_var(fl.n_clients, fl.k):.3f} "
          f"Var markov*={load_metric.optimal_var(fl.n_clients, fl.k, fl.m):.3f}")
    print(f"staleness: mean={ws['mean_staleness']:.2f} max={ws['max_staleness']}")
    if out["selection"] is not None:
        es = empirical_load_stats(out["selection"])
        print(f"dispatch cohorts: mean={es['mean_cohort']:.2f} std={es['std_cohort']:.2f} "
              f"range [{es['min_cohort']}, {es['max_cohort']}]")
    h = out["history"]
    if h["accuracy"]:
        print(f"final: acc={h['accuracy'][-1]:.4f} eval_loss={h['eval_loss'][-1]:.4f} "
              f"(v{h['version'][-1]} @ t={h['clock'][-1]:.2f}s)")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(
                _nan_to_null(
                    {"history": h, "wall_stats": ws, "config": vars(args),
                     "wall_time_s": out["wall_time_s"]}
                ),
                f, indent=1, allow_nan=False,
            )
        print("wrote", args.out)


def _nan_to_null(x):
    """Strict-JSON payloads: empty-aggregation steps carry NaN losses."""
    if isinstance(x, dict):
        return {k: _nan_to_null(v) for k, v in x.items()}
    if isinstance(x, list):
        return [_nan_to_null(v) for v in x]
    if isinstance(x, float) and not math.isfinite(x):
        return None
    return x


if __name__ == "__main__":
    main()
