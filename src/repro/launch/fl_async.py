"""Asynchronous federated training driver — the paper's experiment under
wall-clock heterogeneity (stragglers, dropouts, availability windows).

Mirrors ``fl_train`` but runs the event-driven simulator through the same
unified engine API: clients that become available consult their selection
policy (admission control), train on the model version they pulled, and
the server aggregates a buffer of updates per step through the configured
aggregator (staleness-discounted ``fedbuff`` by default, ``fedprox`` for
proximal damping). Load-metric statistics are reported in *simulated
seconds* alongside the round-indexed theory.

Examples:
  PYTHONPATH=src python -m repro.launch.fl_async --policy markov \
      --rounds 40 --clients 200
  PYTHONPATH=src python -m repro.launch.fl_async --latency-profile mobile \
      --policy markov --buffer-size 10 --staleness-weight 0.5
  PYTHONPATH=src python -m repro.launch.fl_async --policy markov_hetero \
      --latency-profile mobile --rounds 30   # per-client-rate admission
  PYTHONPATH=src python -m repro.launch.fl_async --latency-profile uniform \
      --policy random --rounds 30     # degenerate: reduces to sync FedAvg
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.fl_async --mesh-shards 0 \
      --clients 200 --rounds 40       # fleet state sharded over 8 devices
  PYTHONPATH=src python -m repro.launch.fl_async --faults dropout,corrupt \
      --fault-rate 0.1 --robust-agg trimmed_mean \
      --redispatch-timeout 30         # chaos run with graceful degradation
"""
from __future__ import annotations

import argparse

from repro.core import load_metric
from repro.engine import make_engine, run_engine
from repro.launch._fl_cli import (
    add_common_args,
    build_run_config,
    build_task,
    print_defense_stats,
    print_tier_stats,
    write_result,
)
from repro.sim import PROFILES

# async default: frequent small local updates (FedBuff-style) — with
# per-client shards this small, 5 epochs at lr 0.1 diverges (sync too)
DEFAULTS = {
    "rounds": 40, "clients": 200, "local_epochs": 2, "lr": 0.05,
    "rounds_help": "server steps (buffer flushes)",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    add_common_args(ap, DEFAULTS)
    ap.add_argument("--buffer-size", type=int, default=None,
                    help="updates aggregated per server step (default k)")
    ap.add_argument("--latency-profile", default="lognormal",
                    choices=sorted(PROFILES))
    ap.add_argument("--staleness-weight", type=float, default=0.5,
                    help="polynomial discount exponent a in (1+s)^-a; 0 = constant")
    ap.add_argument("--max-versions", type=int, default=8)
    args = ap.parse_args()

    task = build_task(args)
    cfg = build_run_config(
        args, mode="async", eval_div=20,
        aggregator_kwargs={
            "staleness_mode": "const" if args.staleness_weight == 0 else "poly",
            "staleness_exp": args.staleness_weight,
        } if (args.aggregator in (None, "fedbuff", "fedprox", "norm_clip")
              and args.robust_agg in (None, "norm_clip")) else {},
        buffer_size=args.buffer_size,
        max_versions=args.max_versions,
        profile=args.latency_profile,
    )
    engine = make_engine(task, cfg)
    shards = getattr(engine, "mesh_shards", None)
    print(
        f"async policy={cfg.policy} profile={args.latency_profile} "
        f"n={cfg.n_clients} k={cfg.k} m={cfg.m} buffer={cfg.resolved_buffer_size()} "
        f"steps={cfg.rounds} aggregator={cfg.resolved_aggregator()} "
        f"staleness=(1+s)^-{args.staleness_weight} "
        f"chunk={cfg.resolved_steps_per_chunk()}"
        + (f" mesh_shards={shards}" if shards else "")
        + (" cohort=sharded" if cfg.shard_cohort else "")
        + (f" topology={cfg.topology_name()}" if cfg.topology else "")
    )
    res = run_engine(engine, progress=True)

    ws = res.wall_stats
    print("\n== load metric X (wall clock) ==")
    print(f"simulated time: {ws['sim_time']:.2f}s over {ws['aggregations']} aggregations "
          f"({ws['updates_applied']} client updates)")
    print(f"X_wall : E[X]={ws['mean_X_wall']:.3f}s Var[X]={ws['var_X_wall']:.3f} "
          f"(samples {ws['num_samples_wall']})")
    print(f"X_epoch: E[X]={ws['mean_X_epoch']:.3f} Var[X]={ws['var_X_epoch']:.3f} "
          f"(samples {ws['num_samples_epoch']})")
    print(f"theory (sync rounds): E[X]={cfg.n_clients / cfg.k:.3f} "
          f"Var random={load_metric.random_selection_var(cfg.n_clients, cfg.k):.3f} "
          f"Var markov*={load_metric.optimal_var(cfg.n_clients, cfg.k, cfg.m):.3f}")
    print(f"staleness: mean={ws['mean_staleness']:.2f} max={ws['max_staleness']}")
    if "hb_expired" in ws:
        print(f"heartbeat churn: {ws['hb_expired']} updates expired")
    ls = res.load_stats or {}
    injected = {k[len("fault_"):-len("_injected")]: v for k, v in ls.items()
                if k.startswith("fault_") and k.endswith("_injected")}
    if injected:
        print("faults injected: " + ", ".join(
            f"{nm}={int(v)}" for nm, v in injected.items()))
    if "redispatched" in ls:
        print(f"re-dispatch: {ls['redispatched']} re-sent, "
              f"{ls['rd_expired']} deadline hits")
    agg_stats = {k[len("agg_"):]: v for k, v in ls.items()
                 if k.startswith("agg_")}
    if agg_stats:
        print("robust aggregation: " + ", ".join(
            f"{nm}={int(v)}" for nm, v in agg_stats.items()))
    # load_stats now come from the device-resident accumulators whenever
    # the (rounds, n) history is not materialized — fleet scale included
    if res.load_stats:
        es = res.load_stats
        print(f"dispatch cohorts: mean={es['mean_cohort']:.2f} std={es['std_cohort']:.2f} "
              f"range [{es['min_cohort']}, {es['max_cohort']}]")
        print(f"X_round: E[X]={es['mean_X']:.3f} Var[X]={es['var_X']:.3f} "
              f"(samples {es['num_samples']}, "
              f"{'history' if res.selection is not None else 'accumulators'})")
    print_defense_stats(res.load_stats)
    print_tier_stats(res.load_stats)
    if res.records:
        last = res.records[-1]
        print(f"final: acc={last.accuracy:.4f} eval_loss={last.eval_loss:.4f} "
              f"(v{last.version} @ t={last.clock:.2f}s)")
    write_result(args.out, res, args)


if __name__ == "__main__":
    main()
