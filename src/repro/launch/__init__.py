"""Launchers: production meshes, multi-pod dry-run, train/serve/fl_train.

NOTE: importing ``repro.launch.dryrun`` sets XLA_FLAGS (512 host devices)
as its first statement — import it only in a dedicated process.
"""
from repro.launch.mesh import make_host_mesh, make_production_mesh  # noqa: F401
