"""Checkpoint store: pytree -> sharded .npz files + JSON manifest.

Saves arbitrary pytrees (model params, optimizer state, FL server state
incl. scheduler ages — so a federated run can resume with its AoI state
intact). Large leaves are split across multiple npz shards to bound file
size; dtypes (incl. bfloat16, stored as uint16 bit patterns) round-trip.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SHARD_BYTES = 512 * 1024 * 1024


def _key_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_checkpoint(directory: str, tree: Any, step: int = 0) -> str:
    os.makedirs(directory, exist_ok=True)
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest: Dict = {"step": step, "leaves": [], "shards": []}
    shard_arrays: Dict[str, np.ndarray] = {}
    shard_id, shard_bytes = 0, 0
    for path, leaf in leaves:
        name = _key_str(path)
        arr = np.asarray(leaf)
        entry = {"name": name, "dtype": str(arr.dtype), "shape": list(arr.shape)}
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
            entry["stored_as"] = "uint16_bf16"
        if shard_bytes + arr.nbytes > _SHARD_BYTES and shard_arrays:
            _flush(directory, shard_id, shard_arrays, manifest)
            shard_arrays, shard_bytes = {}, 0
            shard_id += 1
        key = f"a{len(shard_arrays)}"
        shard_arrays[key] = arr
        entry["shard"] = shard_id
        entry["key"] = key
        shard_bytes += arr.nbytes
        manifest["leaves"].append(entry)
    if shard_arrays:
        _flush(directory, shard_id, shard_arrays, manifest)
    mpath = os.path.join(directory, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    return mpath


def _flush(directory, shard_id, arrays, manifest):
    fname = f"shard_{shard_id:04d}.npz"
    np.savez(os.path.join(directory, fname), **arrays)
    manifest["shards"].append(fname)


def load_checkpoint(directory: str, like: Any) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs)."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    shards = [
        np.load(os.path.join(directory, fname)) for fname in manifest["shards"]
    ]
    by_name = {}
    for e in manifest["leaves"]:
        arr = shards[e["shard"]][e["key"]]
        if e.get("stored_as") == "uint16_bf16":
            arr = arr.view(jnp.bfloat16)
        by_name[e["name"]] = arr
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, leaf in paths:
        name = _key_str(path)
        if name not in by_name:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = by_name[name]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {name}: {arr.shape} vs {leaf.shape}")
        out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]
