"""Checkpoint store: pytree -> sharded .npz files + JSON manifest.

Saves arbitrary pytrees (model params, optimizer state, FL server state
incl. scheduler ages — so a federated run can resume with its AoI state
intact). Large leaves are split across multiple npz shards to bound file
size; dtypes (incl. bfloat16, stored as uint16 bit patterns) round-trip.

Typed PRNG keys (``jax.random.key`` leaves, e.g. the engines' ``k_run``
carry entry) round-trip too: the raw key data is stored and the key impl
name recorded in the manifest, so a mid-run engine carry — including its
scan key — restores bit-for-bit and the run continues exactly where it
crashed.

Every shard's sha256 is recorded in the manifest and re-checked on load:
a corrupted or truncated shard fails loudly (``ValueError``) instead of
silently resuming from garbage.
"""
from __future__ import annotations

import hashlib
import json
import os
import zipfile
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SHARD_BYTES = 512 * 1024 * 1024


def _key_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _is_typed_key(leaf) -> bool:
    try:
        return jax.dtypes.issubdtype(
            jnp.asarray(leaf).dtype, jax.dtypes.prng_key
        )
    except TypeError:
        return False


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def save_checkpoint(directory: str, tree: Any, step: int = 0) -> str:
    os.makedirs(directory, exist_ok=True)
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest: Dict = {"step": step, "leaves": [], "shards": []}
    shard_arrays: Dict[str, np.ndarray] = {}
    shard_id, shard_bytes = 0, 0
    for path, leaf in leaves:
        name = _key_str(path)
        if _is_typed_key(leaf):
            # typed PRNG key: store the raw key data, remember the impl
            impl = str(jax.random.key_impl(leaf))
            arr = np.asarray(jax.random.key_data(leaf))
            entry = {
                "name": name, "dtype": str(arr.dtype),
                "shape": list(arr.shape), "prng_impl": impl,
            }
        else:
            arr = np.asarray(leaf)
            entry = {
                "name": name, "dtype": str(arr.dtype),
                "shape": list(arr.shape),
            }
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
            entry["stored_as"] = "uint16_bf16"
        if shard_bytes + arr.nbytes > _SHARD_BYTES and shard_arrays:
            _flush(directory, shard_id, shard_arrays, manifest)
            shard_arrays, shard_bytes = {}, 0
            shard_id += 1
        key = f"a{len(shard_arrays)}"
        shard_arrays[key] = arr
        entry["shard"] = shard_id
        entry["key"] = key
        shard_bytes += arr.nbytes
        manifest["leaves"].append(entry)
    if shard_arrays:
        _flush(directory, shard_id, shard_arrays, manifest)
    mpath = os.path.join(directory, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    return mpath


def _flush(directory, shard_id, arrays, manifest):
    fname = f"shard_{shard_id:04d}.npz"
    fpath = os.path.join(directory, fname)
    np.savez(fpath, **arrays)
    manifest["shards"].append({"file": fname, "sha256": _sha256(fpath)})


def _shard_file(entry) -> str:
    # pre-hash manifests stored shards as plain filenames
    return entry["file"] if isinstance(entry, dict) else entry


def _load_shard(directory: str, entry) -> Any:
    fname = _shard_file(entry)
    fpath = os.path.join(directory, fname)
    if isinstance(entry, dict):
        got = _sha256(fpath)
        if got != entry["sha256"]:
            raise ValueError(
                f"checkpoint shard {fname} is corrupted: sha256 {got} != "
                f"manifest {entry['sha256']} — refusing to restore"
            )
    try:
        shard = np.load(fpath)
        shard.files  # force the zip directory read
        return shard
    except (zipfile.BadZipFile, OSError, ValueError) as e:
        raise ValueError(
            f"checkpoint shard {fname} is unreadable (truncated or "
            f"corrupted): {e}"
        ) from None


def load_checkpoint(directory: str, like: Any) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs)."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    shards = [_load_shard(directory, e) for e in manifest["shards"]]
    by_name, impl_by_name = {}, {}
    for e in manifest["leaves"]:
        arr = shards[e["shard"]][e["key"]]
        if e.get("stored_as") == "uint16_bf16":
            arr = arr.view(jnp.bfloat16)
        by_name[e["name"]] = arr
        if "prng_impl" in e:
            impl_by_name[e["name"]] = e["prng_impl"]
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, leaf in paths:
        name = _key_str(path)
        if name not in by_name:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = by_name[name]
        if name in impl_by_name:
            restored = jax.random.wrap_key_data(
                jnp.asarray(arr), impl=impl_by_name[name]
            )
            if tuple(restored.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"shape mismatch for {name}: {restored.shape} vs "
                    f"{leaf.shape}"
                )
            out.append(restored)
            continue
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {name}: {arr.shape} vs {leaf.shape}")
        out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]
