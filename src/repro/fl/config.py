"""Legacy federated-learning run configuration (paper Sec. IV defaults).

Kept as a thin convenience facade: the unified contract is
``repro.engine.RunConfig`` (which absorbs this plus ``AsyncConfig``);
``run_config_from_legacy`` converts. New code should build a ``RunConfig``
directly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class FLConfig:
    n_clients: int = 100
    k: int = 15  # paper: 15% participation
    m: int = 10  # max permissible age (Markov policy)
    policy: str = "markov"  # any name in repro.engine.policy_names()
    rounds: int = 100
    local_epochs: int = 5
    batch_size: int = 50
    lr0: float = 0.1
    lr_decay: float = 0.998
    seed: int = 0
    # cohort padding for variable-size policies (markov): vmap width
    max_cohort: Optional[int] = None
    eval_every: int = 1

    def __post_init__(self) -> None:
        if self.max_cohort is not None and self.max_cohort < self.k:
            raise ValueError(
                f"max_cohort={self.max_cohort} < k={self.k}: the cohort "
                "buffer could not hold even an exact-k selection; raise "
                "max_cohort (or leave it None for the binomial-tail default)"
            )

    def cohort_width(self) -> int:
        """Padded cohort buffer width for variable-size policies: the
        Markov cohort is ~Binomial(n, k/n), padded to k + 4*sigma."""
        from repro.engine.config import default_cohort_width

        if self.max_cohort is not None:
            return self.max_cohort
        return default_cohort_width(self.n_clients, self.k)
