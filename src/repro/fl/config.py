"""Federated-learning run configuration (paper Sec. IV defaults)."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class FLConfig:
    n_clients: int = 100
    k: int = 15  # paper: 15% participation
    m: int = 10  # max permissible age (Markov policy)
    policy: str = "markov"  # random | markov | oldest_age | round_robin | gumbel_age
    rounds: int = 100
    local_epochs: int = 5
    batch_size: int = 50
    lr0: float = 0.1
    lr_decay: float = 0.998
    seed: int = 0
    # cohort padding for variable-size policies (markov): vmap width
    max_cohort: Optional[int] = None
    eval_every: int = 1

    def cohort_width(self) -> int:
        if self.max_cohort is not None:
            return self.max_cohort
        # Markov cohort is ~Binomial(n, k/n): pad to k + 5*sigma
        import math

        sigma = math.sqrt(self.n_clients * (self.k / self.n_clients) * (1 - self.k / self.n_clients))
        return min(self.n_clients, int(self.k + 4 * sigma) + 1)
