"""FedAvg server: cohort gather, aggregation, global state.

Aggregation handles *variable-size* cohorts (the Markov policy selects a
Binomial(~k) number of clients each round): selected indices are padded to
``max_cohort`` and averaged with 0/1 weights. On TPU the weighted mean is
the ``fedavg_reduce`` Pallas kernel; the jnp path is its reference.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def cohort_indices(selected: jnp.ndarray, width: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(indices (width,), weights (width,)) from an (n,) bool mask.

    Overflow beyond ``width`` is dropped (rare: the default width is
    k + 4 sigma of the binomial cohort size); padding entries point at
    client 0 with weight 0.
    """
    idx = jnp.nonzero(selected, size=width, fill_value=-1)[0]
    w = (idx >= 0).astype(jnp.float32)
    return jnp.maximum(idx, 0), w


def fedavg_aggregate(
    global_params, cohort_params, weights: jnp.ndarray, use_kernel: bool = False
):
    """Weighted mean over the stacked cohort axis; falls back to the global
    params when the cohort is empty (no update this round).

    cohort_params: pytree with leading axis = max_cohort.
    """
    wsum = weights.sum()
    empty = wsum == 0.0
    denom = jnp.maximum(wsum, 1.0)

    if use_kernel:
        from repro.kernels import ops as kops

        def agg(g, c):
            flat = c.reshape(c.shape[0], -1).astype(jnp.float32)
            out = kops.fedavg_reduce(flat, weights / denom)
            return jnp.where(empty, g, out.reshape(g.shape).astype(g.dtype))

    else:

        def agg(g, c):
            wshape = (-1,) + (1,) * (c.ndim - 1)
            out = jnp.sum(c * weights.reshape(wshape).astype(c.dtype), axis=0) / denom.astype(c.dtype)
            return jnp.where(empty, g, out.astype(g.dtype))

    return jax.tree.map(agg, global_params, cohort_params)


def broadcast_to_cohort(params, width: int):
    """Replicate global params along a new cohort axis (for vmap)."""
    return jax.tree.map(lambda p: jnp.broadcast_to(p[None], (width,) + p.shape), params)
