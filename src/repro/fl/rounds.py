"""Back-compat wrappers over the unified engine (``repro.engine``).

The FedAvg round loop that used to live here is now ``SyncEngine`` in
``repro.engine.sync``, driven through the one ``RunConfig``/``RunResult``
contract shared with the async engine. ``run_training`` keeps the legacy
signature and returns the legacy history dict, reproducing the
pre-refactor loop bit-for-bit on fixed seeds (pinned by
``tests/test_engine_equivalence.py``).
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.core.selection import Policy
from repro.fl.config import FLConfig
from repro.fl.task import FLTask


def make_round_fn(task: FLTask, fl: FLConfig, policy: Policy):
    """One jit'd FedAvg round (legacy helper): policy step -> cohort gather
    -> vmapped local training -> fedavg aggregation -> age update."""
    from repro.engine.config import run_config_from_legacy
    from repro.engine.registry import make_aggregator
    from repro.engine.sync import _make_round_fn

    cfg = run_config_from_legacy(fl)
    return _make_round_fn(task, cfg, policy, make_aggregator("fedavg"))


def run_training(
    task: FLTask,
    fl: FLConfig,
    policy: Optional[Policy] = None,
    progress: bool = False,
) -> Dict:
    """Full FL run. Returns history dict with per-round eval metrics and
    the load-metric statistics of the realized selection history."""
    from repro.engine.api import run_engine
    from repro.engine.config import run_config_from_legacy
    from repro.engine.sync import SyncEngine

    cfg = run_config_from_legacy(fl)
    res = run_engine(SyncEngine(task, cfg, policy=policy), progress=progress)
    return {
        "history": res.history(),
        "selection": res.selection,
        "load_stats": res.load_stats,
        "params": res.params,
        "wall_time_s": res.wall_time_s,
    }


def rounds_to_target(history: Dict, target_acc: float) -> Optional[int]:
    """First round at which eval accuracy reaches the target (paper's
    convergence-speed metric)."""
    for r, a in zip(history["round"], history["accuracy"]):
        if a >= target_acc:
            return r
    return None
