"""The FedAvg round loop with pluggable client selection.

One jit'd round = policy step -> cohort gather -> vmapped local training ->
masked FedAvg aggregation -> age update. The selection history is streamed
back to host for load-metric statistics (Var[X], cohort sizes) — the
quantities the paper's Figs. 2-4 and Theorems 1-2 are about.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import empirical_load_stats
from repro.core.selection import Policy, make_policy
from repro.fl.client import make_local_update
from repro.fl.config import FLConfig
from repro.fl.server import broadcast_to_cohort, cohort_indices, fedavg_aggregate
from repro.fl.task import FLTask
from repro.optim.schedules import exponential_decay


def make_round_fn(task: FLTask, fl: FLConfig, policy: Policy):
    width = fl.cohort_width() if not policy.exact_k else fl.k
    local_update = make_local_update(
        task.loss_fn, fl.local_epochs, fl.batch_size, task.examples_per_client
    )
    lr_fn = exponential_decay(fl.lr0, fl.lr_decay)

    @jax.jit
    def round_fn(params, sched_state, key):
        k_sel, k_local = jax.random.split(key)
        selected, sched_state = policy.step(sched_state, k_sel)
        idx, weights = cohort_indices(selected, width)
        shards = jax.tree.map(lambda a: a[idx], task.client_data)
        lr = lr_fn(sched_state["round"] - 1)
        cohort_params = broadcast_to_cohort(params, width)
        keys = jax.random.split(k_local, width)
        updated, losses = jax.vmap(local_update, in_axes=(0, 0, 0, None))(
            cohort_params, shards, keys, lr
        )
        params = fedavg_aggregate(params, updated, weights)
        mean_loss = jnp.sum(losses * weights) / jnp.maximum(weights.sum(), 1.0)
        return params, sched_state, selected, mean_loss

    return round_fn


def run_training(
    task: FLTask,
    fl: FLConfig,
    policy: Optional[Policy] = None,
    progress: bool = False,
) -> Dict:
    """Full FL run. Returns history dict with per-round eval metrics and
    the load-metric statistics of the realized selection history."""
    key = jax.random.PRNGKey(fl.seed)
    k_init, k_policy, k_run = jax.random.split(key, 3)
    policy = policy or make_policy(fl.policy, fl.n_clients, fl.k, fl.m)
    params = task.init(k_init)
    sched_state = policy.init(k_policy, fl.n_clients)
    round_fn = make_round_fn(task, fl, policy)

    history = {"round": [], "accuracy": [], "eval_loss": [], "train_loss": []}
    sel_hist = np.zeros((fl.rounds, fl.n_clients), dtype=bool)
    t0 = time.time()
    for r in range(fl.rounds):
        params, sched_state, selected, loss = round_fn(
            params, sched_state, jax.random.fold_in(k_run, r)
        )
        sel_hist[r] = np.asarray(selected)
        if (r + 1) % fl.eval_every == 0 or r == fl.rounds - 1:
            ev = task.eval_fn(params)
            history["round"].append(r + 1)
            history["accuracy"].append(float(ev["accuracy"]))
            history["eval_loss"].append(float(ev["loss"]))
            history["train_loss"].append(float(loss))
            if progress:
                print(
                    f"  [{policy.name}] round {r + 1:4d} acc={float(ev['accuracy']):.4f} "
                    f"loss={float(ev['loss']):.4f} ({time.time() - t0:.1f}s)",
                    flush=True,
                )
    stats = empirical_load_stats(sel_hist)
    return {
        "history": history,
        "selection": sel_hist,
        "load_stats": stats,
        "params": params,
        "wall_time_s": time.time() - t0,
    }


def rounds_to_target(history: Dict, target_acc: float) -> Optional[int]:
    """First round at which eval accuracy reaches the target (paper's
    convergence-speed metric)."""
    for r, a in zip(history["round"], history["accuracy"]):
        if a >= target_acc:
            return r
    return None
