"""FL task abstraction: anything with client-sharded data + a loss.

Two constructors: the paper's CNN classification task, and a causal-LM
task so any assigned architecture (reduced variant on CPU, full under the
production mesh) can be the federated workload.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.configs.paper_cnn import CNNConfig
from repro.data import partition_dirichlet, partition_iid
from repro.data.synthetic import ImageDataset, make_token_stream
from repro.models import cnn as cnn_mod
from repro.models import factory


@dataclasses.dataclass(frozen=True)
class FLTask:
    name: str
    init: Callable  # key -> params
    loss_fn: Callable  # (params, batch) -> scalar
    eval_fn: Callable  # (params) -> dict (accuracy/loss on held-out data)
    client_data: Dict  # pytree, leading axis = n_clients
    examples_per_client: int
    # optional batched-eval seam for cohort-parallel engines: the same
    # metrics as ``eval_fn`` but computed from explicitly-passed held-out
    # data (``eval_batch_fn(params, eval_data)``), so the engine can lay
    # the eval-batch axis out over a device mesh while params stay
    # replicated. ``eval_data``'s leading axis is the *usable* eval
    # prefix ``eval_fn`` scores (it drops the last partial batch), so the
    # two paths agree up to floating-point reduction order. Tasks without
    # these fields fall back to the replicated ``eval_fn`` everywhere.
    eval_data: Optional[Dict] = None  # pytree, leading axis = eval examples
    eval_batch_fn: Optional[Callable] = None  # (params, eval_data) -> dict


# ---------------------------------------------------------------------------
# Paper CNN task
# ---------------------------------------------------------------------------


def make_cnn_task(
    cfg: CNNConfig,
    train: ImageDataset,
    test: ImageDataset,
    n_clients: int,
    noniid_alpha: Optional[float] = None,
    seed: int = 0,
) -> FLTask:
    if noniid_alpha is None:
        parts = partition_iid(len(train.labels), n_clients, seed)
    else:
        parts = partition_dirichlet(train.labels, n_clients, alpha=noniid_alpha, seed=seed)
    cx = jnp.asarray(train.images[parts])  # (n, shard, H, W, C)
    cy = jnp.asarray(train.labels[parts])  # (n, shard)
    tx, ty = jnp.asarray(test.images), jnp.asarray(test.labels)

    def loss_fn(params, batch):
        logits = cnn_mod.forward(params, batch["x"])
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, batch["y"][:, None], axis=-1).mean()

    @jax.jit
    def eval_fn(params):
        # batched eval to bound memory
        bs = min(500, int(tx.shape[0]))
        nb = max(tx.shape[0] // bs, 1)

        def body(carry, i):
            correct, loss = carry
            xb = jax.lax.dynamic_slice_in_dim(tx, i * bs, bs)
            yb = jax.lax.dynamic_slice_in_dim(ty, i * bs, bs)
            logits = cnn_mod.forward(params, xb)
            logp = jax.nn.log_softmax(logits)
            loss += -jnp.take_along_axis(logp, yb[:, None], axis=-1).sum()
            correct += (logits.argmax(-1) == yb).sum()
            return (correct, loss), None

        (correct, loss), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.int32), jnp.zeros(())), jnp.arange(nb)
        )
        ntot = nb * bs
        return {"accuracy": correct / ntot, "loss": loss / ntot}

    n_used = max(tx.shape[0] // min(500, int(tx.shape[0])), 1) * min(
        500, int(tx.shape[0])
    )

    def eval_batch_fn(params, data):
        # one full-width pass: under a mesh the batch axis is sharded, so
        # each device scores 1/devices of the prefix and the sums reduce
        logits = cnn_mod.forward(params, data["x"])
        logp = jax.nn.log_softmax(logits)
        n = data["y"].shape[0]
        loss = -jnp.take_along_axis(logp, data["y"][:, None], axis=-1).sum() / n
        correct = (logits.argmax(-1) == data["y"]).sum()
        return {"accuracy": correct / n, "loss": loss}

    return FLTask(
        name=cfg.name,
        init=lambda key: cnn_mod.init_params(key, cfg),
        loss_fn=loss_fn,
        eval_fn=eval_fn,
        client_data={"x": cx, "y": cy},
        examples_per_client=int(cx.shape[1]),
        eval_data={"x": tx[:n_used], "y": ty[:n_used]},
        eval_batch_fn=eval_batch_fn,
    )


# ---------------------------------------------------------------------------
# Causal-LM task (any assigned architecture as the FL workload)
# ---------------------------------------------------------------------------


def make_lm_task(
    cfg: ArchConfig,
    n_clients: int,
    seq_len: int = 128,
    docs_per_client: int = 16,
    seed: int = 0,
) -> FLTask:
    model = factory.build(cfg)
    total = n_clients * docs_per_client * (seq_len + 1)
    stream = make_token_stream(cfg.vocab_size, total + seq_len, seed)
    docs = np.lib.stride_tricks.sliding_window_view(stream, seq_len + 1)[
        : n_clients * docs_per_client * (seq_len + 1) : seq_len + 1
    ][: n_clients * docs_per_client]
    docs = docs.reshape(n_clients, docs_per_client, seq_len + 1)
    cdata = {"docs": jnp.asarray(docs)}
    held = jnp.asarray(
        np.lib.stride_tricks.sliding_window_view(
            make_token_stream(cfg.vocab_size, 32 * (seq_len + 1) + seq_len, seed + 99),
            seq_len + 1,
        )[:: seq_len + 1][:32]
    )

    def loss_fn(params, batch):
        docs_b = batch["docs"]  # (bs, seq+1)
        b = {"tokens": docs_b[:, :-1], "labels": docs_b[:, 1:]}
        loss, _ = model.loss(params, b)
        return loss

    @jax.jit
    def eval_fn(params):
        loss = loss_fn(params, {"docs": held})
        return {"loss": loss, "accuracy": -loss}  # higher is better convention

    def eval_batch_fn(params, data):
        loss = loss_fn(params, data)
        return {"loss": loss, "accuracy": -loss}

    return FLTask(
        name=f"lm:{cfg.name}",
        init=model.init,
        loss_fn=loss_fn,
        eval_fn=eval_fn,
        client_data=cdata,
        examples_per_client=docs_per_client,
        eval_data={"docs": held},
        eval_batch_fn=eval_batch_fn,
    )
