"""Client local training: E epochs of SGD over the client shard (FedAvg
step (i)). Pure function of (global params, client shard, key, lr) so it
vmaps across the cohort and shards across the data axis of the mesh.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp


def make_local_update(
    loss_fn: Callable, epochs: int, batch_size: int, examples: int
) -> Callable:
    """Returns f(params, client_shard, key, lr) -> (params, mean_loss).

    Each epoch reshuffles the shard and runs floor(examples/bs) SGD steps
    (paper: E=5, B=50).
    """
    nb = max(examples // batch_size, 1)
    bs = min(batch_size, examples)

    def local_update(params, shard: Dict, key, lr):
        def epoch_perm(k):
            return jax.random.permutation(k, examples)[: nb * bs].reshape(nb, bs)

        perms = jax.vmap(epoch_perm)(jax.random.split(key, epochs)).reshape(
            epochs * nb, bs
        )

        def step(carry, idx):
            p = carry
            batch = jax.tree.map(lambda a: a[idx], shard)
            loss, g = jax.value_and_grad(loss_fn)(p, batch)
            p = jax.tree.map(lambda w, gw: w - lr * gw.astype(w.dtype), p, g)
            return p, loss

        params, losses = jax.lax.scan(step, params, perms)
        return params, losses.mean()

    return local_update
