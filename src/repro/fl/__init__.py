from repro.fl.config import FLConfig  # noqa: F401
from repro.fl.rounds import make_round_fn, rounds_to_target, run_training  # noqa: F401
from repro.fl.task import FLTask, make_cnn_task, make_lm_task  # noqa: F401
