"""The event engine: pending-completion times with next-k extraction.

State is a flat struct-of-arrays over the fleet — one f32 completion time
per client (``+inf`` when idle) plus availability/dropout bookkeeping —
so every engine operation is a fused vector op and the whole engine jits
into the training step. The only "priority queue" operation the async
loop needs is *pop the k earliest events*, which is a top-k over negated
times: the ``event_topk`` Pallas kernel at fleet scale, a plain
``lax.top_k`` reference otherwise — or, with the fleet state sharded
over a device mesh, the ``core.distributed.sharded_next_k_events``
local-top-k + gather + merge feeding ``apply_pop``. All paths break ties
toward the lower client index, which the sync-equivalence test relies on.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

# fleets at or above this size route through the tiled Pallas kernel
KERNEL_THRESHOLD = 16384


def init_event_state(n: int) -> Dict[str, jnp.ndarray]:
    """Fresh engine state: everyone idle, available at t=0, never done."""
    return {
        "t_done": jnp.full((n,), jnp.inf, jnp.float32),  # completion time
        "disp_ver": jnp.full((n,), -1, jnp.int32),  # model version at dispatch
        "next_avail": jnp.zeros((n,), jnp.float32),  # availability-window start
        "dropped": jnp.zeros((n,), jnp.bool_),  # current dispatch will be lost
        "last_done": jnp.full((n,), -1.0, jnp.float32),  # last *successful* update
    }


def schedule_completions(
    ev: Dict[str, jnp.ndarray],
    send: jnp.ndarray,  # (n,) bool — clients dispatched this step
    clock: jnp.ndarray,  # () f32 current simulated time
    latency: jnp.ndarray,  # (n,) f32 per-client wall time if dispatched
    version: jnp.ndarray,  # () i32 current model version
    dropped: jnp.ndarray,  # (n,) bool per-dispatch dropout draw
) -> Dict[str, jnp.ndarray]:
    """Mark ``send`` clients in flight: completion at clock + latency."""
    return {
        **ev,
        "t_done": jnp.where(send, clock + latency, ev["t_done"]),
        "disp_ver": jnp.where(send, version, ev["disp_ver"]),
        "dropped": jnp.where(send, dropped, ev["dropped"]),
    }


def next_k_events(
    times: jnp.ndarray, k: int, *, use_kernel: bool | None = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(times (k,), idx (k,)) of the k earliest pending events.

    Slots beyond the number of pending events carry ``+inf`` times —
    callers mask by ``jnp.isfinite``. Ties break toward lower index.
    """
    n = times.shape[0]
    if use_kernel is None:
        # interpret-mode Pallas on CPU is far slower than lax.top_k
        use_kernel = n >= KERNEL_THRESHOLD and jax.default_backend() != "cpu"
    if use_kernel:
        from repro.kernels import ops

        return ops.event_next_k(times, k)
    neg, idx = jax.lax.top_k(-times.astype(jnp.float32), k)
    return -neg, idx


def pop_events(
    ev: Dict[str, jnp.ndarray], k: int, *, use_kernel: bool | None = None
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Extract the next k completions and return those clients to idle.

    Returns (event times (k,), client idx (k,), valid mask (k,), state').
    Invalid slots (fewer than k events pending) may carry duplicate or
    arbitrary indices — the kernel path emits a tile's argmax-of-nothing
    when exhausted — so they gather client 0 data under a zero mask and
    are scattered to an out-of-range sentinel (dropped), never to a real
    client.
    """
    t, idx = next_k_events(ev["t_done"], k, use_kernel=use_kernel)
    return apply_pop(ev, t, idx)


def apply_pop(
    ev: Dict[str, jnp.ndarray], t: jnp.ndarray, idx: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Bookkeeping shared by every pop path (kernel, reference, and the
    mesh-sharded merge): mask invalid slots, return popped clients to
    idle. ``(t, idx)`` is any next-k extraction over ``ev["t_done"]``."""
    valid = jnp.isfinite(t)
    idx_safe = jnp.where(valid, idx, 0)
    t_done = ev["t_done"].at[scatter_idx(idx, valid)].set(jnp.inf, mode="drop")
    return t, idx_safe, valid, {**ev, "t_done": t_done}


def scatter_idx(idx: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Indices for a masked scatter over popped events: masked-out slots
    go out of range so ``.at[...].set(..., mode="drop")`` ignores them —
    duplicate indices from exhausted kernel tiles must never write back."""
    return jnp.where(mask, idx, jnp.iinfo(jnp.int32).max)
