"""Back-compat wrappers over the unified engine (``repro.engine``).

The FedBuff-style buffered asynchronous loop that used to live here is
now ``AsyncEngine`` in ``repro.engine.async_engine``, driven through the
one ``RunConfig``/``RunResult`` contract shared with the sync engine, with
the staleness-discounted delta aggregation factored out into the
``fedbuff`` aggregator. ``run_async_training`` keeps the legacy signature
and returns the legacy history dict, reproducing the pre-refactor loop
bit-for-bit on fixed seeds (pinned by ``tests/test_engine_equivalence.py``).

With the degenerate ``uniform`` latency profile (zero spread, always
available, no dropout) and ``buffer_size = k`` every dispatch completes
inside its own step with staleness 0, and the loop reproduces the
synchronous FedAvg round exactly — the equivalence
``tests/test_async_rounds.py`` pins down.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Union

from repro.core.selection import Policy
from repro.engine.aggregators import staleness_weight  # noqa: F401  (back-compat)
from repro.fl.config import FLConfig
from repro.fl.task import FLTask
from repro.sim import latency as lat_mod

# collect the full (steps, n) selection matrix only below this cell count
# (re-exported for back-compat; the engine's run loop owns the cap now)
HISTORY_CELL_CAP = 4_000_000


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    buffer_size: Optional[int] = None  # aggregation buffer; default fl.k
    staleness_mode: str = "poly"  # poly | const
    staleness_exp: float = 0.5  # weight = (1+s)^(-exp) for mode=poly
    max_versions: int = 8  # ring of retained global models
    profile: Union[str, lat_mod.LatencyProfile] = "lognormal"
    use_kernel: Optional[bool] = None  # None: kernel when fleet is large

    def resolved_profile(self) -> lat_mod.LatencyProfile:
        if isinstance(self.profile, lat_mod.LatencyProfile):
            return self.profile
        return lat_mod.get_profile(self.profile)


def make_async_step(
    task: FLTask, fl: FLConfig, acfg: AsyncConfig, policy: Policy
):
    """Builds (init_state, jitted step) for one async server step (legacy
    helper)."""
    import jax

    from repro.engine.async_engine import _make_async_step
    from repro.engine.config import run_config_from_legacy
    from repro.engine.registry import make_aggregator

    cfg = run_config_from_legacy(fl, acfg)
    agg = make_aggregator(
        "fedbuff", staleness_mode=acfg.staleness_mode,
        staleness_exp=acfg.staleness_exp,
    )
    init_state, step = _make_async_step(
        task, cfg, policy, agg, acfg.resolved_profile()
    )
    return init_state, jax.jit(step)


def run_async_training(
    task: FLTask,
    fl: FLConfig,
    acfg: Optional[AsyncConfig] = None,
    policy: Optional[Policy] = None,
    progress: bool = False,
) -> Dict:
    """Full asynchronous FL run. ``fl.rounds`` counts *server steps* (one
    buffer flush each). Returns history + load stats on both clocks."""
    from repro.engine.api import run_engine
    from repro.engine.async_engine import AsyncEngine
    from repro.engine.config import run_config_from_legacy

    acfg = acfg or AsyncConfig()
    cfg = run_config_from_legacy(fl, acfg)
    res = run_engine(AsyncEngine(task, cfg, policy=policy), progress=progress)
    return {
        "history": res.history(),
        "selection": res.selection,
        "wall_stats": res.wall_stats,
        "params": res.params,
        "wall_time_s": res.wall_time_s,
    }
