"""FedBuff-style buffered asynchronous training over the event engine.

One jit'd server step = admission control (idle+available clients consult
their selection policy — the Markov chain decides *locally* whether to
pull the model, preserving the paper's zero-coordination property) ->
dispatch with sampled wall-clock latencies -> pop the next ``buffer_size``
completions (event_topk kernel at fleet scale) -> vmapped local training
from each client's *dispatch-time* model version (a ring buffer of the
last ``max_versions`` global models) -> staleness-weighted delta
aggregation -> clock/version advance.

Staleness s = (server version now) - (version the client trained from);
updates are discounted by ``(1+s)^-a`` (polynomial, FedBuff/FedAsync
style) or applied uniformly (``const``). With the degenerate ``uniform``
latency profile (zero spread, always available, no dropout) and
``buffer_size = k`` every dispatch completes inside its own step with
s = 0, and the loop reproduces the synchronous FedAvg round of
``fl/rounds.py`` exactly — the equivalence ``tests/test_async_rounds.py``
pins down.

The load metric is reported on two clocks: X in decision epochs (the
paper's round-indexed Var[X]) and X in simulated seconds (wall-clock
inter-update gaps per client), which is where stragglers and availability
windows actually show up.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aoi import age_update, peak_age_accumulate
from repro.core.selection import Policy, make_policy
from repro.fl.client import make_local_update
from repro.fl.config import FLConfig
from repro.fl.task import FLTask
from repro.optim.schedules import exponential_decay
from repro.sim import events as ev_mod
from repro.sim import latency as lat_mod

# collect the full (steps, n) selection matrix only below this cell count
HISTORY_CELL_CAP = 4_000_000


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    buffer_size: Optional[int] = None  # aggregation buffer; default fl.k
    staleness_mode: str = "poly"  # poly | const
    staleness_exp: float = 0.5  # weight = (1+s)^(-exp) for mode=poly
    max_versions: int = 8  # ring of retained global models
    profile: Union[str, lat_mod.LatencyProfile] = "lognormal"
    use_kernel: Optional[bool] = None  # None: kernel when fleet is large

    def resolved_profile(self) -> lat_mod.LatencyProfile:
        if isinstance(self.profile, lat_mod.LatencyProfile):
            return self.profile
        return lat_mod.get_profile(self.profile)


def staleness_weight(
    s: jnp.ndarray, mode: str = "poly", exp: float = 0.5
) -> jnp.ndarray:
    """Aggregation discount for an update of staleness ``s`` versions."""
    s = jnp.maximum(s.astype(jnp.float32), 0.0)
    if mode == "const":
        return jnp.ones_like(s)
    if mode == "poly":
        return (1.0 + s) ** (-exp)
    raise ValueError(f"unknown staleness mode {mode!r}")


def _init_stats() -> Dict[str, jnp.ndarray]:
    z = jnp.zeros((), jnp.float32)
    return {
        "wall_sx": z, "wall_sx2": z, "wall_cnt": z,  # X in simulated seconds
        "ep_sx": z, "ep_sx2": z, "ep_cnt": z,  # X in decision epochs
        "stale_sum": z, "stale_cnt": z,
        "stale_max": jnp.zeros((), jnp.int32),
        "updates": z,  # successful updates aggregated
        "aggs": z,  # server versions produced
    }


def make_async_step(
    task: FLTask, fl: FLConfig, acfg: AsyncConfig, policy: Policy
):
    """Builds (init_state, step). ``step(state, key) -> (state, aux)``."""
    n = fl.n_clients
    B = acfg.buffer_size or fl.k
    H = acfg.max_versions
    profile = acfg.resolved_profile()
    local_update = make_local_update(
        task.loss_fn, fl.local_epochs, fl.batch_size, task.examples_per_client
    )
    lr_fn = exponential_decay(fl.lr0, fl.lr_decay)

    def init_state(params, sched_state, key):
        return {
            "params": params,
            # ring buffer of the last H global models; slot v % H = version v
            "hist": jax.tree.map(
                lambda p: jnp.broadcast_to(p[None], (H,) + p.shape), params
            ),
            "sched": sched_state,
            "ev": ev_mod.init_event_state(n),
            "speed": lat_mod.client_speed(key, n, profile),
            "clock": jnp.zeros((), jnp.float32),
            "version": jnp.zeros((), jnp.int32),
            "stats": _init_stats(),
        }

    @jax.jit
    def step(state, key):
        ev, sched, stats = state["ev"], state["sched"], state["stats"]
        clock, version = state["clock"], state["version"]
        # same key split as the sync round so the degenerate case is
        # bit-for-bit comparable; latency/dropout/gap keys are fresh folds
        k_sel, k_local = jax.random.split(key)
        k_lat = jax.random.fold_in(k_sel, 101)
        k_drop = jax.random.fold_in(k_sel, 102)
        k_gap = jax.random.fold_in(k_sel, 103)

        # --- admission control: idle+available clients consult the policy
        prev_ages = sched["ages"]
        idle = jnp.isinf(ev["t_done"])
        available = ev["next_avail"] <= clock
        want, sched = policy.step(sched, k_sel)
        send = want & idle & available
        # only actual dispatches reset the AoI clock; everyone else ages
        sched = {**sched, "ages": age_update(prev_ages, send)}
        ep_sx, ep_sx2, ep_cnt = peak_age_accumulate(
            prev_ages, send, stats["ep_sx"], stats["ep_sx2"], stats["ep_cnt"]
        )

        # --- dispatch: sample wall-clock latencies, mark in flight
        latency = lat_mod.sample_latency(k_lat, profile, state["speed"])
        dropped = lat_mod.sample_dropout(k_drop, profile, n)
        ev = ev_mod.schedule_completions(ev, send, clock, latency, version, dropped)

        # --- pop the next B completions, advance the simulated clock
        t_ev, idx, valid, ev = ev_mod.pop_events(ev, B, use_kernel=acfg.use_kernel)
        new_clock = jnp.maximum(clock, jnp.max(jnp.where(valid, t_ev, -jnp.inf)))
        # an all-idle fleet inside availability gaps must not freeze the
        # clock: with nothing in flight to pop, jump to the earliest
        # window opening so availability can recover next step
        new_clock = jnp.where(
            valid.any(), new_clock,
            jnp.maximum(new_clock, jnp.min(ev["next_avail"])),
        )

        # --- local training from each client's dispatch-time model
        disp_ver = ev["disp_ver"][idx]
        # versions older than the ring are trained from the oldest retained
        # model; staleness for weighting still uses the true dispatch version
        read_ver = jnp.clip(disp_ver, jnp.maximum(version - (H - 1), 0), version)
        disp_params = jax.tree.map(lambda h: h[read_ver % H], state["hist"])
        shards = jax.tree.map(lambda a: a[idx], task.client_data)
        keys = jax.random.split(k_local, B)
        lr = lr_fn(jnp.maximum(disp_ver, 0))
        updated, losses = jax.vmap(local_update, in_axes=(0, 0, 0, 0))(
            disp_params, shards, keys, lr
        )

        # --- staleness-weighted buffered aggregation of deltas
        succ = valid & ~ev["dropped"][idx]
        staleness = jnp.maximum(version - disp_ver, 0)
        w = succ.astype(jnp.float32) * staleness_weight(
            staleness, acfg.staleness_mode, acfg.staleness_exp
        )
        wsum = w.sum()
        has = wsum > 0
        denom = jnp.maximum(wsum, 1e-9)

        def agg(g, u, d):
            wshape = (-1,) + (1,) * (g.ndim)
            delta = (u - d).astype(jnp.float32)
            upd = g + (jnp.sum(delta * w.reshape(wshape), axis=0) / denom).astype(g.dtype)
            return jnp.where(has, upd, g)

        params = jax.tree.map(agg, state["params"], updated, disp_params)
        version = version + has.astype(jnp.int32)
        hist = jax.tree.map(
            lambda h, p: h.at[version % H].set(p), state["hist"], params
        )
        # NaN, not a fake 0.0 datapoint, when nothing was aggregated
        mean_loss = jnp.where(has, jnp.sum(losses * w) / denom, jnp.nan)

        # --- completed clients go idle; wall-clock AoI samples
        # gaps are i.i.d. — draw only the B popped clients' worth
        gaps = lat_mod.sample_avail_gap(k_gap, profile, B)
        ev = {
            **ev,
            "next_avail": ev["next_avail"]
            .at[ev_mod.scatter_idx(idx, valid)]
            .set(new_clock + gaps, mode="drop"),
        }
        x_wall = t_ev - ev["last_done"][idx]
        wall_ok = succ & (ev["last_done"][idx] >= 0.0)
        wall_okf = wall_ok.astype(jnp.float32)
        ev = {
            **ev,
            "last_done": ev["last_done"]
            .at[ev_mod.scatter_idx(idx, succ)]
            .set(t_ev, mode="drop"),
        }

        stats = {
            "wall_sx": stats["wall_sx"] + jnp.sum(jnp.where(wall_ok, x_wall, 0.0)),
            "wall_sx2": stats["wall_sx2"] + jnp.sum(jnp.where(wall_ok, x_wall**2, 0.0)),
            "wall_cnt": stats["wall_cnt"] + wall_okf.sum(),
            "ep_sx": ep_sx, "ep_sx2": ep_sx2, "ep_cnt": ep_cnt,
            "stale_sum": stats["stale_sum"]
            + jnp.sum(jnp.where(succ, staleness, 0).astype(jnp.float32)),
            "stale_cnt": stats["stale_cnt"] + succ.astype(jnp.float32).sum(),
            "stale_max": jnp.maximum(
                stats["stale_max"], jnp.max(jnp.where(succ, staleness, 0))
            ),
            "updates": stats["updates"] + succ.astype(jnp.float32).sum(),
            "aggs": stats["aggs"] + has.astype(jnp.float32),
        }
        state = {
            **state,
            "params": params, "hist": hist, "sched": sched, "ev": ev,
            "clock": new_clock, "version": version, "stats": stats,
        }
        aux = {
            "send": send,
            "loss": mean_loss,
            "buffer_fill": valid.astype(jnp.int32).sum(),
            "clock": new_clock,
            "version": version,
        }
        return state, aux

    return init_state, step


def run_async_training(
    task: FLTask,
    fl: FLConfig,
    acfg: Optional[AsyncConfig] = None,
    policy: Optional[Policy] = None,
    progress: bool = False,
) -> Dict:
    """Full asynchronous FL run. ``fl.rounds`` counts *server steps* (one
    buffer flush each). Returns history + load stats on both clocks."""
    acfg = acfg or AsyncConfig()
    key = jax.random.PRNGKey(fl.seed)
    k_init, k_policy, k_run = jax.random.split(key, 3)
    policy = policy or make_policy(fl.policy, fl.n_clients, fl.k, fl.m)
    params = task.init(k_init)
    sched = policy.init(k_policy, fl.n_clients)
    init_state, step = make_async_step(task, fl, acfg, policy)
    state = init_state(params, sched, jax.random.fold_in(k_run, 2**31))

    steps = fl.rounds
    keep_hist = steps * fl.n_clients <= HISTORY_CELL_CAP
    sel_hist = np.zeros((steps, fl.n_clients), dtype=bool) if keep_hist else None
    history = {
        "round": [], "clock": [], "version": [], "accuracy": [],
        "eval_loss": [], "train_loss": [], "buffer_fill": [],
    }
    t0 = time.time()
    for s in range(steps):
        state, aux = step(state, jax.random.fold_in(k_run, s))
        if keep_hist:
            sel_hist[s] = np.asarray(aux["send"])
        if (s + 1) % fl.eval_every == 0 or s == steps - 1:
            evm = task.eval_fn(state["params"])
            history["round"].append(s + 1)
            history["clock"].append(float(aux["clock"]))
            history["version"].append(int(aux["version"]))
            history["accuracy"].append(float(evm["accuracy"]))
            history["eval_loss"].append(float(evm["loss"]))
            history["train_loss"].append(float(aux["loss"]))
            history["buffer_fill"].append(int(aux["buffer_fill"]))
            if progress:
                print(
                    f"  [{policy.name}/{acfg.resolved_profile().name}] "
                    f"step {s + 1:4d} t={float(aux['clock']):9.2f}s "
                    f"v={int(aux['version']):4d} "
                    f"acc={float(evm['accuracy']):.4f} "
                    f"loss={float(evm['loss']):.4f} "
                    f"({time.time() - t0:.1f}s)",
                    flush=True,
                )
    st = {k: float(v) for k, v in state["stats"].items()}

    def _mv(sx, sx2, cnt):
        if cnt <= 0:
            return float("nan"), float("nan")
        mean = sx / cnt
        return mean, max(sx2 / cnt - mean * mean, 0.0)

    mean_w, var_w = _mv(st["wall_sx"], st["wall_sx2"], st["wall_cnt"])
    mean_e, var_e = _mv(st["ep_sx"], st["ep_sx2"], st["ep_cnt"])
    wall_stats = {
        "mean_X_wall": mean_w, "var_X_wall": var_w,
        "num_samples_wall": int(st["wall_cnt"]),
        "mean_X_epoch": mean_e, "var_X_epoch": var_e,
        "num_samples_epoch": int(st["ep_cnt"]),
        "mean_staleness": st["stale_sum"] / max(st["stale_cnt"], 1.0),
        "max_staleness": int(st["stale_max"]),
        "updates_applied": int(st["updates"]),
        "aggregations": int(st["aggs"]),
        "sim_time": float(state["clock"]),
    }
    return {
        "history": history,
        "selection": sel_hist,
        "wall_stats": wall_stats,
        "params": state["params"],
        "wall_time_s": time.time() - t0,
    }
