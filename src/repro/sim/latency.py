"""Composable per-client latency/availability models.

A ``LatencyProfile`` describes the wall-clock behaviour of one fleet:

  * compute time   ~ speed_i * LogNormal(mu, sigma)       (local training)
  * comm time      ~ shift + Exponential(rate)            (up/down link)
  * availability   ~ Exponential(mean gap) off-time between sessions
  * dropout        ~ Bernoulli(hazard) per dispatch (update is lost)
  * speed_i        ~ LogNormal(0, hetero) — persistent per-client multiplier
                     (device classes: phones vs workstations)

All samplers are pure jit-compatible functions returning ``(n,)`` arrays,
so the event engine can draw a whole fleet's latencies in one fused op.
Setting every spread parameter to zero gives the *degenerate* profile
(every client takes exactly ``exp(mu)`` seconds, always available, never
drops) under which the asynchronous loop provably collapses onto the
synchronous FedAvg round — the reduction the tests pin down.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LatencyProfile:
    name: str
    compute_mu: float = 0.0  # log of median compute seconds
    compute_sigma: float = 0.0  # lognormal spread; 0 => deterministic
    comm_shift: float = 0.0  # deterministic link latency floor
    comm_rate: float = 0.0  # exponential tail rate; 0 => no stochastic tail
    avail_gap: float = 0.0  # mean off-time between sessions; 0 => always on
    dropout: float = 0.0  # per-dispatch probability the update is lost
    hetero: float = 0.0  # per-client persistent speed spread (lognormal)

    def mean_latency(self) -> float:
        """Closed-form mean of one dispatch's wall time: E[speed * compute]
        + E[comm], matching ``sample_latency`` exactly (lognormal mean
        ``exp(mu + (sigma^2 + hetero^2)/2)`` plus ``shift + 1/rate``).

        Deliberately *excludes* ``avail_gap`` and ``dropout`` — those
        shape when a dispatch can start and whether its update survives,
        not how long the dispatch itself takes. For sizing runs on
        profiles with off-windows or dropouts (``mobile``), use
        ``mean_update_interval``, which folds both in; pinned against
        the empirical samplers by ``tests/test_latency_profiles.py``.
        """
        compute = math.exp(self.compute_mu + 0.5 * (self.compute_sigma**2 + self.hetero**2))
        comm = self.comm_shift + (1.0 / self.comm_rate if self.comm_rate > 0 else 0.0)
        return compute + comm

    def mean_update_interval(self) -> float:
        """Expected wall time per *successful* update from one client
        dispatching back-to-back: each attempt pays the dispatch latency
        plus the mean off-window before the next session
        (``sample_avail_gap``'s exponential has mean ``avail_gap``), and
        a ``dropout`` fraction of attempts is lost, inflating the
        per-success cost by ``1/(1 - dropout)``. This is the number to
        size run lengths with on profiles like ``mobile``, where
        ``mean_latency`` alone underestimates wall time by ~1.8x."""
        if not 0.0 <= self.dropout < 1.0:
            raise ValueError(
                f"dropout must be in [0, 1) for a finite per-success "
                f"interval, got {self.dropout}"
            )
        return (self.mean_latency() + self.avail_gap) / (1.0 - self.dropout)


PROFILES: Dict[str, LatencyProfile] = {
    # zero-spread reference: async loop == sync FedAvg round
    "uniform": LatencyProfile("uniform"),
    # mild datacenter jitter: tight compute, thin comm tail
    "datacenter": LatencyProfile(
        "datacenter", compute_sigma=0.1, comm_shift=0.05, comm_rate=20.0
    ),
    # the paper's edge setting: heavy-tailed devices, flaky links
    "lognormal": LatencyProfile(
        "lognormal", compute_sigma=0.6, comm_shift=0.1, comm_rate=2.0, hetero=0.4
    ),
    # mobile fleet: long off-windows, dropouts, extreme stragglers
    "mobile": LatencyProfile(
        "mobile",
        compute_sigma=1.0,
        comm_shift=0.2,
        comm_rate=1.0,
        avail_gap=2.0,
        dropout=0.1,
        hetero=0.8,
    ),
}


def get_profile(name: str) -> LatencyProfile:
    try:
        return PROFILES[name]
    except KeyError:
        raise ValueError(
            f"unknown latency profile {name!r}; options: {sorted(PROFILES)}"
        ) from None


def client_speed(key: jax.Array, n: int, profile: LatencyProfile) -> jnp.ndarray:
    """Persistent per-client speed multiplier, sampled once per run."""
    if profile.hetero <= 0:
        return jnp.ones((n,), jnp.float32)
    return jnp.exp(profile.hetero * jax.random.normal(key, (n,), jnp.float32))


def sample_latency(
    key: jax.Array, profile: LatencyProfile, speed: jnp.ndarray
) -> jnp.ndarray:
    """One dispatch's total wall time (compute + comm) per client, (n,) f32."""
    n = speed.shape[0]
    k_c, k_t = jax.random.split(key)
    if profile.compute_sigma > 0:
        compute = jnp.exp(
            profile.compute_mu
            + profile.compute_sigma * jax.random.normal(k_c, (n,), jnp.float32)
        )
    else:
        compute = jnp.full((n,), math.exp(profile.compute_mu), jnp.float32)
    comm = jnp.full((n,), profile.comm_shift, jnp.float32)
    if profile.comm_rate > 0:
        comm = comm + jax.random.exponential(k_t, (n,), jnp.float32) / profile.comm_rate
    return speed * compute + comm


def sample_avail_gap(key: jax.Array, profile: LatencyProfile, n: int) -> jnp.ndarray:
    """Off-time before a client re-enters its availability window, (n,) f32."""
    if profile.avail_gap <= 0:
        return jnp.zeros((n,), jnp.float32)
    return profile.avail_gap * jax.random.exponential(key, (n,), jnp.float32)


def sample_dropout(key: jax.Array, profile: LatencyProfile, n: int) -> jnp.ndarray:
    """Per-dispatch dropout draw, (n,) bool (True = update is lost)."""
    if profile.dropout <= 0:
        return jnp.zeros((n,), jnp.bool_)
    return jax.random.uniform(key, (n,)) < profile.dropout


def simulate_sync_duration(
    selection, profile: LatencyProfile, key: jax.Array
) -> float:
    """Simulated wall time of a *synchronous* run with realized selection
    history (rounds, n): each round waits for its slowest selected client
    under this profile. The baseline the async loop is compared against."""
    selection = jnp.asarray(selection)
    n = selection.shape[1]
    speed = client_speed(key, n, profile)
    total = 0.0
    for r, sel in enumerate(selection):
        lat = sample_latency(jax.random.fold_in(key, r), profile, speed)
        total += float(jnp.max(jnp.where(sel, lat, 0.0)))
    return total
