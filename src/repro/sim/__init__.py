"""Event-driven asynchronous fleet simulator.

Advances a simulated wall clock over an arbitrarily large client fleet and
drives buffered asynchronous federated training (FedBuff-style) with
staleness-aware aggregation. The paper's load metric (Var[X], AoI) is
measured here in *simulated seconds* rather than round index, which is
where its fairness and no-coordination claims become systems claims:
stragglers, dropouts, and availability windows all shift the realized
selection process.
"""
from repro.sim.arrivals import (  # noqa: F401
    ArrivalProcess,
    sample_arrival_counts,
    sample_gen_lens,
    sample_requests,
)
from repro.sim.latency import (  # noqa: F401
    PROFILES,
    LatencyProfile,
    client_speed,
    get_profile,
    sample_avail_gap,
    sample_dropout,
    sample_latency,
)
from repro.sim.events import (  # noqa: F401
    init_event_state,
    next_k_events,
    schedule_completions,
)
from repro.sim.async_rounds import (  # noqa: F401
    AsyncConfig,
    run_async_training,
    staleness_weight,
)
