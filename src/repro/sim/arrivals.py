"""Open-loop request arrival process for the serving tier.

An ``ArrivalProcess`` describes synthetic inference traffic the way
``LatencyProfile`` describes fleet wall-clock behaviour:

  * arrivals per tick ~ Poisson(rate)            (open loop: demand does
                                                  not wait for capacity)
  * generation length ~ gen_len * LogNormal(0, spread), clipped to
                        [1, max(1, 2 * gen_len)]
  * prompt tokens     ~ Uniform(vocab)

``from_profile`` derives the length spread from a latency profile's
heterogeneity (``compute_sigma + hetero``): fleets with heavy-tailed
device behaviour get matching heavy-tailed request sizes, the uniform
profile gets fixed-size requests. All samplers are pure ``jax.random``
functions, so a whole trace is drawn up front and the serving loop stays
deterministic under a seed.
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.sim.latency import LatencyProfile


@dataclasses.dataclass(frozen=True)
class ArrivalProcess:
    name: str
    rate: float  # mean requests per scheduler tick (Poisson)
    prompt_len: int  # prompt tokens per request
    gen_len: int  # median tokens to generate
    len_spread: float = 0.0  # lognormal sigma of the generation length

    def __post_init__(self) -> None:
        if self.rate < 0:
            raise ValueError(f"rate must be >= 0, got {self.rate}")
        if self.prompt_len < 1 or self.gen_len < 1:
            raise ValueError("prompt_len and gen_len must be >= 1")


def from_profile(
    profile: LatencyProfile, rate: float, prompt_len: int, gen_len: int
) -> ArrivalProcess:
    """Traffic shaped by a fleet latency profile: the request-length
    spread inherits the profile's compute heterogeneity."""
    return ArrivalProcess(
        name=f"poisson[{profile.name}]",
        rate=rate,
        prompt_len=prompt_len,
        gen_len=gen_len,
        len_spread=profile.compute_sigma + profile.hetero,
    )


def sample_arrival_counts(key, proc: ArrivalProcess, ticks: int) -> jnp.ndarray:
    """(ticks,) int32 — requests arriving at each tick."""
    return jax.random.poisson(key, proc.rate, (ticks,)).astype(jnp.int32)


def sample_gen_lens(key, proc: ArrivalProcess, n: int) -> jnp.ndarray:
    """(n,) int32 generation lengths ~ gen_len * LogNormal(0, spread),
    clipped to [1, max(1, 2 * gen_len)] so one giant request cannot pin a
    slot for an unbounded run."""
    if proc.len_spread == 0.0:
        return jnp.full((n,), proc.gen_len, jnp.int32)
    ln = jnp.exp(proc.len_spread * jax.random.normal(key, (n,)))
    return jnp.clip(
        jnp.round(proc.gen_len * ln), 1, max(1, 2 * proc.gen_len)
    ).astype(jnp.int32)


def sample_requests(key, proc: ArrivalProcess, ticks: int, vocab: int) -> List:
    """Materialize a whole request trace: a list of
    ``repro.serve.Request`` covering ``ticks`` scheduler ticks."""
    from repro.serve.loop import Request

    k_cnt, k_len, k_tok = jax.random.split(key, 3)
    counts = np.asarray(sample_arrival_counts(k_cnt, proc, ticks))
    total = int(counts.sum())
    lens = np.asarray(sample_gen_lens(k_len, proc, total))
    prompts = np.asarray(
        jax.random.randint(k_tok, (total, proc.prompt_len), 0, vocab, jnp.int32)
    )
    out, rid = [], 0
    for t, c in enumerate(counts):
        for _ in range(int(c)):
            out.append(
                Request(rid=rid, tick=t, prompt=prompts[rid],
                        gen_len=int(lens[rid]))
            )
            rid += 1
    return out
