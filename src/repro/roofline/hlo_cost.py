"""While-loop-aware cost model over optimized HLO text.

``compiled.cost_analysis()`` on the CPU backend counts each ``while`` body
ONCE regardless of trip count — a framework that scans over layers would
see its per-step flops undercounted by ~num_layers. This module re-derives
  * dot/conv FLOPs,
  * bytes written (fusion/op results — a proxy for HBM traffic closer to
    TPU reality than raw "bytes accessed", since fusion internals stay in
    registers/VMEM),
  * per-collective-kind communication bytes,
from the optimized HLO text, multiplying every computation by its loop
trip count (nested whiles compose multiplicatively).

This is the dry-run "profiler": hillclimbing reads its per-kind collective
table and flop/byte totals (EXPERIMENTS.md §Roofline documents the
cross-check against cost_analysis()).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_TOKEN = re.compile(r"([a-z]\w*)\[([\d,]*)\]")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$"
)
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_CALLED = re.compile(r"(?:body|condition|calls)=%([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONSTANT = re.compile(r"constant\((\d+)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota", "while", "conditional", "call", "custom-call",
    "broadcast", "reshape",
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_TOKEN.findall(type_str):
        nb = _DTYPE_BYTES.get(dt)
        if nb is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nb
    return total


def _first_shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_TOKEN.search(type_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return dims


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.lines: List[str] = []
        self.flops = 0.0
        self.bytes = 0.0
        self.coll: Dict[str, float] = defaultdict(float)
        self.calls: List[Tuple[str, str, Optional[str]]] = []  # (kind, callee, cond)
        self.max_const = 0  # for trip-count inference when used as condition


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HEADER.match(line)
            if m:
                cur = Computation(m.group(1))
                if line.lstrip().startswith("ENTRY"):
                    entry = cur.name
                comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        cur.lines.append(line)
        for c in _CONSTANT.findall(line):
            cur.max_const = max(cur.max_const, int(c))
    if entry:
        comps["__entry__"] = comps[entry]
    return comps


def _analyze_computation(comp: Computation) -> None:
    symtab: Dict[str, str] = {}
    # first pass: symbol table (types of each value)
    for line in comp.lines:
        m = _OP_LINE.match(line)
        if m:
            name, type_str = m.group(1), m.group(2)
            symtab[name] = type_str
        else:
            pm = re.match(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+parameter\(", line)
            if pm:
                symtab[pm.group(1)] = pm.group(2)
    for line in comp.lines:
        m = _OP_LINE.match(line)
        if not m:
            continue
        name, type_str, op, rest = m.groups()
        # call edges
        cm = _CALLED.findall(line)
        if op == "while":
            body = re.search(r"body=%([\w.\-]+)", line)
            cond = re.search(r"condition=%([\w.\-]+)", line)
            if body:
                comp.calls.append(("while", body.group(1), cond.group(1) if cond else None))
            if cond:
                comp.calls.append(("cond", cond.group(1), None))
        elif op in ("fusion", "call", "async-start"):
            for c in cm:
                comp.calls.append(("call", c, None))
        bm = _BRANCHES.search(line)
        if bm:
            for c in bm.group(1).split(","):
                c = c.strip().lstrip("%")
                if c:
                    comp.calls.append(("branch", c, None))
        # flops
        if op in ("dot", "convolution") or (
            op == "custom-call" and ("matmul" in line or "dot" in line)
        ):
            res_dims = _first_shape_dims(type_str) or []
            res_prod = 1
            for d in res_dims:
                res_prod *= d
            contract = 1
            cmatch = _CONTRACT.search(line)
            first_operand = re.search(r"%([\w.\-]+)", rest)
            # operand shapes print inline (newer HLO: "dot(f32[a,b] %x, ...)")
            # or resolve through the symbol table (older: "dot(%x, %y)")
            operands_str = rest.split(")")[0]
            lhs_dims = _first_shape_dims(operands_str)
            if lhs_dims is None and first_operand:
                lhs_dims = _first_shape_dims(symtab.get(first_operand.group(1), ""))
            if cmatch and lhs_dims:
                for idx in cmatch.group(1).split(","):
                    if idx and int(idx) < len(lhs_dims):
                        contract *= lhs_dims[int(idx)]
            elif op == "convolution":
                wnd = re.search(r"window=\{size=([\dx]+)", line)
                if wnd:
                    spatial = 1
                    for s in wnd.group(1).split("x"):
                        spatial *= int(s)
                    contract = spatial * (lhs_dims[-1] if lhs_dims else 1)
            comp.flops += 2.0 * res_prod * contract
        # collective bytes
        for kind in COLLECTIVES:
            if op == kind or op == kind + "-start":
                res_b = _shape_bytes(type_str)
                # operands: resolve named refs
                operand_names = re.findall(r"%([\w.\-]+)", rest.split(")")[0])
                op_b = sum(_shape_bytes(symtab.get(o, "")) for o in operand_names)
                if kind == "all-gather":
                    comp.coll[kind] += res_b
                elif kind == "reduce-scatter":
                    comp.coll[kind] += op_b
                elif kind == "all-reduce":
                    comp.coll[kind] += 2 * max(res_b, op_b)
                else:
                    comp.coll[kind] += max(res_b, op_b)
                break
        # bytes written
        if op == "dynamic-update-slice":
            # in-place update with buffer donation/aliasing on TPU: traffic
            # is the UPDATE operand (e.g. one decode token written into a
            # ring cache), not the whole result buffer
            operands = re.findall(r"%([\w.\-]+)", rest.split(")")[0])
            if len(operands) >= 2 and operands[1] in symtab:
                comp.bytes += _shape_bytes(symtab[operands[1]])
            else:
                comp.bytes += _shape_bytes(type_str)
        elif op not in _SKIP_BYTES_OPS:
            comp.bytes += _shape_bytes(type_str)
        elif op == "custom-call":
            comp.bytes += _shape_bytes(type_str)


def _trip_count(comps: Dict[str, Computation], cond_name: Optional[str]) -> int:
    if cond_name and cond_name in comps:
        return max(comps[cond_name].max_const, 1)
    return 1


def top_contributors(text: str, n: int = 15) -> List[Dict]:
    """Per-computation (flops, bytes, multiplier) table, largest bytes first
    — the dry-run 'profile' used to target §Perf iterations."""
    comps = parse_hlo(text)
    entry = comps.get("__entry__")
    if entry is None:
        return []
    for c in comps.values():
        if not c.flops and not c.bytes and not c.coll and c.lines:
            _analyze_computation(c)
    mult, mult_b = _multipliers(comps, entry)
    rows = []
    for cname, c in comps.items():
        if cname == "__entry__" or mult[cname] == 0:
            continue
        rows.append(
            {
                "computation": cname,
                "mult": mult[cname],
                "flops": mult[cname] * c.flops,
                "bytes": mult_b[cname] * c.bytes,
                "collective_bytes": mult[cname] * sum(c.coll.values()),
            }
        )
    rows.sort(key=lambda r: -r["bytes"])
    return rows[:n]


def _multipliers(comps, entry):
    """(flop/collective multiplier, bytes multiplier) per computation.

    Fusion-called computations execute in registers/VMEM: their dot flops
    and collectives count, but their elementwise intermediates do NOT
    touch memory — only the fusion's result (counted at the call site)
    does. While bodies count fully, x trip count.
    """
    mult: Dict[str, float] = defaultdict(float)
    mult_b: Dict[str, float] = defaultdict(float)
    mult[entry.name] = 1.0
    mult_b[entry.name] = 1.0
    for _ in range(64):
        changed = False
        for cname, c in comps.items():
            if cname == "__entry__" or mult[cname] == 0:
                continue
            for kind, callee, cond in c.calls:
                if callee not in comps:
                    continue
                m = mult[cname]
                mb = mult_b[cname]
                if kind == "while":
                    trip = _trip_count(comps, cond)
                    m *= trip
                    mb *= trip
                elif kind == "call":
                    mb = 0.0  # fusion internals stay in registers
                if m > mult[callee]:
                    mult[callee] = m
                    changed = True
                if mb > mult_b[callee]:
                    mult_b[callee] = mb
                    changed = True
        if not changed:
            break
    return mult, mult_b


def analyze(text: str) -> Dict:
    """Full-module analysis. Returns dict with flops, bytes, collectives
    (per-kind), all per-device (post-SPMD shapes)."""
    comps = parse_hlo(text)
    entry = comps.get("__entry__")
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collectives": {}}
    for c in comps.values():
        if not c.flops and not c.bytes and not c.coll and c.lines:
            _analyze_computation(c)
    mult, mult_b = _multipliers(comps, entry)
    flops = 0.0
    bytes_ = 0.0
    coll: Dict[str, float] = defaultdict(float)
    for cname, c in comps.items():
        if cname == "__entry__":
            continue
        m = mult[cname]
        if m == 0:
            continue
        flops += m * c.flops
        bytes_ += mult_b[cname] * c.bytes
        for k, v in c.coll.items():
            coll[k] += m * v
    return {"flops": flops, "bytes": bytes_, "collectives": dict(coll)}
