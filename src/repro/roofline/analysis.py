"""Three-term roofline from a compiled dry-run artifact.

  compute  = HLO_FLOPs_per_device / peak_FLOP/s
  memory   = HLO_bytes_per_device / HBM_bw
  collect. = per-device collective bytes / link_bw

FLOPs/bytes come from ``compiled.cost_analysis()`` (post-SPMD, so already
per device). Collective bytes are parsed from the optimized HLO text:
we sum the transferred sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops (all-reduce counted twice: it moves
~2x the payload in a ring).
"""
from __future__ import annotations

import math
import re
from typing import Dict, List, Tuple

from repro.roofline import hw

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# e.g.  bf16[2,336,21504]{2,1,0} all-gather(...)
_SHAPE_RE = re.compile(r"([a-z]+\d*)\[([\d,]*)\]")
_COLL_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(shape_str: str) -> int:
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    nbytes = _DTYPE_BYTES.get(dt)
    if nbytes is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes moved by each collective kind.

    HLO after SPMD partitioning has per-device shapes. A line looks like:
      %ag = bf16[16,336,...] all-gather(bf16[1,336,...] %x), ...
    For all-gather we count the result size (what each device receives);
    for reduce-scatter the operand size (what each device sends); for
    all-reduce 2x the size (ring = reduce-scatter + all-gather); for
    all-to-all and collective-permute the payload size.
    """
    out = {k: 0 for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        line = line.strip()
        for kind in _COLL_KINDS:
            token = f" {kind}("
            if token not in line and not line.startswith(kind + "("):
                continue
            if f"{kind}-start" in line or f"{kind}-done" in line:
                # async pairs: count only the -start (has the shapes)
                if f"{kind}-done" in line:
                    continue
            # result shape: first shape token at/after '=' (tuple results:
            # sum components)
            try:
                rhs = line.split("=", 1)[1]
            except IndexError:
                continue
            head = rhs.split(kind)[0]
            shapes = _SHAPE_RE.findall(head)
            result_bytes = 0
            for dt, dims in shapes:
                nb = _DTYPE_BYTES.get(dt, 0)
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                result_bytes += n * nb
            # operand shapes: inside kind(...)
            inner = rhs.split(token if token in rhs else kind + "(", 1)[-1]
            op_shapes = _SHAPE_RE.findall(inner.split(")")[0])
            operand_bytes = 0
            for dt, dims in op_shapes:
                nb = _DTYPE_BYTES.get(dt, 0)
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                operand_bytes += n * nb
            if kind == "all-gather":
                out[kind] += result_bytes
            elif kind == "reduce-scatter":
                out[kind] += operand_bytes
            elif kind == "all-reduce":
                out[kind] += 2 * max(result_bytes, operand_bytes)
            else:
                out[kind] += max(result_bytes, operand_bytes)
            break
    return out


def roofline_terms(
    flops_per_device: float,
    bytes_per_device: float,
    collective_bytes: Dict[str, int],
) -> Dict:
    coll_total = sum(collective_bytes.values())
    t_compute = flops_per_device / hw.PEAK_FLOPS_BF16
    t_memory = bytes_per_device / hw.HBM_BW
    t_coll = coll_total / hw.ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    total = sum(terms.values())
    return {
        **terms,
        "dominant": dominant,
        "collective_bytes": collective_bytes,
        "collective_bytes_total": coll_total,
        # fraction of a perfectly-overlapped step spent on the dominant term
        "dominant_fraction": bound / total if total > 0 else 0.0,
    }


def model_flops(param_count: int, tokens: int, mode: str = "train") -> float:
    """6·N·D for training, 2·N·D for inference forward (per global step)."""
    mult = 6 if mode == "train" else 2
    return mult * param_count * tokens
