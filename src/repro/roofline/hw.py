"""TPU v5e hardware constants (the dry-run's roofline denominators)."""

PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (~per-direction usable)

CHIPS_PER_POD = 256
PODS = 2
