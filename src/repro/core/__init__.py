"""Paper core: AoI load metric, optimal Markov scheduling (Theorems 1-2)."""
from repro.core.aoi import age_update, chain_state  # noqa: F401
from repro.core.load_metric import (  # noqa: F401
    empirical_load_stats,
    init_selection_accum,
    markov_moments,
    markov_var,
    optimal_probs,
    optimal_var,
    peak_ages_from_history,
    random_selection_mean,
    random_selection_var,
    selection_rate,
    selection_stats_from_accum,
    steady_state,
    update_selection_accum,
    theorem1_optimal,
    theorem1_var,
)
from repro.core.selection import (  # noqa: F401
    POLICY_NAMES,
    Policy,
    make_policy,
    simulate,
    simulate_stats,
)
