"""Adaptive / dropout-robust Markov policies (paper Remark 1 + Conclusion).

The optimal chain of Theorem 2 sets p_i = 0 below the threshold age: a
client is *never* selected early. Remark 1 observes that with client
dropout one may want p_i > 0 everywhere, trading a little Var[X] for a
chance to collect an update before the client leaves. This module builds
the blended family

    p(eps, c) = clip((1 - eps) * p_opt + eps * c, 0, 1),   p_m kept > 0,

solving the scalar c by bisection so the steady-state selection rate stays
exactly k/n (constraint (8) — the same fairness constraint as the paper),
and quantifies the trade-off: Var[X] (load balance) vs the probability
that a client is selected at least once before dropping out.
"""
from __future__ import annotations

import math
from typing import Tuple

import numpy as np

from repro.core import load_metric as lm


def floored_probs(n: int, k: int, m: int, eps: float) -> np.ndarray:
    """Blend of the optimal policy with a uniform floor, rate-corrected.

    eps = 0 -> Theorem 2 optimum; eps = 1 -> age-independent Bernoulli
    (geometric X, random-selection statistics).
    """
    if not 0.0 <= eps <= 1.0:
        raise ValueError("eps in [0,1]")
    p_opt = lm.optimal_probs(n, k, m)
    target = k / n
    lo, hi = 0.0, 1.0

    def rate(c: float) -> float:
        p = np.clip((1 - eps) * p_opt + eps * c, 0.0, 1.0)
        p[m] = max(p[m], 1e-6)
        return lm.selection_rate(p)

    # rate(c) is monotone increasing in c
    if rate(lo) > target:
        c = lo
    elif rate(hi) < target:
        c = hi
    else:
        for _ in range(80):
            mid = (lo + hi) / 2
            if rate(mid) < target:
                lo = mid
            else:
                hi = mid
        c = (lo + hi) / 2
    p = np.clip((1 - eps) * p_opt + eps * c, 0.0, 1.0)
    p[m] = max(p[m], 1e-6)
    return p


def dropout_update_probability(probs: np.ndarray, d: float) -> float:
    """P(a fresh client is selected at least once before dropping out),
    with i.i.d. per-round dropout probability d.

    Closed form over the age chain: starting at state 0, each round the
    client survives w.p. (1-d) and is then selected w.p. p_state.
    """
    m = len(probs) - 1
    # f_i = P(eventually selected before dropout | current state i)
    # f_i = (1-d) * (p_i + (1-p_i) f_{i+1}), f at state m self-loops:
    # f_m = (1-d) p_m / (1 - (1-d)(1-p_m))
    p = np.asarray(probs, dtype=np.float64)
    fm = (1 - d) * p[m] / (1 - (1 - d) * (1 - p[m]))
    f = fm
    for i in range(m - 1, -1, -1):
        f = (1 - d) * (p[i] + (1 - p[i]) * f)
    return float(f)


def tradeoff_curve(
    n: int, k: int, m: int, d: float, eps_grid=None
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(eps, Var[X], P(update before dropout)) along the blend family."""
    if eps_grid is None:
        eps_grid = np.linspace(0.0, 1.0, 11)
    var = np.array([lm.markov_var(floored_probs(n, k, m, e)) for e in eps_grid])
    pup = np.array(
        [dropout_update_probability(floored_probs(n, k, m, e), d) for e in eps_grid]
    )
    return np.asarray(eps_grid), var, pup
