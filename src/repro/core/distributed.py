"""Distributed decentralized scheduling via ``shard_map``.

The paper's key systems claim: the Markov policy needs *no coordination* —
each client decides from its own age. At fleet scale this maps onto
``shard_map``: the (n,) age vector is sharded across the ``data`` axis, each
device runs the Bernoulli decisions for its local client shard with an
independent per-device RNG fold, and the only cross-device traffic is the
O(1) ``psum`` of cohort counts (vs. an O(n) gather that a centralized
policy such as oldest-age top-k requires — which we also provide, for an
honest comparison of communication volume).

This module also owns the fleet-mesh primitives the sharded async engine
(``repro.engine.sharded``) is built on: ``fleet_mesh`` (a 1-D device mesh
over a ``fleet`` axis) and ``sharded_next_k_events`` — the O(devices * k)
buffer-pop merge (per-shard local top-k, an ``all_gather`` of the
``devices x k`` candidates, then a global merge) that replaces
materializing the full (n,) completion-time vector on one device.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.aoi import age_update

# the engine's fleet-sharding axis name (1-D mesh over client shards)
FLEET_AXIS = "fleet"


def fleet_mesh(shards: int = 0, axis: str = FLEET_AXIS) -> Mesh:
    """1-D mesh of the first ``shards`` local devices over ``axis``
    (``shards=0`` takes every available device)."""
    devices = jax.devices()
    d = shards or len(devices)
    if d > len(devices):
        raise ValueError(
            f"requested {d} fleet shards but only {len(devices)} devices "
            "are available (on CPU, XLA_FLAGS="
            "--xla_force_host_platform_device_count=N makes N fake devices)"
        )
    return Mesh(np.asarray(devices[:d]), (axis,))


def cohort_padding(b: int, shards: int) -> int:
    """Zero-weight slots appended to a ``b``-wide cohort so its axis
    divides a ``shards``-device mesh — the cohort-parallel execution mode
    shards the padded axis evenly and the padding slots carry weight 0
    (they never touch the aggregate, the telemetry, or the event state,
    which masks them exactly like invalid buffer slots)."""
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    return -b % shards


def resolve_fleet_shards(n: int, shards: int, available: int) -> int:
    """Shard count for an ``n``-client fleet: ``shards`` when explicit
    (must divide ``n`` so every device owns an equal client block), else
    the largest divisor of ``n`` at most ``available`` — auto-detection
    never fails, it just leaves devices idle for awkward fleet sizes."""
    if shards:
        if n % shards:
            raise ValueError(
                f"n_clients={n} is not divisible by mesh_shards={shards}; "
                "pick a shard count dividing the fleet (or 0 to auto-detect)"
            )
        return shards
    d = max(min(available, n), 1)
    while n % d:
        d -= 1
    return d


def markov_step_sharded(
    mesh: Mesh,
    axis: str,
    probs: jnp.ndarray,
    m: int,
):
    """Returns a jit'able f(ages, round_idx, seed) -> (selected, new_ages, count).

    ``ages`` is sharded over ``axis``; decisions are computed purely locally
    (decentralized), only the cohort count is psum'd.
    """
    spec = P(axis)

    def local(ages, round_idx, seed):
        di = jax.lax.axis_index(axis)
        key = jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(0), seed), di)
        key = jax.random.fold_in(key, round_idx)
        chain = jnp.minimum(ages, m)
        send_p = probs[chain]
        sel = jax.random.uniform(key, ages.shape) < send_p
        new_ages = age_update(ages, sel)
        count = jax.lax.psum(jnp.sum(sel.astype(jnp.int32)), axis)
        return sel, new_ages, count

    f = shard_map(
        local,
        mesh=mesh,
        in_specs=(spec, P(), P()),
        out_specs=(spec, spec, P()),
    )
    return jax.jit(f)


def oldest_age_step_sharded(mesh: Mesh, axis: str, k: int):
    """Centralized oldest-age at fleet scale: per-shard local top-k then a
    global top-k over the gathered per-shard candidates (communication
    O(devices * k), vs O(1) for the Markov policy — this asymmetry is the
    paper's decentralization argument, made concrete).

    Ties break toward the lower *global* client index, deterministically,
    matching the contract of ``sim/events.py``: ``lax.top_k`` is stable
    (equal scores surface the lower local index first) and the gathered
    candidate list is ordered by shard, so the flat merge prefers lower
    shards — i.e. lower global ids — among equal ages. No RNG is involved.
    """
    spec = P(axis)

    def local(ages):
        di = jax.lax.axis_index(axis)
        kk = min(k, ages.shape[0])
        top_v, top_i = jax.lax.top_k(ages, kk)
        # global offset of this shard
        base = di * ages.shape[0]
        cand_v = jax.lax.all_gather(top_v, axis)  # (devices, kk)
        cand_i = jax.lax.all_gather(top_i + base, axis)
        flat_v = cand_v.reshape(-1)
        flat_i = cand_i.reshape(-1)
        _, sel_pos = jax.lax.top_k(flat_v, k)
        chosen = flat_i[sel_pos]  # (k,) global ids, replicated
        # local selection mask
        local_ids = base + jnp.arange(ages.shape[0])
        sel = jnp.isin(local_ids, chosen)
        new_ages = age_update(ages, sel)
        return sel, new_ages, chosen

    # ``chosen`` is replicated by construction (every device merges the
    # same gathered candidates), which the static checker can't infer
    f = shard_map(
        local,
        mesh=mesh,
        in_specs=(spec,),
        out_specs=(spec, spec, P()),
        check_rep=False,
    )
    return jax.jit(f)


def sharded_next_k_events(
    mesh: Mesh, n: int, k: int, axis: str = FLEET_AXIS
) -> Callable:
    """The sharded buffer pop: ``f(times (n,)) -> (t (k,), idx (k,))``,
    bit-identical (values, indices, and tie order) to a global
    ``lax.top_k(-times, k)`` over the full fleet.

    Each shard extracts its local k earliest events with a stable local
    top-k, the ``devices x k`` candidates are ``all_gather``-ed, and one
    merge picks the global k — O(devices * k) communication per step
    instead of materializing the (n,) completion-time vector on a single
    device. Tie order is preserved for free: candidates arrive ordered by
    (shard, local rank), both orderings ascending in global index, and
    ``lax.top_k`` stability does the rest.

    Fleets with ``n % devices != 0`` are padded with ``+inf`` sentinels up
    to the next multiple (a padded slot can only surface as an *invalid*
    pop — callers already mask by ``jnp.isfinite``). Returns a function to
    be called under ``jit``; ``k <= n`` as everywhere in the event engine.
    """
    devices = mesh.shape[axis]
    n_pad = -(-n // devices) * devices
    spec = P(axis)

    def local(times):  # (n_pad / devices,)
        di = jax.lax.axis_index(axis)
        shard = times.shape[0]
        kk = min(k, shard)
        neg_v, loc_i = jax.lax.top_k(-times, kk)
        base = di * shard
        cand_v = jax.lax.all_gather(neg_v, axis)  # (devices, kk)
        cand_i = jax.lax.all_gather(loc_i + base, axis)
        # k <= n <= devices * kk: the merge always has enough candidates
        top_v, pos = jax.lax.top_k(cand_v.reshape(-1), k)
        return -top_v, cand_i.reshape(-1)[pos]

    # outputs are replicated by construction (every device merges the same
    # gathered candidates); the static replication checker can't see that
    # through the gather + indexing, hence check_rep=False
    merge = shard_map(
        local, mesh=mesh, in_specs=(spec,), out_specs=(P(), P()),
        check_rep=False,
    )

    def next_k(times):
        if n_pad != n:
            times = jnp.concatenate(
                [times, jnp.full((n_pad - n,), jnp.inf, times.dtype)]
            )
        times = jax.lax.with_sharding_constraint(
            times, NamedSharding(mesh, spec)
        )
        return merge(times)

    return next_k


def scheduler_comm_bytes(n: int, k: int, devices: int) -> Tuple[int, int]:
    """(markov, oldest_age) per-round scheduler communication in bytes —
    the decentralization win, quantified."""
    markov = 4  # one int32 psum
    oldest = devices * k * 8  # gathered (value, index) candidates
    return markov, oldest
