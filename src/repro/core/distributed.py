"""Distributed decentralized scheduling via ``shard_map``.

The paper's key systems claim: the Markov policy needs *no coordination* —
each client decides from its own age. At fleet scale this maps onto
``shard_map``: the (n,) age vector is sharded across the ``data`` axis, each
device runs the Bernoulli decisions for its local client shard with an
independent per-device RNG fold, and the only cross-device traffic is the
O(1) ``psum`` of cohort counts (vs. an O(n) gather that a centralized
policy such as oldest-age top-k requires — which we also provide, for an
honest comparison of communication volume).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.aoi import age_update


def markov_step_sharded(
    mesh: Mesh,
    axis: str,
    probs: jnp.ndarray,
    m: int,
):
    """Returns a jit'able f(ages, round_idx, seed) -> (selected, new_ages, count).

    ``ages`` is sharded over ``axis``; decisions are computed purely locally
    (decentralized), only the cohort count is psum'd.
    """
    spec = P(axis)

    def local(ages, round_idx, seed):
        di = jax.lax.axis_index(axis)
        key = jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(0), seed), di)
        key = jax.random.fold_in(key, round_idx)
        chain = jnp.minimum(ages, m)
        send_p = probs[chain]
        sel = jax.random.uniform(key, ages.shape) < send_p
        new_ages = age_update(ages, sel)
        count = jax.lax.psum(jnp.sum(sel.astype(jnp.int32)), axis)
        return sel, new_ages, count

    f = shard_map(
        local,
        mesh=mesh,
        in_specs=(spec, P(), P()),
        out_specs=(spec, spec, P()),
    )
    return jax.jit(f)


def oldest_age_step_sharded(mesh: Mesh, axis: str, k: int):
    """Centralized oldest-age at fleet scale: per-shard local top-k then a
    global top-k over the gathered per-shard candidates (communication
    O(devices * k), vs O(1) for the Markov policy — this asymmetry is the
    paper's decentralization argument, made concrete).
    """
    spec = P(axis)

    def local(ages, seed):
        di = jax.lax.axis_index(axis)
        key = jax.random.fold_in(jax.random.PRNGKey(seed[0]), di)
        noise = jax.random.uniform(key, ages.shape, minval=0.0, maxval=0.5)
        score = ages.astype(jnp.float32) + noise
        kk = min(k, score.shape[0])
        top_v, top_i = jax.lax.top_k(score, kk)
        # global offset of this shard
        base = di * ages.shape[0]
        cand_v = jax.lax.all_gather(top_v, axis)  # (devices, kk)
        cand_i = jax.lax.all_gather(top_i + base, axis)
        flat_v = cand_v.reshape(-1)
        flat_i = cand_i.reshape(-1)
        _, sel_pos = jax.lax.top_k(flat_v, k)
        chosen = flat_i[sel_pos]  # (k,) global ids, replicated
        # local selection mask
        local_ids = base + jnp.arange(ages.shape[0])
        sel = jnp.isin(local_ids, chosen)
        new_ages = age_update(ages, sel)
        return sel, new_ages, chosen

    f = shard_map(
        local,
        mesh=mesh,
        in_specs=(spec, P(None)),
        out_specs=(spec, spec, P()),
    )
    return jax.jit(f)


def scheduler_comm_bytes(n: int, k: int, devices: int) -> Tuple[int, int]:
    """(markov, oldest_age) per-round scheduler communication in bytes —
    the decentralization win, quantified."""
    markov = 4  # one int32 psum
    oldest = devices * k * 8  # gathered (value, index) candidates
    return markov, oldest
