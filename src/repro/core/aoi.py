"""Age-of-Information dynamics (Eq. 4) as pure JAX functions.

Each client's age increases by one when not selected and resets to zero when
selected: A^{t+1} = (A^t + 1)(1 - S^t). The Markov *chain state* is the age
clipped to the maximum permissible age m (state m self-loops).
"""
from __future__ import annotations

import jax.numpy as jnp


def age_update(ages: jnp.ndarray, selected: jnp.ndarray) -> jnp.ndarray:
    """Eq. (4): elementwise age evolution. ``selected`` is bool/0-1."""
    return (ages + 1) * (1 - selected.astype(ages.dtype))


def chain_state(ages: jnp.ndarray, m: int) -> jnp.ndarray:
    """Markov chain state = min(age, m)."""
    return jnp.minimum(ages, m)


def peak_age_accumulate(
    ages: jnp.ndarray, selected: jnp.ndarray, sum_x: jnp.ndarray, sum_x2: jnp.ndarray, count: jnp.ndarray
):
    """Streaming accumulation of peak-age (= X) first/second moments.

    On each selection, the client's pre-reset age + 1 is one sample of X
    (age counts rounds since last selection; the gap between selections is
    age+1 when selection happens on the current round).
    """
    x = (ages + 1).astype(jnp.float64) if ages.dtype == jnp.int64 else (ages + 1).astype(jnp.float32)
    sel = selected.astype(x.dtype)
    sum_x = sum_x + jnp.sum(x * sel)
    sum_x2 = sum_x2 + jnp.sum(x * x * sel)
    count = count + jnp.sum(sel)
    return sum_x, sum_x2, count
