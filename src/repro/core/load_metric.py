"""The paper's load metric X and its closed-form statistics.

X = number of rounds between subsequent selections of a client (= peak age).
The paper (Eq. 5-7) gives random selection's geometric law; Theorems 1-2 give
the optimal age-dependent Markov policy. This module implements every
closed form plus a numerically exact evaluator for *arbitrary* transition
probabilities via Eqs. (12)-(22), so theory can be cross-checked.
"""
from __future__ import annotations

import math
from typing import Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Random selection (Eq. 5-7)
# ---------------------------------------------------------------------------


def random_selection_mean(n: int, k: int) -> float:
    """E[X] = n/k for uniform random selection of k of n clients."""
    return n / k


def random_selection_var(n: int, k: int) -> float:
    """Var[X] = n(n-k)/k^2 (Eq. 7)."""
    return n * (n - k) / k**2


# ---------------------------------------------------------------------------
# Markov policy: steady state + exact moments for arbitrary probs
# (Eqs. 8-22 of the paper)
# ---------------------------------------------------------------------------


def steady_state(probs: Sequence[float]) -> np.ndarray:
    """Stationary distribution pi_0..pi_m of the age chain (Eqs. 12-14)."""
    p = np.asarray(probs, dtype=np.float64)
    m = len(p) - 1
    if p[m] <= 0:
        raise ValueError("p_m must be > 0 for a recurrent chain")
    # unnormalized weights: w_0 = 1, w_i = prod_{j<i}(1-p_j) for i<m,
    # w_m = prod_{j<m}(1-p_j) / p_m
    w = np.ones(m + 1)
    for i in range(1, m + 1):
        w[i] = w[i - 1] * (1.0 - p[i - 1])
    w[m] = w[m] / p[m]
    return w / w.sum()


def selection_rate(probs: Sequence[float]) -> float:
    """Steady-state selection probability pi_0 = sum_i pi_i p_i = k/n (Eq. 8)."""
    return float(steady_state(probs)[0])


def markov_moments(probs: Sequence[float]) -> Tuple[float, float, float]:
    """(E[X], E[X^2], Var[X]) for the age chain, via Eqs. (15)-(22).

    E_i = expected rounds to return to state 0 starting the *next* round
    from state i; X is the return time from a selection (state 0).
    """
    p = np.asarray(probs, dtype=np.float64)
    m = len(p) - 1
    if p[m] <= 0:
        raise ValueError("p_m must be > 0")
    # E_i backward recursion: E_m = 1/p_m; E_i = 1 + (1-p_i) E_{i+1}
    E = np.zeros(m + 1)
    E[m] = 1.0 / p[m]
    for i in range(m - 1, -1, -1):
        E[i] = 1.0 + (1.0 - p[i]) * E[i + 1]
    # second moments S_i = E[X_i^2]: S_m = (2-p_m)/p_m^2;
    # S_i = 1 + (1-p_i)(2 E_{i+1} + S_{i+1})
    S = np.zeros(m + 1)
    S[m] = (2.0 - p[m]) / p[m] ** 2
    for i in range(m - 1, -1, -1):
        S[i] = 1.0 + (1.0 - p[i]) * (2.0 * E[i + 1] + S[i + 1])
    ex, ex2 = float(E[0]), float(S[0])
    return ex, ex2, ex2 - ex * ex


def markov_var(probs: Sequence[float]) -> float:
    return markov_moments(probs)[2]


# ---------------------------------------------------------------------------
# Optimal policy (Theorems 1-2)
# ---------------------------------------------------------------------------


def optimal_probs_for_mean(mean_gap: float, m: int) -> np.ndarray:
    """Optimal p_0..p_m for a target E[X] = mean_gap (Theorem 2 with
    n/k := mean_gap). Enables per-client heterogeneous selection rates."""
    if mean_gap < 1.0:
        raise ValueError("mean gap must be >= 1 round")
    if m < 1:
        raise ValueError("need m >= 1")
    r = float(mean_gap)
    i = math.floor(r)
    p = np.zeros(m + 1)
    if m <= i - 1:
        p[m] = 1.0 / (r - m)
    else:
        # note: if r is an integer, p_{i-1} = i+1-r = 1 and the policy is
        # deterministic "send exactly every r rounds" (Var = 0).
        if i >= 1:
            p[i - 1] = (i + 1) - r
        p[i:] = 1.0
    return p


def optimal_probs(n: int, k: int, m: int) -> np.ndarray:
    """Optimal transition probabilities p_0..p_m (Theorem 2).

    - m <= floor(n/k) - 1:  p* = [0,...,0, 1/(n/k - m)]
    - m >= floor(n/k):      with i = floor(n/k),
      p* = [0,...,0, (i+1) - n/k at index i-1, 1,...,1]
    """
    if not (0 < k <= n):
        raise ValueError("need 0 < k <= n")
    return optimal_probs_for_mean(n / k, m)


def optimal_var_for_mean(mean_gap: float, m: int) -> float:
    r = float(mean_gap)
    i = math.floor(r)
    if m <= i - 1:
        return (r - m) * (r - (m + 1))
    c = r - i
    return c * (1.0 - c)


def optimal_var(n: int, k: int, m: int) -> float:
    """Minimum Var[X] (Theorem 2 / Remark 2)."""
    return optimal_var_for_mean(n / k, m)


def theorem1_var(n: int, k: int, p0: float, p1: float) -> float:
    """Var[X] for m=1 as a function of (p0, p1) (Theorem 1)."""
    if p1 <= 0:
        raise ValueError("p1 must be > 0")
    return (1.0 + p0 - p1) * (1.0 - p0) / p1**2


def theorem1_optimal(n: int, k: int) -> Tuple[np.ndarray, float]:
    """Optimal (p0, p1) and Var for m=1 (Theorem 1)."""
    if 2 * k <= n:
        p = np.array([0.0, k / (n - k)])
        v = (n - k) * (n - 2 * k) / k**2
    else:
        p = np.array([(2 * k - n) / k, 1.0])
        v = (n - k) * (2 * k - n) / k**2
    return p, v


# ---------------------------------------------------------------------------
# Empirical estimation from selection histories
# ---------------------------------------------------------------------------


def peak_ages_from_history(history: np.ndarray) -> np.ndarray:
    """Extract all inter-selection gaps X from a (T, n) 0/1 selection matrix.

    Gaps are measured between consecutive selections of the same client
    (the first selection of each client opens its window and produces no
    sample, matching the paper's steady-state X).
    """
    history = np.asarray(history, dtype=bool)
    gaps = []
    T, n = history.shape
    for c in range(n):
        rounds = np.flatnonzero(history[:, c])
        if len(rounds) >= 2:
            gaps.append(np.diff(rounds))
    if not gaps:
        return np.zeros((0,), dtype=np.int64)
    return np.concatenate(gaps)


def empirical_load_stats(history: np.ndarray) -> dict:
    """Mean/var of X plus cohort-size statistics from a selection history."""
    gaps = peak_ages_from_history(history)
    sizes = np.asarray(history, dtype=np.int64).sum(axis=1)
    return {
        "num_samples": int(gaps.size),
        "mean_X": float(gaps.mean()) if gaps.size else float("nan"),
        "var_X": float(gaps.var()) if gaps.size else float("nan"),
        "mean_cohort": float(sizes.mean()),
        "std_cohort": float(sizes.std()),
        "min_cohort": int(sizes.min()),
        "max_cohort": int(sizes.max()),
    }


# ---------------------------------------------------------------------------
# Device-resident sufficient statistics for the same quantities
# ---------------------------------------------------------------------------
#
# The accumulators replace the materialized (rounds, n) selection matrix in
# the engines' hot loop: per-client last-selection step plus streaming
# first/second moments of the inter-selection gaps X and of the cohort
# sizes — enough to evaluate ``empirical_load_stats`` without ever pulling
# an (n,)-vector to the host. ``update_selection_accum`` is a pure
# jit/scan-compatible jnp function; ``selection_stats_from_accum`` runs on
# host floats at finalize time. Like ``peak_ages_from_history``, a
# client's first selection only opens its gap window (no X sample).
#
# The scalar moments are Kahan-compensated (value + running compensation
# pairs): x64 is disabled under jax's defaults, and a plain float32 sum
# loses the billions-of-samples counts/sums a fleet-scale run produces
# (float32 stops representing consecutive integers at 2^24). The
# compensated pair keeps the sequential-accumulation error at O(eps)
# instead of O(steps * eps), at the cost of four scalar flops per moment
# per step — nothing next to the (n,)-wide work around it.

_MOMENTS = ("gap_sum", "gap_sumsq", "gap_cnt", "size_sum", "size_sumsq")


def _kahan_add(sum_, comp, x):
    y = x - comp
    t = sum_ + y
    return t, (t - sum_) - y


def ewma_scatter_update(vec, idx, values, mask, alpha):
    """Masked scatter-EWMA over an (n,) per-client statistic.

    ``vec[idx[j]] <- (1 - alpha) * vec[idx[j]] + alpha * values[j]`` for
    every slot with ``mask[j]``; other slots (padding, failed cohort
    members) contribute an exact add-of-zero, so duplicate/padded idx
    entries are race-free and an all-False mask is bitwise identity.
    jit/scan-compatible; used by the defense tier's reputation scores.
    """
    import jax.numpy as jnp

    delta = jnp.where(mask, alpha * (values - vec[idx]), 0.0)
    return vec.at[idx].add(delta.astype(vec.dtype), mode="drop")


def ewma_scatter_update_rows(mat, idx, rows, mask, alpha):
    """Row-wise :func:`ewma_scatter_update` over an (n, d) per-client matrix.

    ``mat[idx[j]] <- (1 - alpha) * mat[idx[j]] + alpha * rows[j]`` for every
    slot with ``mask[j]``; masked slots contribute an exact add-of-zero, so
    padded/duplicate idx entries stay race-free and an all-False mask is
    bitwise identity. jit/scan-compatible; used by the defense tier's
    historical-direction sketches.
    """
    import jax.numpy as jnp

    delta = jnp.where(mask[:, None], alpha * (rows - mat[idx]), 0.0)
    return mat.at[idx].add(delta.astype(mat.dtype), mode="drop")


def init_selection_accum(n: int, expected_cohort: int = 0):
    """Fresh accumulator pytree for an ``n``-client fleet.

    ``expected_cohort`` (the configured k, when known) centers the
    cohort-size moments: sizes are accumulated as exact integer
    deviations from it, so ``size_sumsq`` stays O(steps * Var[size])
    instead of O(steps * k^2) — at a 100M-client fleet k^2 alone would
    exhaust float32's mantissa and drown ``std_cohort`` in input
    rounding, which no summation trick downstream can undo.
    """
    import jax.numpy as jnp

    z = jnp.zeros((), jnp.float32)
    acc = {
        "last_sel": jnp.full((n,), -1, jnp.int32),  # step of last selection
        "size_shift": jnp.full((), expected_cohort, jnp.int32),
        "size_min": jnp.full((), np.iinfo(np.int32).max, jnp.int32),
        "size_max": jnp.zeros((), jnp.int32),
        "steps": jnp.zeros((), jnp.int32),  # rounds accumulated
    }
    for name in _MOMENTS:  # moments of X / of the centered cohort size
        acc[name] = z
        acc["c_" + name] = z  # Kahan compensation
    return acc


def update_selection_accum(acc, selected):
    """Fold one round's (n,) bool selection vector into the accumulator."""
    import jax.numpy as jnp

    r = acc["steps"]
    has_gap = selected & (acc["last_sel"] >= 0)
    gap = jnp.where(has_gap, r - acc["last_sel"], 0).astype(jnp.float32)
    size = jnp.sum(selected.astype(jnp.int32))
    # exact integer deviation from the expected cohort (see init docstring)
    dev = (size - acc["size_shift"]).astype(jnp.float32)
    out = {
        "last_sel": jnp.where(selected, r, acc["last_sel"]),
        "size_shift": acc["size_shift"],
        "size_min": jnp.minimum(acc["size_min"], size),
        "size_max": jnp.maximum(acc["size_max"], size),
        "steps": r + 1,
    }
    increments = {
        "gap_sum": jnp.sum(gap),
        "gap_sumsq": jnp.sum(gap * gap),
        "gap_cnt": jnp.sum(has_gap.astype(jnp.float32)),
        "size_sum": dev,
        "size_sumsq": dev * dev,
    }
    for name, inc in increments.items():
        out[name], out["c_" + name] = _kahan_add(
            acc[name], acc["c_" + name], inc
        )
    return out


def selection_stats_from_accum(acc) -> dict:
    """``empirical_load_stats``-shaped dict from a selection accumulator."""
    # resolve each compensated pair in float64 on the host
    a = {name: float(acc[name]) - float(acc["c_" + name]) for name in _MOMENTS}
    steps = int(acc["steps"])
    cnt = a["gap_cnt"]
    if cnt > 0:
        mean_x = a["gap_sum"] / cnt
        var_x = max(a["gap_sumsq"] / cnt - mean_x * mean_x, 0.0)
    else:
        mean_x = var_x = float("nan")
    if steps > 0:
        mean_dev = a["size_sum"] / steps
        mean_c = float(acc["size_shift"]) + mean_dev
        var_c = max(a["size_sumsq"] / steps - mean_dev * mean_dev, 0.0)
        min_c, max_c = int(acc["size_min"]), int(acc["size_max"])
    else:
        mean_c = var_c = float("nan")
        min_c = max_c = 0
    return {
        "num_samples": int(cnt),
        "mean_X": mean_x,
        "var_X": var_x,
        "mean_cohort": mean_c,
        "std_cohort": math.sqrt(var_c) if steps > 0 else float("nan"),
        "min_cohort": min_c,
        "max_cohort": max_c,
    }


# ---------------------------------------------------------------------------
# Per-tier accumulators: the same X moments, grouped by aggregation node
# ---------------------------------------------------------------------------
#
# Under a multi-tier topology (repro.topo) the fleet-wide Var[X] hides
# imbalance *between* tiers: a region of stragglers can run a load
# distribution nothing like the fleet's. The grouped accumulator keeps
# the selection-gap moments per tier-0 node — (E,) vectors instead of
# scalars, segment-summed from the same per-client gap increments, with
# the identical Kahan compensation (the per-node sums face the same
# billions-of-steps growth the fleet-wide sums do).

_TIER_MOMENTS = ("gap_sum", "gap_sumsq", "gap_cnt")


def init_tier_accum(n: int, n_groups: int):
    """Fresh per-tier gap accumulator: ``n`` clients over ``n_groups``
    tier-0 aggregation nodes."""
    import jax.numpy as jnp

    z = jnp.zeros((n_groups,), jnp.float32)
    acc = {
        "last_sel": jnp.full((n,), -1, jnp.int32),
        "steps": jnp.zeros((), jnp.int32),
    }
    for name in _TIER_MOMENTS:
        acc[name] = z
        acc["c_" + name] = z
    return acc


def update_tier_accum(acc, selected, group_of_client):
    """Fold one round's (n,) bool selection into the per-tier moments;
    ``group_of_client`` is the static (n,) int32 client -> tier-0 node
    map from ``Topology.assign``."""
    import jax.numpy as jnp
    from jax.ops import segment_sum

    e = acc["gap_sum"].shape[0]
    r = acc["steps"]
    has_gap = selected & (acc["last_sel"] >= 0)
    gap = jnp.where(has_gap, r - acc["last_sel"], 0).astype(jnp.float32)
    increments = {
        "gap_sum": segment_sum(gap, group_of_client, num_segments=e),
        "gap_sumsq": segment_sum(gap * gap, group_of_client, num_segments=e),
        "gap_cnt": segment_sum(
            has_gap.astype(jnp.float32), group_of_client, num_segments=e
        ),
    }
    out = {
        "last_sel": jnp.where(selected, r, acc["last_sel"]),
        "steps": r + 1,
    }
    for name, inc in increments.items():
        out[name], out["c_" + name] = _kahan_add(
            acc[name], acc["c_" + name], inc
        )
    return out


def tier_stats_from_accum(acc) -> dict:
    """Per-tier-node mean/var of X as plain lists (JSON-safe), NaN where
    a node has no gap samples yet."""
    a = {
        name: np.asarray(acc[name], np.float64)
        - np.asarray(acc["c_" + name], np.float64)
        for name in _TIER_MOMENTS
    }
    cnt = a["gap_cnt"]
    with np.errstate(invalid="ignore", divide="ignore"):
        mean = np.where(cnt > 0, a["gap_sum"] / cnt, np.nan)
        var = np.where(
            cnt > 0,
            np.maximum(a["gap_sumsq"] / np.maximum(cnt, 1.0) - mean * mean, 0.0),
            np.nan,
        )
    return {
        "tier_num_samples": [int(c) for c in cnt],
        "tier_mean_X": [float(v) for v in mean],
        "tier_var_X": [float(v) for v in var],
    }


# ---------------------------------------------------------------------------
# Per-replica accumulators: the serving tier's load metric
# ---------------------------------------------------------------------------
#
# The serving tier (repro.serve) applies the identical Var[X] argument to
# inference replicas: X = number of routing decisions between subsequent
# assignments of a replica (one decision = one epoch of the age chain, so
# the paper's closed forms for n := replicas, k := 1 apply verbatim). The
# machinery is the tier accumulator with the identity grouping — each
# replica is its own "node" — which keeps the per-replica moments as (R,)
# vectors under the same Kahan compensation, and the fleet-wide moments
# fall out of the summed per-replica sums.


def init_replica_accum(n_replicas: int):
    """Fresh per-replica assignment-gap accumulator for ``n_replicas``
    serving replicas (one slot per replica; identity grouping)."""
    return init_tier_accum(n_replicas, n_replicas)


def update_replica_accum(acc, assigned):
    """Fold one routing decision's (R,) bool assignment vector into the
    accumulator (all-False advances the epoch without a sample — a
    rejected admission still ages every replica's chain)."""
    import jax.numpy as jnp

    r = assigned.shape[0]
    return update_tier_accum(acc, assigned, jnp.arange(r, dtype=jnp.int32))


def replica_stats_from_accum(acc) -> dict:
    """``serve_stats``: fleet-wide mean/Var of the replica assignment gap
    X (from the summed per-replica moments) plus the per-replica
    breakdown, in the same shape ``selection_stats_from_accum`` /
    ``tier_stats_from_accum`` report."""
    a = {
        name: np.asarray(acc[name], np.float64)
        - np.asarray(acc["c_" + name], np.float64)
        for name in _TIER_MOMENTS
    }
    cnt = float(a["gap_cnt"].sum())
    if cnt > 0:
        mean = float(a["gap_sum"].sum()) / cnt
        var = max(float(a["gap_sumsq"].sum()) / cnt - mean * mean, 0.0)
    else:
        mean = var = float("nan")
    per = tier_stats_from_accum(acc)
    return {
        "num_samples": int(cnt),
        "mean_X": mean,
        "var_X": var,
        "decisions": int(acc["steps"]),
        "replica_num_samples": per["tier_num_samples"],
        "replica_mean_X": per["tier_mean_X"],
        "replica_var_X": per["tier_var_X"],
    }
