"""Client-selection policies.

Every policy is a pair of pure functions wrapped in a ``Policy`` record —
the policy protocol of the engine API:

    state  = policy.init(key, n)
    sel, state = policy.step(state, key)     # sel: (n,) bool

All steps are jit-compatible (n, k, m static). State is an explicit dict
pytree so it can be checkpointed alongside the model and threaded through
either engine.

Each policy registers a ``(n, k, m, **kwargs) -> Policy`` factory in the
``repro.engine`` registry (see the module bottom), so every name here —
and any user-registered one — is constructible via
``make_policy(name, n, k, m, ...)`` and a ``RunConfig(policy=name)``.

Policies:
  * ``random``       — paper's baseline [2]: exactly k uniform at random.
  * ``markov``       — the paper's decentralized age-dependent Markov policy
                       with the optimal probabilities of Theorem 2.
  * ``markov_probs`` — same mechanism, arbitrary user-supplied p_0..p_m
                       (Remark 1's dropout-robust variants); defaults to
                       the Theorem-2 optimum when no probs are given.
  * ``markov_hetero``— per-client participation rates, each client on its
                       own Theorem-2-optimal chain (beyond paper).
  * ``oldest_age``   — centralized equivalent (Remark 1): top-k by age.
  * ``round_robin``  — deterministic staggered blocks (Var[X] = 0 when k | n).
  * ``gumbel_age``   — beyond-paper: age-weighted sampling without
                       replacement (Gumbel top-k on beta*age), interpolating
                       random (beta=0) -> oldest-age (beta->inf).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import load_metric
from repro.core.aoi import age_update


@dataclasses.dataclass(frozen=True)
class Policy:
    name: str
    init: Callable  # (key, n) -> state
    step: Callable  # (state, key) -> (selected bool (n,), state)
    exact_k: bool  # cohort size deterministic?


def _base_state(n: int) -> Dict:
    return {
        "ages": jnp.zeros((n,), jnp.int32),
        "round": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Random selection (paper's baseline)
# ---------------------------------------------------------------------------


def make_random(n: int, k: int) -> Policy:
    def init(key, n_=n):
        return _base_state(n_)

    def step(state, key):
        perm = jax.random.permutation(key, n)
        sel = jnp.zeros((n,), jnp.bool_).at[perm[:k]].set(True)
        return sel, _advance(state, sel)

    return Policy("random", init, step, exact_k=True)


# ---------------------------------------------------------------------------
# Decentralized Markov policy (the paper's contribution)
# ---------------------------------------------------------------------------


def make_markov(
    n: int,
    k: int,
    m: int,
    probs: Optional[np.ndarray] = None,
    steady_start: bool = True,
) -> Policy:
    """Age-dependent Bernoulli policy. Each client *independently* draws
    send ~ Bernoulli(p_{min(age, m)}) — no coordination (paper Sec. III).

    ``steady_start=True`` samples initial ages from the stationary
    distribution (the paper analyses the chain at steady state); with a
    cold start (all ages 0 and p_0 = 0) the chain still converges but the
    first ~n/k rounds select nobody.
    """
    p = np.asarray(
        load_metric.optimal_probs(n, k, m) if probs is None else probs,
        dtype=np.float32,
    )
    if len(p) != m + 1:
        raise ValueError(f"probs must have length m+1={m + 1}")
    pi = load_metric.steady_state(p)
    p_dev = jnp.asarray(p)
    pi_dev = jnp.asarray(pi.astype(np.float32))

    def init(key, n_=n):
        state = _base_state(n_)
        if steady_start:
            ages = jax.random.choice(key, m + 1, shape=(n_,), p=pi_dev)
            state["ages"] = ages.astype(jnp.int32)
        return state

    def step(state, key):
        chain = jnp.minimum(state["ages"], m)
        send_p = p_dev[chain]
        sel = jax.random.uniform(key, (n,)) < send_p
        return sel, _advance(state, sel)

    return Policy("markov", init, step, exact_k=False)


def make_markov_hetero(
    rates: np.ndarray, m: int, steady_start: bool = True
) -> Policy:
    """Heterogeneous decentralized Markov policy: client i is selected at
    its own rate ``rates[i]`` (mean gap 1/rates[i]), each with its own
    Theorem-2-optimal chain. Extends the paper beyond uniform k/n —
    clients with more compute/data can participate more often while every
    client's own Var[X_i] stays at its optimum. Fully decentralized: the
    per-client probability table is the only coordination artifact.
    """
    rates = np.asarray(rates, dtype=np.float64)
    if np.any(rates <= 0) or np.any(rates > 1):
        raise ValueError("rates in (0, 1]")
    n = len(rates)
    table = np.stack(
        [load_metric.optimal_probs_for_mean(max(1.0 / r, 1.0), m) for r in rates]
    )  # (n, m+1)
    table_dev = jnp.asarray(table, jnp.float32)
    pis = np.stack([load_metric.steady_state(p) for p in table])

    def init(key, n_=n):
        state = _base_state(n_)
        if steady_start:
            u = jax.random.uniform(key, (n_,))
            cdf = jnp.asarray(np.cumsum(pis, axis=1), jnp.float32)
            ages = jnp.sum(u[:, None] > cdf, axis=1)
            state["ages"] = ages.astype(jnp.int32)
        return state

    def step(state, key):
        chain = jnp.minimum(state["ages"], m)
        send_p = jnp.take_along_axis(table_dev, chain[:, None], axis=1)[:, 0]
        sel = jax.random.uniform(key, (n,)) < send_p
        return sel, _advance(state, sel)

    return Policy("markov_hetero", init, step, exact_k=False)


# ---------------------------------------------------------------------------
# Oldest-age top-k (Remark 1's centralized equivalent)
# ---------------------------------------------------------------------------


def make_oldest_age(n: int, k: int) -> Policy:
    def init(key, n_=n):
        state = _base_state(n_)
        # stagger initial ages so the first rounds aren't degenerate ties
        state["ages"] = jax.random.permutation(key, n_).astype(jnp.int32) % max(
            2 * (n_ // max(k, 1)), 2
        )
        return state

    def step(state, key):
        # random tie-break: add sub-integer uniform noise to ages
        noise = jax.random.uniform(key, (n,), minval=0.0, maxval=0.5)
        score = state["ages"].astype(jnp.float32) + noise
        _, idx = jax.lax.top_k(score, k)
        sel = jnp.zeros((n,), jnp.bool_).at[idx].set(True)
        return sel, _advance(state, sel)

    return Policy("oldest_age", init, step, exact_k=True)


# ---------------------------------------------------------------------------
# Round robin (deterministic; Var[X]=0 when k divides n)
# ---------------------------------------------------------------------------


def make_round_robin(n: int, k: int) -> Policy:
    def init(key, n_=n):
        return _base_state(n_)

    def step(state, key):
        start = (state["round"] * k) % n
        idx = (start + jnp.arange(k)) % n
        sel = jnp.zeros((n,), jnp.bool_).at[idx].set(True)
        return sel, _advance(state, sel)

    return Policy("round_robin", init, step, exact_k=True)


# ---------------------------------------------------------------------------
# Gumbel age-weighted top-k (beyond paper)
# ---------------------------------------------------------------------------


def make_gumbel_age(n: int, k: int, beta: float = 1.0) -> Policy:
    def init(key, n_=n):
        return _base_state(n_)

    def step(state, key):
        g = jax.random.gumbel(key, (n,))
        score = beta * state["ages"].astype(jnp.float32) + g
        _, idx = jax.lax.top_k(score, k)
        sel = jnp.zeros((n,), jnp.bool_).at[idx].set(True)
        return sel, _advance(state, sel)

    return Policy(f"gumbel_age(beta={beta})", init, step, exact_k=True)


# ---------------------------------------------------------------------------


def _advance(state: Dict, sel: jnp.ndarray) -> Dict:
    return {
        **state,
        "ages": age_update(state["ages"], sel),
        "round": state["round"] + 1,
    }


def make_policy(name: str, n: int, k: int, m: int = 10, **kw) -> Policy:
    """Construct any registered policy by name (back-compat signature;
    dispatches through the ``repro.engine`` registry)."""
    from repro.engine.registry import make_policy as _dispatch

    return _dispatch(name, n, k, m, **kw)


def default_hetero_rates(n: int, k: int, rate_spread: float = 0.0) -> np.ndarray:
    """Per-client participation rates with mean ~k/n. ``rate_spread`` is the
    log-range of the spread: client rates span a factor of e^rate_spread
    between the slowest and fastest client (0 = uniform k/n)."""
    base = k / n
    if rate_spread == 0.0:
        return np.full(n, base)
    factors = np.exp(np.linspace(-rate_spread / 2, rate_spread / 2, n))
    return np.clip(base * factors, 1e-4, 1.0)


def simulate(policy: Policy, key: jax.Array, n: int, rounds: int) -> np.ndarray:
    """Run a policy for ``rounds`` rounds; returns (rounds, n) bool history."""
    state = policy.init(key, n)

    def body(state, key):
        sel, state = policy.step(state, key)
        return state, sel

    keys = jax.random.split(jax.random.fold_in(key, 1), rounds)
    _, hist = jax.lax.scan(body, state, keys)
    return np.asarray(hist)


def simulate_stats(
    policy: Policy, key: jax.Array, n: int, rounds: int,
    expected_cohort: int = 0,
) -> dict:
    """Load statistics of a ``rounds``-round policy run without ever
    materializing the (rounds, n) history: the whole run is one scan over
    the device-resident selection accumulators, and only the O(1)
    sufficient statistics come back to the host. Same key schedule and
    same output dict as ``empirical_load_stats(simulate(...))``.

    Pass the policy's target cohort size k as ``expected_cohort`` — it
    centers the float32 cohort-size moments, which is what keeps
    ``std_cohort`` meaningful at fleet-scale k (see
    ``init_selection_accum``)."""
    state = policy.init(key, n)
    acc = load_metric.init_selection_accum(n, expected_cohort)

    def body(carry, key):
        state, acc = carry
        sel, state = policy.step(state, key)
        return (state, load_metric.update_selection_accum(acc, sel)), None

    keys = jax.random.split(jax.random.fold_in(key, 1), rounds)
    (_, acc), _ = jax.lax.scan(body, (state, acc), keys)
    return load_metric.selection_stats_from_accum(acc)


# ---------------------------------------------------------------------------
# Registry wiring: every policy is a named (n, k, m, **kw) -> Policy factory.
# Imported at the bottom, after all public defs, so a partially initialized
# repro.engine package (which itself imports this module) never bites.
# ---------------------------------------------------------------------------

from repro.engine import registry as _registry  # noqa: E402

_registry.register_policy("random")(lambda n, k, m=10: make_random(n, k))
_registry.register_policy("markov")(make_markov)
_registry.register_policy("markov_probs")(
    lambda n, k, m=10, probs=None, steady_start=True: make_markov(
        n, k, m, probs=probs, steady_start=steady_start
    )
)


@_registry.register_policy("markov_hetero")
def _make_markov_hetero_by_name(
    n: int, k: int, m: int = 10, rates=None, rate_spread: float = 0.0,
    steady_start: bool = True,
) -> Policy:
    if rates is None:
        rates = default_hetero_rates(n, k, rate_spread)
    return make_markov_hetero(rates, m, steady_start=steady_start)


_registry.register_policy("oldest_age")(lambda n, k, m=10: make_oldest_age(n, k))
_registry.register_policy("round_robin")(lambda n, k, m=10: make_round_robin(n, k))
_registry.register_policy("gumbel_age")(
    lambda n, k, m=10, beta=1.0: make_gumbel_age(n, k, beta=beta)
)

POLICY_NAMES = _registry.policy_names()
