"""Learning-rate schedules. The paper uses lr0=0.1 with decay 0.998/round."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def exponential_decay(lr0: float, decay: float):
    """Paper Sec. IV: lr_t = lr0 * decay^t (decay per communication round)."""
    return lambda step: jnp.asarray(lr0, jnp.float32) * decay ** step.astype(
        jnp.float32
    )


def cosine(lr0: float, total_steps: int, lr_min: float = 0.0):
    def f(step):
        frac = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        return lr_min + 0.5 * (lr0 - lr_min) * (1 + jnp.cos(jnp.pi * frac))

    return f


def warmup_cosine(lr0: float, warmup: int, total_steps: int, lr_min: float = 0.0):
    cos = cosine(lr0, max(total_steps - warmup, 1), lr_min)

    def f(step):
        w = jnp.minimum(step.astype(jnp.float32) / max(warmup, 1), 1.0)
        return jnp.where(step < warmup, lr0 * w, cos(step - warmup))

    return f
