from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adamw,
    sgd,
)
from repro.optim.schedules import constant, cosine, exponential_decay, warmup_cosine  # noqa: F401
