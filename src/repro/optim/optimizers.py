"""Minimal optimizer library (no optax offline): SGD(+momentum), AdamW.

    opt = sgd(momentum=0.9)
    state = opt.init(params)
    params, state = opt.update(params, grads, state, lr)
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable
    update: Callable  # (params, grads, state, lr) -> (params, state)


def sgd(momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    """Paper's local optimizer is plain SGD (Sec. IV)."""

    def init(params):
        if momentum == 0.0:
            return {}
        return {"m": jax.tree.map(jnp.zeros_like, params)}

    def update(params, grads, state, lr):
        if momentum == 0.0:
            new = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
            return new, state
        m = jax.tree.map(lambda m_, g: momentum * m_ + g, state["m"], grads)
        step = (
            jax.tree.map(lambda g, m_: g + momentum * m_, grads, m) if nesterov else m
        )
        new = jax.tree.map(lambda p, s: p - lr * s.astype(p.dtype), params, step)
        return new, {"m": m}

    return Optimizer(f"sgd(m={momentum})", init, update)


def adamw(
    b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8, weight_decay: float = 0.0
) -> Optimizer:
    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(params, grads, state, lr):
        t = state["t"] + 1
        m = jax.tree.map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32), state["m"], grads
        )
        v = jax.tree.map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"],
            grads,
        )
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(p, m_, v_):
            step = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

        new = jax.tree.map(upd, params, m, v)
        return new, {"m": m, "v": v, "t": t}

    return Optimizer("adamw", init, update)
