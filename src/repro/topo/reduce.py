"""Tier reductions and per-hop latency: the jnp half of ``repro.topo``.

``tiered_apply`` turns a :class:`~repro.topo.graph.Topology` into the
engines' ``aggregate(global_params, updates, bases, w, idx) ->
(params, stats)`` hook. It is pure *reduction structure* over the existing aggregator
protocol — no new aggregator math:

  1. every cohort slot becomes its own additive accumulator
     (``agg.init`` is the zero element, so a one-slot ``accumulate``
     is exact);
  2. slot accumulators ``segment_sum`` into their tier-0 node by the
     topology's client assignment — the edge aggregation;
  3. each tier's node accumulators ``segment_sum`` up the parent maps
     (regional aggregation), and the top tier sums into the implicit
     global root — or, for gossip graphs, the flat peer tier mixes
     accumulators through the doubly stochastic ring matrix for
     ``gossip_rounds`` rounds and the global model reads node 0's view;
  4. one ``agg.finalize`` on the merged accumulator.

Because each merge is a plain leaf-wise sum of accumulators, the whole
tree costs O(params) traffic per cross-tier edge and requires
``agg.additive`` — exactly the contract ``cohort_sharded_apply``
established. Under cohort-parallel execution (``mesh`` given) steps 1-2
run inside a ``shard_map`` over the sharded cohort axis and the per-node
accumulator merges with one ``psum`` — the identical
shard-local-accumulate + psum path, just keyed by tier-0 node instead of
a single server, so the hierarchical reduction compiles to the same
cross-device pattern the star does.

``make_hop_latency`` prices the DAG: an update pays one latency draw per
cross-tier hop (client->tier0 per client from ``tier_profiles[0]``, then
one draw per *aggregation node* per upper hop — clients under the same
edge node share that node's uplink draw; gossip peers pay their link
once per gossip round). The (n,) extra wall time adds onto the client's
own dispatch latency in the async engine under a dedicated key fold.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.aggregators import Aggregator, acc_stats
from repro.sim import latency as lat_mod
from repro.topo.graph import Topology


def _segment_sum_tree(tree, seg, num_segments: int):
    return jax.tree.map(
        lambda a: jax.ops.segment_sum(a, seg, num_segments=num_segments),
        tree,
    )


def _slot_accums(agg: Aggregator, g, updates, bases, w, stacked_bases: bool):
    """(B,)-stacked per-slot accumulators: each cohort slot accumulated
    alone into the zero element (exact because the aggregator is
    additive)."""
    zero = agg.init(g)

    def lift(t):
        return jax.tree.map(lambda x: x[None], t)

    if stacked_bases:
        def one(u, b, wi):
            return agg.accumulate(zero, lift(u), lift(b), wi[None])

        return jax.vmap(one)(updates, bases, w)

    # sync convention: bases is the unstacked global tree, broadcast
    def one(u, wi):
        return agg.accumulate(zero, lift(u), bases, wi[None])

    return jax.vmap(one)(updates, w)


def tiered_apply(
    agg: Aggregator,
    topo: Topology,
    n_clients: int,
    mesh=None,
    axis: Optional[str] = None,
    stacked_bases: bool = True,
):
    """Build the tiered ``aggregate(g, updates, bases, w, idx)`` hook.

    ``idx`` is the (B,) cohort -> client index map the engines already
    hold; padded/invalid slots carry weight 0 and contribute the zero
    accumulator, exactly like an under-filled buffer. With ``mesh``/
    ``axis`` the slot accumulation and the tier-0 segment sum run
    shard-locally over the cohort axis and merge with one psum
    (requires the cohort length, after engine padding, to divide the
    mesh — the same contract as ``cohort_sharded_apply``).
    """
    if topo.is_star:
        raise ValueError(
            f"topology {topo.name!r} is a star: engines use the plain "
            "aggregator path (bit-for-bit identical), not tiered_apply"
        )
    if not agg.additive:
        raise ValueError(
            f"aggregator {agg.name!r} is not additive: tier reductions "
            "are accumulator merges, so non-additive aggregators cannot "
            "run under a multi-tier topology"
        )
    assign_dev = jnp.asarray(topo.assign(n_clients))
    parents_dev = [jnp.asarray(p) for p in topo.parents()]
    e0 = int(topo.tier_sizes[0])
    mix = (
        jnp.asarray(topo.gossip_mixing()) if topo.kind == "gossip" else None
    )

    def local_tier0(g, updates, bases, w, seg):
        accs = _slot_accums(agg, g, updates, bases, w, stacked_bases)
        return _segment_sum_tree(accs, seg, e0)

    if mesh is not None:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        spec = P(axis)

        def tier0(g, updates, bases, w, seg):
            def local(g_l, u_l, b_l, w_l, s_l):
                return jax.lax.psum(
                    local_tier0(g_l, u_l, b_l, w_l, s_l), axis
                )

            return shard_map(
                local,
                mesh=mesh,
                in_specs=(P(), spec, spec if stacked_bases else P(), spec,
                          spec),
                out_specs=P(),
            )(g, updates, bases, w, seg)
    else:
        tier0 = local_tier0

    def apply(g, updates, bases, w, idx):
        acc = tier0(g, updates, bases, w, assign_dev[idx])
        for pmap, size in zip(parents_dev, topo.tier_sizes[1:]):
            acc = _segment_sum_tree(acc, pmap, int(size))
        if mix is not None:
            for _ in range(topo.gossip_rounds):
                acc = jax.tree.map(
                    lambda a: jnp.tensordot(
                        mix, a, axes=(1, 0)
                    ).astype(a.dtype),
                    acc,
                )
            # node 0's decentralized estimate of the network sum: the
            # doubly stochastic mixing preserves the total, so as rounds
            # grow every node's view -> (sum / E) and the x E readout
            # converges to the hierarchical reduction (finalize ratios
            # are scale-invariant for the built-in aggregators anyway)
            acc = jax.tree.map(lambda a: a[0] * e0, acc)
        else:
            acc = jax.tree.map(lambda a: a.sum(axis=0), acc)
        return agg.finalize(g, acc), acc_stats(acc)

    return apply


def tier_suspect_counts(topo: Topology, n_clients: int, status) -> list:
    """Host-side per-edge-node suspect census for run telemetry.

    Buckets the defense tier's final per-client status (non-zero =
    quarantined or on probation) by the topology's tier-0 assignment, so
    operators can see *where* in the aggregation DAG the flagged clients
    sit. Star topologies have one implicit edge node — the whole fleet
    buckets into it."""
    suspect = (np.asarray(status) != 0).astype(np.float64)
    if topo.is_star:
        return [float(suspect.sum())]
    assign = np.asarray(topo.assign(n_clients))
    counts = np.bincount(
        assign, weights=suspect, minlength=int(topo.tier_sizes[0])
    )
    return [float(c) for c in counts]


def make_hop_latency(topo: Topology, n_clients: int):
    """Per-client extra wall time through the aggregation DAG.

    Returns ``hop(key) -> (n,) f32`` (or None for a star — no extra
    hops): one draw per client for the client->tier0 link, then one draw
    per *aggregation node* for each upper hop, gathered down to the
    clients through the assignment maps — clients under the same edge
    node share its uplink draw. Gossip peers pay their link profile once
    per gossip round. Profiles default to ``datacenter`` when the
    topology names none.
    """
    if topo.is_star:
        return None
    hops = topo.n_tiers + 1
    names = topo.tier_profiles or ("datacenter",) * hops
    profs = [lat_mod.get_profile(p) for p in names]
    assign = jnp.asarray(topo.assign(n_clients))
    parents = [jnp.asarray(p) for p in topo.parents()]
    sizes = [int(s) for s in topo.tier_sizes]
    n_links = max(topo.gossip_rounds, 1) if topo.kind == "gossip" else 1

    def hop(key):
        keys = jax.random.split(key, hops + n_links - 1)
        ones_n = jnp.ones((n_clients,), jnp.float32)
        extra = lat_mod.sample_latency(keys[0], profs[0], ones_n)
        node = assign
        for lvl, size in enumerate(sizes):
            ones_e = jnp.ones((size,), jnp.float32)
            if topo.kind == "gossip":
                draw = jnp.zeros((size,), jnp.float32)
                for rr in range(topo.gossip_rounds):
                    draw = draw + lat_mod.sample_latency(
                        keys[1 + rr], profs[1], ones_e
                    )
            else:
                draw = lat_mod.sample_latency(
                    keys[1 + lvl], profs[1 + lvl], ones_e
                )
            extra = extra + draw[node]
            if lvl < len(parents):
                node = parents[lvl][node]
        return extra

    return hop
