"""Aggregation topology as a first-class engine concept.

``repro.topo.graph`` holds the jax-free structure (the ``Topology``
dataclass, its ``@register_topology`` registry, and the built-in star /
hierarchical / gossip factories); ``repro.topo.reduce`` compiles a
topology into the engines' aggregation hook (additive tier reductions,
per-hop latency); ``repro.topo.heartbeat`` adds liveness/churn.
"""
from repro.topo.graph import (
    Topology,
    make_topology,
    register_topology,
    topology_names,
)
from repro.topo.heartbeat import beat, beat_at, expired, init_heartbeat
from repro.topo.reduce import make_hop_latency, tiered_apply

__all__ = [
    "Topology",
    "make_topology",
    "register_topology",
    "topology_names",
    "tiered_apply",
    "make_hop_latency",
    "init_heartbeat",
    "beat",
    "beat_at",
    "expired",
]
