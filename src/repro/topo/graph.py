"""Aggregation topologies as first-class data: the reduction DAG.

The paper's decentralized Markov policy removes the *scheduling*
bottleneck — each client admits itself from local state — but every
engine in this repo still aggregated through one logical star-shaped
server. A ``Topology`` makes the aggregation structure explicit: clients
feed tier-0 aggregation nodes (edge servers), tiers feed their parents
(regional aggregators), and the top tier feeds the global model — or, in
the gossip variant, a flat graph of peer nodes mixes accumulators with
its neighbours instead of reducing up a tree.

A topology is pure *reduction structure*, no aggregator math: tier
reductions are sequences of additive accumulator merges (segment sums of
the same ``init/accumulate`` pytrees every engine already produces), so
any ``Aggregator`` with ``additive=True`` runs under any topology
unchanged (``repro.topo.reduce``). The degenerate single-tier ``star``
is the identity structure — engines treat it exactly like "no topology"
and stay bit-for-bit identical to the pre-topology code path.

This module is deliberately jax-free (dataclasses + numpy only), like
``engine.config``: topologies can be built, validated, and serialized
without touching the device runtime. The jnp machinery lives in
``repro.topo.reduce`` (tier reductions, per-hop latency) and
``repro.topo.heartbeat`` (liveness/churn).

Registry: a topology is a registry entry, not an engine fork —

    from repro.topo import register_topology

    @register_topology("my_topo")
    def _make(**kw):
        return Topology("my_topo", kind="hier", tier_sizes=(16, 4), ...)

and ``RunConfig(topology="my_topo")`` just works.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import numpy as np

KINDS = ("star", "hier", "gossip")


@dataclasses.dataclass(frozen=True)
class Topology:
    """One aggregation DAG: client -> tier 0 -> ... -> global.

    ``tier_sizes`` counts the aggregation nodes per intermediate tier,
    bottom-up and excluding the implicit global root — ``()`` is the
    star (every client talks straight to the server), ``(64, 8)`` is a
    2-tier hierarchy of 64 edge nodes under 8 regional nodes.
    ``tier_profiles`` names one ``sim.latency`` profile per cross-tier
    hop (client->tier0, tier0->tier1, ..., top->global), the per-edge
    latency an update pays on its way up the tree. ``heartbeat_timeout``
    (simulated seconds; 0 disables) arms ``repro.topo.heartbeat``:
    clients the fleet has not heard from for longer than the timeout are
    presumed dead by their tier coordinator and excluded from that
    tier's reduction when their update finally arrives.

    Gossip topologies have exactly one tier of peer nodes mixing
    accumulators over a ``gossip_degree``-regular ring for
    ``gossip_rounds`` rounds; the global model reads node 0's view, which
    converges to the hierarchical reduction as rounds grow (additive
    accumulators are scale-free under the doubly stochastic mixing).
    """

    name: str
    kind: str = "star"
    tier_sizes: Tuple[int, ...] = ()
    tier_profiles: Tuple[str, ...] = ()
    heartbeat_timeout: float = 0.0
    gossip_rounds: int = 2
    gossip_degree: int = 2

    @property
    def n_tiers(self) -> int:
        return len(self.tier_sizes)

    @property
    def is_star(self) -> bool:
        """Degenerate reduction structure: engines must treat a star
        exactly like "no topology" (bit-for-bit pinned by
        ``tests/test_topo.py``). Heartbeat churn still applies."""
        return self.n_tiers == 0

    def __post_init__(self) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"kind must be one of {KINDS}, got {self.kind!r}")
        if self.kind == "star" and self.tier_sizes:
            raise ValueError("star topologies carry no aggregation tiers")
        if self.kind != "star" and not self.tier_sizes:
            raise ValueError(f"{self.kind} topology needs >= 1 tier")
        if self.kind == "gossip" and self.n_tiers != 1:
            raise ValueError(
                f"gossip is a flat peer graph: exactly one tier of nodes, "
                f"got tier_sizes={self.tier_sizes}"
            )
        if any(int(t) < 1 for t in self.tier_sizes):
            raise ValueError(f"tier sizes must be >= 1, got {self.tier_sizes}")
        if any(a < b for a, b in zip(self.tier_sizes, self.tier_sizes[1:])):
            raise ValueError(
                f"tier sizes must be non-increasing bottom-up (fan-in "
                f"toward the root), got {self.tier_sizes}"
            )
        # one latency profile per cross-tier hop, including the final
        # hop into the global root
        hops = self.n_tiers + (1 if self.tier_sizes else 0)
        if len(self.tier_profiles) not in (0, hops):
            raise ValueError(
                f"need {hops} tier_profiles (one per cross-tier hop, "
                f"including top->global), got {len(self.tier_profiles)}"
            )
        if self.heartbeat_timeout < 0:
            raise ValueError(
                f"heartbeat_timeout must be >= 0 (0 disables), got "
                f"{self.heartbeat_timeout}"
            )
        if self.kind == "gossip":
            if self.gossip_rounds < 0:
                raise ValueError("gossip_rounds must be >= 0")
            if not 0 < self.gossip_degree < int(self.tier_sizes[0]) or (
                self.gossip_degree % 2
            ):
                raise ValueError(
                    f"gossip_degree must be a positive even number below "
                    f"the node count {self.tier_sizes[0]}, got "
                    f"{self.gossip_degree}"
                )

    def validate(self, n_clients: int) -> None:
        """Shape check against a concrete fleet."""
        if self.tier_sizes and self.tier_sizes[0] > n_clients:
            raise ValueError(
                f"topology {self.name!r} has {self.tier_sizes[0]} tier-0 "
                f"nodes for only {n_clients} clients"
            )

    def assign(self, n_clients: int) -> np.ndarray:
        """Client -> tier-0 node map, (n,) int32: balanced contiguous
        blocks (node sizes differ by at most one client)."""
        self.validate(n_clients)
        if self.is_star:
            return np.zeros((n_clients,), np.int32)
        e = int(self.tier_sizes[0])
        return (np.arange(n_clients, dtype=np.int64) * e // n_clients).astype(
            np.int32
        )

    def parents(self) -> Tuple[np.ndarray, ...]:
        """Node -> parent-node maps for tiers 0..T-2 (the top tier's
        parent is the implicit global root), each (tier_sizes[l],) int32
        in the same balanced contiguous layout as ``assign``."""
        out = []
        for lo, hi in zip(self.tier_sizes, self.tier_sizes[1:]):
            lo, hi = int(lo), int(hi)
            out.append(
                (np.arange(lo, dtype=np.int64) * hi // lo).astype(np.int32)
            )
        return tuple(out)

    def gossip_mixing(self) -> np.ndarray:
        """Doubly stochastic mixing matrix of the ``gossip_degree``-regular
        ring over the peer nodes, (E, E) float32: uniform weight over self
        plus ``degree`` nearest ring neighbours. Symmetric, so column sums
        are 1 and the summed accumulator is invariant under mixing."""
        if self.kind != "gossip":
            raise ValueError(f"{self.name!r} is not a gossip topology")
        e = int(self.tier_sizes[0])
        w = 1.0 / (self.gossip_degree + 1)
        mix = np.zeros((e, e), np.float32)
        half = self.gossip_degree // 2
        for off in range(-half, half + 1):
            mix[np.arange(e), (np.arange(e) + off) % e] += w
        return mix

    def describe(self) -> str:
        if self.is_star:
            return "star"
        tiers = "x".join(str(t) for t in self.tier_sizes)
        extra = (
            f";gossip d={self.gossip_degree} r={self.gossip_rounds}"
            if self.kind == "gossip"
            else ""
        )
        hb = f";hb={self.heartbeat_timeout}s" if self.heartbeat_timeout else ""
        return f"{self.kind}[{tiers}]{extra}{hb}"


# ---------------------------------------------------------------------------
# Registry (mirrors repro.engine.registry for policies/aggregators)
# ---------------------------------------------------------------------------

_TOPOLOGIES: Dict[str, Callable] = {}


def register_topology(name: str) -> Callable:
    """Decorator: register ``factory(**kw) -> Topology`` under ``name``."""

    def deco(factory: Callable) -> Callable:
        if name in _TOPOLOGIES:
            raise ValueError(f"topology {name!r} already registered")
        _TOPOLOGIES[name] = factory
        return factory

    return deco


def make_topology(name: str, **kw) -> Topology:
    """Construct a registered topology by name."""
    try:
        factory = _TOPOLOGIES[name]
    except KeyError:
        raise ValueError(
            f"unknown topology {name!r}; registered: "
            f"{', '.join(topology_names())}"
        ) from None
    return factory(**kw)


def topology_names() -> Tuple[str, ...]:
    return tuple(_TOPOLOGIES)


def _as_tiers(tiers) -> Tuple[int, ...]:
    if isinstance(tiers, (int, np.integer)):
        return (int(tiers),)
    return tuple(int(t) for t in tiers)


@register_topology("star")
def make_star(heartbeat_timeout: float = 0.0) -> Topology:
    """The degenerate single-tier star — today's engines, verbatim."""
    return Topology("star", heartbeat_timeout=heartbeat_timeout)


@register_topology("hierarchical")
def make_hierarchical(
    tiers=(8,),
    profiles=None,
    heartbeat_timeout: float = 0.0,
) -> Topology:
    """Edge -> regional -> global tree: ``tiers`` is the node count per
    intermediate tier bottom-up (e.g. ``(64, 8)``), ``profiles`` one
    latency profile name per cross-tier hop (default: ``datacenter``
    links everywhere)."""
    tiers = _as_tiers(tiers)
    hops = len(tiers) + 1
    profiles = tuple(profiles) if profiles else ("datacenter",) * hops
    return Topology(
        f"hier{len(tiers)}",
        kind="hier",
        tier_sizes=tiers,
        tier_profiles=profiles,
        heartbeat_timeout=heartbeat_timeout,
    )


@register_topology("gossip")
def make_gossip(
    nodes: int = 8,
    degree: int = 2,
    rounds: int = 2,
    profile: str = "datacenter",
    heartbeat_timeout: float = 0.0,
) -> Topology:
    """Flat peer graph: ``nodes`` aggregation peers on a ``degree``-regular
    ring mixing accumulators for ``rounds`` gossip rounds."""
    return Topology(
        f"gossip{nodes}",
        kind="gossip",
        tier_sizes=(int(nodes),),
        tier_profiles=(profile, profile),
        heartbeat_timeout=heartbeat_timeout,
        gossip_rounds=int(rounds),
        gossip_degree=int(degree),
    )
