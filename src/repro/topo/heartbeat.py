"""Heartbeat-driven liveness: churned clients leave their tier's reduction.

Real hierarchical fleets lose clients mid-round — a phone leaves wifi, an
edge site reboots — and the tier coordinator that stops hearing
heartbeats drops the client from the round rather than stalling the
reduction. In the simulator a heartbeat is any observable contact:
dispatch (the client pulled a model) and completion (its update arrived).
A client whose update lands more than ``timeout`` simulated seconds after
its last contact has, from its coordinator's perspective, been dark the
whole time — the update is *excluded from the tier reduction* (weight 0,
exactly like a dropped or invalid buffer slot) and counted in the
``hb_expired`` churn telemetry.

All functions are pure jnp ops over a flat ``(n,)`` last-beat vector, so
the heartbeat state rides the engines' donated scan carry like every
other per-client array (and shards over the fleet mesh — liveness is a
local decision, zero cross-device traffic, matching the paper's
decentralization story).
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp


def init_heartbeat(n: int) -> Dict[str, jnp.ndarray]:
    """Fresh heartbeat state: everyone checked in at t=0."""
    return {"last_beat": jnp.zeros((n,), jnp.float32)}


def beat(hb: Dict, mask: jnp.ndarray, t: jnp.ndarray) -> Dict:
    """Clients under ``mask`` (n,) check in at time ``t`` (scalar)."""
    return {"last_beat": jnp.where(mask, t, hb["last_beat"])}


def beat_at(
    hb: Dict, scatter_idx: jnp.ndarray, t: jnp.ndarray
) -> Dict:
    """Popped clients check in at their completion times: ``scatter_idx``
    is a masked scatter index vector (out-of-range where invalid, as from
    ``sim.events.scatter_idx``), ``t`` the per-slot times."""
    return {
        "last_beat": hb["last_beat"].at[scatter_idx].set(t, mode="drop")
    }


def expired(
    last_beat: jnp.ndarray, now: jnp.ndarray, timeout: float
) -> jnp.ndarray:
    """Dark-client mask: no contact for more than ``timeout`` seconds at
    observation time ``now`` (elementwise; shapes broadcast)."""
    return (now - last_beat) > timeout
