"""Synthetic datasets.

Real MNIST/CIFAR are unavailable in the offline container; we generate
class-conditional image datasets with matched shapes and cardinalities
(class prototype + structured noise + per-sample affine jitter), hard
enough that the paper's CNN needs many FedAvg rounds to fit them — which
is what the convergence experiments measure. Token streams for the LLM
architectures come from a small synthetic Zipf language model.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ImageDataset:
    name: str
    images: np.ndarray  # (N, H, W, C) float32 in [0, 1]-ish, standardized
    labels: np.ndarray  # (N,) int32


def make_image_dataset(
    name: str,
    num_classes: int,
    image_size: int,
    channels: int,
    train_size: int,
    test_size: int,
    seed: int = 0,
    difficulty: float = 1.6,
) -> Tuple[ImageDataset, ImageDataset]:
    """Class-conditional generator: each class is a mixture of 3 smooth
    prototypes; samples add prototype mixing, spatial shift, and noise.
    ``difficulty`` scales the noise (higher = slower convergence)."""
    rng = np.random.default_rng(seed)
    protos_per_class = 3
    # smooth prototypes: low-frequency random fields
    freq = 4
    base = rng.normal(
        size=(num_classes, protos_per_class, freq, freq, channels)
    ).astype(np.float32)

    def upsample(field):  # (.., freq, freq, C) -> (.., H, W, C) bilinear-ish
        reps = image_size // freq
        out = np.repeat(np.repeat(field, reps, axis=-3), reps, axis=-2)
        return out

    protos = upsample(base)  # (classes, P, H, W, C)

    def gen(n, seed_):
        r = np.random.default_rng(seed_)
        labels = r.integers(0, num_classes, size=n).astype(np.int32)
        mix = r.dirichlet(np.ones(protos_per_class), size=n).astype(np.float32)
        imgs = np.einsum("np,nphwc->nhwc", mix, protos[labels])
        # random spatial roll
        sh = r.integers(-2, 3, size=(n, 2))
        for i in range(n):  # small n; fine on host
            imgs[i] = np.roll(imgs[i], sh[i], axis=(0, 1))
        imgs += difficulty * r.normal(size=imgs.shape).astype(np.float32)
        imgs = (imgs - imgs.mean()) / (imgs.std() + 1e-6)
        return ImageDataset(name, imgs.astype(np.float32), labels)

    return gen(train_size, seed + 1), gen(test_size, seed + 2)


DATASET_SPECS = {
    # name: (classes, size, channels, train, test)
    "mnist": (10, 28, 1, 12000, 2000),
    "cifar10": (10, 32, 3, 12000, 2000),
    "cifar100": (100, 32, 3, 20000, 4000),
}


def load_dataset(name: str, seed: int = 0, scale: float = 1.0):
    classes, size, ch, ntr, nte = DATASET_SPECS[name]
    return make_image_dataset(
        name, classes, size, ch, int(ntr * scale), int(nte * scale), seed=seed
    )


def make_token_stream(
    vocab_size: int, num_tokens: int, seed: int = 0, order: int = 2
) -> np.ndarray:
    """Zipf-distributed token stream with local bigram structure, so a
    language model has something learnable."""
    rng = np.random.default_rng(seed)
    v = min(vocab_size, 4096)
    zipf = 1.0 / np.arange(1, v + 1) ** 1.1
    zipf /= zipf.sum()
    # bigram transition: mixture of zipf and a random permutation successor
    succ = rng.permutation(v)
    toks = np.empty(num_tokens, dtype=np.int32)
    toks[0] = rng.choice(v, p=zipf)
    draws = rng.random(num_tokens)
    zipf_draws = rng.choice(v, size=num_tokens, p=zipf)
    for i in range(1, num_tokens):
        toks[i] = succ[toks[i - 1]] if draws[i] < 0.5 else zipf_draws[i]
    return toks % vocab_size
