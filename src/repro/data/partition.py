"""Client data partitioning: IID and Dirichlet non-IID [14].

The paper's non-IID experiment draws each client's label distribution from
Dirichlet(alpha=0.6) (Yurochkin et al. [14]). We partition a dataset into
n equal-size client shards (the paper assumes |D_i| all equal, Sec. II).
"""
from __future__ import annotations

from typing import List

import numpy as np


def partition_iid(num_examples: int, n_clients: int, seed: int = 0) -> np.ndarray:
    """Returns (n_clients, shard) index matrix, equal sizes."""
    rng = np.random.default_rng(seed)
    order = rng.permutation(num_examples)
    shard = num_examples // n_clients
    return order[: shard * n_clients].reshape(n_clients, shard)


def partition_dirichlet(
    labels: np.ndarray, n_clients: int, alpha: float = 0.6, seed: int = 0
) -> np.ndarray:
    """Dirichlet label-skew partition with equal client sizes.

    Each client gets a Dirichlet(alpha) label distribution; examples are
    assigned greedily by those quotas, then trimmed/padded to equal size
    (paper assumption |D_i| equal).
    """
    rng = np.random.default_rng(seed)
    n = len(labels)
    classes = np.unique(labels)
    shard = n // n_clients
    quotas = rng.dirichlet(alpha * np.ones(len(classes)), size=n_clients)
    by_class: List[np.ndarray] = [
        rng.permutation(np.flatnonzero(labels == c)) for c in classes
    ]
    ptr = np.zeros(len(classes), dtype=np.int64)
    out = np.empty((n_clients, shard), dtype=np.int64)
    for ci in range(n_clients):
        want = (quotas[ci] * shard).astype(np.int64)
        # fix rounding to hit exactly `shard`
        while want.sum() < shard:
            want[rng.integers(len(classes))] += 1
        while want.sum() > shard:
            nz = np.flatnonzero(want > 0)
            want[rng.choice(nz)] -= 1
        got = []
        for k, cls_idx in enumerate(by_class):
            take = min(want[k], len(cls_idx) - ptr[k])
            got.append(cls_idx[ptr[k] : ptr[k] + take])
            ptr[k] += take
        got = np.concatenate(got) if got else np.empty(0, np.int64)
        if len(got) < shard:  # class exhausted: fill from global leftovers
            leftovers = np.concatenate(
                [c[p:] for c, p in zip(by_class, ptr)] or [np.empty(0, np.int64)]
            )
            extra = rng.choice(leftovers, size=shard - len(got), replace=False)
            # advance pointers approximately: mark taken by removing later is
            # costly; instead draw from a shrinking pool
            taken = set(extra.tolist())
            for k in range(len(by_class)):
                rest = by_class[k][ptr[k] :]
                keep = np.array([i for i in rest if i not in taken], dtype=np.int64)
                by_class[k] = np.concatenate([by_class[k][: ptr[k]], keep])
            got = np.concatenate([got, extra])
        out[ci] = got[:shard]
    return out


def label_histograms(labels: np.ndarray, parts: np.ndarray, num_classes: int):
    """(n_clients, num_classes) label counts — for non-IID diagnostics."""
    return np.stack(
        [np.bincount(labels[p], minlength=num_classes) for p in parts]
    )
