from repro.data.partition import (  # noqa: F401
    label_histograms,
    partition_dirichlet,
    partition_iid,
)
from repro.data.synthetic import (  # noqa: F401
    DATASET_SPECS,
    ImageDataset,
    load_dataset,
    make_image_dataset,
    make_token_stream,
)
