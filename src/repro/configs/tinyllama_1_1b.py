"""tinyllama-1.1b [dense] — 22L d_model=2048 32H (GQA kv=4) d_ff=5632
vocab=32000. llama2-arch small. [arXiv:2401.02385]
"""
from repro.configs.base import (
    ArchConfig,
    AttentionSpec,
    LayerSpec,
    MLPSpec,
    register,
)

_LAYER = LayerSpec(
    kind="attn",
    attn=AttentionSpec(num_heads=32, num_kv_heads=4, head_dim=64),
    mlp=MLPSpec(kind="dense", d_ff=5632, activation="silu"),
)


@register
def tinyllama_1_1b() -> ArchConfig:
    return ArchConfig(
        name="tinyllama-1.1b",
        family="dense",
        citation="arXiv:2401.02385",
        d_model=2048,
        vocab_size=32_000,
        pattern=(_LAYER,),
        repeats=22,
        rope_theta=10_000.0,
        norm_eps=1e-5,
    )
