"""Config registry. ``load_all()`` imports every arch module (idempotent)."""
from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    ArchConfig,
    AttentionSpec,
    EncoderSpec,
    LayerSpec,
    MLPSpec,
    MoESpec,
    ShapeConfig,
    SSMSpec,
    all_archs,
    get_arch,
    shape_applicable,
)

_LOADED = False

ARCH_MODULES = (
    "gemma3_27b",
    "tinyllama_1_1b",
    "jamba_v0_1_52b",
    "llama3_8b",
    "whisper_tiny",
    "mamba2_370m",
    "deepseek_v2_236b",
    "pixtral_12b",
    "stablelm_1_6b",
    "llama4_maverick_400b",
)


def load_all() -> None:
    global _LOADED
    if _LOADED:
        return
    import importlib

    for mod in ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")
    _LOADED = True
