"""gemma3-27b [dense] — 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144, 5:1 local:global sliding-window interleave, 128k context.
[hf:google/gemma-3-1b-pt family, scaled to 27b]
"""
from repro.configs.base import (
    ArchConfig,
    AttentionSpec,
    LayerSpec,
    MLPSpec,
    register,
)

_LOCAL = LayerSpec(
    kind="attn",
    attn=AttentionSpec(
        num_heads=32,
        num_kv_heads=16,
        head_dim=128,
        kind="sliding",
        window=1024,
        qk_norm=True,
    ),
    mlp=MLPSpec(kind="dense", d_ff=21504, activation="silu"),
)
_GLOBAL = LayerSpec(
    kind="attn",
    attn=AttentionSpec(
        num_heads=32, num_kv_heads=16, head_dim=128, kind="full", qk_norm=True
    ),
    mlp=MLPSpec(kind="dense", d_ff=21504, activation="silu"),
)


@register
def gemma3_27b() -> ArchConfig:
    # 62 layers = (5 local + 1 global) * 10 + 2 local remainder
    return ArchConfig(
        name="gemma3-27b",
        family="dense",
        citation="hf:google/gemma-3-1b-pt (5:1 local:global, 128k)",
        d_model=5376,
        vocab_size=262_144,
        pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
        repeats=10,
        remainder=(_LOCAL, _LOCAL),
        rope_theta=1_000_000.0,
        rope_theta_local=10_000.0,
        tie_embeddings=True,
        embed_scale=True,
        # 51/62 layers have a 1024-token bounded cache; the 11 global layers
        # decode linearly in S => long_500k applicable.
        supports_long_context=True,
    )
