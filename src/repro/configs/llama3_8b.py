"""llama3-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256. [arXiv:2407.21783]
"""
from repro.configs.base import (
    ArchConfig,
    AttentionSpec,
    LayerSpec,
    MLPSpec,
    register,
)

_LAYER = LayerSpec(
    kind="attn",
    attn=AttentionSpec(num_heads=32, num_kv_heads=8, head_dim=128),
    mlp=MLPSpec(kind="dense", d_ff=14336, activation="silu"),
)


@register
def llama3_8b() -> ArchConfig:
    return ArchConfig(
        name="llama3-8b",
        family="dense",
        citation="arXiv:2407.21783",
        d_model=4096,
        vocab_size=128_256,
        pattern=(_LAYER,),
        repeats=32,
        rope_theta=500_000.0,
        norm_eps=1e-5,
    )
