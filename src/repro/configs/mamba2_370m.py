"""mamba2-370m [ssm] — 48L d_model=1024 attention-free, vocab=50280,
ssm_state=128, SSD (state-space duality). [arXiv:2405.21060]
"""
from repro.configs.base import ArchConfig, LayerSpec, MLPSpec, SSMSpec, register

_LAYER = LayerSpec(
    kind="mamba",
    ssm=SSMSpec(d_inner=2048, d_state=128, head_dim=64, conv_width=4, chunk=256),
    mlp=MLPSpec(kind="none"),
)


@register
def mamba2_370m() -> ArchConfig:
    return ArchConfig(
        name="mamba2-370m",
        family="ssm",
        citation="arXiv:2405.21060",
        d_model=1024,
        vocab_size=50_280,
        pattern=(_LAYER,),
        repeats=48,
        norm_eps=1e-5,
        tie_embeddings=True,
        supports_long_context=True,  # O(1) recurrent state
    )
