"""pixtral-12b [vlm] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072. Pixtral-ViT vision encoder + projector is a STUB —
``input_specs`` provides patch embeddings prepended to the token stream;
the language backbone is mistral-nemo-like. [hf:mistralai/Pixtral-12B-2409]
"""
from repro.configs.base import (
    ArchConfig,
    AttentionSpec,
    LayerSpec,
    MLPSpec,
    register,
)

_LAYER = LayerSpec(
    kind="attn",
    attn=AttentionSpec(num_heads=32, num_kv_heads=8, head_dim=128),
    mlp=MLPSpec(kind="dense", d_ff=14336, activation="silu"),
)


@register
def pixtral_12b() -> ArchConfig:
    return ArchConfig(
        name="pixtral-12b",
        family="vlm",
        citation="hf:mistralai/Pixtral-12B-2409",
        d_model=5120,
        vocab_size=131_072,
        pattern=(_LAYER,),
        repeats=40,
        rope_theta=1_000_000.0,
        norm_eps=1e-5,
        frontend="vision_stub",
        frontend_tokens=256,  # one 1024px image -> 256 merged patch embeddings
    )
