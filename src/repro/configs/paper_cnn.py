"""The paper's own simulation model: the CNN of McMahan et al. [1]
(two 5x5 conv layers 32/64 + 2x2 maxpool each + fc512), used for the
MNIST / CIFAR-10 / CIFAR-100 convergence experiments (Figs. 2-4).

This is not one of the assigned pool architectures; it is registered so the
FL repro drivers can select it with ``--arch paper-cnn-<dataset>``.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    image_size: int
    channels: int
    num_classes: int
    conv_channels: tuple = (32, 64)
    kernel: int = 5
    fc_width: int = 512


MNIST_CNN = CNNConfig("paper-cnn-mnist", image_size=28, channels=1, num_classes=10)
CIFAR10_CNN = CNNConfig("paper-cnn-cifar10", image_size=32, channels=3, num_classes=10)
CIFAR100_CNN = CNNConfig(
    "paper-cnn-cifar100", image_size=32, channels=3, num_classes=100
)

CNN_CONFIGS = {c.name: c for c in (MNIST_CNN, CIFAR10_CNN, CIFAR100_CNN)}
