"""stablelm-1.6b [dense] — 24L d_model=2048 32H (kv=32, i.e. MHA)
d_ff=5632 vocab=100352. LayerNorm + partial rotary (25%).
[hf:stabilityai/stablelm-2-1_6b]
"""
from repro.configs.base import (
    ArchConfig,
    AttentionSpec,
    LayerSpec,
    MLPSpec,
    register,
)

_LAYER = LayerSpec(
    kind="attn",
    attn=AttentionSpec(num_heads=32, num_kv_heads=32, head_dim=64, rope_frac=0.25),
    mlp=MLPSpec(kind="dense", d_ff=5632, activation="silu"),
)


@register
def stablelm_1_6b() -> ArchConfig:
    return ArchConfig(
        name="stablelm-1.6b",
        family="dense",
        citation="hf:stabilityai/stablelm-2-1_6b",
        d_model=2048,
        vocab_size=100_352,
        pattern=(_LAYER,),
        repeats=24,
        norm="layernorm",
        norm_eps=1e-5,
        rope_theta=10_000.0,
    )
