"""whisper-tiny [audio] — 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865.
Encoder-decoder; the mel-spectrogram + conv frontend is a STUB —
``input_specs`` provides precomputed frame embeddings. [arXiv:2212.04356]
"""
from repro.configs.base import (
    ArchConfig,
    AttentionSpec,
    EncoderSpec,
    LayerSpec,
    MLPSpec,
    register,
)

_DEC = LayerSpec(
    kind="attn",
    attn=AttentionSpec(num_heads=6, num_kv_heads=6, head_dim=64, rope=False),
    mlp=MLPSpec(kind="dense", d_ff=1536, activation="gelu"),
)


@register
def whisper_tiny() -> ArchConfig:
    return ArchConfig(
        name="whisper-tiny",
        family="audio",
        citation="arXiv:2212.04356",
        d_model=384,
        vocab_size=51_865,
        pattern=(_DEC,),
        repeats=4,
        norm="layernorm",
        norm_eps=1e-5,
        tie_embeddings=True,
        encoder=EncoderSpec(num_layers=4, num_heads=6, d_ff=1536, source_len=1500),
        frontend="audio_stub",
        # decoder context is architecturally bounded (448 in the paper);
        # long_500k decode is not meaningful for whisper.
        supports_long_context=False,
    )
