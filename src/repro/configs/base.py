"""Architecture / shape / mesh configuration system.

Every assigned architecture is expressed as an ``ArchConfig``: a *layer
pattern* (a short heterogeneous block) repeated ``repeats`` times via
``lax.scan`` plus an unrolled ``remainder``.  This keeps the HLO O(pattern)
in depth while supporting interleaves like gemma3's 5 local : 1 global or
jamba's 7 mamba : 1 attention.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Tuple

# ---------------------------------------------------------------------------
# Layer specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttentionSpec:
    """Self-attention configuration for one layer."""

    num_heads: int
    num_kv_heads: int
    head_dim: int
    kind: str = "full"  # full | sliding | chunked
    window: int = 0  # sliding-window length or chunk size (kind != full)
    # Multi-head latent attention (deepseek-v2).  When set, K/V are
    # compressed to rank ``kv_lora`` (+ ``rope_dim`` decoupled rope dims).
    kv_lora: int = 0
    q_lora: int = 0
    rope_dim: int = 0  # decoupled rope dims for MLA
    causal: bool = True
    rope: bool = True
    rope_frac: float = 1.0  # fraction of head_dim rotated (stablelm: 0.25)
    softmax_scale: Optional[float] = None
    qk_norm: bool = False  # gemma3-style RMSNorm on q/k

    @property
    def is_mla(self) -> bool:
        return self.kv_lora > 0

    @property
    def cache_kv_heads(self) -> int:
        return self.num_kv_heads

    def cache_len(self, seq_len: int) -> int:
        """KV-cache length actually required for decode at context seq_len."""
        if self.kind in ("sliding", "chunked") and self.window > 0:
            return min(self.window, seq_len)
        return seq_len


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLPSpec:
    kind: str = "dense"  # dense | moe | none
    d_ff: int = 0
    activation: str = "silu"  # silu (gated) | gelu (ungated)
    moe: Optional[MoESpec] = None


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    """Mamba2 (SSD) block spec."""

    d_inner: int
    d_state: int = 128
    head_dim: int = 64
    conv_width: int = 4
    chunk: int = 256
    expand: int = 2

    @property
    def num_heads(self) -> int:
        return self.d_inner // self.head_dim


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str  # "attn" | "mamba"
    attn: Optional[AttentionSpec] = None
    mlp: MLPSpec = MLPSpec(kind="none")
    ssm: Optional[SSMSpec] = None


@dataclasses.dataclass(frozen=True)
class EncoderSpec:
    """Bidirectional encoder stack (whisper)."""

    num_layers: int
    num_heads: int
    d_ff: int
    source_len: int = 1500  # frames after the (stubbed) conv frontend


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    citation: str
    d_model: int
    vocab_size: int
    pattern: Tuple[LayerSpec, ...]
    repeats: int
    prefix: Tuple[LayerSpec, ...] = ()  # unrolled layers BEFORE the scanned pattern
    remainder: Tuple[LayerSpec, ...] = ()  # unrolled layers AFTER the scanned pattern
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    rope_theta_local: float = 0.0  # gemma3: distinct base for local layers
    tie_embeddings: bool = False
    logits_softcap: float = 0.0
    embed_scale: bool = False  # gemma: scale embeddings by sqrt(d_model)
    encoder: Optional[EncoderSpec] = None  # whisper
    frontend: str = "none"  # none | audio_stub | vision_stub
    frontend_tokens: int = 0  # patches/frames prepended for stub frontends
    # long_500k applicability (sub-quadratic attention / bounded caches)
    supports_long_context: bool = False
    # dtype policy
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # ---- derived ----------------------------------------------------------
    @property
    def num_layers(self) -> int:
        return len(self.prefix) + len(self.pattern) * self.repeats + len(self.remainder)

    def all_layers(self) -> Tuple[LayerSpec, ...]:
        return self.prefix + self.pattern * self.repeats + self.remainder

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers + head)."""
        total = self.vocab_size * self.d_model  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * self.d_model
        for spec in self.all_layers():
            total += _layer_params(self.d_model, spec)
        total += self.d_model  # final norm
        if self.encoder is not None:
            e = self.encoder
            hd = self.d_model // e.num_heads
            enc_layer = (
                4 * self.d_model * e.num_heads * hd + 2 * self.d_model * e.d_ff
            )
            total += e.num_layers * enc_layer
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k + shared)."""
        total = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            total += self.vocab_size * self.d_model
        for spec in self.all_layers():
            total += _layer_params(self.d_model, spec, active_only=True)
        total += self.d_model
        return total

    def reduced(self) -> "ArchConfig":
        """A tiny same-family variant for CPU smoke tests.

        2 pattern layers (preserving heterogeneity), d_model <= 512,
        <= 4 experts, vocab <= 512.
        """
        d_model = min(self.d_model, 256)
        # keep one of each distinct layer kind from the pattern
        kinds_seen = []
        small_pattern = []
        for spec in self.pattern + self.prefix + self.remainder:
            sig = (spec.kind, spec.attn.kind if spec.attn else "", spec.mlp.kind)
            if sig not in kinds_seen and len(small_pattern) < 2:
                kinds_seen.append(sig)
                small_pattern.append(_reduce_layer(spec, d_model))
        while len(small_pattern) < 2:
            small_pattern.append(small_pattern[-1])
        encoder = None
        if self.encoder is not None:
            encoder = EncoderSpec(
                num_layers=2, num_heads=4, d_ff=2 * d_model, source_len=64
            )
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            d_model=d_model,
            vocab_size=min(self.vocab_size, 512),
            pattern=tuple(small_pattern),
            repeats=1,
            prefix=(),
            remainder=(),
            encoder=encoder,
            frontend_tokens=min(self.frontend_tokens, 16) if self.frontend_tokens else 0,
            param_dtype="float32",
            compute_dtype="float32",
        )


def _layer_params(d_model: int, spec: LayerSpec, active_only: bool = False) -> int:
    total = 2 * d_model  # two norms
    if spec.kind == "mamba":
        s = spec.ssm
        di, ds = s.d_inner, s.d_state
        nh = s.num_heads
        total += d_model * (2 * di + 2 * ds + nh)  # in_proj (z,x,B,C,dt)
        total += di * s.conv_width + di  # conv + skip D... (approx)
        total += di * d_model  # out_proj
    a = spec.attn
    if a is not None:
        if a.is_mla:
            total += d_model * (a.kv_lora + a.rope_dim)  # kv down
            total += a.kv_lora * a.num_heads * 2 * a.head_dim  # kv up
            if a.q_lora:
                total += d_model * a.q_lora
                total += a.q_lora * a.num_heads * (a.head_dim + a.rope_dim)
            else:
                total += d_model * a.num_heads * (a.head_dim + a.rope_dim)
            total += a.num_heads * a.head_dim * d_model  # o_proj
        else:
            total += d_model * a.num_heads * a.head_dim  # q
            total += 2 * d_model * a.num_kv_heads * a.head_dim  # k,v
            total += a.num_heads * a.head_dim * d_model  # o
    m = spec.mlp
    if m.kind == "dense":
        mult = 3 if m.activation == "silu" else 2
        total += mult * d_model * m.d_ff
    elif m.kind == "moe":
        mo = m.moe
        n_routed = mo.top_k if active_only else mo.num_experts
        total += n_routed * 3 * d_model * mo.d_ff_expert
        total += mo.num_shared * 3 * d_model * mo.d_ff_shared
        total += d_model * mo.num_experts  # router
    return total


def _reduce_layer(spec: LayerSpec, d_model: int) -> LayerSpec:
    attn = spec.attn
    if attn is not None:
        heads = 4
        kv = max(1, min(attn.num_kv_heads * heads // max(attn.num_heads, 1), heads))
        attn = dataclasses.replace(
            attn,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=d_model // heads,
            window=min(attn.window, 32) if attn.window else 0,
            kv_lora=32 if attn.is_mla else 0,
            q_lora=32 if attn.q_lora else 0,
            rope_dim=16 if attn.is_mla else 0,
        )
    mlp = spec.mlp
    if mlp.kind == "dense":
        mlp = dataclasses.replace(mlp, d_ff=2 * d_model)
    elif mlp.kind == "moe":
        mo = mlp.moe
        mlp = dataclasses.replace(
            mlp,
            moe=dataclasses.replace(
                mo,
                num_experts=4,
                top_k=min(mo.top_k, 2),
                d_ff_expert=d_model,
                num_shared=min(mo.num_shared, 1),
                d_ff_shared=d_model if mo.num_shared else 0,
            ),
        )
    ssm = spec.ssm
    if ssm is not None:
        ssm = SSMSpec(d_inner=2 * d_model, d_state=16, head_dim=32, chunk=16)
    return LayerSpec(kind=spec.kind, attn=attn, mlp=mlp, ssm=ssm)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applicable(arch: "ArchConfig", shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch, shape) is a required dry-run pair; reason if not."""
    if shape.name == "long_500k" and not arch.supports_long_context:
        return False, (
            "pure full-attention at every layer (or enc-dec with bounded "
            "decoder context) — 500k KV cache unsupported; noted in DESIGN.md"
        )
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(fn: Callable[[], ArchConfig]):
    cfg = fn()
    _REGISTRY[cfg.name] = cfg
    return fn


def get_arch(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        # late import of the config modules
        from repro import configs as _c  # noqa: F401

        _c.load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_archs() -> dict:
    from repro import configs as _c

    _c.load_all()
    return dict(_REGISTRY)
