"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff_expert=8192 vocab=202048, MoE 128e top-1 + 1 shared expert on every
other layer (interleave step 2), dense d_ff=16384 otherwise; 3 chunked-local
(8192) : 1 global attention; early-fusion multimodal (vision stub).
[hf:meta-llama/Llama-4-Scout-17B-16E family]
"""
from repro.configs.base import (
    ArchConfig,
    AttentionSpec,
    LayerSpec,
    MLPSpec,
    MoESpec,
    register,
)

_LOCAL = AttentionSpec(
    num_heads=40, num_kv_heads=8, head_dim=128, kind="chunked", window=8192
)
_GLOBAL = AttentionSpec(num_heads=40, num_kv_heads=8, head_dim=128, kind="full")
_DENSE = MLPSpec(kind="dense", d_ff=16384, activation="silu")
_MOE = MLPSpec(
    kind="moe",
    moe=MoESpec(
        num_experts=128,
        top_k=1,
        d_ff_expert=8192,
        num_shared=1,
        d_ff_shared=8192,
    ),
)


@register
def llama4_maverick_400b() -> ArchConfig:
    # 4-layer block: [local+dense, local+moe, local+dense, global+moe] x 12
    pattern = (
        LayerSpec(kind="attn", attn=_LOCAL, mlp=_DENSE),
        LayerSpec(kind="attn", attn=_LOCAL, mlp=_MOE),
        LayerSpec(kind="attn", attn=_LOCAL, mlp=_DENSE),
        LayerSpec(kind="attn", attn=_GLOBAL, mlp=_MOE),
    )
    return ArchConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        citation="hf:meta-llama/Llama-4-Scout-17B-16E (maverick sibling)",
        d_model=5120,
        vocab_size=202_048,
        pattern=pattern,
        repeats=12,
        rope_theta=500_000.0,
        norm_eps=1e-5,
        frontend="vision_stub",
        frontend_tokens=144,  # early-fusion image patches
        # 36/48 layers chunked-local (8192-bounded cache); 12 global layers
        # decode linearly in S => long_500k applicable.
        supports_long_context=True,
    )
