"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2. Mamba+attention 1:7 interleave (attention at
index 4 of each 8-layer block), MoE on every other layer. [arXiv:2403.19887]
"""
from repro.configs.base import (
    ArchConfig,
    AttentionSpec,
    LayerSpec,
    MLPSpec,
    MoESpec,
    SSMSpec,
    register,
)

_SSM = SSMSpec(d_inner=8192, d_state=128, head_dim=64, conv_width=4, chunk=256)
_DENSE = MLPSpec(kind="dense", d_ff=14336, activation="silu")
_MOE = MLPSpec(
    kind="moe",
    moe=MoESpec(num_experts=16, top_k=2, d_ff_expert=14336),
)
_ATTN = AttentionSpec(num_heads=32, num_kv_heads=8, head_dim=128, rope=False)


def _layer(idx: int) -> LayerSpec:
    mlp = _MOE if idx % 2 == 1 else _DENSE
    if idx == 4:
        return LayerSpec(kind="attn", attn=_ATTN, mlp=mlp)
    return LayerSpec(kind="mamba", ssm=_SSM, mlp=mlp)


@register
def jamba_v0_1_52b() -> ArchConfig:
    return ArchConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        citation="arXiv:2403.19887",
        d_model=4096,
        vocab_size=65_536,
        pattern=tuple(_layer(i) for i in range(8)),
        repeats=4,
        # attention in only 4/32 layers => 500k decode cache is 4 layers'
        # worth of KV; mamba state is O(1).
        supports_long_context=True,
    )
