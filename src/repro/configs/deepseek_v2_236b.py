"""deepseek-v2-236b [moe] — 60L d_model=5120 128H d_ff_expert=1536
vocab=102400. MLA kv_lora=512 (+64 decoupled rope dims), q_lora=1536,
2 shared + 160 routed experts top-6. First layer is dense (d_ff 12288).
[arXiv:2405.04434]
"""
from repro.configs.base import (
    ArchConfig,
    AttentionSpec,
    LayerSpec,
    MLPSpec,
    MoESpec,
    register,
)

_MLA = AttentionSpec(
    num_heads=128,
    num_kv_heads=128,  # MLA decompresses to per-head K/V
    head_dim=128,
    kv_lora=512,
    q_lora=1536,
    rope_dim=64,
)
_MOE_LAYER = LayerSpec(
    kind="attn",
    attn=_MLA,
    mlp=MLPSpec(
        kind="moe",
        moe=MoESpec(
            num_experts=160,
            top_k=6,
            d_ff_expert=1536,
            num_shared=2,
            d_ff_shared=1536,
        ),
    ),
)
_DENSE_LAYER = LayerSpec(
    kind="attn",
    attn=_MLA,
    mlp=MLPSpec(kind="dense", d_ff=12288, activation="silu"),
)


@register
def deepseek_v2_236b() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-236b",
        family="moe",
        citation="arXiv:2405.04434",
        d_model=5120,
        vocab_size=102_400,
        prefix=(_DENSE_LAYER,),
        pattern=(_MOE_LAYER,),
        repeats=59,
        rope_theta=10_000.0,
    )
