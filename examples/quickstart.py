"""Quickstart: the paper in 60 seconds.

1. Build the optimal age-dependent Markov policy (Theorem 2).
2. Verify its load-metric variance against theory and random selection.
3. Run a few federated rounds on a synthetic MNIST with both policies.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.paper_cnn import MNIST_CNN
from repro.core import (
    empirical_load_stats,
    load_metric as lm,
    make_policy,
    simulate,
)
from repro.data.synthetic import load_dataset
from repro.fl import FLConfig, make_cnn_task, run_training

N, K, M = 100, 15, 10  # the paper's simulation setting

# --- 1. the optimal policy --------------------------------------------------
probs = lm.optimal_probs(N, K, M)
print(f"optimal send-probabilities p*_0..p*_{M}: {probs.round(4).tolist()}")
print(f"theory: E[X]={N / K:.3f}, Var*[X]={lm.optimal_var(N, K, M):.4f}, "
      f"random Var[X]={lm.random_selection_var(N, K):.2f}")

# --- 2. Monte-Carlo check ---------------------------------------------------
key = jax.random.PRNGKey(0)
for name in ("random", "markov"):
    hist = simulate(make_policy(name, N, K, M), key, N, 3000)
    s = empirical_load_stats(hist)
    print(f"{name:8s}: E[X]={s['mean_X']:.3f} Var[X]={s['var_X']:.3f} "
          f"cohort {s['mean_cohort']:.1f}±{s['std_cohort']:.1f}")

# --- 3. federated training --------------------------------------------------
train, test = load_dataset("mnist", scale=0.1)
task = make_cnn_task(MNIST_CNN, train, test, N)
for policy in ("random", "markov"):
    fl = FLConfig(n_clients=N, k=K, m=M, policy=policy, rounds=8,
                  local_epochs=2, batch_size=12, eval_every=4)
    out = run_training(task, fl, progress=True)
    print(f"{policy}: final acc {out['history']['accuracy'][-1]:.3f}, "
          f"Var[X]={out['load_stats']['var_X']:.3f}")
