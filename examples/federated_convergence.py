"""End-to-end driver: the paper's Fig. 4 experiment — FedAvg on (synthetic)
MNIST, IID and non-IID Dirichlet(0.6), random vs Markov selection, with
rounds-to-target-accuracy reporting. Scaled for CPU by default; pass
--paper-scale for the full n=100/k=15/E=5/B=50/300-round protocol.

  PYTHONPATH=src python examples/federated_convergence.py [--paper-scale]
"""
import argparse
import os
import sys

# the shared experiment helpers live in benchmarks/, next to examples/
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.bench_convergence import run_one
from repro.core import load_metric as lm
from repro.fl.rounds import rounds_to_target

ap = argparse.ArgumentParser()
ap.add_argument("--paper-scale", action="store_true")
ap.add_argument("--rounds", type=int, default=16)
args = ap.parse_args()
rounds = 300 if args.paper_scale else args.rounds
scale = 1.0 if args.paper_scale else 0.08

print(f"n=100 k=15 m=10 rounds={rounds} (Var theory: random "
      f"{lm.random_selection_var(100, 15):.1f}, markov {lm.optimal_var(100, 15, 10):.3f})")
for noniid in (False, True):
    tag = "non-IID Dir(0.6)" if noniid else "IID"
    print(f"\n== MNIST {tag} ==")
    results = {}
    for policy in ("random", "markov"):
        out = run_one("mnist", noniid, policy, rounds, scale)
        h = out.history()
        results[policy] = h
        print(f"  {policy:7s}: acc " +
              " ".join(f"{a:.2f}" for a in h["accuracy"][-6:]) +
              f" | Var[X]={out.load_stats['var_X']:.2f}")
    for target in (0.5, 0.6, 0.7):
        rr = rounds_to_target(results["random"], target)
        rm = rounds_to_target(results["markov"], target)
        if rr or rm:
            print(f"  rounds to {target:.0%}: random={rr} markov={rm}")
