"""Fleet-scale decentralized scheduling: 1M clients, age-dependent Markov
decisions sharded with shard_map — each device decides for its client
shard independently (the paper's zero-coordination property), with only an
O(1) psum of the cohort count crossing the network. Compares against the
centralized oldest-age top-k (Remark 1) via the aoi_topk kernel.

Runs on however many devices exist (1 on CPU); the production dry-run
exercises the same code on the 16x16 mesh.

  PYTHONPATH=src python examples/fleet_scheduling.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import load_metric as lm
from repro.core.distributed import markov_step_sharded, scheduler_comm_bytes
from repro.kernels import ops
from repro.launch.mesh import make_host_mesh

N = 1_000_000
K = 150_000
M = 10

mesh = make_host_mesh()
probs = jnp.asarray(lm.optimal_probs(N, K, M), jnp.float32)
step = markov_step_sharded(mesh, "data", probs, M)

# start at the stationary age distribution (the paper analyses steady state;
# a cold all-zero start would make the fleet march in synchronized cohorts)
pi = lm.steady_state(np.asarray(probs))
ages = jnp.asarray(
    np.random.default_rng(0).choice(M + 1, size=N, p=pi), jnp.int32
)
counts = []
t0 = time.time()
for r in range(20):
    sel, ages, count = step(ages, jnp.asarray(r), jnp.asarray(0))
    counts.append(int(count))
dt = (time.time() - t0) / 20
print(f"decentralized markov: n={N:,} devices={len(jax.devices())} "
      f"{dt * 1e3:.1f} ms/round")
print(f"cohort sizes (target {K:,}): {counts[-5:]}")

ages_f = ages.astype(jnp.float32)
t0 = time.time()
vals, idx = ops.oldest_age_topk(ages_f, 128)
jax.block_until_ready(vals)
print(f"centralized oldest-age top-128 (pallas kernel, interpret mode): "
      f"{(time.time() - t0) * 1e3:.1f} ms")
mk, old = scheduler_comm_bytes(N, K, 256)
print(f"per-round scheduler comms on a 256-chip pod: markov {mk} B, "
      f"oldest-age {old:,} B")
