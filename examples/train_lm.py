"""Train a ~20M-param llama-family model for a few hundred steps on CPU —
the same train_step the dry-run lowers for the production mesh.

  PYTHONPATH=src python examples/train_lm.py --steps 150
"""
import sys

from repro.launch import train

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "tinyllama-1.1b", "--steps", "150",
                "--batch", "8", "--seq", "128", *sys.argv[1:]]
    train.main()
