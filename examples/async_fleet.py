"""Asynchronous fleet simulation: the paper's Markov policy as admission
control for a straggler-heavy edge fleet, driven through the unified
engine API.

Trains the same small CNN task twice — once with the synchronous FedAvg
engine (a round takes as long as its slowest selected client) and once
with the event-driven FedBuff-style async engine under the ``mobile``
latency profile (heavy-tailed compute, availability windows, dropouts) —
and reports accuracy against *simulated wall-clock seconds*, plus the
load metric X measured on both clocks. The two runs differ only in the
``mode`` field of one ``RunConfig``.

  PYTHONPATH=src python examples/async_fleet.py
  PYTHONPATH=src python examples/async_fleet.py --clients 12 --k 3 --steps 3
"""
import argparse
import dataclasses

import jax

from repro.configs.paper_cnn import MNIST_CNN
from repro.core import load_metric as lm
from repro.data.synthetic import make_image_dataset
from repro.engine import RunConfig, make_engine, run_engine
from repro.fl import make_cnn_task
from repro.sim import latency as lat_mod

ap = argparse.ArgumentParser()
ap.add_argument("--clients", type=int, default=40)
ap.add_argument("--k", type=int, default=8)
ap.add_argument("--m", type=int, default=8)
ap.add_argument("--steps", type=int, default=16)
ap.add_argument("--profile", default="mobile")
args = ap.parse_args()
N, K, M, STEPS, PROFILE = args.clients, args.k, args.m, args.steps, args.profile

small = dataclasses.replace(
    MNIST_CNN, name="paper-cnn-mnist-ex", image_size=16,
    conv_channels=(8, 16), fc_width=64,
)
train, test = make_image_dataset("mnist-ex", 10, 16, 1, 1200, 500, seed=0,
                                 difficulty=0.8)
task = make_cnn_task(small, train, test, n_clients=N)
cfg = RunConfig(n_clients=N, k=K, m=M, policy="markov", rounds=STEPS,
                local_epochs=2, batch_size=10, eval_every=max(STEPS // 4, 1))

print(f"== synchronous FedAvg ({STEPS} rounds) ==")
sync = run_engine(make_engine(task, cfg), progress=True)

# simulated duration of the sync run: each round waits for its slowest client
profile = lat_mod.get_profile(PROFILE)
sync_t = lat_mod.simulate_sync_duration(
    sync.selection, profile, jax.random.PRNGKey(42)
)

print(f"\n== asynchronous FedBuff ({STEPS} server steps, profile={PROFILE}) ==")
acfg = dataclasses.replace(
    cfg, mode="async", buffer_size=K, profile=PROFILE,
    aggregator_kwargs={"staleness_exp": 0.5},
)
asy = run_engine(make_engine(task, acfg), progress=True)

ws = asy.wall_stats
print("\n== verdict ==")
print(f"sync : acc={sync.records[-1].accuracy:.3f} "
      f"simulated {sync_t:8.1f}s (straggler-bound rounds)")
print(f"async: acc={asy.records[-1].accuracy:.3f} "
      f"simulated {ws['sim_time']:8.1f}s "
      f"(staleness mean {ws['mean_staleness']:.2f} max {ws['max_staleness']})")
print(f"load metric: E[X_wall]={ws['mean_X_wall']:.2f}s "
      f"Var[X_wall]={ws['var_X_wall']:.2f} | "
      f"E[X_epoch]={ws['mean_X_epoch']:.2f} Var[X_epoch]={ws['var_X_epoch']:.2f} "
      f"(theory sync Var* = {lm.optimal_var(N, K, M):.2f})")
