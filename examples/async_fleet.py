"""Asynchronous fleet simulation: the paper's Markov policy as admission
control for a straggler-heavy edge fleet.

Trains the same small CNN task twice — once with the synchronous FedAvg
round loop (a round takes as long as its slowest selected client) and
once with the event-driven FedBuff-style loop under the ``mobile``
latency profile (heavy-tailed compute, availability windows, dropouts) —
and reports accuracy against *simulated wall-clock seconds*, plus the
load metric X measured on both clocks.

  PYTHONPATH=src python examples/async_fleet.py
"""
import dataclasses

import jax

from repro.configs.paper_cnn import MNIST_CNN
from repro.core import load_metric as lm
from repro.data.synthetic import make_image_dataset
from repro.fl import FLConfig, make_cnn_task, run_training
from repro.sim import AsyncConfig, get_profile, run_async_training
from repro.sim import latency as lat_mod

N, K, M, STEPS = 40, 8, 8, 16
PROFILE = "mobile"

small = dataclasses.replace(
    MNIST_CNN, name="paper-cnn-mnist-ex", image_size=16,
    conv_channels=(8, 16), fc_width=64,
)
train, test = make_image_dataset("mnist-ex", 10, 16, 1, 1200, 500, seed=0,
                                 difficulty=0.8)
task = make_cnn_task(small, train, test, n_clients=N)
fl = FLConfig(n_clients=N, k=K, m=M, policy="markov", rounds=STEPS,
              local_epochs=2, batch_size=10, eval_every=4)

print(f"== synchronous FedAvg ({STEPS} rounds) ==")
sync = run_training(task, fl, progress=True)

# simulated duration of the sync run: each round waits for its slowest client
profile = get_profile(PROFILE)
sync_t = lat_mod.simulate_sync_duration(
    sync["selection"], profile, jax.random.PRNGKey(42)
)

print(f"\n== asynchronous FedBuff ({STEPS} server steps, profile={PROFILE}) ==")
acfg = AsyncConfig(buffer_size=K, profile=PROFILE, staleness_exp=0.5)
asy = run_async_training(task, fl, acfg, progress=True)

ws = asy["wall_stats"]
print("\n== verdict ==")
print(f"sync : acc={sync['history']['accuracy'][-1]:.3f} "
      f"simulated {sync_t:8.1f}s (straggler-bound rounds)")
print(f"async: acc={asy['history']['accuracy'][-1]:.3f} "
      f"simulated {ws['sim_time']:8.1f}s "
      f"(staleness mean {ws['mean_staleness']:.2f} max {ws['max_staleness']})")
print(f"load metric: E[X_wall]={ws['mean_X_wall']:.2f}s "
      f"Var[X_wall]={ws['var_X_wall']:.2f} | "
      f"E[X_epoch]={ws['mean_X_epoch']:.2f} Var[X_epoch]={ws['var_X_epoch']:.2f} "
      f"(theory sync Var* = {lm.optimal_var(N, K, M):.2f})")
