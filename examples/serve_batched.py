"""Serve a small model with batched requests: prefill + cached decode
(the serve_step the decode_32k / long_500k dry-runs lower).

  PYTHONPATH=src python examples/serve_batched.py --arch mamba2-370m
"""
import sys

from repro.launch import serve

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--batch", "4", "--prompt-len", "16",
                "--gen", "24", *sys.argv[1:]]
    serve.main()
