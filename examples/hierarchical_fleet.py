"""Hierarchical & decentralized aggregation topologies (``repro.topo``):
the same Markov-admission async fleet run three ways — flat star,
2-tier edge -> regional -> global hierarchy, and a gossip peer graph —
differing only in the ``topology`` field of one ``RunConfig``.

The tiered runs pay per-hop simulated latency (each tier crossing draws
from its link's ``LatencyProfile``), exclude clients the heartbeat
declares dark, and report the load metric X *per tier-0 aggregation
node* next to the fleet-wide figure — which is where cross-region
imbalance shows up even when the global Var[X] looks healthy. The star
run is bit-for-bit the plain async engine: topology is a no-op until
you actually add tiers.

  PYTHONPATH=src python examples/hierarchical_fleet.py
  PYTHONPATH=src python examples/hierarchical_fleet.py --clients 24 \
      --tiers 4,2 --steps 6
"""
import argparse
import dataclasses

from repro.configs.paper_cnn import MNIST_CNN
from repro.data.synthetic import make_image_dataset
from repro.engine import RunConfig, make_engine, run_engine
from repro.launch._fl_cli import print_tier_stats
from repro.topo import make_topology

ap = argparse.ArgumentParser()
ap.add_argument("--clients", type=int, default=48)
ap.add_argument("--k", type=int, default=8)
ap.add_argument("--m", type=int, default=8)
ap.add_argument("--steps", type=int, default=12)
ap.add_argument("--tiers", default="8,2",
                help="aggregation nodes per tier, bottom-up")
ap.add_argument("--heartbeat-timeout", type=float, default=200.0,
                help="simulated-seconds liveness timeout for churn")
args = ap.parse_args()
N, K, M, STEPS = args.clients, args.k, args.m, args.steps
TIERS = tuple(int(t) for t in args.tiers.split(","))

small = dataclasses.replace(
    MNIST_CNN, name="paper-cnn-mnist-hier", image_size=16,
    conv_channels=(8, 16), fc_width=64,
)
train, test = make_image_dataset("mnist-hier", 10, 16, 1, 1200, 500, seed=0,
                                 difficulty=0.8)
from repro.fl import make_cnn_task  # noqa: E402  (after data so --help is fast)

task = make_cnn_task(small, train, test, n_clients=N)
base = RunConfig(n_clients=N, k=K, m=M, policy="markov", rounds=STEPS,
                 local_epochs=2, batch_size=10, mode="async", buffer_size=K,
                 profile="lognormal", eval_every=max(STEPS // 4, 1))


def report(tag, res):
    ws = res.wall_stats
    line = (f"{tag:12s} acc={res.records[-1].accuracy:.3f} "
            f"simulated {ws['sim_time']:8.1f}s "
            f"Var[X_wall]={ws['var_X_wall']:.2f}")
    if "hb_expired" in ws:
        line += f" churned={ws['hb_expired']}"
    print(line)
    print_tier_stats(res.load_stats)


print(f"== star (flat server, the degenerate topology) ==")
star = run_engine(make_engine(task, dataclasses.replace(
    base, topology="star"
)), progress=True)

print(f"\n== hierarchical tiers={TIERS} "
      f"(heartbeat timeout {args.heartbeat_timeout}s) ==")
hier = run_engine(make_engine(task, dataclasses.replace(
    base, topology="hierarchical",
    topology_kwargs={"tiers": TIERS,
                     "heartbeat_timeout": args.heartbeat_timeout},
)), progress=True)

# a Topology object works too — here a prebuilt gossip ring whose nodes
# mix updates peer-to-peer instead of reducing up a tree
gossip = make_topology("gossip", nodes=TIERS[0], degree=2, rounds=8)
print(f"\n== gossip {gossip.describe()} ==")
goss = run_engine(make_engine(task, dataclasses.replace(
    base, topology=gossip
)), progress=True)

print("\n== verdict ==")
report("star", star)
report(f"hier{TIERS}", hier)
report("gossip", goss)
print("(the star row is bit-for-bit the plain async engine; tiered rows "
      "pay per-hop latency, hence the longer simulated clock)")
