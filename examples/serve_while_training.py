"""Serve while training: round-robin vs Markov-admission routing.

One fleet trains a reduced LLM arch with the async engine while a
replica pool serves inference traffic from the same ring of retained
global versions. The same request trace is routed twice — once with the
deterministic ``round_robin`` router (the Var[X] = 0 reference) and once
with the paper's Markov admission rule — and the two runs are compared
on the serving tier's load metric: Var[X] over replicas (assignment-gap
variance, one routing decision = one epoch), time-to-first-token, and
staleness-of-served-version.

  PYTHONPATH=src python examples/serve_while_training.py
  PYTHONPATH=src python examples/serve_while_training.py --replicas 4 --ticks 24
"""
import argparse

import jax
import numpy as np

from repro.configs import get_arch
from repro.engine import AsyncEngine, RunConfig
from repro.fl.task import make_lm_task
from repro.models import factory
from repro.serve import VersionStore, run_serve_loop
from repro.sim import arrivals as arr_mod, get_profile

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="tinyllama-1.1b")
ap.add_argument("--clients", type=int, default=16)
ap.add_argument("--k", type=int, default=4)
ap.add_argument("--steps", type=int, default=6)
ap.add_argument("--replicas", type=int, default=3)
ap.add_argument("--slots", type=int, default=2)
ap.add_argument("--ticks", type=int, default=16)
ap.add_argument("--rate", type=float, default=1.0)
ap.add_argument("--prompt-len", type=int, default=6)
ap.add_argument("--gen", type=int, default=6)
ap.add_argument("--seed", type=int, default=0)
args = ap.parse_args()

cfg_arch = get_arch(args.arch).reduced()
task = make_lm_task(cfg_arch, args.clients, seq_len=32, docs_per_client=4,
                    seed=args.seed)
model = factory.build(cfg_arch)
cfg = RunConfig(
    mode="async", n_clients=args.clients, k=args.k, m=8, policy="markov",
    rounds=args.steps, local_epochs=1, batch_size=4, lr0=0.05,
    seed=args.seed, eval_every=args.steps, collect_history=False,
)

print(f"== training {cfg_arch.name} federated ({args.steps} async steps) ==")
engine = AsyncEngine(task, cfg)
state = engine.init()
state, aux = engine.run_chunk(state, 0, args.steps, False)
store = VersionStore.from_engine(engine, state)
print(f"ring: versions {store.retained_versions()} retained "
      f"(H={store.max_versions}), head v{store.latest}, "
      f"train loss {float(np.asarray(aux['loss'])[-1]):.4f}")

proc = arr_mod.from_profile(
    get_profile("lognormal"), args.rate, args.prompt_len, args.gen
)
reqs = arr_mod.sample_requests(
    jax.random.PRNGKey(args.seed + 1), proc, args.ticks, cfg_arch.vocab_size
)
print(f"\n== serving {len(reqs)} requests on {args.replicas} replicas x "
      f"{args.slots} slots (staggered pins) ==")

reports = {}
for router in ("round_robin", "markov"):
    reports[router] = run_serve_loop(
        model, store, reqs, router=router, n_replicas=args.replicas,
        slots=args.slots, seed=args.seed,
    )

print(f"\n{'':14s} {'round_robin':>14s} {'markov':>14s}")
rows = [
    ("Var[X]", lambda r: f"{r.serve_stats['var_X']:.3f}"),
    ("E[X]", lambda r: f"{r.serve_stats['mean_X']:.3f}"),
    ("ttft ticks", lambda r: f"{r.ttft_ticks_mean:.2f}"),
    ("staleness", lambda r: f"{r.staleness_mean:.2f}"),
    ("tok/s", lambda r: f"{r.tok_s:.0f}"),
    ("rejected", lambda r: str(r.rejections)),
]
for label, fmt in rows:
    print(f"{label:14s} {fmt(reports['round_robin']):>14s} "
          f"{fmt(reports['markov']):>14s}")
for name, rep in reports.items():
    per = rep.serve_stats["replica_mean_X"]
    print(f"per-replica E[X] ({name}): "
          + ", ".join("-" if np.isnan(v) else f"{v:.2f}" for v in per))
print("\nround_robin is the Var[X] = 0 reference; the Markov rule gets "
      "close without any coordination — each replica admits itself from "
      "its own age chain, the paper's argument applied to serving.")
